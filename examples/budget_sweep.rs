//! Budget sweep: how the HBM envelope shapes the precision plan and the
//! serving outcome (modeled engine, qwen30b-sim at paper scale).
//!
//! Sweeps the device budget from "barely fits all-cold" to "everything
//! hot", printing the derived per-layer hot capacity, achieved hi-tier
//! traffic share, throughput, and migration volume.
//!
//! ```bash
//! cargo run --release --example budget_sweep
//! ```

use dynaexq::bench::Table;
use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::serving::engine::{Engine, EngineConfig};
use dynaexq::workload::WorkloadProfile;
use dynaexq::Coordinator;
use dynaexq::{BackendCtx, BackendRegistry};

fn main() -> anyhow::Result<()> {
    let preset = ModelPreset::qwen30b_sim();
    let dev = DeviceConfig::default();
    let w = WorkloadProfile::text();
    let mut table = Table::new(&[
        "budget GB", "n_hi/layer", "hot frac", "hi-tier traffic %",
        "tok/s (modeled)", "migrated GB",
    ]);
    for budget_gb in [28.0, 30.0, 33.0, 36.0, 42.0, 48.0] {
        let mut cfg = ServingConfig::default();
        cfg.hbm_budget_bytes = (budget_gb * 1e9) as usize;
        let plan = match Coordinator::plan_for(&preset, &cfg) {
            Ok(p) => p,
            Err(e) => {
                println!("{budget_gb} GB: infeasible ({e})");
                continue;
            }
        };
        let backend = BackendRegistry::with_builtins()
            .build("dynaexq", &BackendCtx::new(&preset, &cfg, &dev))
            .map_err(anyhow::Error::msg)?;
        let mut engine = Engine::new(
            &preset,
            &w,
            backend,
            &dev,
            EngineConfig { max_batch: 8, seed: 3, track_activation: false },
        );
        for _ in 0..4 {
            engine.serve_uniform(&w, 8, 128, 16);
        }
        table.row(&[
            format!("{budget_gb:.0}"),
            format!("{}", plan.n_hi_per_layer()),
            format!("{:.2}", plan.hot_fraction(&preset)),
            format!("{:.1}", engine.backend.hi_fraction() * 100.0),
            format!("{:.0}", engine.metrics.throughput()),
            format!(
                "{:.2}",
                engine.backend.migrated_bytes() as f64 / 1e9
            ),
        ]);
    }
    println!(
        "== budget sweep: qwen30b-sim under a shrinking HBM envelope ==\n{}",
        table.render()
    );
    println!(
        "(hot capacity and hi-tier traffic share grow with the envelope — \
         that is the quality lever; modeled throughput *drops* slightly \
         because fp16 experts move more bytes per call than int4 in the \
         bandwidth-bound regime, exactly why static-int4 has the lowest \
         latency in the paper's Fig. 6. The plan is budget-feasible by \
         construction at every point.)"
    );
    Ok(())
}
