//! Quality comparison on real numerics: FP16 vs static INT4 vs DynaExq on
//! the Phi-3.5-MoE analogue — a minimal Table-4-style run.
//!
//! ```bash
//! make artifacts && cargo run --release --example quality_compare
//! ```

use dynaexq::experiments::quality_exp::QualityFixture;
use dynaexq::quality::{greedy_agreement, logit_kl, logit_rel_err};
use dynaexq::workload::WorkloadProfile;

fn main() -> anyhow::Result<()> {
    let fixture = QualityFixture::new("phi-sim")?;
    let w = WorkloadProfile::text();
    let (n_prompts, prompt_len) = (4, 48);

    let (ref_logits, ref_ppl) =
        fixture.eval("fp16", &w, n_prompts, prompt_len, None)?;
    println!("fp16     : ppl {ref_ppl:.3} (reference)");

    for method in ["static", "dynaexq"] {
        let (hyp, ppl) =
            fixture.eval(method, &w, n_prompts, prompt_len, None)?;
        let n = n_prompts as f64;
        let kl: f64 = ref_logits
            .iter()
            .zip(&hyp)
            .map(|(r, h)| logit_kl(r, h))
            .sum::<f64>()
            / n;
        let rel: f64 = ref_logits
            .iter()
            .zip(&hyp)
            .map(|(r, h)| logit_rel_err(r, h))
            .sum::<f64>()
            / n;
        let agree: f64 = ref_logits
            .iter()
            .zip(&hyp)
            .map(|(r, h)| greedy_agreement(r, h))
            .sum::<f64>()
            / n;
        println!(
            "{method:<9}: ppl {ppl:.3}  KL {kl:.5}  relerr {rel:.4}  \
             greedy-agree {agree:.3}"
        );
    }
    println!(
        "\nexpected ordering (paper Table 4 shape): fp16 best; dynaexq \
         recovers most of static's loss by keeping hot experts at FP16."
    );
    Ok(())
}
