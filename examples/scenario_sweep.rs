//! Scenario sweep: every canned workload scenario served by the fixed-α
//! coordinator and the drift-aware adaptive one, side by side (modeled
//! engine, qwen30b-sim at paper scale — DESIGN.md §10).
//!
//! ## The scenario DSL
//!
//! A `Scenario` is a named script of phases; each phase pins a routing
//! distribution (`WorkloadProfile`, possibly a `.rotated(frac)` or
//! `.flash_crowd()` derivation), a round count, and a load multiplier:
//!
//! ```ignore
//! let sc = Scenario::named("my-shift")
//!     .phase("warm", WorkloadProfile::text(), 4)
//!     .phase_loaded("rush", WorkloadProfile::text().flash_crowd(), 2, 2.0)
//!     .phase("cool", WorkloadProfile::code(), 4);
//! session.run_scenario(&sc, 8, 128, 16)?;   // → per-phase snapshots
//! ```
//!
//! The canned library (`Scenario::by_name`) scripts the six regimes the
//! invariant suite pins down: `steady` (stationary Zipf), `swap` (hard
//! hot-set swap onto a disjoint head), `rotation` (gradual permutation
//! drift), `burst` (flash crowd on a few head experts), `multi-tenant`
//! (interleaved text/math/code), and `diurnal` (load ramp). Scenarios
//! compose with `.then(other)` and also drive `Engine::run_scenario`,
//! `Scenario::synthesize_trace` (DXTR recording), and
//! `dynaexq serve --scenario <name>`.
//!
//! ```bash
//! cargo run --release --example scenario_sweep
//! ```

use dynaexq::bench::Table;
use dynaexq::{Scenario, ServeSession};

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&[
        "scenario",
        "method",
        "drift events",
        "recovery ticks",
        "hi-tier traffic %",
        "migrated GB",
        "tok/s (modeled)",
    ]);
    for name in Scenario::names() {
        let sc = Scenario::by_name(name).expect("canned scenario");
        for method in ["dynaexq", "dynaexq-adaptive"] {
            let mut s = ServeSession::builder()
                .model("qwen30b-sim")
                .method(method)
                .workload("text")
                .seed(23)
                .warmup(1)
                .build()?;
            s.run_scenario(&sc, 8, 128, 16)?;
            let snap = s.snapshot();
            table.row(&[
                name.to_string(),
                method.to_string(),
                format!("{}", snap.drift_events),
                format!("{}", snap.drift_recovery_ticks),
                format!("{:.1}", snap.hi_fraction * 100.0),
                format!("{:.2}", snap.migrated_bytes as f64 / 1e9),
                format!("{:.0}", snap.throughput_tok_s),
            ]);
        }
    }
    println!(
        "== scenario sweep: fixed-α vs drift-aware hotness across every \
         canned scenario (qwen30b-sim) ==\n{}",
        table.render()
    );
    println!(
        "(the adaptive method should stay silent under `steady` — zero \
         change-points, identical residency — and fire under `swap`/`burst`, \
         where the dropped α and stale-score rescale pull the resident \
         top-n onto the new hot set within bounded update intervals.)"
    );
    Ok(())
}
