//! QoS sweep: class-weighted allocation ladders over the multi-tenant
//! scenario (modeled engine, front-doored session — DESIGN.md §15).
//!
//! ## The QoS surface
//!
//! A `QosConfig` prices three tenant classes — `premium`, `standard`,
//! `best-effort` — with a hotness weight (multiplier on routed-token
//! counts before the waterfill fold) and an optional per-tenant budget
//! on *outstanding* modeled hi-precision bytes at the front door:
//!
//! ```ignore
//! let q = QosConfig::tiered()                  // 4 / 1 / 0.25
//!     .with_budget(QosClass::Premium, 2_000_000_000)
//!     .on_exhausted(LimitAction::Downgrade);   // demote, don't reject
//! let mut s = ServeSession::builder()
//!     .frontdoor(FrontDoorConfig::default())
//!     .qos(q)
//!     .build()?;
//! ```
//!
//! The degenerate config (equal weights, no budgets) is structurally
//! absent — byte-identical to a session with no QoS at all — so the
//! first ladder below is the control row. The same policy drives
//! `dynaexq serve --qos tiered|class=weight[:budget_bytes][,...]` and
//! the bench matrix's QoS axis.
//!
//! ```bash
//! cargo run --release --example qos_sweep
//! ```

use dynaexq::bench::Table;
use dynaexq::config::frontdoor::{FrontDoorConfig, LimitAction};
use dynaexq::config::{QosClass, QosConfig};
use dynaexq::{Scenario, ServeSession};

fn main() -> anyhow::Result<()> {
    let ladders: Vec<(&str, QosConfig)> = vec![
        ("degenerate 1/1/1 (off)", QosConfig::degenerate()),
        ("tiered 4/1/0.25", QosConfig::tiered()),
        (
            "skewed 8/1/0.1",
            QosConfig::degenerate()
                .with_weight(QosClass::Premium, 8.0)
                .with_weight(QosClass::BestEffort, 0.1),
        ),
        (
            "tiered + tight premium budget (downgrade)",
            QosConfig::tiered()
                .with_budget(QosClass::Premium, 200_000)
                .on_exhausted(LimitAction::Downgrade),
        ),
    ];
    let sc = Scenario::by_name("multi-tenant").expect("canned scenario");
    let mut table = Table::new(&[
        "ladder",
        "class",
        "weight",
        "hi-resolve %",
        "resolves",
        "charged MB",
        "downgraded",
        "budget-rejected",
    ]);
    for (label, q) in &ladders {
        let mut s = ServeSession::builder()
            .model("qwen30b-sim")
            .method("dynaexq")
            .workload("text")
            .seed(0x905)
            .warmup(1)
            .frontdoor(FrontDoorConfig::default())
            .qos(q.clone())
            .build()?;
        s.run_scenario_frontdoor(&sc, 4, 32, 8)?;
        let snap = s.snapshot();
        if snap.qos_class_resolved.is_empty() {
            // degenerate: no class planes exist — report the one
            // undifferentiated row
            table.row(&[
                label.to_string(),
                "(all)".to_string(),
                "1".to_string(),
                format!("{:.1}", snap.hi_fraction * 100.0),
                "-".to_string(),
                "-".to_string(),
                "0".to_string(),
                "0".to_string(),
            ]);
            continue;
        }
        for class in QosClass::ALL {
            let row = &snap.qos_class_resolved[class.index()];
            let total: u64 = row.iter().sum();
            let hi = if total > 0 {
                row[0] as f64 / total as f64 * 100.0
            } else {
                0.0
            };
            table.row(&[
                label.to_string(),
                class.name().to_string(),
                format!("{}", q.class(class).weight),
                format!("{hi:.1}"),
                format!("{total}"),
                format!(
                    "{:.2}",
                    snap.qos_charged[class.index()] as f64 / 1e6
                ),
                format!("{}", snap.qos_downgraded),
                format!("{}", snap.qos_budget_rejected),
            ]);
        }
    }
    println!(
        "== qos sweep: weight ladders over the multi-tenant scenario \
         (qwen30b-sim, front-doored) ==\n{}",
        table.render()
    );
    println!(
        "(premium's hi-resolve share should climb with its weight — the \
         waterfill ranks experts by class-weighted hotness, so at equal \
         routed volume premium traffic lands on the hi rung first. The \
         degenerate ladder is the control: structurally identical to no \
         QoS. The tight-budget ladder shows the downgrade action: once a \
         premium tenant's outstanding occupancy exceeds its budget, it \
         is demoted to best-effort instead of rejected.)"
    );
    Ok(())
}
