//! Quickstart: load the small MoE model, serve one batch of requests with
//! DynaExq, and print quality + residency + serving metrics.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::model::ModelWeights;
use dynaexq::quality::perplexity;
use dynaexq::runtime::Runtime;
use dynaexq::serving::numeric::NumericEngine;
use dynaexq::util::XorShiftRng;
use dynaexq::workload::WorkloadProfile;
use dynaexq::{BackendCtx, BackendRegistry};

fn main() -> anyhow::Result<()> {
    // 1. The model: Phi-3.5-MoE analogue (16 experts/layer, top-2),
    //    deterministic synthetic weights, prepared at fp16/int4/int2.
    let preset = ModelPreset::phi_sim().executed_scale();
    let weights = Arc::new(ModelWeights::generate(&preset, 7));
    println!(
        "model {} — {} layers × {} experts (top-{}), host store {:.1} MB",
        preset.name,
        preset.n_layers,
        preset.n_experts,
        preset.top_k,
        weights.host_bytes() as f64 / 1e6
    );

    // 2. The runtime: AOT artifacts (HLO text) on the PJRT CPU client.
    let rt = Arc::new(Runtime::load_default()?);

    // 3. DynaExq: hot experts at FP16, cold at INT4, 4 hot slots per layer.
    let mut cfg = ServingConfig::default();
    cfg.n_hi_override = Some(4);
    cfg.update_interval_ms = 5.0;
    let backend = BackendRegistry::with_builtins()
        .build(
            "dynaexq",
            &BackendCtx::new(&preset, &cfg, &DeviceConfig::default()),
        )
        .map_err(anyhow::Error::msg)?;
    let mut engine = NumericEngine::new(rt, weights, backend)?;

    // 4. Serve: a few text-workload requests, real execution end to end.
    let workload = WorkloadProfile::text();
    let mut rng = XorShiftRng::new(1);
    for req in 0..4u64 {
        let prompt = workload.sample_prompt(&mut rng, 48);
        let out = engine.generate(&prompt, 12, req)?;
        println!(
            "req {req}: prompt 48 tok → ppl {:.2}, generated {:?}...",
            perplexity(&out.prompt_logits, &prompt),
            &out.tokens[..4.min(out.tokens.len())]
        );
    }

    // 5. What the coordinator did while we served:
    println!(
        "hi-tier traffic share {:.1}%, migrated {:.1} MB (modeled, \
         paper-scale bytes), modeled time {:.2}s",
        engine.backend.hi_fraction() * 100.0,
        engine.backend.migrated_bytes() as f64 / 1e6,
        engine.now(),
    );
    println!("quickstart OK");
    Ok(())
}
