//! End-to-end serving driver (the validation run recorded in
//! EXPERIMENTS.md): load the small MoE model, serve batched requests
//! through the full stack — router → VER handle resolution → per-precision
//! expert executables → KV-cached decode — across a text → math → code
//! workload shift, and report quality, residency adaptation, and
//! latency/throughput (modeled A6000-scale timing alongside wall-clock).
//!
//! The shift script is expressed as a `workload::Scenario` (DESIGN.md
//! §10): one phase per workload, each held for `ROUNDS_PER_WORKLOAD`
//! rounds — the same hard-swap phases the scenario-matrix suite pins
//! down, driven here through the *numeric* engine. Output is
//! byte-identical to the pre-scenario version of this example (same
//! profiles, same order, same per-phase RNG seeding), which is the
//! regression check for the migration.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_workload_shift
//! ```

use std::sync::Arc;
use std::time::Instant;

use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::model::ModelWeights;
use dynaexq::quality::perplexity;
use dynaexq::runtime::Runtime;
use dynaexq::serving::numeric::{NumericEngine, SeqState};
use dynaexq::util::XorShiftRng;
use dynaexq::workload::WorkloadProfile;
use dynaexq::{BackendCtx, BackendRegistry, Scenario};

const PROMPT_LEN: usize = 48;
const OUTPUT_LEN: usize = 16;
const BATCH: usize = 4;
const ROUNDS_PER_WORKLOAD: usize = 3;

fn main() -> anyhow::Result<()> {
    let preset = ModelPreset::qwen30b_sim().executed_scale();
    let weights = Arc::new(ModelWeights::generate(&preset, 12));
    let rt = Arc::new(Runtime::load_default()?);

    let mut cfg = ServingConfig::default();
    cfg.n_hi_override = Some(
        dynaexq::Coordinator::plan_for(
            &ModelPreset::qwen30b_sim(),
            &ServingConfig::default(),
        )
        .map_err(anyhow::Error::msg)?
        .n_hi_per_layer(),
    );
    cfg.update_interval_ms = 10.0;
    println!(
        "== DynaExq end-to-end: {} | {} hot slots/layer of {} (paper-scale \
         48 GB plan) ==",
        preset.name,
        cfg.n_hi_override.unwrap(),
        preset.n_experts
    );
    let backend = BackendRegistry::with_builtins()
        .build(
            "dynaexq",
            &BackendCtx::new(&preset, &cfg, &DeviceConfig::default()),
        )
        .map_err(anyhow::Error::msg)?;
    let mut engine = NumericEngine::new(rt, weights, backend)?;

    // The text → math → code shift as a scripted scenario: each hard swap
    // is a phase boundary (the two-phase `Scenario::swap` generalized to
    // the full three-workload tour).
    let mut scenario = Scenario::named("workload-shift");
    for w in WorkloadProfile::all() {
        scenario = scenario.phase(w.name, w, ROUNDS_PER_WORKLOAD);
    }

    let mut tag = 0u64;
    let wall0 = Instant::now();
    let mut total_tokens = 0usize;
    for phase in &scenario.phases {
        let workload = &phase.profile;
        println!("-- workload {} --", workload.name);
        let mut rng = XorShiftRng::new(workload.seed);
        for round in 0..phase.rounds {
            let model_t0 = engine.now();
            let wall_t0 = Instant::now();
            // batched prefill
            let mut seqs: Vec<SeqState> = Vec::new();
            let mut ppl_sum = 0.0;
            for _ in 0..BATCH {
                let prompt = workload.sample_prompt(&mut rng, PROMPT_LEN);
                let (kv, logits) = engine.prefill(&prompt, tag)?;
                ppl_sum += perplexity(&logits, &prompt);
                seqs.push(SeqState {
                    kv,
                    last_token: *prompt.last().unwrap(),
                    tag,
                    generated: Vec::new(),
                });
                tag += 1;
            }
            let ttft_model = engine.now() - model_t0;
            // lockstep batched decode
            for _ in 0..OUTPUT_LEN {
                engine.decode_step(&mut seqs)?;
            }
            total_tokens += BATCH * (PROMPT_LEN + OUTPUT_LEN);
            let dt_model = engine.now() - model_t0;
            println!(
                "round {round}: ppl {:.2} | modeled ttft {:.3}s e2e {:.3}s \
                 ({:.0} tok/s modeled) | wall {:.2}s | hi-tier {:.1}% | \
                 migrated {:.2} GB",
                ppl_sum / BATCH as f64,
                ttft_model,
                dt_model,
                (BATCH * (PROMPT_LEN + OUTPUT_LEN)) as f64 / dt_model,
                wall_t0.elapsed().as_secs_f64(),
                engine.backend.hi_fraction() * 100.0,
                engine.backend.migrated_bytes() as f64 / 1e9,
            );
        }
    }
    println!(
        "== done: {} tokens, modeled {:.2}s ({:.0} tok/s), wall {:.1}s ==",
        total_tokens,
        engine.now(),
        total_tokens as f64 / engine.now(),
        wall0.elapsed().as_secs_f64()
    );
    Ok(())
}
