use dynaexq::experiments::helpers::engine;
use dynaexq::workload::WorkloadProfile;
use std::time::Instant;
fn main() {
    let w = WorkloadProfile::text();
    let mut e = engine("qwen30b-sim", "static", "text", 1, false).unwrap();
    let t0 = Instant::now();
    e.serve_uniform(&w, 8, 2048, 16);
    println!("serve 8x2048 prompt: {:.2}s wall", t0.elapsed().as_secs_f64());
    let t0 = Instant::now();
    e.serve_uniform(&w, 32, 512, 64);
    println!("serve 32x512+64: {:.2}s wall", t0.elapsed().as_secs_f64());
}
