//! Wall-clock probe of the modeled engine on the session API (how long a
//! big closed batch takes to *simulate*, not the modeled latency).
use dynaexq::ServeSession;
use std::time::Instant;
fn main() {
    let mut s = ServeSession::builder()
        .model("qwen30b-sim")
        .method("static")
        .workload("text")
        .seed(1)
        .track_activation(false)
        .build()
        .unwrap();
    let t0 = Instant::now();
    s.serve_closed(8, 2048, 16).unwrap();
    println!("serve 8x2048 prompt: {:.2}s wall", t0.elapsed().as_secs_f64());
    let t0 = Instant::now();
    s.serve_closed(32, 512, 64).unwrap();
    println!("serve 32x512+64: {:.2}s wall", t0.elapsed().as_secs_f64());
}
