//! Calibration probe: prints the routing-sampler statistics the paper's
//! Tables 1–2 and Figure 2 depend on, for the current profile parameters.
use dynaexq::util::XorShiftRng;
use dynaexq::workload::{RoutingSampler, WorkloadProfile};
use std::collections::HashSet;

fn main() {
    for (zg, zl, mix) in [(1.8, 1.2, 0.85), (1.6, 1.2, 0.85), (2.0, 1.0, 0.85)] {
        let mut p = WorkloadProfile::text();
        p.zipf_global = zg; p.zipf_local = zl; p.local_mix = mix;
        for (e, k, label) in [(128usize, 8usize, "q30"), (512, 10, "q80"), (16, 2, "phi")] {
            let s = RoutingSampler::new(&p, 4, e, k);
            let mut rng = XorShiftRng::new(9);
            let mut counts = vec![0u64; e];
            for tag in 0..300u64 { for _ in 0..16 { for x in s.sample_topk(&mut rng, tag, 0) { counts[x]+=1; } } }
            let total: u64 = counts.iter().sum();
            let mut sorted = counts.clone(); sorted.sort_unstable_by(|a,b| b.cmp(a));
            let tophead: u64 = sorted[..(e/8).max(1)].iter().sum();
            let union_decode = |b: u64| -> f64 {
                let mut rng = XorShiftRng::new(77);
                let mut acc = 0.0;
                for _ in 0..30 {
                    let mut u = HashSet::new();
                    for tag in 0..b { u.extend(s.sample_topk(&mut rng, tag, 0)); }
                    acc += u.len() as f64;
                }
                acc / 30.0 / e as f64
            };
            let prefill = |b: u64, t: usize| -> f64 {
                let mut rng = XorShiftRng::new(5);
                let mut u = HashSet::new();
                for tag in 0..b { for _ in 0..t { u.extend(s.sample_topk(&mut rng, 900+tag, 0)); } }
                u.len() as f64 / e as f64
            };
            println!("zg={zg} zl={zl} mix={mix} {label}: skew(top12.5%)={:.2} d1={:.3} d8={:.3} d32={:.3} pre1={:.3} pre8={:.3} pre32={:.3}",
                tophead as f64/total as f64, union_decode(1), union_decode(8), union_decode(32),
                prefill(1,512), prefill(8,512), prefill(32,512));
        }
    }
}
