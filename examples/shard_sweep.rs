//! Shard sweep: the same model and group-wide HBM envelope served by
//! 1-, 2-, and 4-device expert-sharded groups (modeled engine,
//! qwen30b-sim at paper scale — DESIGN.md §9).
//!
//! Sharding splits each layer's expert compute across per-device lanes
//! (throughput rises) while also splitting the envelope: every device
//! waterfills its own slack over its own expert shard, and promotions ride
//! per-device migration streams that contend on the host aggregate past
//! two devices. The 1-device row is the exact single-GPU system.
//!
//! ```bash
//! cargo run --release --example shard_sweep
//! ```

use dynaexq::bench::Table;
use dynaexq::{MetricsSnapshot, ServeSession};

fn main() -> anyhow::Result<()> {
    let mut table = Table::new(&[
        "devices",
        "resident/rung/device",
        "promo-queue",
        "hi-tier traffic %",
        "tok/s (modeled)",
        "migrated GB",
    ]);
    for devices in [1usize, 2, 4] {
        let mut s = ServeSession::builder()
            .model("qwen30b-sim")
            .method("dynaexq-sharded")
            .workload("text")
            .devices(devices)
            .seed(11)
            .warmup(1)
            .build()?;
        for _ in 0..4 {
            s.serve_closed(8, 128, 16)?;
        }
        let snap = s.snapshot();
        table.row(&[
            format!("{devices}"),
            MetricsSnapshot::encode_per_device(&snap.device_resident),
            snap.promo_queue_depth
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join("/"),
            format!("{:.1}", snap.hi_fraction * 100.0),
            format!("{:.0}", snap.throughput_tok_s),
            format!("{:.2}", snap.migrated_bytes as f64 / 1e9),
        ]);
    }
    println!(
        "== shard sweep: qwen30b-sim across 1/2/4-device expert-sharded \
         groups ==\n{}",
        table.render()
    );
    println!(
        "(per-device lanes shorten each layer's expert compute, so modeled \
         throughput rises with the group — while the per-device envelopes \
         shrink, so each shard's waterfill funds fewer hot slots and the \
         promotion queues stay per-device. A 1-device group is \
         byte-identical to `--method dynaexq`.)"
    );
    Ok(())
}
