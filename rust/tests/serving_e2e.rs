//! Integration: modeled serving end to end — all three methods, all three
//! models — asserting the paper's headline orderings hold through the full
//! engine (not just in unit-scale fixtures).

use dynaexq::experiments::helpers::{engine, warm};
use dynaexq::experiments::latency::{run_config, run_config_seeded};
use dynaexq::util::XorShiftRng;
use dynaexq::workload::WorkloadProfile;

#[test]
fn all_models_all_methods_serve() {
    for model in ["qwen30b-sim", "qwen80b-sim", "phi-sim"] {
        for method in ["static", "dynaexq", "expertflow", "dynaexq-sharded"] {
            let mut e = engine(model, method, "text", 1, false).unwrap();
            e.serve_uniform(&WorkloadProfile::text(), 2, 32, 4);
            assert_eq!(e.metrics.e2e.count(), 2, "{model}/{method}");
            assert!(e.metrics.throughput() > 0.0, "{model}/{method}");
        }
    }
}

#[test]
fn headline_throughput_ratio_in_band() {
    // Paper: DynaExq achieves 1.42×–2.73× over ExpertFlow at batch 32.
    // The workload RNG seed is pinned through `util::rng` (not the
    // sampler's default state), and the engine syncs staging at iteration
    // boundaries, so the whole run derives from this one seed — the band
    // can be tight on both sides instead of a loose one-sided floor.
    let seed = XorShiftRng::new(0xE2E_5EED).next_u64();
    let dy = run_config_seeded("qwen30b-sim", "dynaexq", 32, 256, 32, true, seed)
        .unwrap()
        .throughput();
    let ef =
        run_config_seeded("qwen30b-sim", "expertflow", 32, 256, 32, true, seed)
            .unwrap()
            .throughput();
    let ratio = dy / ef;
    assert!(
        (1.25..25.0).contains(&ratio),
        "DynaExq/ExpertFlow at batch 32 out of band (got {ratio:.2}x)"
    );
    // the determinism the tightened band rests on: an identical seeded run
    // reproduces the exact same floats
    let dy2 =
        run_config_seeded("qwen30b-sim", "dynaexq", 32, 256, 32, true, seed)
            .unwrap()
            .throughput();
    assert_eq!(dy, dy2, "seeded runs must be byte-stable");
}

#[test]
fn static_baseline_is_fastest_dynaexq_close() {
    let st = run_config("phi-sim", "static", 8, 128, 16, true).unwrap();
    let dy = run_config("phi-sim", "dynaexq", 8, 128, 16, true).unwrap();
    let ef = run_config("phi-sim", "expertflow", 8, 128, 16, true).unwrap();
    assert!(st.e2e.avg() <= dy.e2e.avg() * 1.1);
    assert!(dy.e2e.avg() < ef.e2e.avg());
    // DynaExq should sit much closer to static than to ExpertFlow
    let gap_static = dy.e2e.avg() / st.e2e.avg();
    let gap_ef = ef.e2e.avg() / dy.e2e.avg();
    assert!(
        gap_ef > gap_static,
        "dynaexq/static {gap_static:.2} vs expertflow/dynaexq {gap_ef:.2}"
    );
}

#[test]
fn warmup_reduces_dynaexq_latency() {
    // Cold start pays for promotions in hi-tier misses (quality) but never
    // in stalls; latency should not degrade after convergence.
    let mut e = engine("qwen30b-sim", "dynaexq", "text", 5, false).unwrap();
    let w = WorkloadProfile::text();
    e.serve_uniform(&w, 8, 128, 16);
    let cold = e.metrics.e2e.avg();
    warm(&mut e, &w, 2);
    e.serve_uniform(&w, 8, 128, 16);
    let hot = e.metrics.e2e.avg();
    // hot experts run at fp16 (slower per-op than int4) so latency may rise
    // slightly, but must stay within the static/expertflow envelope
    assert!(hot < cold * 1.5, "warm {hot} vs cold {cold}");
    assert_eq!(e.metrics.wait.max(), 0.0, "never stalls, warm or cold");
}

#[test]
fn p99_tail_ordering() {
    let dy = run_config("qwen30b-sim", "dynaexq", 16, 256, 16, true).unwrap();
    let ef =
        run_config("qwen30b-sim", "expertflow", 16, 256, 16, true).unwrap();
    assert!(
        dy.ttft.p99() < ef.ttft.p99(),
        "DynaExq P99 TTFT {} must beat ExpertFlow {}",
        dy.ttft.p99(),
        ef.ttft.p99()
    );
}
