//! Integration: the numeric engine — full MoE forward through real PJRT
//! execution, across precision tiers, with KV-cache-consistent decode.
//!
//! Requires the `numeric` build feature (PJRT runtime).
#![cfg(feature = "numeric")]

use std::sync::Arc;

use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig, VOCAB};
use dynaexq::model::{ModelWeights, Precision};
use dynaexq::quality::{logit_rel_err, perplexity};
use dynaexq::runtime::Runtime;
use dynaexq::serving::backend::{DynaExqBackend, StaticBackend};
use dynaexq::serving::numeric::{NumericEngine, SeqState};
use dynaexq::workload::WorkloadProfile;

fn small_preset() -> ModelPreset {
    let mut p = ModelPreset::phi_sim().executed_scale();
    p.n_layers = 2;
    p
}

/// The PJRT runtime, or `None` when this environment cannot execute
/// numerics (missing AOT artifacts, or the stubbed `xla` bindings) —
/// tests then skip with a note so `cargo test --features numeric` stays
/// meaningful on every CI-matrix builder. Any other load error is a real
/// regression and still fails hard.
fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::load_default() {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            let msg = format!("{e:#}");
            if msg.contains("xla stub")
                || msg.contains("artifacts")
                || msg.contains("manifest")
            {
                eprintln!("skipping: PJRT runtime unavailable ({msg})");
                return None;
            }
            panic!("runtime load failed: {msg}");
        }
    }
}

fn engine_with(
    preset: &ModelPreset,
    precision: Precision,
) -> Option<NumericEngine> {
    let rt = runtime()?;
    let weights = Arc::new(ModelWeights::generate(preset, 42));
    Some(
        NumericEngine::new(rt, weights, Box::new(StaticBackend::new(precision)))
            .unwrap(),
    )
}

#[test]
fn prefill_produces_logits_and_kv() {
    let preset = small_preset();
    let Some(mut e) = engine_with(&preset, Precision::Fp16) else { return };
    let prompt: Vec<i32> = (0..12).map(|i| (i * 7) % 256).collect();
    let (kv, logits) = e.prefill(&prompt, 0).unwrap();
    assert_eq!(kv.len(), 12);
    assert_eq!(kv.n_layers(), 2);
    assert_eq!(logits.len(), 12 * VOCAB);
    assert!(logits.iter().all(|x| x.is_finite()));
    let ppl = perplexity(&logits, &prompt);
    assert!(ppl.is_finite() && ppl > 1.0);
}

#[test]
fn decode_steps_extend_generation() {
    let preset = small_preset();
    let Some(mut e) = engine_with(&preset, Precision::Fp16) else { return };
    let prompt: Vec<i32> = (0..8).collect();
    let (kv, _) = e.prefill(&prompt, 0).unwrap();
    let mut seqs = vec![SeqState {
        kv,
        last_token: 7,
        tag: 0,
        generated: Vec::new(),
    }];
    for _ in 0..5 {
        let next = e.decode_step(&mut seqs).unwrap();
        assert_eq!(next.len(), 1);
        assert!((0..VOCAB as i32).contains(&next[0]));
    }
    assert_eq!(seqs[0].generated.len(), 5);
    assert_eq!(seqs[0].kv.len(), 8 + 5);
}

#[test]
fn batched_decode_matches_single_sequence() {
    // Greedy decode of the same prompt must be identical whether the
    // sequence runs alone or inside a batch (padding/batching correctness).
    let preset = small_preset();
    let Some(mut e1) = engine_with(&preset, Precision::Fp16) else { return };
    let prompt: Vec<i32> = (0..16).map(|i| (i * 13) % 256).collect();
    let out_single = e1.generate(&prompt, 6, 0).unwrap();

    let mut e2 = engine_with(&preset, Precision::Fp16).unwrap();
    let (kv_a, _) = e2.prefill(&prompt, 0).unwrap();
    let other: Vec<i32> = (0..16).map(|i| (i * 29 + 5) % 256).collect();
    let (kv_b, _) = e2.prefill(&other, 1).unwrap();
    let mut seqs = vec![
        SeqState { kv: kv_a, last_token: *prompt.last().unwrap(), tag: 0, generated: Vec::new() },
        SeqState { kv: kv_b, last_token: *other.last().unwrap(), tag: 1, generated: Vec::new() },
    ];
    for _ in 0..6 {
        e2.decode_step(&mut seqs).unwrap();
    }
    assert_eq!(
        seqs[0].generated, out_single.tokens,
        "batching must not change greedy decoding"
    );
}

#[test]
fn quantized_tiers_degrade_gracefully() {
    // relerr(int2) > relerr(int4) > 0 against the fp16 logits, and all
    // remain finite — the foundation of the Table 4 / Fig. 3 experiments.
    let preset = small_preset();
    let prompt: Vec<i32> = WorkloadProfile::text()
        .sample_prompt(&mut dynaexq::util::XorShiftRng::new(3), 24);
    if runtime().is_none() {
        return;
    }
    let run = |prec: Precision| {
        let mut e = engine_with(&preset, prec).unwrap();
        let (_, logits) = e.prefill(&prompt, 0).unwrap();
        logits
    };
    let fp = run(Precision::Fp16);
    let i4 = run(Precision::Int4);
    let i2 = run(Precision::Int2);
    let e4 = logit_rel_err(&fp, &i4);
    let e2 = logit_rel_err(&fp, &i2);
    assert!(e4 > 0.0, "int4 must differ from fp16");
    assert!(e2 > e4, "int2 ({e2}) must be worse than int4 ({e4})");
    assert!(e4 < 0.5, "int4 should stay close to fp16 ({e4})");
}

#[test]
fn dynaexq_backend_runs_mixed_precision() {
    let preset = small_preset();
    let Some(rt) = runtime() else { return };
    let weights = Arc::new(ModelWeights::generate(&preset, 42));
    let mut cfg = ServingConfig::default();
    cfg.n_hi_override = Some(4); // 4 of 16 experts hot
    cfg.update_interval_ms = 1.0;
    let backend =
        DynaExqBackend::new(&preset, &cfg, &DeviceConfig::default()).unwrap();
    let mut e = NumericEngine::new(rt, weights, Box::new(backend)).unwrap();
    let w = WorkloadProfile::text();
    let mut rng = dynaexq::util::XorShiftRng::new(5);
    // warm: promote hot experts
    for i in 0..3 {
        let prompt = w.sample_prompt(&mut rng, 32);
        e.prefill(&prompt, i).unwrap();
    }
    let t = e.now() + 60.0;
    e.backend.tick(t);
    // post-warm resolution mixes tiers
    assert!(e.backend.hi_fraction() >= 0.0);
    let prompt = w.sample_prompt(&mut rng, 32);
    let (_, logits) = e.prefill(&prompt, 99).unwrap();
    assert!(logits.iter().all(|x| x.is_finite()));
    assert!(
        e.backend.migrated_bytes() > 0,
        "hot traffic must have triggered promotions"
    );
}
