//! Property tests for the request front door (DESIGN.md §12): degenerate
//! byte-identity with `ContinuousBatch`, per-tenant fair-share bands,
//! starvation aging, token conservation through `submit`/`drain`, and
//! deterministic typed rejections.

use dynaexq::config::frontdoor::{
    FrontDoorConfig, Lane, LimitAction, TenantLimits,
};
use dynaexq::config::{DeviceConfig, ModelPreset};
use dynaexq::serving::backend::StaticBackend;
use dynaexq::serving::engine::{Engine, EngineConfig};
use dynaexq::serving::frontdoor::{FrontDoor, Rejected, SloScheduler};
use dynaexq::serving::scheduler::ContinuousBatch;
use dynaexq::serving::session::MetricsSnapshot;
use dynaexq::testutil::prop::Prop;
use dynaexq::workload::{Request, RequestGenerator, Scenario, WorkloadProfile};
use dynaexq::ServeSession;

fn engine(max_batch: usize, seed: u64) -> Engine {
    let preset = ModelPreset::phi_sim();
    Engine::new(
        &preset,
        &WorkloadProfile::text(),
        Box::new(StaticBackend::for_preset(&preset)),
        &DeviceConfig::default(),
        EngineConfig { max_batch, seed, track_activation: false },
    )
}

#[test]
fn prop_degenerate_slo_scheduler_matches_continuous_batch() {
    // One default-class tenant, unbounded limits: the SLO selection key
    // collapses to (arrival, submission order), which is exactly
    // ContinuousBatch's stable arrival sort. Every recorded sample must
    // match bit-for-bit, not just the aggregates.
    let mut prop = Prop::new("frontdoor_degenerate_equivalence");
    prop.run(20, |rng| {
        let n = 1 + rng.below(20);
        let cap = 1 + rng.below(5);
        let mut gen =
            RequestGenerator::new(WorkloadProfile::text(), rng.next_u64());
        let mut reqs: Vec<Request> = (0..n)
            .map(|_| {
                let prompt = 1 + rng.below(48);
                let output = 1 + rng.below(8);
                let arrival = rng.range_f64(0.0, 3.0);
                gen.request(prompt, output, arrival)
            })
            .collect();
        rng.shuffle(&mut reqs);
        let eng_seed = rng.next_u64();

        let mut a = engine(cap, eng_seed);
        a.serve_with(&mut ContinuousBatch::default(), reqs.clone());
        let mut b = engine(cap, eng_seed);
        b.serve_with(
            &mut SloScheduler::new(FrontDoorConfig::unbounded()),
            reqs.clone(),
        );
        assert_eq!(a.metrics.ttft.samples(), b.metrics.ttft.samples());
        assert_eq!(a.metrics.tpop.samples(), b.metrics.tpop.samples());
        assert_eq!(a.metrics.e2e.samples(), b.metrics.e2e.samples());
        assert_eq!(a.metrics.decode_tokens, b.metrics.decode_tokens);
        assert_eq!(a.metrics.prefill_tokens, b.metrics.prefill_tokens);
        assert_eq!(a.metrics.duration_s, b.metrics.duration_s);

        // the default config is equally degenerate for untagged requests:
        // aging promotes oldest-first, which IS arrival order, and the
        // single tenant keeps fair-share counts equal at every decision
        let mut c = engine(cap, eng_seed);
        c.serve_with(&mut SloScheduler::new(FrontDoorConfig::default()), reqs);
        assert_eq!(a.metrics.ttft.samples(), c.metrics.ttft.samples());
        assert_eq!(a.metrics.e2e.samples(), c.metrics.e2e.samples());
        assert_eq!(a.metrics.duration_s, c.metrics.duration_s);
    });
}

#[test]
fn prop_fair_share_band_under_arrival_shuffles() {
    // Equal per-tenant offered load, all same lane and arrival: at every
    // admission prefix the per-tenant service counts stay within one of
    // each other, regardless of the submission interleaving.
    let mut prop = Prop::new("frontdoor_fair_share_band");
    prop.run(15, |rng| {
        let tenants = 2 + rng.below(3);
        let per = 4 + rng.below(5);
        let cap = 1 + rng.below(4);
        let mut gen =
            RequestGenerator::new(WorkloadProfile::text(), rng.next_u64());
        let mut subs: Vec<usize> = (0..tenants)
            .flat_map(|t| std::iter::repeat(t).take(per))
            .collect();
        rng.shuffle(&mut subs);

        let fd = FrontDoor::new(FrontDoorConfig::unbounded()).unwrap();
        for &t in &subs {
            let req = gen.request(1 + rng.below(32), 1 + rng.below(6), 0.0);
            fd.submit(req, &format!("t{t}"), Lane::Standard, 0.0).unwrap();
        }
        let (mut sched, reqs) = fd.take_scheduled();
        let mut e = engine(cap, rng.next_u64());
        e.serve_with(&mut sched, reqs);

        let mut counts = vec![0u64; tenants];
        for (i, &(t, _lane)) in sched.admission_log.iter().enumerate() {
            counts[t] += 1;
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            assert!(
                max - min <= 1,
                "fairness band broken at admission {i}: {counts:?}"
            );
        }
        fd.absorb(&sched);
        for (tenant, served) in fd.tenant_served() {
            assert_eq!(served, per as u64, "{tenant}");
        }
        assert_eq!(e.metrics.e2e.count(), tenants * per);
    });
}

#[test]
fn starvation_aging_bounds_batch_lane_wait() {
    let mut gen = RequestGenerator::new(WorkloadProfile::text(), 11);
    let mut serve = |age: f64| -> (usize, f64) {
        let mut cfg = FrontDoorConfig::unbounded();
        cfg.starvation_age_s = age;
        let fd = FrontDoor::new(cfg).unwrap();
        for _ in 0..24 {
            fd.submit(gen.request(8, 4, 0.0), "a", Lane::Interactive, 0.0)
                .unwrap();
        }
        for _ in 0..3 {
            fd.submit(gen.request(8, 4, 0.0), "b", Lane::Batch, 0.0).unwrap();
        }
        let (mut sched, reqs) = fd.take_scheduled();
        let mut e = engine(2, 5);
        e.serve_with(&mut sched, reqs);
        let first_batch = sched
            .admission_log
            .iter()
            .position(|&(_, l)| l == Lane::Batch)
            .expect("batch lane starved outright");
        fd.absorb(&sched);
        let worst =
            fd.lane_ttft(Lane::Batch).iter().fold(0.0, |a: f64, &b| a.max(b));
        (first_batch, worst)
    };
    // infinite age = strict lane priority: batch waits out every
    // interactive admission
    let (strict_pos, strict_ttft) = serve(f64::INFINITY);
    assert_eq!(strict_pos, 24);
    // a tiny aging threshold promotes the queued batch requests to rank 0,
    // where fair share prefers the unserved tenant — earlier admission,
    // strictly better worst-case batch TTFT
    let (aged_pos, aged_ttft) = serve(0.001);
    assert!(aged_pos < strict_pos, "aging never promoted: {aged_pos}");
    assert!(
        aged_ttft < strict_ttft,
        "aged worst TTFT {aged_ttft} not better than strict {strict_ttft}"
    );
}

#[test]
fn prop_token_conservation_through_session_submit_drain() {
    // Random bounded configs, random submissions: every offered request
    // is either fully served (its tokens land in the engine counters) or
    // rejected with a typed reason — never lost, never queued forever.
    let mut prop = Prop::new("frontdoor_session_token_conservation");
    prop.run(8, |rng| {
        let mut cfg = FrontDoorConfig::default();
        cfg.queue_capacity = 1 + rng.below(10);
        let hard = 1 + rng.below(6);
        cfg.tenant_limits = TenantLimits {
            soft_limit: hard,
            soft_action: LimitAction::Warn,
            hard_limit: hard,
        };
        let mut s = ServeSession::builder()
            .model("phi-sim")
            .method("static")
            .workload("text")
            .seed(rng.next_u64())
            .frontdoor(cfg)
            .build()
            .unwrap();
        let mut gen =
            RequestGenerator::new(WorkloadProfile::text(), rng.next_u64());
        let (mut offered, mut accepted, mut rejected) = (0u64, 0u64, 0u64);
        let (mut in_tok, mut out_tok) = (0u64, 0u64);
        for _ in 0..3 {
            let n = 1 + rng.below(12);
            for _ in 0..n {
                let prompt = 1 + rng.below(24);
                let output = 1 + rng.below(6);
                let now = s.now();
                let req = gen.request(prompt, output, now);
                let tenant = format!("t{}", rng.below(3));
                let lane = Lane::ALL[rng.below(3)];
                offered += 1;
                match s.submit(req, &tenant, lane).unwrap() {
                    Ok(()) => {
                        accepted += 1;
                        in_tok += prompt as u64;
                        out_tok += output as u64;
                    }
                    Err(_) => rejected += 1,
                }
            }
            s.drain().unwrap();
        }
        let snap = s.snapshot();
        assert_eq!(snap.decode_tokens, out_tok);
        assert_eq!(snap.prefill_tokens, in_tok);
        assert_eq!(snap.fd_queue_depth, 0);
        assert_eq!(snap.fd_lane_admitted.iter().sum::<u64>(), accepted);
        assert_eq!(snap.fd_lane_rejected.iter().sum::<u64>(), rejected);
        assert_eq!(accepted + rejected, offered);
        assert_eq!(s.metrics().e2e.count(), accepted as usize);
        let rt = MetricsSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(rt, snap);
    });
}

#[test]
fn prop_demote_accounting_matches_reference_model() {
    // Random bounded configs under `LimitAction::Demote`, random
    // submission scripts with interleaved drains: the door's counters
    // must track a straight-line reference model of the documented
    // check order (hard limit → soft demote → queue bound), with
    // soft-overage/demotion counted only on actual admission and
    // rejections charged to the *effective* (post-demotion) lane.
    // This pins the accounting fix: a demoted-then-rejected submission
    // must move no admission-side counter.
    let mut prop = Prop::new("frontdoor_demote_accounting");
    prop.run(10, |rng| {
        let soft = 1 + rng.below(3);
        let cfg = FrontDoorConfig {
            queue_capacity: 1 + rng.below(6),
            tenant_limits: TenantLimits {
                soft_limit: soft,
                soft_action: LimitAction::Demote,
                hard_limit: soft + 1 + rng.below(4),
            },
            est_service_s: 0.0,
            ..FrontDoorConfig::default()
        };
        let cap = cfg.queue_capacity;
        let limits = cfg.tenant_limits;
        let fd = FrontDoor::new(cfg).unwrap();
        let mut gen =
            RequestGenerator::new(WorkloadProfile::text(), rng.next_u64());

        // reference model state
        let mut queued = [0usize; 3];
        let mut qdepth = 0usize;
        let mut admitted = [0u64; 3];
        let mut rejected = [0u64; 3];
        let (mut soft_overages, mut demoted) = (0u64, 0u64);
        let mut offered = 0u64;

        for _ in 0..4 {
            let n = 1 + rng.below(16);
            for _ in 0..n {
                let t = rng.below(3);
                let lane = Lane::ALL[rng.below(3)];
                let req = gen.request(1 + rng.below(16), 1 + rng.below(4), 0.0);
                let got = fd.submit(req, &format!("t{t}"), lane, 0.0);
                offered += 1;

                // straight-line reference of the documented semantics
                let want = if queued[t] >= limits.hard_limit {
                    rejected[lane.index()] += 1;
                    Err(Rejected::TenantOverLimit)
                } else {
                    let over = queued[t] >= limits.soft_limit;
                    let eff = if over && lane != Lane::Batch {
                        Lane::Batch
                    } else {
                        lane
                    };
                    if qdepth >= cap {
                        rejected[eff.index()] += 1;
                        Err(Rejected::QueueFull)
                    } else {
                        if over {
                            soft_overages += 1;
                            if eff != lane {
                                demoted += 1;
                            }
                        }
                        admitted[eff.index()] += 1;
                        queued[t] += 1;
                        qdepth += 1;
                        Ok(())
                    }
                };
                assert_eq!(got, want, "queued {queued:?} depth {qdepth}");
            }
            assert_eq!(fd.depth(), qdepth);
            assert_eq!(fd.stats().lane_admitted(), admitted.to_vec());
            assert_eq!(fd.stats().lane_rejected(), rejected.to_vec());
            assert_eq!(fd.stats().soft_overages(), soft_overages);
            assert_eq!(fd.stats().demoted(), demoted);

            // drain through an engine; tenant occupancy resets to zero
            let (mut sched, reqs) = fd.take_scheduled();
            let mut e = engine(2, rng.next_u64());
            e.serve_with(&mut sched, reqs);
            fd.absorb(&sched);
            queued = [0; 3];
            qdepth = 0;
        }
        // every submission landed exactly once, somewhere typed
        let a: u64 = admitted.iter().sum();
        let r: u64 = rejected.iter().sum();
        assert_eq!(a + r, offered);
        assert!(demoted <= soft_overages);
    });
}

#[test]
fn typed_rejections_are_deterministic() {
    // The check order (hard limit → soft action → queue bound) is fixed,
    // so the same submission script yields the same typed outcomes —
    // independent of request contents.
    let run = |seed: u64| -> Vec<Result<(), Rejected>> {
        let cfg = FrontDoorConfig {
            queue_capacity: 3,
            tenant_limits: TenantLimits {
                soft_limit: 2,
                soft_action: LimitAction::Reject,
                hard_limit: 4,
            },
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::new(cfg).unwrap();
        let mut gen = RequestGenerator::new(WorkloadProfile::text(), seed);
        let subs = [
            ("a", Lane::Interactive),
            ("a", Lane::Interactive),
            ("a", Lane::Interactive),
            ("b", Lane::Standard),
            ("b", Lane::Standard),
            ("c", Lane::Batch),
        ];
        subs.iter()
            .map(|&(t, lane)| {
                fd.submit(gen.request(8, 2, 0.0), t, lane, 0.0)
            })
            .collect()
    };
    let expect = vec![
        Ok(()),
        Ok(()),
        Err(Rejected::TenantOverLimit),
        Ok(()),
        Err(Rejected::QueueFull),
        Err(Rejected::QueueFull),
    ];
    assert_eq!(run(1), expect);
    assert_eq!(run(2), expect);
}

#[test]
fn infeasible_deadlines_reject_at_submit() {
    let cfg =
        FrontDoorConfig { est_service_s: 1.0, ..FrontDoorConfig::default() };
    let fd = FrontDoor::new(cfg).unwrap();
    let mut gen = RequestGenerator::new(WorkloadProfile::text(), 3);
    // interactive budget (0.5s) < the 1s service estimate: provably late
    assert_eq!(
        fd.submit(gen.request(8, 2, 0.0), "a", Lane::Interactive, 0.0),
        Err(Rejected::DeadlineInfeasible)
    );
    // the batch budget (30s) absorbs the estimate
    fd.submit(gen.request(8, 2, 0.0), "a", Lane::Batch, 0.0).unwrap();
    assert_eq!(fd.stats().rejection_kinds(), (0, 0, 1));
    assert_eq!(fd.depth(), 1);
}

#[test]
fn deadline_misses_count_per_lane() {
    let mut cfg = FrontDoorConfig::unbounded();
    cfg.classes[Lane::Interactive.index()].ttft_budget_s = 1e-9;
    let fd = FrontDoor::new(cfg).unwrap();
    let mut gen = RequestGenerator::new(WorkloadProfile::text(), 17);
    for _ in 0..4 {
        fd.submit(gen.request(16, 2, 0.0), "a", Lane::Interactive, 0.0)
            .unwrap();
    }
    for _ in 0..2 {
        fd.submit(gen.request(16, 2, 0.0), "b", Lane::Batch, 0.0).unwrap();
    }
    let (mut sched, reqs) = fd.take_scheduled();
    let mut e = engine(2, 7);
    e.serve_with(&mut sched, reqs);
    fd.absorb(&sched);
    assert_eq!(fd.lane_ttft(Lane::Interactive).len(), 4);
    assert_eq!(fd.lane_ttft(Lane::Batch).len(), 2);
    let late = fd
        .lane_ttft(Lane::Interactive)
        .iter()
        .filter(|&&t| t > 1e-9)
        .count() as u64;
    let miss = fd.stats().lane_deadline_miss();
    assert_eq!(miss[Lane::Interactive.index()], late);
    assert!(late >= 2, "cap-2 queueing must blow a nanosecond budget");
    // infinite budgets never miss
    assert_eq!(miss[Lane::Batch.index()], 0);
}

#[test]
fn multi_tenant_scenario_through_front_door_holds_invariants() {
    let mut s = ServeSession::builder()
        .model("phi-sim")
        .method("dynaexq")
        .workload("text")
        .seed(9)
        .frontdoor(FrontDoorConfig::default())
        .build()
        .unwrap();
    let sc = Scenario::multi_tenant();
    let (batch, output) = (2usize, 2usize);
    let marks = s.run_scenario_frontdoor(&sc, batch, 16, output).unwrap();
    assert_eq!(marks.len(), sc.phases.len());
    let mut expect_admitted = 0u64;
    for (phase, (name, snap)) in sc.phases.iter().zip(&marks) {
        assert_eq!(*name, phase.name);
        expect_admitted +=
            (phase.rounds * Scenario::scaled_batch(batch, phase.load)) as u64;
        // boundary invariants: everything admitted was fully served,
        // nothing rejected, nothing left queued, tokens conserved
        let admitted: u64 = snap.fd_lane_admitted.iter().sum();
        assert_eq!(admitted, expect_admitted, "{name}");
        assert_eq!(snap.fd_lane_rejected.iter().sum::<u64>(), 0, "{name}");
        assert_eq!(snap.fd_queue_depth, 0, "{name}");
        assert_eq!(snap.decode_tokens, admitted * output as u64, "{name}");
        let rt = MetricsSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(rt, *snap);
    }
    // every tenant got its full share, every lane saw traffic
    let fd = s.frontdoor().unwrap();
    let served = fd.tenant_served();
    assert_eq!(served.len(), 3);
    for (tenant, n) in &served {
        assert_eq!(*n, 8, "{tenant}");
    }
    for lane in Lane::ALL {
        assert!(fd.stats().lane_admitted()[lane.index()] > 0, "{lane}");
    }
    assert_eq!(s.metrics().e2e.count(), expect_admitted as usize);
}

#[test]
fn burst_scenario_overflows_into_typed_rejections() {
    let cfg =
        FrontDoorConfig { queue_capacity: 6, ..FrontDoorConfig::default() };
    let mut s = ServeSession::builder()
        .model("phi-sim")
        .method("dynaexq")
        .seed(21)
        .frontdoor(cfg)
        .build()
        .unwrap();
    let marks = s.run_scenario_frontdoor(&Scenario::burst(), 4, 16, 2).unwrap();
    let last = &marks.last().unwrap().1;
    // the crowd phase submits 8/round into a 6-deep queue: the overflow
    // surfaces as typed interactive-lane rejections, not lost tokens
    let rejected: u64 = last.fd_lane_rejected.iter().sum();
    assert!(rejected > 0, "crowd surge never overflowed the queue");
    assert_eq!(last.fd_lane_rejected[Lane::Interactive.index()], rejected);
    let admitted: u64 = last.fd_lane_admitted.iter().sum();
    assert_eq!(last.decode_tokens, admitted * 2);
    assert_eq!(s.metrics().e2e.count(), admitted as usize);
    assert_eq!(last.fd_queue_depth, 0);
}
