//! Integration: the AOT bridge — load HLO-text artifacts, compile on the
//! PJRT CPU client, execute, and check numerics against hand-computed
//! expectations. This is the riskiest seam in the stack, so it gets its own
//! test file that runs against the real `artifacts/` directory.
//!
//! Requires the `numeric` build feature (PJRT runtime).
#![cfg(feature = "numeric")]

use std::path::Path;

use dynaexq::config::{D_MODEL, VOCAB};
use dynaexq::runtime::{lit_f32, lit_i32, to_f32, to_i32, Runtime};

/// The PJRT runtime, or `None` when this environment cannot execute
/// numerics — AOT artifacts missing, or the crate was built against the
/// stubbed `xla` bindings. Only those two cases skip (pass vacuously,
/// with a note on stderr) so the CI matrix can run
/// `cargo test --features numeric` meaningfully on both kinds of
/// builders; any other `Runtime::load` error with artifacts present is a
/// real regression and still fails hard.
fn runtime() -> Option<Runtime> {
    let dir = std::env::var("DYNAEXQ_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !Path::new(&dir).join("manifest.txt").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts` first");
        return None;
    }
    match Runtime::load(Path::new(&dir)) {
        Ok(rt) => Some(rt),
        Err(e) if format!("{e:#}").contains("xla stub") => {
            eprintln!("skipping: built against the stubbed xla bindings");
            None
        }
        Err(e) => panic!("runtime load failed with artifacts present: {e:#}"),
    }
}

#[test]
fn embed_gathers_rows() {
    let Some(rt) = runtime() else { return };
    // table[v, d] = v * 1000 + d  → row 5 is recognizable
    let table: Vec<f32> = (0..VOCAB * D_MODEL)
        .map(|i| ((i / D_MODEL) * 1000 + (i % D_MODEL)) as f32)
        .collect();
    let tokens = [5i32];
    let out = rt
        .execute(
            "embed_t1",
            &[
                lit_i32(&tokens, &[1]).unwrap(),
                lit_f32(&table, &[VOCAB as i64, D_MODEL as i64]).unwrap(),
            ],
        )
        .unwrap();
    let x = to_f32(&out[0]).unwrap();
    assert_eq!(x.len(), D_MODEL);
    assert_eq!(x[0], 5000.0);
    assert_eq!(x[63], 5063.0);
}

#[test]
fn expert_fp16_matches_host_math() {
    let Some(rt) = runtime() else { return };
    // x = e_0 (one-hot) → h1 = w1 row 0, h3 = w3 row 0; choose w1 rows so
    // silu() saturates: silu(large) ≈ large.
    let f = dynaexq::config::FF_DIM;
    let d = D_MODEL;
    let x = {
        let mut v = vec![0f32; d];
        v[0] = 1.0;
        v
    };
    let w1 = vec![10.0f32; d * f]; // h1 = 10 (silu(10) ≈ 9.999546)
    let w3 = vec![0.5f32; d * f];  // h3 = 0.5
    let w2 = {
        // w2[f, d]: only column 0 nonzero = 1/f → y[0] = mean(h)
        let mut v = vec![0f32; f * d];
        for row in 0..f {
            v[row * d] = 1.0 / f as f32;
        }
        v
    };
    let out = rt
        .execute(
            "expert_fp16_t1",
            &[
                lit_f32(&x, &[1, d as i64]).unwrap(),
                lit_f32(&w1, &[d as i64, f as i64]).unwrap(),
                lit_f32(&w3, &[d as i64, f as i64]).unwrap(),
                lit_f32(&w2, &[f as i64, d as i64]).unwrap(),
            ],
        )
        .unwrap();
    let y = to_f32(&out[0]).unwrap();
    let silu10 = 10.0 / (1.0 + (-10.0f32).exp());
    let expect = silu10 * 0.5;
    assert!((y[0] - expect).abs() < 1e-4, "y0={} expect={}", y[0], expect);
    assert!(y[1].abs() < 1e-6);
}

#[test]
fn router_top_k_selects_biased_expert() {
    let Some(rt) = runtime() else { return };
    let d = D_MODEL;
    let e = 16usize; // phi-sim router e16k2
    let x = vec![1.0f32; d];
    let g = vec![1.0f32; d];
    // wr: expert 7 gets weight 1 everywhere → logit = sum(xn); others 0
    let mut wr = vec![0f32; d * e];
    for row in 0..d {
        wr[row * e + 7] = 1.0;
        wr[row * e + 3] = 0.5;
    }
    let out = rt
        .execute(
            "router_e16k2_t1",
            &[
                lit_f32(&x, &[1, d as i64]).unwrap(),
                lit_f32(&g, &[d as i64]).unwrap(),
                lit_f32(&wr, &[d as i64, e as i64]).unwrap(),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 3); // (xn, idx, weights)
    let idx = to_i32(&out[1]).unwrap();
    let w = to_f32(&out[2]).unwrap();
    assert_eq!(idx[0], 7, "top-1 should be the biased expert");
    assert_eq!(idx[1], 3);
    assert!(w[0] > w[1]);
    assert!((w[0] + w[1] - 1.0).abs() < 1e-5, "softmax normalizes");
}

#[test]
fn quantized_expert_matches_rust_dequant_reference() {
    use dynaexq::model::quant::{dequantize, quantize};
    use dynaexq::model::Precision;
    use dynaexq::util::XorShiftRng;

    let Some(rt) = runtime() else { return };
    let d = D_MODEL;
    let f = dynaexq::config::FF_DIM;
    let mut rng = XorShiftRng::new(99);
    let gen = |rng: &mut XorShiftRng, n: usize| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32() * 0.2).collect()
    };
    let (w1, w3, w2) = (gen(&mut rng, d * f), gen(&mut rng, d * f), gen(&mut rng, f * d));
    let x = gen(&mut rng, 4 * d);

    for p in [Precision::Int4, Precision::Int2] {
        let q1 = quantize(&w1, d, f, p);
        let q3 = quantize(&w3, d, f, p);
        let q2 = quantize(&w2, f, d, p);
        let name = format!("expert_{}_t4", p.tag());
        let out = rt
            .execute(
                &name,
                &[
                    lit_f32(&x, &[4, d as i64]).unwrap(),
                    dynaexq::runtime::lit_u8(&q1.data, &[(d / p.pack()) as i64, f as i64]).unwrap(),
                    lit_f32(&q1.scales, &[f as i64]).unwrap(),
                    dynaexq::runtime::lit_u8(&q3.data, &[(d / p.pack()) as i64, f as i64]).unwrap(),
                    lit_f32(&q3.scales, &[f as i64]).unwrap(),
                    dynaexq::runtime::lit_u8(&q2.data, &[(f / p.pack()) as i64, d as i64]).unwrap(),
                    lit_f32(&q2.scales, &[d as i64]).unwrap(),
                ],
            )
            .unwrap();
        let y = to_f32(&out[0]).unwrap();

        // host reference: dequantize + SwiGLU in f32
        let dw1 = dequantize(&q1);
        let dw3 = dequantize(&q3);
        let dw2 = dequantize(&q2);
        let matmul = |x: &[f32], w: &[f32], t: usize, k: usize, n: usize| {
            let mut out = vec![0f32; t * n];
            for ti in 0..t {
                for ki in 0..k {
                    let xv = x[ti * k + ki];
                    for ni in 0..n {
                        out[ti * n + ni] += xv * w[ki * n + ni];
                    }
                }
            }
            out
        };
        let h1 = matmul(&x, &dw1, 4, d, f);
        let h3 = matmul(&x, &dw3, 4, d, f);
        let h: Vec<f32> = h1
            .iter()
            .zip(&h3)
            .map(|(&a, &b)| (a / (1.0 + (-a).exp())) * b)
            .collect();
        let want = matmul(&h, &dw2, 4, f, d);
        for i in 0..y.len() {
            assert!(
                (y[i] - want[i]).abs() < 1e-3,
                "{name} i={i}: got {} want {}",
                y[i],
                want[i]
            );
        }
    }
}

#[test]
fn executable_cache_hits() {
    let Some(rt) = runtime() else { return };
    rt.executable("embed_t1").unwrap();
    rt.executable("embed_t1").unwrap();
    let (compiles, _, _) = rt.stats.snapshot();
    assert_eq!(compiles, 1);
    assert_eq!(rt.compiled_count(), 1);
}
