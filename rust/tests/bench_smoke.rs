//! Bench-harness smoke suite (DESIGN.md §11).
//!
//! Runs the smallest bench matrix cell end to end, asserts the emitted
//! `BENCH_serving.json` parses and carries every required key, and pins
//! the hot-path refactor's equivalence contract: the scratch-buffer
//! `sample_topk_into` produces the identical expert sequence (and RNG
//! stream) to the allocating `sample_topk` path for seeded RNGs.

use dynaexq::bench::json;
use dynaexq::bench::runtime::{
    report_to_json, run_cell, run_matrix, validate_report_json, BenchMatrix,
    BENCH_BATCHES, BENCH_DEVICES, BENCH_METHODS, BENCH_PRODUCERS,
    BENCH_QOS, BENCH_REPLICAS, CELL_KEYS,
};
use dynaexq::serving::registry::BackendRegistry;
use dynaexq::util::XorShiftRng;
use dynaexq::workload::{RoutingSampler, Scenario, WorkloadProfile};

#[test]
fn smoke_cell_emits_schema_valid_bench_json() {
    let matrix = BenchMatrix::smoke("phi-sim");
    let report = run_matrix(&matrix, |_| {}).expect("smoke matrix runs");
    // the smoke matrix is one cell on every axis except the front-door
    // knobs: a direct cell plus {serial, threaded} producers × {1, 2}
    // fleet replicas × {off, on} qos fronted twins: 1 + 2×2×2 = 9
    assert_eq!(report.cells.len(), 9);
    let text = report_to_json(&report);

    // The schema self-check the CLI runs before writing the file.
    validate_report_json(&text).expect("schema-valid");

    // Independently: parse and assert every required key on the cell,
    // plus the sanity of the values the trajectory is judged on.
    let doc = json::parse(&text).expect("BENCH_serving.json parses");
    assert_eq!(
        doc.get("schema").and_then(|v| v.as_str()),
        Some("dynaexq-bench-serving/v5")
    );
    let cells = doc.get("cells").and_then(|v| v.as_arr()).unwrap();
    // the fronted fan-out nests producers → replicas → qos innermost:
    // cells[0] direct, cells[1] p1 r1 q0, cells[2] p1 r1 q1,
    // cells[3..5] p1 r2, cells[5] p2 r1 q0, …
    let cell = &cells[0];
    assert_eq!(cell.get("frontdoor").unwrap().as_u64(), Some(0));
    assert_eq!(cell.get("producers").unwrap().as_u64(), Some(0));
    assert_eq!(cell.get("qos").unwrap().as_u64(), Some(0));
    for &key in CELL_KEYS {
        assert!(cell.get(key).is_some(), "cell missing required key {key:?}");
    }
    assert_eq!(cell.get("method").unwrap().as_str(), Some("dynaexq"));
    assert_eq!(cell.get("scenario").unwrap().as_str(), Some("steady"));
    let rounds = cell.get("rounds").unwrap().as_u64().unwrap();
    assert_eq!(rounds as usize, Scenario::steady().total_rounds());
    assert!(cell.get("wall_total_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(
        cell.get("wall_p95_round_s").unwrap().as_f64().unwrap()
            >= cell.get("wall_p50_round_s").unwrap().as_f64().unwrap()
    );
    assert!(cell.get("modeled_tok_s").unwrap().as_f64().unwrap() > 0.0);
    // steady × batch 1 × output 4 × 6 rounds → 24 decode tokens
    assert_eq!(cell.get("decode_tokens").unwrap().as_u64(), Some(24));
    // dynaexq converged during warmup: the timed rounds resolve hot
    // traffic at the top rung (migration counters are warmup-excluded
    // deltas, so a converged steady cell may legitimately report 0)
    assert!(cell.get("hi_fraction").unwrap().as_f64().unwrap() > 0.0);

    // The fronted twins conserve the token totals and carry live
    // per-lane counters: steady admits everything on the standard lane.
    // Every twin — threaded, replicated, or qos-armed — must agree with
    // the serial reference on every modeled token total; only wall-clock
    // may differ.
    let coords: [(u64, u64, u64); 8] = [
        (1, 1, 0),
        (1, 1, 1),
        (1, 2, 0),
        (1, 2, 1),
        (2, 1, 0),
        (2, 1, 1),
        (2, 2, 0),
        (2, 2, 1),
    ];
    for (i, &(producers, replicas, qos)) in coords.iter().enumerate() {
        let fronted = &cells[i + 1];
        assert_eq!(fronted.get("frontdoor").unwrap().as_u64(), Some(1));
        assert_eq!(
            fronted.get("producers").unwrap().as_u64(),
            Some(producers)
        );
        assert_eq!(
            fronted.get("replicas").unwrap().as_u64(),
            Some(replicas)
        );
        assert_eq!(fronted.get("qos").unwrap().as_u64(), Some(qos));
        assert_eq!(fronted.get("decode_tokens").unwrap().as_u64(), Some(24));
        let lane_sum = |key: &str| -> u64 {
            fronted
                .get(key)
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_u64().unwrap())
                .sum()
        };
        assert_eq!(lane_sum("fd_lane_admitted"), rounds);
        assert_eq!(lane_sum("fd_lane_rejected"), 0);
        let p50s =
            fronted.get("fd_lane_ttft_p50_s").unwrap().as_arr().unwrap();
        assert_eq!(p50s.len(), 3);
        // lane order is interactive, standard, batch — steady is
        // all-standard
        assert!(p50s[1].as_f64().unwrap() > 0.0);
        // every fronted cell samples its admission path
        assert!(
            fronted.get("fd_submit_p95_s").unwrap().as_f64().unwrap()
                >= fronted.get("fd_submit_p50_s").unwrap().as_f64().unwrap()
        );
        // qos-armed cells settle every charge they admit; unarmed cells
        // carry no ledger at all (the degenerate-collapse contract)
        let ledger_sum = |key: &str| -> (usize, u64) {
            let arr = fronted.get(key).unwrap().as_arr().unwrap();
            let sum = arr.iter().map(|v| v.as_u64().unwrap()).sum();
            (arr.len(), sum)
        };
        let (charged_len, charged) = ledger_sum("qos_charged");
        let (refunded_len, refunded) = ledger_sum("qos_refunded");
        if qos == 1 {
            assert_eq!(charged_len, 3);
            assert_eq!(refunded_len, 3);
            assert_eq!(charged, refunded, "qos ledger failed to settle");
            assert!(charged > 0, "qos cell admitted nothing chargeable");
        } else {
            assert_eq!(charged_len, 0);
            assert_eq!(refunded_len, 0);
        }
    }
}

#[test]
fn full_matrix_axes_cover_registry_and_canned_scenarios() {
    // The declared matrix is the acceptance surface: every bench method
    // must be a registered serving method, and the scenario axis must be
    // exactly the canned library.
    let registry = BackendRegistry::with_builtins();
    for m in BENCH_METHODS {
        assert!(registry.contains(m), "bench method {m:?} not registered");
    }
    let full = BenchMatrix::full("qwen30b-sim");
    assert_eq!(full.scenarios, Scenario::names());
    assert_eq!(full.devices, BENCH_DEVICES);
    assert_eq!(full.batches, BENCH_BATCHES);
    assert_eq!(full.producers, BENCH_PRODUCERS);
    assert_eq!(full.replicas, BENCH_REPLICAS);
    assert_eq!(full.qos, BENCH_QOS);
    // methods × scenarios × 2 device widths × 3 batches × (1 direct +
    // one fronted cell per producer × replica × qos coordinate)
    assert_eq!(
        full.n_cells(),
        BENCH_METHODS.len()
            * Scenario::names().len()
            * 2
            * 3
            * (1 + BENCH_PRODUCERS.len()
                * BENCH_REPLICAS.len()
                * BENCH_QOS.len())
    );
}

#[test]
fn bench_runs_a_sharded_and_an_adaptive_cell() {
    // Beyond the smoke cell: one sharded and one adaptive cell of the
    // full matrix execute and carry live counters (2-device groups and
    // the drift layer are the axes the smoke cell does not touch).
    let mut matrix = BenchMatrix::smoke("phi-sim");
    matrix.prompt_len = 16;
    matrix.output_len = 2;
    let sharded =
        run_cell(&matrix, "dynaexq-sharded", "swap", 2, 2, false, 0, 0, false)
            .unwrap();
    assert_eq!(sharded.devices, 2);
    assert_eq!(sharded.rounds, Scenario::swap().total_rounds());
    assert!(sharded.migrated_bytes > 0, "sharded cell migrated nothing");
    // direct cells carry no per-lane counters
    assert!(sharded.fd_lane_admitted.is_empty());
    let adaptive = run_cell(
        &matrix,
        "dynaexq-adaptive",
        "steady",
        1,
        1,
        false,
        0,
        0,
        false,
    )
    .unwrap();
    assert_eq!(adaptive.drift_events, 0, "steady traffic must not drift");
}

#[test]
fn frontdoor_burst_cell_records_typed_rejections() {
    // The bench queue bound is 3/2 × batch, so burst's 2× crowd surge
    // (8 submits per round at batch 4 into a 6-deep queue) must overflow
    // into interactive-lane rejections while tokens stay conserved.
    let mut matrix = BenchMatrix::smoke("phi-sim");
    matrix.prompt_len = 16;
    matrix.output_len = 2;
    let cell =
        run_cell(&matrix, "dynaexq", "burst", 1, 4, true, 1, 1, false)
            .unwrap();
    assert!(cell.frontdoor);
    assert_eq!(cell.producers, 1);
    assert_eq!(cell.fd_lane_admitted.len(), 3);
    let admitted: u64 = cell.fd_lane_admitted.iter().sum();
    let rejected: u64 = cell.fd_lane_rejected.iter().sum();
    assert!(rejected > 0, "crowd surge never overflowed the bench queue");
    // burst's crowd phase is pinned to the interactive lane
    assert_eq!(cell.fd_lane_rejected[0], rejected);
    assert_eq!(cell.decode_tokens, admitted * 2);
}

#[test]
fn scratch_sample_topk_identical_to_allocation_path() {
    // Acceptance contract: the scratch-buffer sampler the engine now
    // runs produces the identical expert sequence to the old allocating
    // path for seeded RNGs — across profiles, layers, and request tags,
    // with the scratch buffer reused (dirty) between calls.
    for profile in WorkloadProfile::all() {
        for seed in [1u64, 0xDC, 0xBE4C] {
            let sampler = RoutingSampler::new(&profile, 4, 128, 8);
            let mut rng_alloc = XorShiftRng::new(seed);
            let mut rng_scratch = XorShiftRng::new(seed);
            let mut scratch = Vec::new();
            let mut total = 0usize;
            for tag in 0..300u64 {
                let layer = (tag % 4) as usize;
                let fresh = sampler.sample_topk(&mut rng_alloc, tag, layer);
                sampler.sample_topk_into(
                    &mut rng_scratch,
                    tag,
                    layer,
                    &mut scratch,
                );
                assert_eq!(
                    fresh, scratch,
                    "{}: divergence at seed {seed:#x} tag {tag}",
                    profile.name
                );
                total += scratch.len();
            }
            // identical RNG state afterwards — the streams never forked
            assert_eq!(rng_alloc.next_u64(), rng_scratch.next_u64());
            assert_eq!(total, 300 * 8);
        }
    }
}
