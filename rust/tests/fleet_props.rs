//! Property tests for fleet-scale replicated serving (DESIGN.md §14):
//! the 1-replica byte-identity anchor against a bare front-doored
//! session, exactly-once completion across scripted mid-stream failover,
//! parallel-drain determinism, and elastic drain/restore.

use dynaexq::config::fleet::FleetConfig;
use dynaexq::config::frontdoor::{FrontDoorConfig, Lane};
use dynaexq::serving::fleet::Fleet;
use dynaexq::serving::session::MetricsSnapshot;
use dynaexq::testutil::prop::Prop;
use dynaexq::workload::{
    FaultPlan, RequestGenerator, Scenario, WorkloadProfile,
};
use dynaexq::ServeSession;

/// Strip the fleet-level fields so a fleet snapshot can be compared
/// byte-for-byte against a bare session snapshot (which leaves them at
/// their defaults).
fn without_fleet_fields(snap: &MetricsSnapshot) -> MetricsSnapshot {
    let mut s = snap.clone();
    s.fleet_replicas = 0;
    s.fleet_health = Vec::new();
    s.fleet_served = Vec::new();
    s.fleet_failovers = 0;
    s.fleet_readmitted = 0;
    s
}

#[test]
fn prop_one_replica_fleet_reproduces_bare_session_byte_for_byte() {
    // A 1-replica, no-fault, un-chunked fleet is the same machine as a
    // bare front-doored session: same generator seeding, same engine
    // seed, same admission/drain loop. Every phase mark must match
    // byte-for-byte once the fleet-only fields are stripped.
    let mut prop = Prop::new("fleet_one_replica_identity");
    prop.run(6, |rng| {
        let seed = rng.next_u64();
        let batch = 1 + rng.below(4);
        let output = 1 + rng.below(3);
        let sc = if rng.below(2) == 0 {
            Scenario::steady()
        } else {
            Scenario::swap()
        };

        let mut session = ServeSession::builder()
            .model("phi-sim")
            .method("dynaexq")
            .workload("text")
            .seed(seed)
            .warmup(1)
            .frontdoor(FrontDoorConfig::default())
            .build()
            .unwrap();
        let mut fleet = Fleet::builder()
            .model("phi-sim")
            .method("dynaexq")
            .workload("text")
            .seed(seed)
            .warmup(1)
            .replicas(1)
            .build()
            .unwrap();

        let want = session.run_scenario_frontdoor(&sc, batch, 16, output).unwrap();
        let got = fleet.run_scenario(&sc, batch, 16, output).unwrap();
        assert_eq!(want.len(), got.len());
        for ((wn, ws), (gn, gs)) in want.iter().zip(&got) {
            assert_eq!(wn, gn);
            assert_eq!(gs.fleet_replicas, 1, "{gn}");
            assert_eq!(gs.fleet_health, vec![0], "{gn}");
            assert_eq!(gs.fleet_failovers, 0, "{gn}");
            assert_eq!(
                without_fleet_fields(gs).encode(),
                ws.encode(),
                "phase {gn} diverged from the bare session"
            );
        }
        // the per-replica view is the bare-session shape directly
        assert_eq!(
            fleet.replica_snapshot(0).encode(),
            session.snapshot().encode()
        );
    });
}

#[test]
fn two_replica_midstream_failover_completes_every_request_exactly_once() {
    // Chunked streaming keeps requests in flight across serve rounds;
    // the scripted fault downs replica 0 while it still holds streams.
    // Exactly-once across failover: every admitted request's full
    // output lands in the decode counters — no token lost to the dead
    // replica, none generated twice — and the whole run is byte-stable.
    let output = 6usize;
    let run = || -> (Fleet, Vec<(String, MetricsSnapshot)>) {
        let mut fleet = Fleet::builder()
            .model("phi-sim")
            .method("dynaexq")
            .seed(0xFEE7)
            .warmup(0)
            .fleet_cfg(FleetConfig {
                replicas: 2,
                stream_chunk: Some(1),
                ..FleetConfig::default()
            })
            .build()
            .unwrap();
        let sc = Scenario::steady().with_faults(FaultPlan::fail(0, 2));
        let marks = fleet.run_scenario(&sc, 4, 16, output).unwrap();
        (fleet, marks)
    };
    let (fleet, marks) = run();
    let snap = fleet.snapshot();
    let stats = fleet.stats();

    // the fault script actually fired: replica 0 is Down, its streams
    // failed over to replica 1
    assert_eq!(snap.fleet_health, vec![2, 0]);
    assert!(stats.failovers >= 1, "no failover edge: {stats:?}");
    assert!(stats.readmitted > 0, "no stream was in flight at the edge");
    assert_eq!(snap.fleet_readmitted, stats.readmitted);

    // exactly-once: nothing queued, nothing in flight, decode tokens
    // equal admitted requests × output length (readmission bypasses the
    // admitted counters, so double service would overshoot)
    assert_eq!(fleet.in_flight(), 0);
    assert_eq!(snap.fd_queue_depth, 0);
    let admitted: u64 = snap.fd_lane_admitted.iter().sum();
    assert!(admitted > 0);
    assert_eq!(snap.fd_lane_rejected.iter().sum::<u64>(), 0);
    assert_eq!(snap.decode_tokens, admitted * output as u64);
    // both replicas did real work
    assert!(snap.fleet_served.iter().all(|&n| n > 0), "{:?}", snap.fleet_served);

    // byte-stable: an identical second run reproduces every mark and
    // the final snapshot, and the kv encoding round-trips
    let (fleet2, marks2) = run();
    assert_eq!(fleet2.snapshot().encode(), snap.encode());
    assert_eq!(marks.len(), marks2.len());
    for ((_, a), (_, b)) in marks.iter().zip(&marks2) {
        assert_eq!(a.encode(), b.encode());
    }
    let rt = MetricsSnapshot::decode(&snap.encode()).unwrap();
    assert_eq!(rt, snap);
}

#[test]
fn prop_parallel_drain_is_byte_identical_to_serial() {
    // `parallel_drain` serves replicas on threads; folding outcomes in
    // replica-index order must make it indistinguishable from the
    // serial loop — including under failover and chunked streaming.
    let mut prop = Prop::new("fleet_parallel_serial_identity");
    prop.run(4, |rng| {
        let seed = rng.next_u64();
        let chunk = if rng.below(2) == 0 { None } else { Some(1 + rng.below(2)) };
        let faults = if rng.below(2) == 0 {
            FaultPlan::none()
        } else {
            FaultPlan::fail(rng.below(2), 1 + rng.below(3))
        };
        let mut run = |parallel: bool| -> String {
            let mut fleet = Fleet::builder()
                .model("phi-sim")
                .method("dynaexq")
                .seed(seed)
                .warmup(0)
                .fleet_cfg(FleetConfig {
                    replicas: 2,
                    stream_chunk: chunk,
                    parallel_drain: parallel,
                    ..FleetConfig::default()
                })
                .faults(faults.clone())
                .build()
                .unwrap();
            fleet.run_scenario(&Scenario::steady(), 3, 16, 4).unwrap();
            fleet.snapshot().encode()
        };
        assert_eq!(run(false), run(true));
    });
}

#[test]
fn drain_and_restore_shift_traffic_between_replicas() {
    let mut fleet = Fleet::builder()
        .model("phi-sim")
        .method("dynaexq")
        .seed(3)
        .warmup(0)
        .replicas(2)
        .build()
        .unwrap();
    let mut gen = RequestGenerator::new(WorkloadProfile::text(), 7);

    // replica 0 drains: it must take no new work while out of rotation
    fleet.drain_replica(0);
    assert_eq!(fleet.snapshot().fleet_health, vec![3, 0]);
    for _ in 0..2 {
        let now = fleet.now();
        for _ in 0..4 {
            fleet.submit(gen.request(16, 2, now), "a", Lane::Standard).unwrap();
        }
        fleet.drain().unwrap();
    }
    let served = fleet.snapshot().fleet_served;
    assert_eq!(served[0], 0, "draining replica was routed work: {served:?}");
    assert_eq!(served[1], 8);

    // restored, it rejoins the rotation (ties break toward index 0)
    fleet.restore_replica(0);
    assert_eq!(fleet.snapshot().fleet_health, vec![0, 0]);
    for _ in 0..2 {
        let now = fleet.now();
        for _ in 0..4 {
            fleet.submit(gen.request(16, 2, now), "a", Lane::Standard).unwrap();
        }
        fleet.drain().unwrap();
    }
    let served = fleet.snapshot().fleet_served;
    assert!(served[0] > 0, "restored replica never served: {served:?}");
    assert_eq!(served.iter().sum::<u64>(), 16);
    assert_eq!(fleet.in_flight(), 0);
    assert_eq!(fleet.stats().readmitted, 0);
}
