//! Integration: the N-rung precision ladder — degenerate 2-rung
//! equivalence at the coordinator level, and per-rung byte accounting
//! staying inside the envelope across randomized workload-shift sequences
//! (the generalized C1 of DESIGN.md §8).

use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::coordinator::Coordinator;
use dynaexq::model::Precision;
use dynaexq::testutil::prop::Prop;
use dynaexq::util::XorShiftRng;

fn three_tier_preset(rng: &mut XorShiftRng) -> ModelPreset {
    let mut p = ModelPreset::qwen30b_3tier();
    // shrink the logical layer count to keep the property loop fast
    p.paper_layers = 2 + rng.below(3);
    p.n_layers = p.paper_layers;
    p
}

#[test]
fn prop_per_rung_accounting_stays_within_envelope_across_shifts() {
    // Satellite (c): random workload-shift sequences over a 3-rung ladder
    // must never push any rung past its byte cap, leak pool blocks, or
    // publish a precision off the ladder.
    let mut prop = Prop::new("ladder_envelope_shifts");
    prop.run(6, |rng| {
        let preset = three_tier_preset(rng);
        let mut cfg = ServingConfig::default();
        cfg.update_interval_ms = 1.0;
        cfg.hysteresis_margin = rng.range_f64(0.0, 0.3);
        cfg.ema_alpha = rng.range_f64(0.0, 0.9);
        cfg.n_hi_override = Some(1 + rng.below(8));
        let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default())
            .unwrap();
        assert_eq!(c.plan.n_tiers(), 3);
        let mut now = 0.0;
        // a sequence of workload phases, each with its own hot set
        for phase in 0..6 {
            let hot_base = (phase * 17) % preset.n_experts;
            let hot_width = 4 + rng.below(12);
            for _ in 0..40 {
                let layer = rng.below(preset.n_layers);
                let burst: Vec<usize> = (0..1 + rng.below(16))
                    .map(|_| {
                        if rng.below(4) == 0 {
                            rng.below(preset.n_experts) // background noise
                        } else {
                            (hot_base + rng.below(hot_width))
                                % preset.n_experts
                        }
                    })
                    .collect();
                c.record_routing(layer, &burst);
                now += rng.range_f64(0.0, 0.01);
                c.tick(now);
                // generalized C1: every rung inside its cap, every step
                assert!(c.budget.within_envelope(), "C1 violated");
                for (t, pool) in c.pools.iter().enumerate() {
                    assert!(pool.consistent(), "rung-{t} pool leaked");
                }
            }
            // let the phase's migrations land before the next shift
            now += 1.0;
            c.tick(now);
            c.pipeline.wait_staged();
        }
        // liveness + final accounting: all transitions publish, residency
        // counts cover every expert exactly once, caps still hold
        for i in 0..12 {
            now += 1e3 * (i + 1) as f64;
            c.tick(now);
            c.pipeline.wait_staged();
        }
        c.tick(now + 1e6);
        assert_eq!(c.pipeline.inflight_count(), 0, "pipeline stuck");
        assert!(c.budget.within_envelope());
        let counts = c.handles.tier_counts();
        assert_eq!(counts.len(), 3);
        assert_eq!(
            counts.iter().sum::<usize>(),
            preset.n_layers_logical() * preset.n_experts,
        );
        // per-layer occupancy above each boundary respects the cumulative
        // capacity the plan derived
        let cum = c.plan.cumulative_capacity();
        for l in 0..preset.n_layers_logical() {
            let snap = c.handles.tier_snapshot(l);
            for (t, &cap) in cum.iter().enumerate() {
                let occ = snap.iter().filter(|&&x| x <= t).count();
                assert!(
                    occ <= cap,
                    "layer {l} boundary {t}: {occ} experts above it, cap {cap}"
                );
            }
        }
    });
}

#[test]
fn two_rung_ladder_is_behavior_identical_to_binary_coordinator() {
    // The degenerate case: drive the same deterministic trace through a
    // 2-rung coordinator and assert the exact residency the original
    // binary hi/lo implementation converged to (mirrors
    // coordinator::tests::workload_shift_swaps_hot_set).
    let mut cfg = ServingConfig::default();
    cfg.hysteresis_margin = 0.0;
    cfg.ema_alpha = 0.0;
    cfg.max_inflight_promotions = 1024;
    cfg.n_hi_override = Some(2);
    let preset = ModelPreset::phi_sim();
    let c =
        Coordinator::new(&preset, &cfg, &DeviceConfig::default()).unwrap();

    for _ in 0..50 {
        c.record_routing(0, &[0, 1]);
    }
    c.tick(0.1);
    c.pipeline.wait_staged();
    c.tick(10.0);
    assert_eq!(c.resolve(0, 0), Precision::Fp16);
    assert_eq!(c.resolve(0, 1), Precision::Fp16);
    assert_eq!(c.resolve_tier(0, 0), 0);

    for step in 0..20 {
        for _ in 0..50 {
            c.record_routing(0, &[8, 9]);
        }
        c.tick(10.0 + step as f64);
        c.pipeline.wait_staged();
    }
    c.tick(1e4);
    assert_eq!(c.resolve(0, 8), Precision::Fp16);
    assert_eq!(c.resolve(0, 9), Precision::Fp16);
    assert_eq!(c.resolve(0, 0), Precision::Int4);
    assert_eq!(c.resolve(0, 1), Precision::Int4);
    // the 2-rung residency table knows exactly two rungs
    assert_eq!(c.handles.tier_counts().len(), 2);
    assert!(c.budget.within_envelope());
}
