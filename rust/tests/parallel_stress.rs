//! Threaded stress suite for the concurrent hot path (DESIGN.md §13).
//!
//! Three contracts, each driven with real `std::thread` producers:
//!
//! 1. **Shard-merge byte-identity** — routing selections recorded through
//!    [`HotnessShards`] from racing threads merge into counters (and,
//!    after the EMA fold, scores) that are bit-equal to the serial
//!    single-lock recording path, for any producer interleaving.
//! 2. **Concurrent tick determinism** — [`DeviceGroup::tick`]'s scoped
//!    parallel device walk produces the same merged report and the same
//!    residency trajectory as [`DeviceGroup::tick_serial`].
//! 3. **Front-door admission under contention** — concurrent
//!    `FrontDoor::submit` producers never overshoot the queue bound or a
//!    tenant's hard limit, and every offered request lands in exactly one
//!    of admitted/rejected.
//! 4. **Asymmetric-drift attribution** — the group report's OR-merged
//!    `drift_detected` flag cannot say which shard drifted;
//!    `DeviceGroup::device_drift_stats` must attribute a one-shard swap
//!    to that device alone, without the quiet shards masking it.
//! 5. **Rank discipline under contention** — with the lock-order audit
//!    armed (debug builds, or `--features lock-audit` in release: CI's
//!    `parallel-stress` job), racing the lock-heaviest paths — group
//!    ticks, front-door admission, fleet failover — must never trip a
//!    rank-violation panic, and every thread must unwind to an empty
//!    held-rank stack.
//!
//! CI's `parallel-stress` job elevates the case counts through
//! `PARALLEL_STRESS_ITERS`; the default keeps the suite fast enough for
//! the tier-1 test run.

use std::sync::atomic::{AtomicU64, Ordering};

use dynaexq::config::frontdoor::{
    FrontDoorConfig, Lane, LimitAction, TenantLimits,
};
use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::coordinator::{
    Coordinator, DeviceGroup, HotnessEstimator, HotnessShards,
};
use dynaexq::serving::frontdoor::FrontDoor;
use dynaexq::testutil::prop::Prop;
use dynaexq::workload::{RequestGenerator, WorkloadProfile};

/// Randomized case count, scaled up by CI's `parallel-stress` job.
fn stress_cases(default: u32) -> u32 {
    std::env::var("PARALLEL_STRESS_ITERS")
        .ok()
        .and_then(|s| s.parse::<u32>().ok())
        .map(|n| n.max(1))
        .unwrap_or(default)
}

#[test]
fn prop_threaded_shard_merge_is_byte_identical_to_serial() {
    // Pre-generate every thread's selection script on the driver thread
    // (the union is then well-defined), record the union serially into a
    // reference estimator, race the scripts through the sharded front,
    // and demand bit-equality of counts and post-fold scores.
    let mut prop = Prop::new("parallel_shard_merge_byte_identity");
    prop.run(stress_cases(10), |rng| {
        let n_layers = 1 + rng.below(4);
        let n_experts = 2 + rng.below(30);
        let n_threads = 2 + rng.below(7);
        let alpha = rng.range_f64(0.0, 0.95);
        let scripts: Vec<Vec<(usize, usize)>> = (0..n_threads)
            .map(|_| {
                (0..rng.below(400))
                    .map(|_| (rng.below(n_layers), rng.below(n_experts)))
                    .collect()
            })
            .collect();
        let total: u64 = scripts.iter().map(|s| s.len() as u64).sum();

        // serial single-lock reference: same selections, one thread
        let mut reference = HotnessEstimator::new(n_layers, n_experts, alpha);
        for script in &scripts {
            for &(l, e) in script {
                reference.record(l, e);
            }
        }

        let shards = HotnessShards::new(n_layers, n_experts);
        std::thread::scope(|s| {
            for script in &scripts {
                s.spawn(|| {
                    let slot = shards.shard_for_current_thread();
                    for &(l, e) in script {
                        shards.record(slot, l, e);
                    }
                });
            }
        });
        assert_eq!(shards.pending(), total, "recordings lost in the race");

        let mut merged = HotnessEstimator::new(n_layers, n_experts, alpha);
        shards.merge_into(&mut merged);
        assert_eq!(shards.pending(), 0, "merge must drain every shard");
        for l in 0..n_layers {
            assert_eq!(
                merged.layer_counts(l),
                reference.layer_counts(l),
                "layer {l} counts diverged under {n_threads} producers"
            );
        }
        // the EMA fold over equal u64 counts is bit-equal too
        merged.end_interval();
        reference.end_interval();
        for l in 0..n_layers {
            assert_eq!(merged.layer_scores(l), reference.layer_scores(l));
        }
    });
}

#[test]
fn threaded_recording_respects_iteration_boundary_visibility() {
    // The PR 5 contract, now with racing producers: selections recorded
    // from any thread stay invisible to policy until the next tick
    // boundary, then all of them land at once.
    let preset = ModelPreset::phi_sim();
    let mut cfg = ServingConfig::default();
    cfg.update_interval_ms = 1.0;
    cfg.ema_alpha = 0.0;
    let coord =
        Coordinator::new(&preset, &cfg, &DeviceConfig::default()).unwrap();
    let per_thread = 200u64;
    let n_threads = 4u64;
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| {
                for _ in 0..per_thread {
                    coord.record_routing(0, &[0, 1]);
                }
            });
        }
    });
    assert_eq!(coord.pending_routing(), n_threads * per_thread * 2);
    assert_eq!(
        coord.hotness_score(0, 0),
        0.0,
        "recordings visible before the boundary"
    );
    coord.tick(1.0);
    assert_eq!(coord.pending_routing(), 0);
    assert_eq!(coord.hotness_score(0, 0), (n_threads * per_thread) as f64);
    assert_eq!(coord.hotness_score(0, 1), (n_threads * per_thread) as f64);
}

#[test]
fn prop_concurrent_group_tick_merges_reports_deterministically() {
    // Twin groups, identical traffic: the scoped-thread tick must match
    // the serial reference walk on the merged report and the residency
    // table at every step. (The in-module group property covers the
    // fine-grained walk; this integration copy runs under the elevated
    // CI iteration count and a wider device range.)
    let mut prop = Prop::new("parallel_group_tick_determinism");
    prop.run(stress_cases(4), |rng| {
        let mut preset = ModelPreset::phi_sim();
        preset.paper_layers = 2 + rng.below(2);
        preset.n_layers = preset.paper_layers;
        let mut cfg = ServingConfig::default();
        cfg.update_interval_ms = 1.0;
        cfg.hysteresis_margin = rng.range_f64(0.0, 0.3);
        cfg.ema_alpha = rng.range_f64(0.0, 0.9);
        let dev = DeviceConfig::default();
        let n_dev = 2 + rng.below(3);
        let par = DeviceGroup::new(&preset, &cfg, &dev, n_dev).unwrap();
        let ser = DeviceGroup::new(&preset, &cfg, &dev, n_dev).unwrap();
        let mut now = 0.0;
        for _ in 0..25 {
            let layer = rng.below(preset.n_layers);
            let hot: Vec<usize> = (0..1 + rng.below(6))
                .map(|_| rng.below(preset.n_experts))
                .collect();
            for _ in 0..10 {
                par.record_routing(layer, &hot);
                ser.record_routing(layer, &hot);
            }
            par.wait_staged();
            ser.wait_staged();
            now += rng.range_f64(0.001, 0.01);
            let rp = par.tick(now);
            let rs = ser.tick_serial(now);
            assert_eq!(rp.ran, rs.ran, "ran flags diverged at t={now}");
            assert_eq!(rp.promotions_submitted, rs.promotions_submitted);
            assert_eq!(rp.demotions_submitted, rs.demotions_submitted);
            assert_eq!(rp.deferred, rs.deferred);
            assert_eq!(rp.drift_detected, rs.drift_detected);
        }
        for l in 0..preset.n_layers {
            for e in 0..preset.n_experts {
                assert_eq!(
                    par.resolve_tier(l, e),
                    ser.resolve_tier(l, e),
                    "layer {l} expert {e} diverged"
                );
            }
        }
        assert_eq!(par.tier_counts(), ser.tier_counts());
        assert_eq!(par.migrated_bytes(), ser.migrated_bytes());
        assert!(par.within_envelope() && ser.within_envelope());
        assert!(par.pools_consistent() && ser.pools_consistent());
    });
}

#[test]
fn asymmetric_shard_drift_is_attributable_despite_or_merge() {
    // The group report OR-merges `drift_detected` and `drift_stats()`
    // sums across devices — neither can say WHICH shard drifted. Drive a
    // 3-device group where only device 2's expert slice swaps its hot
    // set: the merged flag must still fire (no masking by the two quiet
    // devices), and `device_drift_stats()` must attribute every event to
    // device 2 alone.
    let mut cfg = ServingConfig::default();
    cfg.adaptive_alpha = true;
    cfg.ema_alpha = 0.95;
    cfg.update_interval_ms = 1.0;
    cfg.drift.window = 2;
    let preset = ModelPreset::phi_sim().executed_scale();
    let dev = DeviceConfig::default();
    let g = DeviceGroup::new(&preset, &cfg, &dev, 3).unwrap();
    // striped placement: expert e lives on device e % 3, so 2 and 14
    // are both device-2 experts and 0/1 pin devices 0/1 steady
    assert_eq!(g.device_of(0, 2), 2);
    assert_eq!(g.device_of(0, 14), 2);

    let mut now = 0.0;
    let mut drive = |hot: &[usize]| {
        for _ in 0..60 {
            g.record_routing(0, hot);
        }
        g.wait_staged();
        now += 0.0011;
        g.tick(now)
    };
    // steady phase: every device sees a stable local distribution
    for _ in 0..8 {
        let r = drive(&[0, 1, 2]);
        assert!(!r.drift_detected, "false trigger on steady traffic");
    }
    assert_eq!(g.device_drift_stats(), vec![(0, 0); 3]);

    // flip only device 2's slice (2 → 14); devices 0/1 are untouched
    let mut fired = false;
    for _ in 0..(2 * cfg.drift.window + 1) {
        fired |= drive(&[0, 1, 14]).drift_detected;
        if fired {
            break;
        }
    }
    assert!(fired, "the quiet shards must not mask device 2's swap");
    // settle the recovery window so per-device stats are stable
    for _ in 0..cfg.drift.recovery_intervals {
        drive(&[0, 1, 14]);
    }
    let per = g.device_drift_stats();
    assert_eq!(per[0], (0, 0), "device 0 never drifted: {per:?}");
    assert_eq!(per[1], (0, 0), "device 1 never drifted: {per:?}");
    assert!(per[2].0 >= 1, "device 2's swap unattributed: {per:?}");
    // the group-level sums are exactly device 2's line — the accessor
    // adds attribution, it does not change the totals
    assert_eq!(g.drift_stats(), per[2]);
}

#[test]
fn prop_concurrent_submit_holds_bounds_and_conservation() {
    // Racing producers against one bounded door: whatever the
    // interleaving, (a) every offered request resolves to exactly one of
    // admitted/rejected, (b) the queue never exceeds its capacity, and
    // (c) no tenant overshoots its hard limit.
    let mut prop = Prop::new("parallel_frontdoor_admission_bounds");
    prop.run(stress_cases(8), |rng| {
        let queue_capacity = 1 + rng.below(12);
        let hard = 1 + rng.below(6);
        let n_threads = 2 + rng.below(5);
        let per_thread = 5 + rng.below(30);
        let n_tenants = 1 + rng.below(3);
        let mut cfg = FrontDoorConfig::unbounded();
        cfg.queue_capacity = queue_capacity;
        cfg.tenant_limits = TenantLimits {
            soft_limit: hard,
            soft_action: LimitAction::Warn,
            hard_limit: hard,
        };
        let fd = FrontDoor::new(cfg).unwrap();
        // pre-generate each producer's requests so the offered set is
        // interleaving-independent
        let mut gen =
            RequestGenerator::new(WorkloadProfile::text(), rng.next_u64());
        let scripts: Vec<Vec<_>> = (0..n_threads)
            .map(|t| {
                (0..per_thread)
                    .map(|i| {
                        let req = gen.request(8, 2, 0.0);
                        let tenant = (t + i) % n_tenants;
                        let lane = Lane::ALL[rng.below(3)];
                        (req, tenant, lane)
                    })
                    .collect()
            })
            .collect();
        let offered = (n_threads * per_thread) as u64;
        let rejected = AtomicU64::new(0);
        // each producer reports which tenant every admission belonged to
        let admitted_by: Vec<Vec<usize>> = std::thread::scope(|s| {
            let handles: Vec<_> = scripts
                .iter()
                .map(|script| {
                    s.spawn(|| {
                        let mut mine = Vec::new();
                        for (req, tenant, lane) in script.iter().cloned() {
                            let name = format!("t{tenant}");
                            match fd.submit(req, &name, lane, 0.0) {
                                Ok(()) => mine.push(tenant),
                                Err(_) => {
                                    rejected.fetch_add(1, Ordering::Relaxed); // relaxed-ok: test counter
                                }
                            }
                        }
                        mine
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("producer panicked"))
                .collect()
        });
        let admitted: u64 =
            admitted_by.iter().map(|v| v.len() as u64).sum();
        let rejected = rejected.load(Ordering::Relaxed); // relaxed-ok: read after join
        assert_eq!(admitted + rejected, offered, "requests lost in the race");
        assert_eq!(fd.depth() as u64, admitted, "queue depth out of sync");
        assert!(
            admitted as usize <= queue_capacity,
            "queue bound overshot: {admitted} > {queue_capacity}"
        );
        // no tenant overshoots its hard limit: admissions are counted
        // under the queue lock, so the occupancy check is never stale
        let mut per_tenant = vec![0u64; n_tenants];
        for &t in admitted_by.iter().flatten() {
            per_tenant[t] += 1;
        }
        for (t, &n) in per_tenant.iter().enumerate() {
            assert!(
                n <= hard as u64,
                "tenant t{t} overshot its hard limit: {n} > {hard}"
            );
        }
        // the door's own counters saw the same split
        let stats_admitted: u64 = fd.stats().lane_admitted().iter().sum();
        let stats_rejected: u64 = fd.stats().lane_rejected().iter().sum();
        assert_eq!(stats_admitted, admitted);
        assert_eq!(stats_rejected, rejected);
        // the queue drains clean through the scheduler path
        let (_, reqs) = fd.take_scheduled();
        assert_eq!(reqs.len() as u64, admitted);
        assert_eq!(fd.depth(), 0);
    });
}

#[test]
fn concurrent_tick_submit_failover_holds_rank_discipline() {
    // The lock-audit acceptance case (DESIGN.md §16): every OrderedMutex/
    // OrderedRwLock acquisition panics on a rank inversion when the audit
    // is armed, so it suffices to race the three lock-heaviest paths and
    // demand that (a) no thread panics and (b) every participant unwinds
    // to an empty held-rank stack. The three paths cover the full rank
    // table: group ticks walk UpdateClock → Hotness → QosScores → Drift →
    // PipelineInner → HandleEntry/Pool, front-door traffic walks
    // FrontDoorTenants → FrontDoorQueue → LaneTtft, and the failover
    // fleet exercises both through its own door and replica engines.
    use dynaexq::config::fleet::FleetConfig;
    use dynaexq::serving::fleet::Fleet;
    use dynaexq::util::lockorder::held_ranks;
    use dynaexq::workload::{FaultPlan, Scenario};

    let preset = ModelPreset::phi_sim();
    let (n_layers, n_experts) = (preset.n_layers, preset.n_experts);
    let mut cfg = ServingConfig::default();
    cfg.update_interval_ms = 1.0;
    cfg.adaptive_alpha = true; // arms the Drift rank inside the tick walk
    let group =
        DeviceGroup::new(&preset, &cfg, &DeviceConfig::default(), 2).unwrap();

    let mut fd_cfg = FrontDoorConfig::unbounded();
    fd_cfg.queue_capacity = 64;
    let fd = FrontDoor::new(fd_cfg).unwrap();

    let rounds = stress_cases(10) as usize;
    std::thread::scope(|s| {
        // two producers tick the shared group on interleaved time bases
        for t in 0..2u64 {
            let group = &group;
            s.spawn(move || {
                for i in 0..rounds * 20 {
                    group.record_routing(
                        i % n_layers,
                        &[i % n_experts, (3 * i + 1) % n_experts],
                    );
                    if i % 5 == t as usize {
                        group.wait_staged();
                        group.tick(0.0011 * (i as f64 + t as f64 / 2.0));
                    }
                }
                assert!(
                    held_ranks().is_empty(),
                    "group producer left ranks held: {:?}",
                    held_ranks()
                );
            });
        }
        // two producers hammer the shared front door; one also drains
        for t in 0..2u64 {
            let fd = &fd;
            s.spawn(move || {
                let mut gen = RequestGenerator::new(
                    WorkloadProfile::text(),
                    0xA0D17 + t,
                );
                for i in 0..rounds * 20 {
                    let req = gen.request(8, 2, 0.0);
                    let lane = Lane::ALL[i % 3];
                    let _ = fd.submit(req, &format!("t{}", i % 3), lane, 0.0);
                    if t == 0 && i % 7 == 0 {
                        let _ = fd.take_scheduled();
                    }
                }
                assert!(
                    held_ranks().is_empty(),
                    "door producer left ranks held: {:?}",
                    held_ranks()
                );
            });
        }
        // main thread: a 2-replica fleet through a mid-stream failover
        let mut fleet = Fleet::builder()
            .model("phi-sim")
            .method("dynaexq")
            .seed(0xD15C)
            .warmup(0)
            .fleet_cfg(FleetConfig {
                replicas: 2,
                stream_chunk: Some(1),
                ..FleetConfig::default()
            })
            .build()
            .unwrap();
        let sc = Scenario::steady().with_faults(FaultPlan::fail(0, 2));
        fleet.run_scenario(&sc, 4, 16, 4).unwrap();
        assert!(fleet.stats().failovers >= 1, "fault script never fired");
    });
    assert!(
        held_ranks().is_empty(),
        "driver left ranks held: {:?}",
        held_ranks()
    );
    // drain what the races left behind so the door ends consistent
    let (_, reqs) = fd.take_scheduled();
    assert!(reqs.len() <= 64);
    assert_eq!(fd.depth(), 0);
}
