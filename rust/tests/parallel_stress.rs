//! Threaded stress suite for the concurrent hot path (DESIGN.md §13).
//!
//! Three contracts, each driven with real `std::thread` producers:
//!
//! 1. **Shard-merge byte-identity** — routing selections recorded through
//!    [`HotnessShards`] from racing threads merge into counters (and,
//!    after the EMA fold, scores) that are bit-equal to the serial
//!    single-lock recording path, for any producer interleaving.
//! 2. **Concurrent tick determinism** — [`DeviceGroup::tick`]'s scoped
//!    parallel device walk produces the same merged report and the same
//!    residency trajectory as [`DeviceGroup::tick_serial`].
//! 3. **Front-door admission under contention** — concurrent
//!    `FrontDoor::submit` producers never overshoot the queue bound or a
//!    tenant's hard limit, and every offered request lands in exactly one
//!    of admitted/rejected.
//! 4. **Asymmetric-drift attribution** — the group report's OR-merged
//!    `drift_detected` flag cannot say which shard drifted;
//!    `DeviceGroup::device_drift_stats` must attribute a one-shard swap
//!    to that device alone, without the quiet shards masking it.
//!
//! CI's `parallel-stress` job elevates the case counts through
//! `PARALLEL_STRESS_ITERS`; the default keeps the suite fast enough for
//! the tier-1 test run.

use std::sync::atomic::{AtomicU64, Ordering};

use dynaexq::config::frontdoor::{
    FrontDoorConfig, Lane, LimitAction, TenantLimits,
};
use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::coordinator::{
    Coordinator, DeviceGroup, HotnessEstimator, HotnessShards,
};
use dynaexq::serving::frontdoor::FrontDoor;
use dynaexq::testutil::prop::Prop;
use dynaexq::workload::{RequestGenerator, WorkloadProfile};

/// Randomized case count, scaled up by CI's `parallel-stress` job.
fn stress_cases(default: u32) -> u32 {
    std::env::var("PARALLEL_STRESS_ITERS")
        .ok()
        .and_then(|s| s.parse::<u32>().ok())
        .map(|n| n.max(1))
        .unwrap_or(default)
}

#[test]
fn prop_threaded_shard_merge_is_byte_identical_to_serial() {
    // Pre-generate every thread's selection script on the driver thread
    // (the union is then well-defined), record the union serially into a
    // reference estimator, race the scripts through the sharded front,
    // and demand bit-equality of counts and post-fold scores.
    let mut prop = Prop::new("parallel_shard_merge_byte_identity");
    prop.run(stress_cases(10), |rng| {
        let n_layers = 1 + rng.below(4);
        let n_experts = 2 + rng.below(30);
        let n_threads = 2 + rng.below(7);
        let alpha = rng.range_f64(0.0, 0.95);
        let scripts: Vec<Vec<(usize, usize)>> = (0..n_threads)
            .map(|_| {
                (0..rng.below(400))
                    .map(|_| (rng.below(n_layers), rng.below(n_experts)))
                    .collect()
            })
            .collect();
        let total: u64 = scripts.iter().map(|s| s.len() as u64).sum();

        // serial single-lock reference: same selections, one thread
        let mut reference = HotnessEstimator::new(n_layers, n_experts, alpha);
        for script in &scripts {
            for &(l, e) in script {
                reference.record(l, e);
            }
        }

        let shards = HotnessShards::new(n_layers, n_experts);
        std::thread::scope(|s| {
            for script in &scripts {
                s.spawn(|| {
                    let slot = shards.shard_for_current_thread();
                    for &(l, e) in script {
                        shards.record(slot, l, e);
                    }
                });
            }
        });
        assert_eq!(shards.pending(), total, "recordings lost in the race");

        let mut merged = HotnessEstimator::new(n_layers, n_experts, alpha);
        shards.merge_into(&mut merged);
        assert_eq!(shards.pending(), 0, "merge must drain every shard");
        for l in 0..n_layers {
            assert_eq!(
                merged.layer_counts(l),
                reference.layer_counts(l),
                "layer {l} counts diverged under {n_threads} producers"
            );
        }
        // the EMA fold over equal u64 counts is bit-equal too
        merged.end_interval();
        reference.end_interval();
        for l in 0..n_layers {
            assert_eq!(merged.layer_scores(l), reference.layer_scores(l));
        }
    });
}

#[test]
fn threaded_recording_respects_iteration_boundary_visibility() {
    // The PR 5 contract, now with racing producers: selections recorded
    // from any thread stay invisible to policy until the next tick
    // boundary, then all of them land at once.
    let preset = ModelPreset::phi_sim();
    let mut cfg = ServingConfig::default();
    cfg.update_interval_ms = 1.0;
    cfg.ema_alpha = 0.0;
    let coord =
        Coordinator::new(&preset, &cfg, &DeviceConfig::default()).unwrap();
    let per_thread = 200u64;
    let n_threads = 4u64;
    std::thread::scope(|s| {
        for _ in 0..n_threads {
            s.spawn(|| {
                for _ in 0..per_thread {
                    coord.record_routing(0, &[0, 1]);
                }
            });
        }
    });
    assert_eq!(coord.pending_routing(), n_threads * per_thread * 2);
    assert_eq!(
        coord.hotness_score(0, 0),
        0.0,
        "recordings visible before the boundary"
    );
    coord.tick(1.0);
    assert_eq!(coord.pending_routing(), 0);
    assert_eq!(coord.hotness_score(0, 0), (n_threads * per_thread) as f64);
    assert_eq!(coord.hotness_score(0, 1), (n_threads * per_thread) as f64);
}

#[test]
fn prop_concurrent_group_tick_merges_reports_deterministically() {
    // Twin groups, identical traffic: the scoped-thread tick must match
    // the serial reference walk on the merged report and the residency
    // table at every step. (The in-module group property covers the
    // fine-grained walk; this integration copy runs under the elevated
    // CI iteration count and a wider device range.)
    let mut prop = Prop::new("parallel_group_tick_determinism");
    prop.run(stress_cases(4), |rng| {
        let mut preset = ModelPreset::phi_sim();
        preset.paper_layers = 2 + rng.below(2);
        preset.n_layers = preset.paper_layers;
        let mut cfg = ServingConfig::default();
        cfg.update_interval_ms = 1.0;
        cfg.hysteresis_margin = rng.range_f64(0.0, 0.3);
        cfg.ema_alpha = rng.range_f64(0.0, 0.9);
        let dev = DeviceConfig::default();
        let n_dev = 2 + rng.below(3);
        let par = DeviceGroup::new(&preset, &cfg, &dev, n_dev).unwrap();
        let ser = DeviceGroup::new(&preset, &cfg, &dev, n_dev).unwrap();
        let mut now = 0.0;
        for _ in 0..25 {
            let layer = rng.below(preset.n_layers);
            let hot: Vec<usize> = (0..1 + rng.below(6))
                .map(|_| rng.below(preset.n_experts))
                .collect();
            for _ in 0..10 {
                par.record_routing(layer, &hot);
                ser.record_routing(layer, &hot);
            }
            par.wait_staged();
            ser.wait_staged();
            now += rng.range_f64(0.001, 0.01);
            let rp = par.tick(now);
            let rs = ser.tick_serial(now);
            assert_eq!(rp.ran, rs.ran, "ran flags diverged at t={now}");
            assert_eq!(rp.promotions_submitted, rs.promotions_submitted);
            assert_eq!(rp.demotions_submitted, rs.demotions_submitted);
            assert_eq!(rp.deferred, rs.deferred);
            assert_eq!(rp.drift_detected, rs.drift_detected);
        }
        for l in 0..preset.n_layers {
            for e in 0..preset.n_experts {
                assert_eq!(
                    par.resolve_tier(l, e),
                    ser.resolve_tier(l, e),
                    "layer {l} expert {e} diverged"
                );
            }
        }
        assert_eq!(par.tier_counts(), ser.tier_counts());
        assert_eq!(par.migrated_bytes(), ser.migrated_bytes());
        assert!(par.within_envelope() && ser.within_envelope());
        assert!(par.pools_consistent() && ser.pools_consistent());
    });
}

#[test]
fn asymmetric_shard_drift_is_attributable_despite_or_merge() {
    // The group report OR-merges `drift_detected` and `drift_stats()`
    // sums across devices — neither can say WHICH shard drifted. Drive a
    // 3-device group where only device 2's expert slice swaps its hot
    // set: the merged flag must still fire (no masking by the two quiet
    // devices), and `device_drift_stats()` must attribute every event to
    // device 2 alone.
    let mut cfg = ServingConfig::default();
    cfg.adaptive_alpha = true;
    cfg.ema_alpha = 0.95;
    cfg.update_interval_ms = 1.0;
    cfg.drift.window = 2;
    let preset = ModelPreset::phi_sim().executed_scale();
    let dev = DeviceConfig::default();
    let g = DeviceGroup::new(&preset, &cfg, &dev, 3).unwrap();
    // striped placement: expert e lives on device e % 3, so 2 and 14
    // are both device-2 experts and 0/1 pin devices 0/1 steady
    assert_eq!(g.device_of(0, 2), 2);
    assert_eq!(g.device_of(0, 14), 2);

    let mut now = 0.0;
    let mut drive = |hot: &[usize]| {
        for _ in 0..60 {
            g.record_routing(0, hot);
        }
        g.wait_staged();
        now += 0.0011;
        g.tick(now)
    };
    // steady phase: every device sees a stable local distribution
    for _ in 0..8 {
        let r = drive(&[0, 1, 2]);
        assert!(!r.drift_detected, "false trigger on steady traffic");
    }
    assert_eq!(g.device_drift_stats(), vec![(0, 0); 3]);

    // flip only device 2's slice (2 → 14); devices 0/1 are untouched
    let mut fired = false;
    for _ in 0..(2 * cfg.drift.window + 1) {
        fired |= drive(&[0, 1, 14]).drift_detected;
        if fired {
            break;
        }
    }
    assert!(fired, "the quiet shards must not mask device 2's swap");
    // settle the recovery window so per-device stats are stable
    for _ in 0..cfg.drift.recovery_intervals {
        drive(&[0, 1, 14]);
    }
    let per = g.device_drift_stats();
    assert_eq!(per[0], (0, 0), "device 0 never drifted: {per:?}");
    assert_eq!(per[1], (0, 0), "device 1 never drifted: {per:?}");
    assert!(per[2].0 >= 1, "device 2's swap unattributed: {per:?}");
    // the group-level sums are exactly device 2's line — the accessor
    // adds attribution, it does not change the totals
    assert_eq!(g.drift_stats(), per[2]);
}

#[test]
fn prop_concurrent_submit_holds_bounds_and_conservation() {
    // Racing producers against one bounded door: whatever the
    // interleaving, (a) every offered request resolves to exactly one of
    // admitted/rejected, (b) the queue never exceeds its capacity, and
    // (c) no tenant overshoots its hard limit.
    let mut prop = Prop::new("parallel_frontdoor_admission_bounds");
    prop.run(stress_cases(8), |rng| {
        let queue_capacity = 1 + rng.below(12);
        let hard = 1 + rng.below(6);
        let n_threads = 2 + rng.below(5);
        let per_thread = 5 + rng.below(30);
        let n_tenants = 1 + rng.below(3);
        let mut cfg = FrontDoorConfig::unbounded();
        cfg.queue_capacity = queue_capacity;
        cfg.tenant_limits = TenantLimits {
            soft_limit: hard,
            soft_action: LimitAction::Warn,
            hard_limit: hard,
        };
        let fd = FrontDoor::new(cfg).unwrap();
        // pre-generate each producer's requests so the offered set is
        // interleaving-independent
        let mut gen =
            RequestGenerator::new(WorkloadProfile::text(), rng.next_u64());
        let scripts: Vec<Vec<_>> = (0..n_threads)
            .map(|t| {
                (0..per_thread)
                    .map(|i| {
                        let req = gen.request(8, 2, 0.0);
                        let tenant = (t + i) % n_tenants;
                        let lane = Lane::ALL[rng.below(3)];
                        (req, tenant, lane)
                    })
                    .collect()
            })
            .collect();
        let offered = (n_threads * per_thread) as u64;
        let rejected = AtomicU64::new(0);
        // each producer reports which tenant every admission belonged to
        let admitted_by: Vec<Vec<usize>> = std::thread::scope(|s| {
            let handles: Vec<_> = scripts
                .iter()
                .map(|script| {
                    s.spawn(|| {
                        let mut mine = Vec::new();
                        for (req, tenant, lane) in script.iter().cloned() {
                            let name = format!("t{tenant}");
                            match fd.submit(req, &name, lane, 0.0) {
                                Ok(()) => mine.push(tenant),
                                Err(_) => {
                                    rejected.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                        mine
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("producer panicked"))
                .collect()
        });
        let admitted: u64 =
            admitted_by.iter().map(|v| v.len() as u64).sum();
        let rejected = rejected.load(Ordering::Relaxed);
        assert_eq!(admitted + rejected, offered, "requests lost in the race");
        assert_eq!(fd.depth() as u64, admitted, "queue depth out of sync");
        assert!(
            admitted as usize <= queue_capacity,
            "queue bound overshot: {admitted} > {queue_capacity}"
        );
        // no tenant overshoots its hard limit: admissions are counted
        // under the queue lock, so the occupancy check is never stale
        let mut per_tenant = vec![0u64; n_tenants];
        for &t in admitted_by.iter().flatten() {
            per_tenant[t] += 1;
        }
        for (t, &n) in per_tenant.iter().enumerate() {
            assert!(
                n <= hard as u64,
                "tenant t{t} overshot its hard limit: {n} > {hard}"
            );
        }
        // the door's own counters saw the same split
        let stats_admitted: u64 = fd.stats().lane_admitted().iter().sum();
        let stats_rejected: u64 = fd.stats().lane_rejected().iter().sum();
        assert_eq!(stats_admitted, admitted);
        assert_eq!(stats_rejected, rejected);
        // the queue drains clean through the scheduler path
        let (_, reqs) = fd.take_scheduled();
        assert_eq!(reqs.len() as u64, admitted);
        assert_eq!(fd.depth(), 0);
    });
}
