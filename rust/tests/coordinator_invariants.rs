//! Integration: system-level invariants of the coordinator under sustained
//! randomized serving — the properties §3 declares non-negotiable:
//!
//! (C1) budget feasibility at every instant,
//! (C2) the forward path never blocks,
//! (C3) a handle always resolves to a complete version,
//! plus pool conservation and pipeline liveness.

use std::sync::Arc;

use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::coordinator::{Coordinator, DeviceGroup};
use dynaexq::model::Precision;
use dynaexq::serving::backend::DynaExqShardedBackend;
use dynaexq::serving::engine::{Engine, EngineConfig};
use dynaexq::testutil::prop::Prop;
use dynaexq::util::XorShiftRng;
use dynaexq::workload::WorkloadProfile;

fn random_preset(rng: &mut XorShiftRng) -> ModelPreset {
    let mut p = match rng.below(3) {
        0 => ModelPreset::qwen30b_sim(),
        1 => ModelPreset::qwen80b_sim(),
        _ => ModelPreset::phi_sim(),
    };
    // shrink the logical layer count to keep the property loop fast
    p.paper_layers = 2 + rng.below(3);
    p.n_layers = p.paper_layers;
    p
}

#[test]
fn prop_budget_envelope_never_violated_under_chaotic_traffic() {
    let mut prop = Prop::new("coord_envelope_chaos");
    prop.run(8, |rng| {
        let preset = random_preset(rng);
        let mut cfg = ServingConfig::default();
        cfg.update_interval_ms = 1.0;
        cfg.hysteresis_margin = rng.range_f64(0.0, 0.3);
        cfg.ema_alpha = rng.range_f64(0.0, 0.9);
        cfg.n_hi_override = Some(1 + rng.below(preset.n_experts.min(16)));
        let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default())
            .unwrap();
        let mut now = 0.0;
        for _ in 0..200 {
            // chaotic routing: random layer, random experts, random burst
            let layer = rng.below(preset.n_layers);
            let burst: Vec<usize> = (0..1 + rng.below(24))
                .map(|_| rng.below(preset.n_experts))
                .collect();
            c.record_routing(layer, &burst);
            now += rng.range_f64(0.0, 0.01);
            c.tick(now);
            // invariants, every step
            assert!(c.budget.within_envelope(), "C1 violated");
            for (t, pool) in c.pools.iter().enumerate() {
                assert!(pool.consistent(), "rung-{t} pool leaked");
            }
        }
        // liveness: with traffic stopped, scores decay, the policy stops
        // submitting, and every in-flight transition publishes.
        for i in 0..12 {
            now += 1e3 * (i + 1) as f64;
            c.tick(now);
            c.pipeline.wait_staged();
        }
        c.tick(now + 1e6);
        assert_eq!(c.pipeline.inflight_count(), 0, "pipeline stuck");
        assert!(c.budget.within_envelope());
    });
}

#[test]
fn prop_sharded_group_per_device_envelopes_never_violated() {
    // C1 per device: under chaotic globally-addressed traffic, every
    // device of a 1–3-wide group stays inside its own envelope slice and
    // conserves its pools; the group drains to quiescence afterwards.
    let mut prop = Prop::new("group_envelope_chaos");
    prop.run(6, |rng| {
        let preset = random_preset(rng);
        let n_devices = 1 + rng.below(3);
        let mut cfg = ServingConfig::default();
        cfg.update_interval_ms = 1.0;
        cfg.hysteresis_margin = rng.range_f64(0.0, 0.3);
        cfg.ema_alpha = rng.range_f64(0.0, 0.9);
        cfg.n_hi_override =
            Some(n_devices + rng.below(preset.n_experts.min(16)));
        let group = DeviceGroup::new(
            &preset,
            &cfg,
            &DeviceConfig::default(),
            n_devices,
        )
        .unwrap();
        let mut now = 0.0;
        for _ in 0..150 {
            let layer = rng.below(preset.n_layers);
            let burst: Vec<usize> = (0..1 + rng.below(24))
                .map(|_| rng.below(preset.n_experts))
                .collect();
            group.record_routing(layer, &burst);
            now += rng.range_f64(0.0, 0.01);
            group.tick(now);
            for (d, c) in group.devices.iter().enumerate() {
                assert!(
                    c.budget.within_envelope(),
                    "device {d} violated its envelope"
                );
                for (t, pool) in c.pools.iter().enumerate() {
                    assert!(pool.consistent(), "device {d} rung-{t} leaked");
                }
            }
        }
        // liveness: traffic stops, every device's pipeline drains
        for i in 0..12 {
            now += 1e3 * (i + 1) as f64;
            group.tick(now);
            group.wait_staged();
        }
        group.tick(now + 1e6);
        assert_eq!(
            group.inflight_depths().iter().sum::<usize>(),
            0,
            "a device's pipeline is stuck"
        );
        assert!(group.within_envelope());
    });
}

#[test]
fn sharded_group_serves_all_models_within_per_device_envelopes() {
    // Acceptance: `dynaexq-sharded` with 2 devices serves every sim model
    // end to end, with per-device envelope/pool invariants held at every
    // round boundary and residency fully accounted afterwards.
    for preset in ModelPreset::all() {
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        let group = Arc::new(
            DeviceGroup::new(&preset, &cfg, &dev, 2)
                .unwrap_or_else(|e| panic!("{}: {e}", preset.name)),
        );
        let backend = Box::new(DynaExqShardedBackend::from_group(group.clone()));
        let w = WorkloadProfile::text();
        let mut e = Engine::new(
            &preset,
            &w,
            backend,
            &dev,
            EngineConfig { max_batch: 8, seed: 29, track_activation: false },
        );
        for _ in 0..3 {
            e.serve_uniform(&w, 4, 32, 8);
            for (d, c) in group.devices.iter().enumerate() {
                assert!(
                    c.budget.within_envelope(),
                    "{} device {d} outside its envelope",
                    preset.name
                );
                for pool in &c.pools {
                    assert!(pool.consistent(), "{} device {d}", preset.name);
                }
            }
        }
        assert_eq!(e.metrics.e2e.count(), 12, "{}", preset.name);
        assert!(e.metrics.throughput() > 0.0, "{}", preset.name);
        assert_eq!(
            e.metrics.wait.max(),
            0.0,
            "{}: sharding never stalls",
            preset.name
        );
        assert_eq!(
            group.tier_counts().iter().sum::<usize>(),
            preset.n_layers_logical() * preset.n_experts,
            "{}: every expert at exactly one rung",
            preset.name
        );
    }
}

#[test]
fn prop_resolution_always_valid_during_transitions() {
    // C3: resolve() must return one of the model's two tiers at every
    // moment, including while promotions/demotions are in flight.
    let mut prop = Prop::new("coord_resolution_valid");
    prop.run(6, |rng| {
        let preset = random_preset(rng);
        let mut cfg = ServingConfig::default();
        cfg.update_interval_ms = 0.5;
        cfg.n_hi_override = Some(2);
        let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default())
            .unwrap();
        let mut now = 0.0;
        for step in 0..150 {
            let hot = step % preset.n_experts;
            for _ in 0..20 {
                c.record_routing(0, &[hot]);
            }
            now += 0.001;
            c.tick(now);
            for e in 0..preset.n_experts.min(8) {
                let p = c.resolve(0, e);
                assert!(
                    preset.ladder.tier_of(p).is_some(),
                    "resolved precision {p:?} off the ladder"
                );
            }
        }
    });
}

#[test]
fn hi_set_size_respects_capacity_after_convergence() {
    let preset = ModelPreset::phi_sim().executed_scale();
    let mut cfg = ServingConfig::default();
    cfg.n_hi_override = Some(3);
    cfg.update_interval_ms = 1.0;
    cfg.hysteresis_margin = 0.0;
    let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default()).unwrap();
    let mut now = 0.0;
    let mut rng = XorShiftRng::new(4);
    for _ in 0..100 {
        let sel: Vec<usize> = (0..8).map(|_| rng.below(16)).collect();
        for l in 0..preset.n_layers {
            c.record_routing(l, &sel);
        }
        now += 0.002;
        c.tick(now);
        c.pipeline.wait_staged();
    }
    // quiesce: corrective demotions from the last update must publish
    // before the steady-state capacity claim is checked.
    for i in 0..12 {
        now += 1.0 * (i + 1) as f64;
        c.tick(now);
        c.pipeline.wait_staged();
    }
    for l in 0..preset.n_layers {
        let hi = c.handles.hi_set(l, Precision::Fp16);
        assert!(hi.len() <= 3, "layer {l} hi set {hi:?} exceeds capacity");
    }
}

#[test]
fn demoted_expert_storage_is_reclaimed() {
    let preset = ModelPreset::phi_sim().executed_scale();
    let mut cfg = ServingConfig::default();
    cfg.n_hi_override = Some(2);
    cfg.update_interval_ms = 1.0;
    cfg.ema_alpha = 0.0;
    cfg.hysteresis_margin = 0.0;
    let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default()).unwrap();
    let boot_hi_used = c.budget.hi_used();

    // promote {0,1}, then fully shift to {2,3} several times
    let mut now = 0.0;
    for phase in 0..6 {
        let pair = [(phase * 2) % 16, (phase * 2 + 1) % 16];
        for _ in 0..50 {
            c.record_routing(0, &pair);
        }
        for _ in 0..6 {
            now += 0.002;
            c.tick(now);
            c.pipeline.wait_staged();
        }
    }
    c.tick(now + 1e3);
    c.pipeline.wait_staged();
    c.tick(now + 2e3);
    // hi usage must be bounded by capacity × layers regardless of churn
    let cap_bytes =
        2 * c.plan.hi_expert_bytes() * preset.n_layers + boot_hi_used;
    assert!(
        c.budget.hi_used() <= cap_bytes,
        "hi usage {} exceeds churn-independent cap {}",
        c.budget.hi_used(),
        cap_bytes
    );
}
