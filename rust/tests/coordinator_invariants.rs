//! Integration: system-level invariants of the coordinator under sustained
//! randomized serving — the properties §3 declares non-negotiable:
//!
//! (C1) budget feasibility at every instant,
//! (C2) the forward path never blocks,
//! (C3) a handle always resolves to a complete version,
//! plus pool conservation and pipeline liveness.

use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::coordinator::Coordinator;
use dynaexq::model::Precision;
use dynaexq::testutil::prop::Prop;
use dynaexq::util::XorShiftRng;

fn random_preset(rng: &mut XorShiftRng) -> ModelPreset {
    let mut p = match rng.below(3) {
        0 => ModelPreset::qwen30b_sim(),
        1 => ModelPreset::qwen80b_sim(),
        _ => ModelPreset::phi_sim(),
    };
    // shrink the logical layer count to keep the property loop fast
    p.paper_layers = 2 + rng.below(3);
    p.n_layers = p.paper_layers;
    p
}

#[test]
fn prop_budget_envelope_never_violated_under_chaotic_traffic() {
    let mut prop = Prop::new("coord_envelope_chaos");
    prop.run(8, |rng| {
        let preset = random_preset(rng);
        let mut cfg = ServingConfig::default();
        cfg.update_interval_ms = 1.0;
        cfg.hysteresis_margin = rng.range_f64(0.0, 0.3);
        cfg.ema_alpha = rng.range_f64(0.0, 0.9);
        cfg.n_hi_override = Some(1 + rng.below(preset.n_experts.min(16)));
        let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default())
            .unwrap();
        let mut now = 0.0;
        for _ in 0..200 {
            // chaotic routing: random layer, random experts, random burst
            let layer = rng.below(preset.n_layers);
            let burst: Vec<usize> = (0..1 + rng.below(24))
                .map(|_| rng.below(preset.n_experts))
                .collect();
            c.record_routing(layer, &burst);
            now += rng.range_f64(0.0, 0.01);
            c.tick(now);
            // invariants, every step
            assert!(c.budget.within_envelope(), "C1 violated");
            for (t, pool) in c.pools.iter().enumerate() {
                assert!(pool.consistent(), "rung-{t} pool leaked");
            }
        }
        // liveness: with traffic stopped, scores decay, the policy stops
        // submitting, and every in-flight transition publishes.
        for i in 0..12 {
            now += 1e3 * (i + 1) as f64;
            c.tick(now);
            c.pipeline.wait_staged();
        }
        c.tick(now + 1e6);
        assert_eq!(c.pipeline.inflight_count(), 0, "pipeline stuck");
        assert!(c.budget.within_envelope());
    });
}

#[test]
fn prop_resolution_always_valid_during_transitions() {
    // C3: resolve() must return one of the model's two tiers at every
    // moment, including while promotions/demotions are in flight.
    let mut prop = Prop::new("coord_resolution_valid");
    prop.run(6, |rng| {
        let preset = random_preset(rng);
        let mut cfg = ServingConfig::default();
        cfg.update_interval_ms = 0.5;
        cfg.n_hi_override = Some(2);
        let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default())
            .unwrap();
        let mut now = 0.0;
        for step in 0..150 {
            let hot = step % preset.n_experts;
            for _ in 0..20 {
                c.record_routing(0, &[hot]);
            }
            now += 0.001;
            c.tick(now);
            for e in 0..preset.n_experts.min(8) {
                let p = c.resolve(0, e);
                assert!(
                    preset.ladder.tier_of(p).is_some(),
                    "resolved precision {p:?} off the ladder"
                );
            }
        }
    });
}

#[test]
fn hi_set_size_respects_capacity_after_convergence() {
    let preset = ModelPreset::phi_sim().executed_scale();
    let mut cfg = ServingConfig::default();
    cfg.n_hi_override = Some(3);
    cfg.update_interval_ms = 1.0;
    cfg.hysteresis_margin = 0.0;
    let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default()).unwrap();
    let mut now = 0.0;
    let mut rng = XorShiftRng::new(4);
    for _ in 0..100 {
        let sel: Vec<usize> = (0..8).map(|_| rng.below(16)).collect();
        for l in 0..preset.n_layers {
            c.record_routing(l, &sel);
        }
        now += 0.002;
        c.tick(now);
        c.pipeline.wait_staged();
    }
    // quiesce: corrective demotions from the last update must publish
    // before the steady-state capacity claim is checked.
    for i in 0..12 {
        now += 1.0 * (i + 1) as f64;
        c.tick(now);
        c.pipeline.wait_staged();
    }
    for l in 0..preset.n_layers {
        let hi = c.handles.hi_set(l, Precision::Fp16);
        assert!(hi.len() <= 3, "layer {l} hi set {hi:?} exceeds capacity");
    }
}

#[test]
fn demoted_expert_storage_is_reclaimed() {
    let preset = ModelPreset::phi_sim().executed_scale();
    let mut cfg = ServingConfig::default();
    cfg.n_hi_override = Some(2);
    cfg.update_interval_ms = 1.0;
    cfg.ema_alpha = 0.0;
    cfg.hysteresis_margin = 0.0;
    let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default()).unwrap();
    let boot_hi_used = c.budget.hi_used();

    // promote {0,1}, then fully shift to {2,3} several times
    let mut now = 0.0;
    for phase in 0..6 {
        let pair = [(phase * 2) % 16, (phase * 2 + 1) % 16];
        for _ in 0..50 {
            c.record_routing(0, &pair);
        }
        for _ in 0..6 {
            now += 0.002;
            c.tick(now);
            c.pipeline.wait_staged();
        }
    }
    c.tick(now + 1e3);
    c.pipeline.wait_staged();
    c.tick(now + 2e3);
    // hi usage must be bounded by capacity × layers regardless of churn
    let cap_bytes =
        2 * c.plan.hi_expert_bytes() * preset.n_layers + boot_hi_used;
    assert!(
        c.budget.hi_used() <= cap_bytes,
        "hi usage {} exceeds churn-independent cap {}",
        c.budget.hi_used(),
        cap_bytes
    );
}
