//! Integration: the serving front door — registry, builder validation,
//! scheduler equivalence, and snapshot round-tripping (DESIGN.md §4).

use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::serving::registry::{BackendCtx, BackendRegistry};
use dynaexq::serving::scheduler::{ClosedBatch, ContinuousBatch};
use dynaexq::serving::session::MetricsSnapshot;
use dynaexq::workload::{RequestGenerator, WorkloadProfile};
use dynaexq::ServeSession;

#[test]
fn registry_lists_all_ten_methods_plus_counting() {
    let r = BackendRegistry::with_builtins();
    let methods = r.methods();
    for m in [
        "static",
        "static-hi",
        "fp16",
        "static-map",
        "dynaexq",
        "dynaexq-3tier",
        "dynaexq-sharded",
        "dynaexq-3tier-sharded",
        "expertflow",
        "hobbit",
        "counting",
    ] {
        assert!(methods.contains(&m), "registry missing {m}");
    }
    assert_eq!(methods.len(), 11);
}

#[test]
fn unknown_method_error_enumerates_valid_names() {
    let p = ModelPreset::phi_sim();
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();
    let err = BackendRegistry::with_builtins()
        .build("moe-magic", &BackendCtx::new(&p, &cfg, &dev))
        .unwrap_err();
    for m in ["static", "dynaexq", "expertflow", "hobbit", "static-map"] {
        assert!(err.contains(m), "{err}");
    }
}

#[test]
fn every_registered_method_serves_a_small_batch() {
    let registry = BackendRegistry::with_builtins();
    for method in registry.methods() {
        let mut s = ServeSession::builder()
            .model("phi-sim")
            .method(method)
            .workload("text")
            .seed(13)
            .build()
            .unwrap_or_else(|e| panic!("build {method}: {e}"));
        s.serve_closed(2, 32, 4)
            .unwrap_or_else(|e| panic!("serve {method}: {e}"));
        let snap = s.snapshot();
        assert_eq!(snap.decode_tokens, 8, "{method}");
        assert_eq!(snap.prefill_tokens, 64, "{method}");
        assert!(snap.throughput_tok_s > 0.0, "{method}");
        assert_eq!(snap.method, method);
    }
}

#[test]
fn builder_validation_precedes_engine_construction() {
    // Unknown names enumerate the valid sets.
    let err = ServeSession::builder()
        .model("qwen-9000")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("qwen30b-sim") && err.contains("qwen80b-sim"));

    let err = ServeSession::builder()
        .workload("prose")
        .build()
        .unwrap_err()
        .to_string();
    assert!(err.contains("text") && err.contains("math"));

    // An envelope that cannot hold the all-cold model fails at build().
    let mut cfg = ServingConfig::default();
    cfg.hbm_budget_bytes = cfg.fixed_bytes; // zero slack for weights
    for method in ["dynaexq", "hobbit"] {
        let err = ServeSession::builder()
            .model("qwen30b-sim")
            .method(method)
            .serving_cfg(cfg.clone())
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("infeasible"), "{method}: {err}");
    }
}

#[test]
fn cli_reachable_hobbit_and_static_map_end_to_end() {
    // The two previously dead baselines, through the same path
    // `dynaexq serve --method ...` takes.
    for method in ["hobbit", "static-map"] {
        let report = dynaexq::experiments::helpers::serve_session(
            "qwen30b-sim",
            method,
            "text",
            2,
            32,
            4,
            1,
        )
        .unwrap_or_else(|e| panic!("{method}: {e}"));
        assert!(report.contains("tok/s"), "{method}: {report}");
        assert!(report.contains(method), "{method}: {report}");
    }
}

#[test]
fn scheduler_extraction_is_byte_identical() {
    // serve_batch / serve_stream vs explicit schedulers: identical floats
    // for a fixed seed, not merely close.
    let mk = || {
        dynaexq::experiments::helpers::engine(
            "qwen30b-sim",
            "dynaexq",
            "text",
            0xD0_0D,
            false,
        )
        .unwrap()
    };
    let reqs = || {
        let mut gen = RequestGenerator::new(WorkloadProfile::text(), 21);
        (0..6).map(|i| gen.request(32, 6, i as f64 * 0.02)).collect()
    };

    let (mut a, mut b) = (mk(), mk());
    a.serve_batch(reqs());
    b.serve_with(&mut ClosedBatch, reqs());
    assert_eq!(a.metrics.ttft.samples(), b.metrics.ttft.samples());
    assert_eq!(a.metrics.tpop.samples(), b.metrics.tpop.samples());
    assert_eq!(a.metrics.e2e.samples(), b.metrics.e2e.samples());
    assert_eq!(a.metrics.duration_s, b.metrics.duration_s);

    let (mut a, mut b) = (mk(), mk());
    a.serve_stream(reqs());
    b.serve_with(&mut ContinuousBatch::default(), reqs());
    assert_eq!(a.metrics.ttft.samples(), b.metrics.ttft.samples());
    assert_eq!(a.metrics.tpop.samples(), b.metrics.tpop.samples());
    assert_eq!(a.metrics.e2e.samples(), b.metrics.e2e.samples());
    assert_eq!(a.metrics.duration_s, b.metrics.duration_s);
}

#[test]
fn snapshot_roundtrips_through_kv_text() {
    let mut s = ServeSession::builder()
        .model("phi-sim")
        .method("dynaexq")
        .workload("math")
        .warmup(1)
        .seed(99)
        .build()
        .unwrap();
    s.serve_rounds(2, 4, 64, 8).unwrap();
    let snap = s.snapshot();
    let decoded = MetricsSnapshot::decode(&snap.encode()).unwrap();
    assert_eq!(decoded, snap);
    assert!(snap.duration_s > 0.0);
    assert!(snap.ttft_avg_s > 0.0);
}

#[test]
fn open_loop_serving_through_session() {
    let mut s = ServeSession::builder()
        .model("phi-sim")
        .method("static")
        .max_batch(2)
        .seed(5)
        .build()
        .unwrap();
    let mut gen = RequestGenerator::new(WorkloadProfile::text(), 3);
    let reqs: Vec<_> =
        (0..6).map(|i| gen.request(32, 8, i as f64 * 0.05)).collect();
    let m = s.serve_requests(reqs).unwrap();
    assert_eq!(m.e2e.count(), 6);
    // later arrivals wait for capacity → tail above median
    assert!(m.ttft.max() > m.ttft.p50());
}
