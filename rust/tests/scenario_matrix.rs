//! Scenario-matrix invariant suite (DESIGN.md §10): every registry
//! method × {steady, swap, rotation, burst} × {1, 2}-device groups,
//! asserting the standing invariants at every phase boundary —
//!
//! (I1) per-device HBM envelope never exceeded,
//! (I2) residency fully accounted: every expert published at exactly one
//!      ladder rung (the forward pass only ever resolves materialized
//!      versions),
//! (I3) tier traffic fractions sum to 1,
//! plus kv-roundtrip stability of every boundary snapshot.
//!
//! It also pins the acceptance criterion for the drift-aware hotness
//! layer: under the scripted hot-set swap, the adaptive estimator's
//! resident top-n converges to the new hot set in strictly fewer update
//! intervals than the fixed-α baseline, on both 1- and 2-device groups —
//! and writes `target/drift_recovery_report.txt` (recovery ticks per
//! method × scenario), which CI uploads next to the conformance trace.
//!
//! The class-tagged scenarios (multi-tenant, diurnal) run once more under
//! an armed QoS config (DESIGN.md §15), re-checking the invariants per
//! class at every phase boundary: per-class tier fractions still form a
//! distribution, residency stays fully accounted under class-weighted
//! scores, the class planes partition the stream monotonically, and
//! every boundary snapshot remains kv-stable with the `qos_*` fields
//! live.

use std::io::Write;

use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::coordinator::DeviceGroup;
use dynaexq::serving::engine::{Engine, EngineConfig};
use dynaexq::serving::registry::{BackendCtx, BackendRegistry};
use dynaexq::serving::session::MetricsSnapshot;
use dynaexq::workload::{Scenario, WorkloadProfile};
use dynaexq::ServeSession;

/// The scenario families the matrix pins down (the drift suite's four
/// canonical regimes; the class-tagged multi-tenant and diurnal
/// scenarios get their own QoS invariant pass below).
const SCENARIOS: &[&str] = &["steady", "swap", "rotation", "burst"];

#[test]
fn matrix_every_method_by_scenario_by_devices_holds_invariants() {
    let preset = ModelPreset::phi_sim();
    let registry = BackendRegistry::with_builtins();
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();
    let profile = WorkloadProfile::text();
    let layers = preset.n_layers_logical();
    for method in registry.methods() {
        for sc_name in SCENARIOS {
            let sc = Scenario::by_name(sc_name).unwrap();
            for devices in [1usize, 2] {
                let cell = format!("{method} × {sc_name} × {devices}dev");
                let backend = registry
                    .build(
                        method,
                        &BackendCtx::new(&preset, &cfg, &dev)
                            .with_profile(&profile)
                            .with_devices(devices),
                    )
                    .unwrap_or_else(|e| panic!("{cell}: {e}"));
                let mut e = Engine::new(
                    &preset,
                    &profile,
                    backend,
                    &dev,
                    EngineConfig {
                        max_batch: 8,
                        seed: 0x5CE7 ^ devices as u64,
                        track_activation: false,
                    },
                );
                for phase in &sc.phases {
                    e.run_phase(phase, 4, 16, 4);

                    // I1: every device inside its envelope slice
                    assert!(
                        e.backend.within_envelope(),
                        "{cell}: envelope violated after phase {}",
                        phase.name
                    );
                    // I2: residency fully accounted (one published rung
                    // per expert) wherever a residency table exists
                    let res = e.backend.tier_residency();
                    if !res.is_empty() {
                        assert_eq!(
                            res.iter().sum::<usize>(),
                            layers * preset.n_experts,
                            "{cell}: residency leak after phase {}",
                            phase.name
                        );
                    }
                    for (d, counts) in
                        e.backend.device_residency().iter().enumerate()
                    {
                        assert!(
                            counts.iter().sum::<usize>() > 0,
                            "{cell}: device {d} lost its shard"
                        );
                    }
                    // I3: tier traffic fractions form a distribution
                    let fr = e.backend.tier_fractions();
                    if !fr.is_empty() {
                        let sum: f64 = fr.iter().sum();
                        assert!(
                            (sum - 1.0).abs() < 1e-9,
                            "{cell}: tier fractions sum to {sum} after \
                             phase {}",
                            phase.name
                        );
                        assert!(fr.iter().all(|f| (0.0..=1.0).contains(f)));
                    }
                    // boundary snapshots survive the kv wire format
                    let snap = MetricsSnapshot::from_replay(
                        preset.name,
                        method,
                        phase.profile.name,
                        e.backend.as_ref(),
                        e.now(),
                    );
                    assert_eq!(
                        MetricsSnapshot::decode(&snap.encode()).unwrap(),
                        snap,
                        "{cell}"
                    );
                }
                // the cell actually served the whole script
                assert_eq!(
                    e.metrics.e2e.count(),
                    sc.phases
                        .iter()
                        .map(|p| p.rounds * Scenario::scaled_batch(4, p.load))
                        .sum::<usize>(),
                    "{cell}: request accounting"
                );
            }
        }
    }
}

/// Drive one hard hot-set swap against a device group and count the
/// update intervals until the new hot pair is resident at the top rung.
/// Returns `limit + 1` when it never converges within `limit`.
fn swap_convergence_intervals(
    adaptive: bool,
    devices: usize,
    limit: usize,
) -> usize {
    let preset = ModelPreset::phi_sim().executed_scale();
    let mut cfg = ServingConfig::default();
    cfg.update_interval_ms = 10.0;
    cfg.ema_alpha = 0.95; // sluggish fixed baseline (the regime the
                          // adaptive layer exists for)
    // exactly the hot-pair capacity on every device, so the swap forces
    // hysteresis-gated displacement rather than free promotion
    cfg.n_hi_override = Some(2 * devices);
    cfg.adaptive_alpha = adaptive;
    let dev = DeviceConfig::default();
    let group = DeviceGroup::new(&preset, &cfg, &dev, devices).unwrap();
    // striped placement: consecutive expert ids alternate devices, so
    // both hot sets put exactly two experts on every device
    let hot_a: Vec<usize> = (0..2 * devices).collect();
    let hot_b: Vec<usize> = (8..8 + 2 * devices).collect();
    let mut now = 0.0;
    let interval = |group: &DeviceGroup, now: &mut f64, hot: &[usize]| {
        for _ in 0..30 {
            group.record_routing(0, hot);
        }
        group.wait_staged();
        *now += 0.0101;
        group.tick(*now);
        group.wait_staged();
        group.poll(*now);
    };
    // phase 1: converge on A — long enough that the stale EMA scores are
    // near their fixed point (and every drift window is full)
    for _ in 0..40 {
        interval(&group, &mut now, &hot_a);
    }
    for &e in &hot_a {
        assert_eq!(
            group.resolve_tier(0, e),
            0,
            "warm hot set must be resident (expert {e})"
        );
    }
    // phase 2: hard swap to B; count intervals to full residency
    for i in 1..=limit {
        interval(&group, &mut now, &hot_b);
        if hot_b.iter().all(|&e| group.resolve_tier(0, e) == 0) {
            assert!(group.within_envelope());
            assert!(group.pools_consistent());
            return i;
        }
    }
    limit + 1
}

#[test]
fn adaptive_estimator_reconverges_strictly_faster_on_swap() {
    // Acceptance criterion: on the scripted hot-set swap the adaptive
    // estimator's resident top-n reaches the new hot set within a bounded
    // number of update intervals, strictly faster than the fixed-α
    // baseline — on both 1- and 2-device groups.
    const LIMIT: usize = 60;
    const ADAPTIVE_BOUND: usize = 12; // detector window (3) + recovery +
                                      // migration publish lag
    for devices in [1usize, 2] {
        let fixed = swap_convergence_intervals(false, devices, LIMIT);
        let adaptive = swap_convergence_intervals(true, devices, LIMIT);
        assert!(
            fixed <= LIMIT,
            "{devices}dev: fixed baseline never converged"
        );
        assert!(
            adaptive <= ADAPTIVE_BOUND,
            "{devices}dev: adaptive took {adaptive} intervals \
             (bound {ADAPTIVE_BOUND})"
        );
        assert!(
            adaptive < fixed,
            "{devices}dev: adaptive ({adaptive}) must beat fixed ({fixed})"
        );
    }
}

#[test]
fn steady_two_rung_single_device_matches_fixed_stack_exactly() {
    // Acceptance criterion: under the steady scenario the 2-rung/1-device
    // stack is byte-identical to today's — the adaptive method observes
    // the steady stream without firing, so its serving timeline and
    // residency trajectory match the classic fixed-α method exactly.
    // (qwen30b-sim: at 128 experts and this traffic volume the detector's
    // sampling-noise floor exceeds any possible TV distance, so
    // non-triggering is deterministic, not statistical.)
    // No warmup: the cold-start trajectory is part of the comparison, and
    // the steady phases keep per-window routing counts small enough that
    // the noise floor dominates any same-distribution TV fluctuation.
    let run = |method: &str| {
        let mut s = ServeSession::builder()
            .model("qwen30b-sim")
            .method(method)
            .workload("text")
            .seed(31)
            .build()
            .unwrap();
        s.run_scenario(&Scenario::steady(), 2, 16, 8).unwrap();
        s.snapshot()
    };
    let classic = run("dynaexq");
    let adaptive = run("dynaexq-adaptive");
    // identical serving timeline and residency: the detector observed the
    // steady stream without firing, so α never moved
    assert_eq!(classic.duration_s, adaptive.duration_s);
    assert_eq!(classic.ttft_avg_s, adaptive.ttft_avg_s);
    assert_eq!(classic.tpop_p99_s, adaptive.tpop_p99_s);
    assert_eq!(classic.decode_tokens, adaptive.decode_tokens);
    assert_eq!(classic.migrated_bytes, adaptive.migrated_bytes);
    assert_eq!(classic.tier_resident, adaptive.tier_resident);
    assert_eq!(classic.hi_fraction, adaptive.hi_fraction);
    assert_eq!(adaptive.drift_events, 0, "steady traffic must not trigger");
    assert_eq!(classic.drift_events, 0);
}

#[test]
fn repeat_runs_snapshot_byte_identical_under_concurrent_hot_path() {
    // Determinism pin for the concurrent hot path (DESIGN.md §13): with
    // parallel device ticks and sharded hotness recording live, running
    // the same scenario cell twice yields byte-identical metrics
    // snapshots — on the 1-device group (serial tick gate) and the
    // 2-device group (scoped-thread tick) alike.
    for (method, devices) in [
        ("dynaexq", 1usize),
        ("dynaexq-sharded", 2),
        ("dynaexq-3tier-sharded", 2),
    ] {
        for sc_name in ["swap", "burst"] {
            let sc = Scenario::by_name(sc_name).unwrap();
            let run = || {
                let mut s = ServeSession::builder()
                    .model("phi-sim")
                    .method(method)
                    .workload("text")
                    .devices(devices)
                    .seed(0xC0DE)
                    .build()
                    .unwrap();
                s.run_scenario(&sc, 4, 16, 4).unwrap();
                s.snapshot().encode()
            };
            let first = run();
            let second = run();
            assert_eq!(
                first, second,
                "{method} × {sc_name} × {devices}dev: repeat run diverged"
            );
        }
    }
}

#[test]
fn qos_tagged_scenarios_hold_class_invariants_at_phase_boundaries() {
    use dynaexq::config::frontdoor::FrontDoorConfig;
    use dynaexq::config::{QosClass, QosConfig};

    let preset = ModelPreset::phi_sim();
    let layers = preset.n_layers_logical();
    for sc_name in ["multi-tenant", "diurnal"] {
        let sc = Scenario::by_name(sc_name).unwrap();
        // every phase of the tagged scenarios carries a class tag
        assert!(
            sc.phases.iter().all(|p| p.qos_class.is_some()),
            "{sc_name}: untagged phase"
        );
        let mut s = ServeSession::builder()
            .model("phi-sim")
            .method("dynaexq")
            .workload("text")
            .seed(0x905A)
            .warmup(1)
            .frontdoor(FrontDoorConfig::default())
            .qos(QosConfig::tiered())
            .build()
            .unwrap();
        let marks = s.run_scenario_frontdoor(&sc, 4, 16, 4).unwrap();
        assert_eq!(marks.len(), sc.phases.len());
        let mut prev: Vec<Vec<u64>> = Vec::new();
        for ((phase, snap), spec) in marks.iter().zip(&sc.phases) {
            let classed = &snap.qos_class_resolved;
            assert_eq!(
                classed.len(),
                QosClass::ALL.len(),
                "{sc_name}/{phase}: class plane count"
            );
            // (I3 per class) tier fractions form a distribution wherever
            // the class saw traffic
            for (c, row) in classed.iter().enumerate() {
                let total: u64 = row.iter().sum();
                if total > 0 {
                    let sum: f64 = row
                        .iter()
                        .map(|&v| v as f64 / total as f64)
                        .sum();
                    assert!(
                        (sum - 1.0).abs() < 1e-9,
                        "{sc_name}/{phase}: class {c} fractions sum {sum}"
                    );
                }
            }
            // (I2 under weighting) residency stays fully accounted while
            // the waterfill runs on class-weighted scores
            assert_eq!(
                snap.tier_resident.iter().sum::<usize>(),
                layers * preset.n_experts,
                "{sc_name}/{phase}: residency leak under class weighting"
            );
            // the class planes partition the stream: counters are
            // monotone across boundaries, and the phase's tagged class
            // billed the phase's traffic
            if !prev.is_empty() {
                for (c, row) in classed.iter().enumerate() {
                    for (t, &v) in row.iter().enumerate() {
                        assert!(
                            v >= prev[c][t],
                            "{sc_name}/{phase}: class {c} tier {t} counter \
                             went backwards"
                        );
                    }
                }
            }
            let class = spec.qos_class.unwrap();
            let prev_sum: u64 = prev
                .get(class.index())
                .map(|r| r.iter().sum())
                .unwrap_or(0);
            let cur_sum: u64 = classed[class.index()].iter().sum();
            assert!(
                cur_sum > prev_sum,
                "{sc_name}/{phase}: tagged class {class} billed no traffic"
            );
            prev = classed.clone();
            // boundary snapshots stay kv-stable with the qos fields live
            let dec = MetricsSnapshot::decode(&snap.encode()).unwrap();
            assert_eq!(&dec, snap, "{sc_name}/{phase}: kv roundtrip");
        }
    }
}

#[test]
fn drift_recovery_report_artifact() {
    // Recovery ticks per method × scenario × group width, persisted for
    // CI (uploaded next to the conformance trace as a build artifact).
    let mut rows = Vec::new();
    for sc_name in SCENARIOS {
        let sc = Scenario::by_name(sc_name).unwrap();
        for (method, devices) in [
            ("dynaexq", 1usize),
            ("dynaexq-adaptive", 1),
            ("dynaexq-sharded", 2),
            ("dynaexq-adaptive", 2),
        ] {
            let mut s = ServeSession::builder()
                .model("phi-sim")
                .method(method)
                .workload("text")
                .devices(devices)
                .seed(0xD41F7)
                .warmup(1)
                .build()
                .unwrap();
            s.run_scenario(&sc, 4, 16, 4).unwrap();
            let snap = s.snapshot();
            if !method.contains("adaptive") {
                assert_eq!(
                    (snap.drift_events, snap.drift_recovery_ticks),
                    (0, 0),
                    "{method} × {sc_name}: fixed α must report no drift"
                );
            }
            rows.push(format!(
                "scenario={sc_name};method={method};devices={devices};\
                 drift_events={};recovery_ticks={};hi_fraction={:.4}",
                snap.drift_events,
                snap.drift_recovery_ticks,
                snap.hi_fraction,
            ));
        }
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("drift_recovery_report.txt");
    let mut f = std::fs::File::create(&path).unwrap();
    for row in &rows {
        writeln!(f, "{row}").unwrap();
    }
    assert_eq!(rows.len(), SCENARIOS.len() * 4);
}
