//! Property tests for QoS class-weighted allocation (DESIGN.md §15):
//! degenerate byte-identity with the classless stack across registry
//! methods, device widths, and fleet replica counts; premium-dominance
//! monotonicity at equal raw hotness; per-tenant budget conservation
//! through submit/drain/readmit including mid-stream failover; seeded
//! fuzz over config validation and the CLI spec parser; and kv snapshot
//! roundtrips for every `qos_*` field.

use dynaexq::config::fleet::FleetConfig;
use dynaexq::config::frontdoor::{FrontDoorConfig, Lane, LimitAction};
use dynaexq::config::{
    DeviceConfig, ModelPreset, QosClass, QosConfig, ServingConfig,
};
use dynaexq::coordinator::Coordinator;
use dynaexq::serving::fleet::Fleet;
use dynaexq::serving::session::MetricsSnapshot;
use dynaexq::testutil::prop::Prop;
use dynaexq::workload::{FaultPlan, RequestGenerator, Scenario, WorkloadProfile};
use dynaexq::ServeSession;

/// One fronted session over the class-tagged multi-tenant scenario,
/// returning the encoded snapshot (the byte-identity unit).
fn fronted_snapshot(
    method: &str,
    devices: usize,
    qos: Option<QosConfig>,
) -> String {
    let mut b = ServeSession::builder()
        .model("phi-sim")
        .method(method)
        .workload("text")
        .seed(0x9905)
        .warmup(1)
        .devices(devices)
        .frontdoor(FrontDoorConfig::default());
    if let Some(q) = qos {
        b = b.qos(q);
    }
    let mut s = b.build().unwrap();
    s.run_scenario_frontdoor(&Scenario::multi_tenant(), 4, 24, 4).unwrap();
    s.snapshot().encode()
}

#[test]
fn degenerate_qos_is_byte_identical_across_methods_and_devices() {
    // The collapse contract: a degenerate QosConfig (equal weights, no
    // budgets) must leave the whole stack byte-identical to running with
    // no QoS at all — even though the scenario's phases carry class tags.
    // Equal weights at a *scaled* value are just as degenerate.
    for method in ["dynaexq", "dynaexq-adaptive", "dynaexq-sharded", "static"]
    {
        for devices in [1usize, 2] {
            let base = fronted_snapshot(method, devices, None);
            let degen = fronted_snapshot(
                method,
                devices,
                Some(QosConfig::degenerate()),
            );
            assert_eq!(
                base, degen,
                "{method} x{devices}dev: degenerate config diverged"
            );
            let scaled = fronted_snapshot(
                method,
                devices,
                Some(
                    QosConfig::degenerate()
                        .with_weight(QosClass::Premium, 3.0)
                        .with_weight(QosClass::Standard, 3.0)
                        .with_weight(QosClass::BestEffort, 3.0),
                ),
            );
            assert_eq!(
                base, scaled,
                "{method} x{devices}dev: scaled-equal weights diverged"
            );
        }
    }
}

#[test]
fn degenerate_qos_is_byte_identical_across_fleet_replicas() {
    let run = |replicas: usize, qos: Option<QosConfig>| -> String {
        let mut fc = FleetConfig::default();
        fc.replicas = replicas;
        fc.devices_per_replica = 1;
        let mut b = Fleet::builder()
            .model("phi-sim")
            .method("dynaexq")
            .workload("text")
            .max_batch(4)
            .seed(0x9906)
            .warmup(1)
            .fleet_cfg(fc);
        if let Some(q) = qos {
            b = b.qos(q);
        }
        let mut f = b.build().unwrap();
        f.run_scenario(&Scenario::multi_tenant(), 4, 24, 4).unwrap();
        f.snapshot().encode()
    };
    for replicas in [1usize, 2] {
        let base = run(replicas, None);
        let degen = run(replicas, Some(QosConfig::degenerate()));
        assert_eq!(base, degen, "{replicas} replicas: degenerate diverged");
        assert!(base.contains("qos_charged="), "snapshot lost qos keys");
    }
}

#[test]
fn prop_premium_never_resolves_below_best_effort_at_equal_hotness() {
    // Monotonicity of the class-weighted waterfill: for every expert pair
    // fed *identical* raw routed-token counts — one under the premium
    // class, one under best-effort — the premium expert's resolved rung
    // is never lower (never a larger tier index). Premium experts sit at
    // the higher index of each pair, so index tie-breaks work against
    // them: only the weighting can secure the rung.
    let mut prop = Prop::new("qos_premium_dominance");
    prop.run(12, |rng| {
        let preset = ModelPreset::phi_sim();
        let mut cfg = ServingConfig::default();
        cfg.hysteresis_margin = 0.0;
        cfg.ema_alpha = 0.0; // fully reactive: scores = this interval
        cfg.max_inflight_promotions = 1024;
        cfg.qos = Some(QosConfig::tiered());
        let c =
            Coordinator::new(&preset, &cfg, &DeviceConfig::default()).unwrap();
        assert!(c.qos_armed());
        let layer = rng.below(preset.n_layers_logical());
        let pairs: Vec<(usize, usize, usize)> = (0..preset.n_experts / 2)
            .map(|i| (2 * i, 2 * i + 1, 1 + rng.below(60)))
            .collect();
        for &(be, _, count) in &pairs {
            c.set_active_class(QosClass::BestEffort.index());
            for _ in 0..count {
                c.record_routing(layer, &[be]);
            }
        }
        for &(_, prem, count) in &pairs {
            c.set_active_class(QosClass::Premium.index());
            for _ in 0..count {
                c.record_routing(layer, &[prem]);
            }
        }
        c.tick(1.0);
        c.pipeline.wait_staged();
        c.tick(1e3);
        for &(be, prem, count) in &pairs {
            assert!(
                c.weighted_score(layer, prem) > c.weighted_score(layer, be),
                "pair ({be},{prem}) count {count}: weighting lost"
            );
            assert!(
                c.resolve_tier(layer, prem) <= c.resolve_tier(layer, be),
                "pair ({be},{prem}) count {count}: premium resolved at \
                 tier {} below best-effort's {}",
                c.resolve_tier(layer, prem),
                c.resolve_tier(layer, be),
            );
        }
    });
}

#[test]
fn prop_fleet_budget_charges_conserved_through_failover() {
    // Conservation: every modeled hi-precision byte charged at admission
    // is refunded exactly once when the stream completes — including
    // streams stranded by a mid-scenario replica failure, re-admitted
    // under their original ids, and finished elsewhere.
    let mut prop = Prop::new("qos_budget_conservation");
    prop.run(6, |rng| {
        let mut fc = FleetConfig::default();
        fc.replicas = 2;
        fc.devices_per_replica = 1;
        fc.stream_chunk = Some(1); // keep streams in flight across rounds
        let mut f = Fleet::builder()
            .model("phi-sim")
            .method("dynaexq")
            .workload("text")
            .max_batch(4)
            .seed(rng.next_u64())
            .warmup(1)
            .fleet_cfg(fc)
            .faults(FaultPlan::fail(1, 2).and_recover(1, 6))
            .qos(QosConfig::tiered().with_budget(QosClass::Premium, 1 << 26))
            .build()
            .unwrap();
        let prompt = 8 + rng.below(24);
        let output = 2 + rng.below(4);
        f.run_scenario(&Scenario::multi_tenant(), 4, prompt, output)
            .unwrap();
        assert!(f.stats().failovers >= 1, "fault plan never fired");
        let fd = f.frontdoor();
        assert!(fd.qos_armed());
        let charged = fd.qos_charged();
        let refunded = fd.qos_refunded();
        assert_eq!(charged, refunded, "ledger out of balance");
        assert!(charged.iter().sum::<u64>() > 0, "nothing was charged");
        assert!(
            fd.qos_outstanding().iter().all(|&o| o == 0),
            "outstanding bytes after full drain: {:?}",
            fd.qos_outstanding()
        );
        // the snapshot mirrors the ledger and survives a kv roundtrip
        let snap = f.snapshot();
        assert_eq!(snap.qos_charged, charged);
        let dec = MetricsSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(dec.encode(), snap.encode());
    });
}

#[test]
fn budget_exhaustion_rejects_then_refunds_balance() {
    // Premium budget admits two in-flight requests at this shape
    // (2048 B/token × 20 tokens = 40960 B each); the rest of the round's
    // submissions surface `Rejected::BudgetExhausted` and are never
    // charged — so after drain the ledger still balances exactly.
    let q = QosConfig::tiered()
        .with_budget(QosClass::Premium, 100_000)
        .pin("acme", QosClass::Premium);
    let mut s = ServeSession::builder()
        .model("phi-sim")
        .seed(0xB4D6)
        .warmup(0)
        .frontdoor(FrontDoorConfig::unbounded())
        .qos(q)
        .build()
        .unwrap();
    let mut gen = RequestGenerator::new(WorkloadProfile::text(), 0xB4D6);
    let mut rejected = 0u64;
    for _ in 0..2 {
        let now = s.now();
        for _ in 0..5 {
            let req = gen.request(16, 4, now);
            if s.submit(req, "acme", Lane::Standard).unwrap().is_err() {
                rejected += 1;
            }
        }
        s.drain().unwrap();
    }
    assert_eq!(rejected, 6, "3 of 5 submissions per round over budget");
    let snap = s.snapshot();
    assert_eq!(snap.qos_budget_rejected, 6);
    assert_eq!(snap.qos_downgraded, 0);
    assert_eq!(snap.qos_charged, snap.qos_refunded);
    let pi = QosClass::Premium.index();
    assert_eq!(snap.qos_charged[pi], 2 * 2 * 40960);
}

#[test]
fn budget_exhaustion_downgrade_demotes_instead_of_rejecting() {
    // Same shape, `action=downgrade`: the third submission demotes the
    // tenant to best-effort pricing and admits — nothing is rejected,
    // and post-demotion traffic bills (unmetered) to the new class.
    let q = QosConfig::tiered()
        .with_budget(QosClass::Premium, 100_000)
        .pin("acme", QosClass::Premium)
        .on_exhausted(LimitAction::Downgrade);
    let mut s = ServeSession::builder()
        .model("phi-sim")
        .seed(0xB4D7)
        .warmup(0)
        .frontdoor(FrontDoorConfig::unbounded())
        .qos(q)
        .build()
        .unwrap();
    let mut gen = RequestGenerator::new(WorkloadProfile::text(), 0xB4D7);
    let now = s.now();
    for _ in 0..5 {
        let req = gen.request(16, 4, now);
        assert!(s.submit(req, "acme", Lane::Standard).unwrap().is_ok());
    }
    s.drain().unwrap();
    assert_eq!(
        s.frontdoor().unwrap().tenant_class("acme"),
        Some(QosClass::BestEffort),
        "demotion must be sticky"
    );
    let snap = s.snapshot();
    assert_eq!(snap.qos_budget_rejected, 0);
    assert!(snap.qos_downgraded >= 1);
    assert_eq!(snap.qos_charged, snap.qos_refunded);
    assert!(snap.qos_charged[QosClass::BestEffort.index()] > 0);
}

#[test]
fn prop_invalid_qos_configs_are_refused_at_build_and_never_panic() {
    // Builder-level fuzz: zero/negative weights, budgets exceeding the
    // HBM envelope, and duplicate pins are all rejected with a "qos"-
    // prefixed error before any backend is constructed.
    let mut prop = Prop::new("qos_build_fuzz");
    prop.run(30, |rng| {
        let mut q = QosConfig::tiered();
        let kind = rng.below(4);
        let class = QosClass::ALL[rng.below(QosClass::ALL.len())];
        match kind {
            0 => q = q.with_weight(class, 0.0),
            1 => q = q.with_weight(class, -rng.range_f64(0.1, 5.0)),
            2 => q = q.with_budget(class, u64::MAX),
            _ => {
                let t = format!("t{}", rng.below(3));
                q = q.pin(&t, QosClass::Premium).pin(&t, QosClass::Standard);
            }
        }
        let err = ServeSession::builder()
            .model("phi-sim")
            .frontdoor(FrontDoorConfig::default())
            .qos(q)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("qos"), "kind {kind}: {err}");
        if kind == 2 {
            assert!(err.contains("exceeds the HBM envelope"), "{err}");
        }
    });
}

#[test]
fn cli_spec_parser_enumerates_valid_names_on_rejection() {
    let err = QosConfig::parse_spec("gold=2").unwrap_err();
    assert!(err.contains("premium, standard, best-effort"), "{err}");
    let err = QosConfig::parse_spec("default=bronze").unwrap_err();
    assert!(err.contains("premium, standard, best-effort"), "{err}");
    let err = QosConfig::parse_spec("action=explode").unwrap_err();
    assert!(err.contains("reject, downgrade"), "{err}");
    // weight/budget near-misses carry the offending class and token
    let err = QosConfig::parse_spec("premium=fast").unwrap_err();
    assert!(err.contains("premium"), "{err}");
    let err = QosConfig::parse_spec("premium=4:lots").unwrap_err();
    assert!(err.contains("budget"), "{err}");
}

#[test]
fn qos_snapshot_kv_roundtrips_and_rejects_missing_fields() {
    let q = QosConfig::tiered().pin("t0", QosClass::Premium);
    let mut s = ServeSession::builder()
        .model("phi-sim")
        .seed(0x51ED)
        .warmup(0)
        .frontdoor(FrontDoorConfig::default())
        .qos(q)
        .build()
        .unwrap();
    let mut gen = RequestGenerator::new(WorkloadProfile::text(), 0x51ED);
    for _ in 0..3 {
        let now = s.now();
        for i in 0..4u64 {
            let req = gen.request(16, 4, now);
            s.submit(req, &format!("t{}", i % 2), Lane::Standard)
                .unwrap()
                .unwrap();
        }
        s.drain().unwrap();
    }
    let snap = s.snapshot();
    assert!(!snap.qos_charged.is_empty(), "armed session must report qos");
    let enc = snap.encode();
    let dec = MetricsSnapshot::decode(&enc).unwrap();
    assert_eq!(dec.encode(), enc, "roundtrip not stable");
    assert_eq!(dec.qos_class_resolved, snap.qos_class_resolved);
    assert_eq!(dec.qos_charged, snap.qos_charged);
    assert_eq!(dec.qos_refunded, snap.qos_refunded);
    assert_eq!(dec.qos_downgraded, snap.qos_downgraded);
    assert_eq!(dec.qos_budget_rejected, snap.qos_budget_rejected);
    // a snapshot missing any qos_* key is rejected, not defaulted
    for key in [
        "qos_class_resolved",
        "qos_charged",
        "qos_refunded",
        "qos_downgraded",
        "qos_budget_rejected",
    ] {
        let prefix = format!("{key}=");
        let stripped: Vec<&str> = enc
            .split(';')
            .filter(|part| !part.starts_with(&prefix))
            .collect();
        let stripped = stripped.join(";");
        assert!(
            MetricsSnapshot::decode(&stripped).is_err(),
            "decode accepted a snapshot missing {key}"
        );
    }
}
