//! Property tests for the continuous-batching scheduler: no admitted
//! request starves (every request eventually decodes its full output), and
//! per-tick token bookkeeping conserves counts under random
//! arrival/length shuffles.

use dynaexq::config::{DeviceConfig, ModelPreset};
use dynaexq::serving::backend::StaticBackend;
use dynaexq::serving::engine::{Engine, EngineConfig};
use dynaexq::serving::scheduler::ContinuousBatch;
use dynaexq::testutil::prop::Prop;
use dynaexq::workload::{Request, RequestGenerator, WorkloadProfile};

fn engine(max_batch: usize, seed: u64) -> Engine {
    let preset = ModelPreset::phi_sim();
    Engine::new(
        &preset,
        &WorkloadProfile::text(),
        Box::new(StaticBackend::for_preset(&preset)),
        &DeviceConfig::default(),
        EngineConfig { max_batch, seed, track_activation: false },
    )
}

#[test]
fn prop_no_request_starves_and_token_bookkeeping_conserves() {
    let mut prop = Prop::new("scheduler_no_starvation");
    prop.run(25, |rng| {
        let n = 1 + rng.below(24);
        let cap = 1 + rng.below(6);
        let mut gen = RequestGenerator::new(
            WorkloadProfile::text(),
            rng.next_u64(),
        );
        let mut reqs: Vec<Request> = (0..n)
            .map(|_| {
                let prompt = 1 + rng.below(64);
                let output = 1 + rng.below(16);
                let arrival = rng.range_f64(0.0, 5.0);
                gen.request(prompt, output, arrival)
            })
            .collect();
        // admission order must not depend on the input order
        rng.shuffle(&mut reqs);

        let total_out: u64 = reqs.iter().map(|r| r.output_len as u64).sum();
        let total_in: u64 = reqs.iter().map(|r| r.prompt_len as u64).sum();
        // TPOP counts inter-token gaps from the second generated token on
        let tpop_expected: usize =
            reqs.iter().map(|r| r.output_len - 1).sum();
        let last_arrival =
            reqs.iter().map(|r| r.arrival_s).fold(0.0, f64::max);

        let mut e = engine(cap, rng.next_u64());
        e.serve_with(&mut ContinuousBatch::default(), reqs);

        // liveness: every admitted request retired with a recorded E2E
        // (a starved request would leave the loop spinning or the counts
        // short)
        assert_eq!(e.metrics.e2e.count(), n, "cap {cap}: requests starved");
        assert_eq!(e.metrics.ttft.count(), n);
        // conservation: exactly the offered tokens were prefilled/decoded
        assert_eq!(e.metrics.decode_tokens, total_out);
        assert_eq!(e.metrics.prefill_tokens, total_in);
        assert_eq!(e.metrics.tpop.count(), tpop_expected);
        // the run cannot finish before the last arrival was served
        assert!(e.metrics.duration_s >= last_arrival);
        // latency sanity: measured from arrival, never negative
        assert!(e.metrics.ttft.samples().iter().all(|&x| x >= 0.0));
        assert!(e.metrics.e2e.samples().iter().all(|&x| x >= 0.0));
        assert!(e.metrics.tpop.samples().iter().all(|&x| x >= 0.0));
    });
}

#[test]
fn prop_tight_caps_only_delay_never_drop() {
    // The same request set under shrinking caps: token totals are
    // invariant, only latency moves (and only upward at the tail).
    let mut prop = Prop::new("scheduler_cap_invariance");
    prop.run(10, |rng| {
        let n = 4 + rng.below(12);
        let seed = rng.next_u64();
        let serve = |cap: usize| {
            let mut gen =
                RequestGenerator::new(WorkloadProfile::text(), seed);
            let reqs: Vec<Request> = (0..n)
                .map(|i| gen.request(16, 4, i as f64 * 0.02))
                .collect();
            let mut e = engine(cap, seed ^ 1);
            e.serve_with(&mut ContinuousBatch::default(), reqs);
            (
                e.metrics.decode_tokens,
                e.metrics.prefill_tokens,
                e.metrics.ttft.max(),
            )
        };
        let (out_wide, in_wide, ttft_wide) = serve(8);
        let (out_tight, in_tight, ttft_tight) = serve(1);
        assert_eq!(out_wide, out_tight);
        assert_eq!(in_wide, in_tight);
        assert!(
            ttft_tight >= ttft_wide,
            "cap 1 tail {ttft_tight} < cap 8 tail {ttft_wide}"
        );
    });
}

#[test]
fn zero_cap_is_treated_as_one() {
    // A zero cap could never admit anything; the scheduler clamps to 1.
    let mut gen = RequestGenerator::new(WorkloadProfile::text(), 2);
    let reqs: Vec<Request> =
        (0..3).map(|i| gen.request(8, 2, i as f64 * 0.1)).collect();
    let mut e = engine(4, 9);
    e.serve_with(&mut ContinuousBatch { max_batch: Some(0) }, reqs);
    assert_eq!(e.metrics.e2e.count(), 3);
    assert_eq!(e.metrics.decode_tokens, 6);
}
