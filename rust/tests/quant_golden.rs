//! Cross-language quantization contract: the rust quantizer must reproduce
//! the python quantizer's packed bytes and scales **bit-exactly** (the
//! prepared host weights feed HLO kernels compiled from the python side —
//! any drift would silently corrupt every quantized expert).
//!
//! `make artifacts` writes `artifacts/quant_golden.bin` (python side); this
//! test regenerates the same golden matrix in rust and compares.

use dynaexq::model::quant::quantize;
use dynaexq::model::Precision;

/// Matches `python/compile/aot.py::golden_matrix` exactly: integer Weyl
/// sequence computed in f64, cast to f32.
fn golden_matrix(k: usize, n: usize) -> Vec<f32> {
    (0..k * n)
        .map(|i| {
            let h = (i as u64).wrapping_mul(2654435761) % (1u64 << 32);
            ((h as f64) / (1u64 << 31) as f64 - 1.0) as f32
        })
        .collect()
}

#[test]
fn rust_quantizer_matches_python_bit_exactly() {
    let dir = std::env::var("DYNAEXQ_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    let path = std::path::Path::new(&dir).join("quant_golden.bin");
    let golden = match std::fs::read(&path) {
        Ok(b) => b,
        Err(_) => {
            eprintln!("skipping: {} missing (run `make artifacts`)", path.display());
            return;
        }
    };
    let (k, n) = (64usize, 16usize);
    let w = golden_matrix(k, n);

    let mut offset = 0;
    for p in [Precision::Int4, Precision::Int2] {
        let m = quantize(&w, k, n, p);
        let packed_len = (k / p.pack()) * n;
        assert_eq!(
            &golden[offset..offset + packed_len],
            &m.data[..],
            "{:?}: packed bytes diverge from python",
            p
        );
        offset += packed_len;
        let scale_bytes = n * 4;
        let py_scales: Vec<f32> = golden[offset..offset + scale_bytes]
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        assert_eq!(py_scales, m.scales, "{:?}: scales diverge", p);
        offset += scale_bytes;
    }
    assert_eq!(offset, golden.len(), "golden file length mismatch");
}

#[test]
fn golden_matrix_is_deterministic_and_bounded() {
    let w = golden_matrix(64, 16);
    assert_eq!(w, golden_matrix(64, 16));
    assert!(w.iter().all(|&x| (-1.0..1.0).contains(&x)));
    // non-trivial spread
    let max = w.iter().cloned().fold(f32::MIN, f32::max);
    let min = w.iter().cloned().fold(f32::MAX, f32::min);
    assert!(max > 0.9 && min < -0.9);
}
