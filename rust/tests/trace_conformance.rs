//! Trace-replay conformance: record a `DXTR` trace from the modeled
//! engine, replay it through every registry backend (1- and 2-device
//! groups), and assert the replayed `MetricsSnapshot`s are byte-stable
//! across two replays of the same trace — the determinism golden test
//! behind `dynaexq trace --replay`.
//!
//! The recorded trace is persisted to `target/conformance_trace.dxtr`; CI
//! uploads it as a build artifact so conformance regressions are diffable.

use dynaexq::config::{DeviceConfig, ModelPreset, ServingConfig};
use dynaexq::serving::backend::{RecordingBackend, StaticBackend};
use dynaexq::serving::engine::{Engine, EngineConfig};
use dynaexq::serving::fleet::{FleetBackend, ReplicaHealth};
use dynaexq::serving::registry::{BackendCtx, BackendRegistry};
use dynaexq::serving::session::MetricsSnapshot;
use dynaexq::workload::{FaultPlan, Trace, WorkloadProfile};

/// Capture a trace from a real modeled-engine run (not synthesized): the
/// recording backend observes exactly the routing batches and iteration
/// boundaries the engine produced.
fn recorded_trace(preset: &ModelPreset) -> Trace {
    let (backend, handle) = RecordingBackend::wrap(
        Box::new(StaticBackend::for_preset(preset)),
        preset.n_layers_logical(),
        preset.n_experts,
    );
    let w = WorkloadProfile::text();
    let mut e = Engine::new(
        preset,
        &w,
        Box::new(backend),
        &DeviceConfig::default(),
        EngineConfig { max_batch: 8, seed: 0xDC, track_activation: false },
    );
    e.serve_uniform(&w, 4, 24, 16);
    e.serve_uniform(&w, 2, 16, 8);
    let trace = handle.lock().clone();
    trace
}

fn replay_snapshot(
    registry: &BackendRegistry,
    trace: &Trace,
    preset: &ModelPreset,
    method: &str,
    devices: usize,
) -> MetricsSnapshot {
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();
    let w = WorkloadProfile::text();
    let mut b = registry
        .build(
            method,
            &BackendCtx::new(preset, &cfg, &dev)
                .with_profile(&w)
                .with_devices(devices),
        )
        .unwrap_or_else(|e| panic!("build {method}@{devices}dev: {e}"));
    let end = trace.replay(b.as_mut(), 0.01);
    MetricsSnapshot::from_replay(preset.name, method, "text", b.as_ref(), end)
}

#[test]
fn every_backend_replays_byte_stable_on_one_and_two_device_groups() {
    let preset = ModelPreset::phi_sim();
    let trace = recorded_trace(&preset);
    assert!(trace.selections() > 0, "engine produced routing traffic");

    // Persist as the CI artifact and exercise the binary roundtrip on the
    // way: the replayed trace is the *loaded* one, as in the CLI path.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("target");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("conformance_trace.dxtr");
    trace.save(&path).unwrap();
    let trace = Trace::load(&path).unwrap();
    trace
        .check_matches(preset.n_layers_logical(), preset.n_experts)
        .unwrap();

    let registry = BackendRegistry::with_builtins();
    assert!(
        registry.methods().len() >= 10,
        "conformance covers all 10+ methods: {:?}",
        registry.methods()
    );
    for method in registry.methods() {
        for devices in [1usize, 2] {
            let a = replay_snapshot(&registry, &trace, &preset, method, devices);
            let b = replay_snapshot(&registry, &trace, &preset, method, devices);
            assert_eq!(
                a.encode(),
                b.encode(),
                "{method}@{devices}dev: replay must be byte-stable"
            );
            // the encoding itself round-trips losslessly
            assert_eq!(MetricsSnapshot::decode(&a.encode()).unwrap(), a);
        }
    }
}

#[test]
fn replay_drives_adaptive_backends() {
    // Conformance is only meaningful if the replay actually exercises the
    // residency machinery: the coordinator methods must migrate bytes.
    let preset = ModelPreset::phi_sim();
    let trace = recorded_trace(&preset);
    let registry = BackendRegistry::with_builtins();
    for (method, devices) in
        [("dynaexq", 1), ("dynaexq-sharded", 2), ("dynaexq-3tier-sharded", 2)]
    {
        let snap = replay_snapshot(&registry, &trace, &preset, method, devices);
        assert!(
            snap.migrated_bytes > 0,
            "{method}@{devices}dev: replay should trigger promotions"
        );
        let layers = preset.n_layers_logical();
        assert_eq!(
            snap.tier_resident.iter().sum::<usize>(),
            layers * preset.n_experts,
            "{method}: every expert accounted at exactly one rung"
        );
        if devices > 1 {
            assert_eq!(snap.device_resident.len(), devices, "{method}");
        }
    }
}

#[test]
fn sharded_replay_stays_byte_stable_across_many_replays() {
    // The concurrent hot path (DESIGN.md §13) must not cost determinism:
    // with parallel device ticks and sharded hotness recording live, a
    // 2-device sharded replay is byte-identical across repeated replays
    // of the same trace — not just across one pair.
    let preset = ModelPreset::phi_sim();
    let trace = recorded_trace(&preset);
    let registry = BackendRegistry::with_builtins();
    for method in ["dynaexq-sharded", "dynaexq-3tier-sharded"] {
        let reference =
            replay_snapshot(&registry, &trace, &preset, method, 2).encode();
        for i in 0..4 {
            let again =
                replay_snapshot(&registry, &trace, &preset, method, 2)
                    .encode();
            assert_eq!(
                reference, again,
                "{method}@2dev: replay {i} diverged from the reference"
            );
        }
    }
}

#[test]
fn two_replica_fleet_replay_is_byte_stable() {
    // The registry loop above already replays `dynaexq-fleet` at its
    // default width; this pins the 2-replica shape (built through
    // `BackendCtx::with_replicas`, as the CLI/registry path does) to the
    // same byte-stability contract — with the concurrent replica ticks
    // checked against the forced-serial reference.
    let preset = ModelPreset::phi_sim();
    let trace = recorded_trace(&preset);
    let registry = BackendRegistry::with_builtins();
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();
    let w = WorkloadProfile::text();

    let registry_replay = || {
        let mut b = registry
            .build(
                "dynaexq-fleet",
                &BackendCtx::new(&preset, &cfg, &dev)
                    .with_profile(&w)
                    .with_replicas(2),
            )
            .unwrap();
        let end = trace.replay(b.as_mut(), 0.01);
        MetricsSnapshot::from_replay(
            preset.name,
            "dynaexq-fleet",
            "text",
            b.as_ref(),
            end,
        )
        .encode()
    };
    let reference = registry_replay();
    for i in 0..3 {
        assert_eq!(registry_replay(), reference, "replay {i} diverged");
    }

    // the threaded replica ticks match the serial reference byte for byte
    let direct_replay = |serial: bool| {
        let mut b = FleetBackend::new(&preset, &cfg, &dev, 1, 2)
            .unwrap()
            .set_serial(serial);
        let end = trace.replay(&mut b, 0.01);
        MetricsSnapshot::from_replay(
            preset.name,
            "dynaexq-fleet",
            "text",
            &b,
            end,
        )
        .encode()
    };
    assert_eq!(direct_replay(false), direct_replay(true));
}

#[test]
fn fleet_replay_under_scripted_failure_re_homes_and_stays_stable() {
    // Down replica 0 a few ticks into the replay: the backend must move
    // its current replica off the dead one, keep serving the whole
    // trace, and stay byte-stable across repeated faulted replays.
    let preset = ModelPreset::phi_sim();
    let trace = recorded_trace(&preset);
    let cfg = ServingConfig::default();
    let dev = DeviceConfig::default();

    let run = || {
        let mut b = FleetBackend::new(&preset, &cfg, &dev, 1, 2)
            .unwrap()
            .with_faults(FaultPlan::fail(0, 3));
        let end = trace.replay(&mut b, 0.01);
        let snap = MetricsSnapshot::from_replay(
            preset.name,
            "dynaexq-fleet",
            "text",
            &b,
            end,
        );
        (b.current(), b.health(), snap)
    };
    let (current, health, snap) = run();
    assert_eq!(current, 1, "replay never re-homed off the failed replica");
    assert_eq!(health[0], ReplicaHealth::Down);
    assert_eq!(health[1], ReplicaHealth::Healthy);
    assert!(snap.migrated_bytes > 0, "the survivor must keep promoting");

    let (current2, health2, snap2) = run();
    assert_eq!(current2, current);
    assert_eq!(health2, health);
    assert_eq!(snap2.encode(), snap.encode(), "faulted replay diverged");
}

#[test]
fn replay_rejects_a_mismatched_preset() {
    let trace = recorded_trace(&ModelPreset::phi_sim());
    let q = ModelPreset::qwen30b_sim();
    let err = trace
        .check_matches(q.n_layers_logical(), q.n_experts)
        .unwrap_err()
        .to_string();
    assert!(err.contains("does not match"), "{err}");
}
