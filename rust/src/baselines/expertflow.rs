//! ExpertFlow-like expert offloading/prefetching baseline.
//!
//! Structure (after ExpertFlow [27] / ProMoE / MoE-Infinity): GPU memory is
//! an expert **cache** under the same HBM envelope; experts execute at full
//! working precision (the model's base tier). A history-based prefetcher
//! keeps recently routed experts warm, but a routed expert that is not
//! resident must be fetched over PCIe **on the critical path** — the
//! forward pass waits for the fetch event. When activation densifies
//! (prefill, large batch), the per-iteration working set exceeds what the
//! overlap window can stage and waiting time becomes visible (paper Fig. 1
//! and the structural limitation of §2.2).
//!
//! This is a faithful reproduction of the *mechanism class*, not of
//! ExpertFlow's exact policy (see DESIGN.md §2 substitutions): cache-aware
//! LRU eviction + temporal-locality prefetch ("keep what the last
//! iterations routed"), which is the regime where all such systems share
//! the same failure mode.

use std::collections::HashMap;

use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::model::Precision;
use crate::serving::backend::ResidencyBackend;
use crate::sim::{LogicalDims, Stream};

/// LRU expert cache + prefetcher + PCIe fetch stream.
pub struct ExpertFlowBackend {
    precision: Precision,
    /// Max experts resident simultaneously (HBM envelope / expert bytes).
    capacity: usize,
    expert_bytes: usize,
    secs_per_byte: f64,
    /// (layer, expert) → last-use tick; presence == resident *or* in
    /// flight; `ready_at` gates use.
    resident: HashMap<(usize, usize), CacheEntry>,
    /// Monotone use counter for LRU.
    tick: u64,
    /// PCIe fetch stream (demand fetches and prefetches share bandwidth).
    stream: Stream,
    /// Per-layer expert sets routed in the previous iteration.
    history: Vec<Vec<usize>>,
    n_layers: usize,
    /// Stats.
    pub demand_fetches: u64,
    pub prefetches: u64,
    pub hits: u64,
    pub stall_s: f64,
    migrated: u64,
}

struct CacheEntry {
    last_use: u64,
    /// Modeled time the weights are fully on-device.
    ready_at: f64,
}

impl ExpertFlowBackend {
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
    ) -> Self {
        let dims = LogicalDims::for_preset(preset);
        // Offloading serves the full-precision model (fp16; int4 for the
        // 80B model) and caches as many experts as the envelope allows —
        // inherently single-precision, so it takes the ladder's top rung.
        let precision = preset.hi();
        let expert_bytes = dims.expert_bytes(precision);
        let avail = cfg.hbm_budget_bytes.saturating_sub(cfg.fixed_bytes);
        let capacity = (avail / expert_bytes).max(1);
        let n_layers = preset.n_layers_logical();
        Self {
            precision,
            capacity,
            expert_bytes,
            secs_per_byte: 1.0 / dev.pcie_bytes_per_s,
            resident: HashMap::new(),
            tick: 0,
            stream: Stream::new(),
            history: vec![Vec::new(); n_layers],
            n_layers,
            demand_fetches: 0,
            prefetches: 0,
            hits: 0,
            stall_s: 0.0,
            migrated: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Evict least-recently-used entries until one slot is free.
    fn make_room(&mut self) {
        while self.resident.len() >= self.capacity {
            let victim = self
                .resident
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(&k, _)| k);
            match victim {
                Some(k) => {
                    self.resident.remove(&k);
                }
                None => break,
            }
        }
    }

    fn fetch(&mut self, key: (usize, usize), now_s: f64) -> f64 {
        self.make_room();
        let done = self
            .stream
            .schedule(now_s, self.expert_bytes as f64 * self.secs_per_byte);
        self.migrated += self.expert_bytes as u64;
        self.tick += 1;
        self.resident.insert(
            key,
            CacheEntry { last_use: self.tick, ready_at: done },
        );
        done
    }
}

impl ResidencyBackend for ExpertFlowBackend {
    fn name(&self) -> &'static str {
        "expertflow"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        let mut set = experts.to_vec();
        set.sort_unstable();
        set.dedup();
        self.history[layer % self.n_layers] = set;
    }

    fn resolve(
        &mut self,
        layer: usize,
        expert: usize,
        now_s: f64,
    ) -> (Precision, f64) {
        let key = (layer, expert);
        self.tick += 1;
        let tick = self.tick;
        if let Some(entry) = self.resident.get_mut(&key) {
            entry.last_use = tick;
            // In flight (prefetch or an earlier demand fetch): wait for the
            // remaining transfer time only.
            let stall = (entry.ready_at - now_s).max(0.0);
            if stall == 0.0 {
                self.hits += 1;
            } else {
                self.stall_s += stall;
            }
            return (self.precision, stall);
        }
        // Miss → demand fetch on the critical path.
        self.demand_fetches += 1;
        let done = self.fetch(key, now_s);
        let stall = (done - now_s).max(0.0);
        self.stall_s += stall;
        (self.precision, stall)
    }

    fn tick(&mut self, now_s: f64) -> f64 {
        // Prefetch pass: keep the previous iteration's routed experts warm
        // for every layer (temporal locality). Prefetches ride the same
        // PCIe stream — they contend with demand fetches, which is exactly
        // the bandwidth pressure the paper describes.
        for layer in 0..self.n_layers {
            let wanted = self.history[layer].clone();
            for e in wanted {
                let key = (layer, e);
                if !self.resident.contains_key(&key) {
                    self.prefetches += 1;
                    self.fetch(key, now_s);
                }
            }
        }
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        self.migrated
    }

    fn hi_fraction(&self) -> f64 {
        1.0 // everything executes at base precision
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend(cap_override: Option<usize>) -> ExpertFlowBackend {
        let preset = ModelPreset::qwen30b_sim();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        let mut b = ExpertFlowBackend::new(&preset, &cfg, &dev);
        if let Some(c) = cap_override {
            b.capacity = c;
        }
        b
    }

    #[test]
    fn capacity_reflects_envelope() {
        let b = backend(None);
        // 48 GB budget − fixed, fp16 experts ≈ 9.4 MB → thousands of slots
        assert!(b.capacity() > 100);
        assert!(b.capacity() < 10_000);
    }

    #[test]
    fn first_touch_stalls_second_hit_free() {
        let mut b = backend(None);
        let (p, stall1) = b.resolve(0, 7, 0.0);
        assert_eq!(p, Precision::Fp16);
        assert!(stall1 > 0.0, "cold miss must stall");
        let later = stall1 + 1.0;
        let (_, stall2) = b.resolve(0, 7, later);
        assert_eq!(stall2, 0.0, "resident hit is free");
        assert_eq!(b.demand_fetches, 1);
        assert_eq!(b.hits, 1);
    }

    #[test]
    fn lru_eviction_under_pressure() {
        let mut b = backend(Some(2));
        b.resolve(0, 1, 0.0);
        b.resolve(0, 2, 10.0);
        b.resolve(0, 3, 20.0); // evicts (0,1)
        assert_eq!(b.resident.len(), 2);
        let (_, stall) = b.resolve(0, 1, 1000.0);
        assert!(stall > 0.0, "evicted expert must refetch");
    }

    #[test]
    fn prefetch_hides_latency_when_working_set_fits() {
        let mut b = backend(None);
        // iteration 1: route experts 0..8 at layer 0 (stalls)
        let mut now = 0.0;
        for e in 0..8 {
            let (_, s) = b.resolve(0, e, now);
            now += s + 1e-3;
        }
        b.record_routing(0, &(0..8).collect::<Vec<_>>());
        b.tick(now);
        // iteration 2 (same experts, later): all warm
        let later = now + 10.0;
        for e in 0..8 {
            let (_, s) = b.resolve(0, e, later);
            assert_eq!(s, 0.0, "expert {e} should be prefetched");
        }
    }

    #[test]
    fn dense_activation_overwhelms_cache() {
        // Working set ≫ capacity → every iteration pays fetch stalls even
        // with prefetch (the paper's structural limitation).
        let mut b = backend(Some(16));
        let mut now = 0.0;
        let mut total_stall = 0.0;
        for iter in 0..5 {
            let experts: Vec<usize> =
                (0..64).map(|i| (i + iter) % 128).collect();
            for &e in &experts {
                let (_, s) = b.resolve(0, e, now);
                total_stall += s;
                now += s + 1e-4;
            }
            b.record_routing(0, &experts);
            b.tick(now);
        }
        // 9.4 MB fp16 experts over 25 GB/s PCIe ≈ 0.38 ms each; hundreds
        // of refetches must accumulate visible waiting time.
        assert!(b.demand_fetches > 100, "fetches {}", b.demand_fetches);
        assert!(total_stall > 0.05, "stall {total_stall}");
    }
}
