//! Comparator systems the paper evaluates against, plus two extensions.
//!
//! * static PTQ lives in [`crate::serving::backend::StaticBackend`] (it is
//!   trivial — uniform precision, no transitions);
//! * [`expertflow`] — the offloading/prefetching comparator (paper §5.3);
//! * [`static_map`] — offline-calibrated per-expert mixed-precision map
//!   (MxMoE/MoPEQ-class; the static alternative Observation 2 targets);
//! * [`hobbit`] — reactive mixed-precision offloading (HOBBIT-class;
//!   isolates the value of DynaExq's long-horizon policy).

pub mod expertflow;
pub mod hobbit;
pub mod static_map;

pub use expertflow::ExpertFlowBackend;
pub use hobbit::HobbitBackend;
pub use static_map::StaticMapBackend;
