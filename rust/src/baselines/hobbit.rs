//! HOBBIT-like reactive mixed-precision offloading baseline.
//!
//! HOBBIT [29] replaces cache-miss experts with lower-precision versions to
//! avoid loading latency: every expert has a low-precision version always
//! available, a bounded high-precision cache holds recently used experts,
//! and a *miss* executes the low tier immediately while the high tier is
//! fetched in the background (reactively, on every miss — no long-horizon
//! hotness estimate, no hysteresis, no admission windows).
//!
//! Versus DynaExq this isolates the value of the *policy*: both systems
//! never stall, both respect the same budget; they differ in who occupies
//! the high-precision slots. Reactive LRU chases the most recent working
//! set and churns under dense/shifting routing; DynaExq's EMA top-n with
//! hysteresis keeps long-horizon hot experts pinned. Experiment A6.

use std::collections::HashMap;

use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::model::Precision;
use crate::serving::backend::ResidencyBackend;
use crate::sim::{LogicalDims, Stream};

/// Reactive hi-tier LRU cache with lo-tier fallback.
pub struct HobbitBackend {
    hi: Precision,
    lo: Precision,
    /// Hi-version slots the envelope affords (same math as DynaExq's plan).
    capacity: usize,
    hi_bytes: usize,
    secs_per_byte: f64,
    /// (layer, expert) → entry; usable once `ready_at` passes.
    cache: HashMap<(usize, usize), Entry>,
    tick: u64,
    stream: Stream,
    migrated: u64,
    resolves: u64,
    hi_resolves: u64,
}

struct Entry {
    last_use: u64,
    ready_at: f64,
}

impl HobbitBackend {
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
    ) -> Result<Self, String> {
        let dims = LogicalDims::for_preset(preset);
        // Identical envelope math to DynaExq's budget plan: base versions
        // of all experts resident, remaining slack buys hi slots. HOBBIT is
        // inherently two-tier, so it consumes the ladder's top and bottom
        // rungs (the degenerate case of the N-rung generalization).
        let plan = crate::coordinator::Coordinator::plan_for(preset, cfg)?;
        let capacity = plan.n_hi_per_layer() * preset.n_layers_logical();
        Ok(Self {
            hi: preset.hi(),
            lo: preset.lo(),
            capacity: capacity.max(1),
            hi_bytes: dims.expert_bytes(preset.hi()),
            secs_per_byte: 1.0 / dev.pcie_bytes_per_s,
            cache: HashMap::new(),
            tick: 0,
            stream: Stream::new(),
            migrated: 0,
            resolves: 0,
            hi_resolves: 0,
        })
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn evict_to_fit(&mut self) {
        while self.cache.len() >= self.capacity {
            let victim = self
                .cache
                .iter()
                .min_by_key(|(_, e)| e.last_use)
                .map(|(&k, _)| k);
            match victim {
                Some(k) => {
                    self.cache.remove(&k);
                }
                None => break,
            }
        }
    }
}

impl ResidencyBackend for HobbitBackend {
    fn name(&self) -> &'static str {
        "hobbit"
    }

    fn record_routing(&mut self, _layer: usize, _experts: &[usize]) {}

    fn resolve(
        &mut self,
        layer: usize,
        expert: usize,
        now_s: f64,
    ) -> (Precision, f64) {
        self.resolves += 1;
        self.tick += 1;
        let tick = self.tick;
        let key = (layer, expert);
        if let Some(e) = self.cache.get_mut(&key) {
            e.last_use = tick;
            if e.ready_at <= now_s {
                self.hi_resolves += 1;
                return (self.hi, 0.0); // hi hit, never a stall
            }
            // still in flight → run the lo fallback now
            return (self.lo, 0.0);
        }
        // Miss: run lo immediately, fetch hi reactively in the background.
        self.evict_to_fit();
        let done = self
            .stream
            .schedule(now_s, self.hi_bytes as f64 * self.secs_per_byte);
        self.migrated += self.hi_bytes as u64;
        self.cache.insert(key, Entry { last_use: tick, ready_at: done });
        (self.lo, 0.0)
    }

    fn tick(&mut self, _now_s: f64) -> f64 {
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        self.migrated
    }

    fn hi_fraction(&self) -> f64 {
        if self.resolves == 0 {
            0.0
        } else {
            self.hi_resolves as f64 / self.resolves as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> HobbitBackend {
        HobbitBackend::new(
            &ModelPreset::qwen30b_sim(),
            &ServingConfig::default(),
            &DeviceConfig::default(),
        )
        .unwrap()
    }

    #[test]
    fn never_stalls() {
        let mut b = backend();
        for e in 0..200 {
            let (_, stall) = b.resolve(0, e % 128, e as f64 * 1e-4);
            assert_eq!(stall, 0.0);
        }
    }

    #[test]
    fn miss_runs_lo_then_hi_after_fetch() {
        let mut b = backend();
        let (p1, _) = b.resolve(0, 5, 0.0);
        assert_eq!(p1, Precision::Int4, "cold miss → lo fallback");
        // long after the fetch completes → hi
        let (p2, _) = b.resolve(0, 5, 10.0);
        assert_eq!(p2, Precision::Fp16);
        assert!(b.migrated_bytes() > 0);
    }

    #[test]
    fn reactive_churn_under_rotation() {
        // rotating working set larger than capacity → every touch migrates
        let mut b = backend();
        b.capacity = 8;
        let before = |b: &HobbitBackend| b.migrated_bytes();
        let mut last = before(&b);
        for round in 0..4u64 {
            for e in 0..16usize {
                b.resolve(0, (e + round as usize) % 32, round as f64);
            }
            let now = b.migrated_bytes();
            assert!(now > last, "reactive policy keeps fetching");
            last = now;
        }
    }
}
