//! Static per-expert mixed-precision map (MxMoE / MoPEQ-class baseline).
//!
//! The strongest *static* alternative to DynaExq: an offline calibration
//! pass measures expert traffic on a calibration workload and fixes the
//! top-n experts per layer at the high tier — forever. No transitions, no
//! transfers, same memory budget as DynaExq.
//!
//! This is the baseline the paper's Observation 2 is aimed at: when the
//! serving workload matches calibration it performs like DynaExq, but
//! under workload shift the map "spends scarce memory budget on experts
//! that contribute little traffic ... while over-compressing the experts
//! that dominate execution". Experiment A5 quantifies exactly that.

use crate::model::Precision;
use crate::serving::backend::ResidencyBackend;

/// Fixed per-(layer, expert) precision assignment.
pub struct StaticMapBackend {
    n_experts: usize,
    map: Vec<Precision>, // [layer × expert]
    resolves: u64,
    hi_resolves: u64,
    hi: Precision,
}

impl StaticMapBackend {
    /// Build from an explicit hot set per layer.
    pub fn from_hot_sets(
        n_layers: usize,
        n_experts: usize,
        hi: Precision,
        lo: Precision,
        hot_sets: &[Vec<usize>],
    ) -> Self {
        let mut map = vec![lo; n_layers * n_experts];
        for (layer, hot) in hot_sets.iter().enumerate().take(n_layers) {
            for &e in hot {
                map[layer * n_experts + e] = hi;
            }
        }
        Self { n_experts, map, resolves: 0, hi_resolves: 0, hi }
    }

    /// Offline calibration: take per-(layer, expert) traffic counts and
    /// pin the top-`n_hi` per layer at the high tier.
    pub fn calibrated(
        n_layers: usize,
        n_experts: usize,
        hi: Precision,
        lo: Precision,
        counts: &[Vec<u64>],
        n_hi: usize,
    ) -> Self {
        let hot_sets: Vec<Vec<usize>> = counts
            .iter()
            .map(|layer_counts| {
                let mut idx: Vec<usize> = (0..layer_counts.len()).collect();
                idx.sort_by_key(|&e| std::cmp::Reverse(layer_counts[e]));
                idx.truncate(n_hi);
                idx
            })
            .collect();
        Self::from_hot_sets(n_layers, n_experts, hi, lo, &hot_sets)
    }

    /// The hot set of one layer (tests/diagnostics).
    pub fn hot_set(&self, layer: usize) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| self.map[layer * self.n_experts + e] == self.hi)
            .collect()
    }
}

impl ResidencyBackend for StaticMapBackend {
    fn name(&self) -> &'static str {
        "static-map"
    }

    fn record_routing(&mut self, _layer: usize, _experts: &[usize]) {}

    fn resolve(
        &mut self,
        layer: usize,
        expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        let p = self.map[layer * self.n_experts + expert];
        self.resolves += 1;
        if p == self.hi {
            self.hi_resolves += 1;
        }
        (p, 0.0)
    }

    fn tick(&mut self, _now_s: f64) -> f64 {
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        0
    }

    fn hi_fraction(&self) -> f64 {
        if self.resolves == 0 {
            0.0
        } else {
            self.hi_resolves as f64 / self.resolves as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_pins_top_n() {
        let counts = vec![vec![5u64, 100, 2, 50], vec![1, 1, 99, 1]];
        let mut b = StaticMapBackend::calibrated(
            2, 4, Precision::Fp16, Precision::Int4, &counts, 2,
        );
        assert_eq!(b.hot_set(0), vec![1, 3]);
        assert_eq!(b.resolve(0, 1, 0.0).0, Precision::Fp16);
        assert_eq!(b.resolve(0, 0, 0.0).0, Precision::Int4);
        assert_eq!(b.resolve(1, 2, 0.0).0, Precision::Fp16);
        assert_eq!(b.migrated_bytes(), 0);
    }

    #[test]
    fn hi_fraction_tracks_traffic_match() {
        let counts = vec![vec![100u64, 0, 0, 0]];
        let mut b = StaticMapBackend::calibrated(
            1, 4, Precision::Fp16, Precision::Int4, &counts, 1,
        );
        // traffic on the calibrated expert → high hi_fraction
        for _ in 0..10 {
            b.resolve(0, 0, 0.0);
        }
        assert_eq!(b.hi_fraction(), 1.0);
        // shifted traffic → hi_fraction collapses
        for _ in 0..10 {
            b.resolve(0, 3, 0.0);
        }
        assert_eq!(b.hi_fraction(), 0.5);
    }
}
