//! Fleet-scale replicated serving with failover (DESIGN.md §14).
//!
//! A [`Fleet`] is N replicated serving groups — each an independent
//! modeled [`Engine`] over its own `DeviceGroup`-backed residency stack —
//! standing behind **one** shared [`FrontDoor`]. Three mechanisms sit on
//! top of the replicas:
//!
//! * [`FleetRouter`] — places each admitted request by *load* and
//!   *hot-set affinity*: replicas are scored by the overlap between the
//!   request's expected expert set (sampled from the live workload's
//!   routing model) and the replica's hi-precision resident set
//!   ([`ResidencyBackend::resident_overlap`]), minus a load penalty.
//!   A replica that already holds a request's hot experts serves it
//!   without promotion traffic.
//! * [`HealthChecker`] — a deterministic modeled health checker: one
//!   heartbeat per replica per serve round, scripted by the scenario's
//!   [`FaultPlan`]. Consecutive failures walk a replica through
//!   [`ReplicaHealth::Degraded`] (deprioritized) to
//!   [`ReplicaHealth::Down`] (drained); a succeeding heartbeat restores
//!   it. No wall clock, no randomness at poll time — a fixed plan yields
//!   a byte-stable failover trajectory.
//! * **Failover** — when a replica goes `Down` (or is drained for
//!   elastic scale-in via [`Fleet::drain_replica`]), its in-flight
//!   requests are re-admitted through the front door with their token
//!   position preserved: the remainder request carries the original id
//!   and `output_len` minus the tokens already generated, re-enters via
//!   [`FrontDoor::readmit`] (never rejected, never re-counted), and
//!   completes on another replica. Every admitted request completes
//!   exactly once — token conservation is property-tested.
//!
//! Two degenerate configurations anchor correctness:
//!
//! * **1 replica, no faults, un-chunked** — the fleet is byte-identical
//!   to a bare front-doored `ServeSession` over the same seed/config
//!   ([`Fleet::replica_snapshot`] vs `ServeSession::snapshot`).
//! * **`parallel_drain`** — replicas of one drain round serve on
//!   concurrent threads; outcomes fold back in replica-index order, so
//!   the concurrent path is byte-identical to the serial reference
//!   (PR 7 determinism rule).
//!
//! [`FleetBackend`] is the *backend-level* projection of the same idea —
//! N sharded residency stacks behind one `ResidencyBackend` face, the
//! registry's `dynaexq-fleet` method — so the DXTR trace-replay
//! conformance suite exercises replicated routing without an engine.

use std::collections::{HashMap, HashSet};

use anyhow::{anyhow, bail, Result};

use crate::config::fleet::FleetConfig;
use crate::config::frontdoor::{FrontDoorConfig, Lane};
use crate::config::{
    DeviceConfig, ModelPreset, QosClass, QosConfig, ServingConfig,
};
use crate::metrics::ServingMetrics;
use crate::util::{mean, XorShiftRng};
use crate::workload::{
    FaultPlan, RequestGenerator, RoutingSampler, Scenario, WorkloadProfile,
};

use super::backend::{DynaExqShardedBackend, ResidencyBackend};
use super::engine::{ActiveRequest, Engine, EngineConfig};
use super::frontdoor::{FrontDoor, QueuedRequest, Rejected, SloScheduler};
use super::registry::{BackendCtx, BackendRegistry};
use super::session::MetricsSnapshot;
use crate::coordinator::TransitionTotals;
use crate::model::Precision;

// ---------------------------------------------------------------------------
// Health
// ---------------------------------------------------------------------------

/// Modeled health of one fleet replica.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicaHealth {
    /// Serving normally.
    Healthy,
    /// Consecutive heartbeat failures at or past `degraded_after`: still
    /// serving, deprioritized by the router.
    Degraded,
    /// Consecutive failures at or past `down_after`: drained, in-flight
    /// work failed over, excluded from routing until a heartbeat lands.
    Down,
    /// Administratively drained (elastic scale-in): healthy but taking
    /// no new work until [`Fleet::restore_replica`].
    Draining,
}

impl ReplicaHealth {
    /// Stable wire code for the snapshot's `fleet_health` field.
    pub fn code(self) -> u64 {
        match self {
            ReplicaHealth::Healthy => 0,
            ReplicaHealth::Degraded => 1,
            ReplicaHealth::Down => 2,
            ReplicaHealth::Draining => 3,
        }
    }

    /// Routing preference tier: lower serves first.
    fn tier(self) -> usize {
        match self {
            ReplicaHealth::Healthy => 0,
            ReplicaHealth::Degraded => 1,
            ReplicaHealth::Draining => 2,
            ReplicaHealth::Down => 3,
        }
    }
}

/// Deterministic consecutive-failure health checker: one observation per
/// replica per serve round, thresholds from [`FleetConfig`].
#[derive(Clone, Debug)]
pub struct HealthChecker {
    degraded_after: u32,
    down_after: u32,
    fails: Vec<u32>,
    states: Vec<ReplicaHealth>,
}

impl HealthChecker {
    pub fn new(replicas: usize, degraded_after: u32, down_after: u32) -> Self {
        Self {
            degraded_after: degraded_after.max(1),
            down_after: down_after.max(degraded_after.max(1)),
            fails: vec![0; replicas],
            states: vec![ReplicaHealth::Healthy; replicas],
        }
    }

    /// Record one heartbeat outcome; returns `(before, after)` states so
    /// the caller can act on the transition edge (failover fires exactly
    /// once, on the edge into `Down`). A draining replica stays
    /// `Draining` whatever its heartbeats say — only
    /// [`HealthChecker::restore`] releases it.
    pub fn observe(
        &mut self,
        replica: usize,
        ok: bool,
    ) -> (ReplicaHealth, ReplicaHealth) {
        let before = self.states[replica];
        if before == ReplicaHealth::Draining {
            return (before, before);
        }
        let after = if ok {
            self.fails[replica] = 0;
            ReplicaHealth::Healthy
        } else {
            self.fails[replica] = self.fails[replica].saturating_add(1);
            if self.fails[replica] >= self.down_after {
                ReplicaHealth::Down
            } else if self.fails[replica] >= self.degraded_after {
                ReplicaHealth::Degraded
            } else {
                ReplicaHealth::Healthy
            }
        };
        self.states[replica] = after;
        (before, after)
    }

    pub fn state(&self, replica: usize) -> ReplicaHealth {
        self.states[replica]
    }

    pub fn states(&self) -> &[ReplicaHealth] {
        &self.states
    }

    /// Administrative drain (elastic scale-in).
    pub fn set_draining(&mut self, replica: usize) {
        self.states[replica] = ReplicaHealth::Draining;
    }

    /// Release a drained (or failed) replica back to `Healthy` with a
    /// clean failure count (elastic scale-out / recovery).
    pub fn restore(&mut self, replica: usize) {
        self.fails[replica] = 0;
        self.states[replica] = ReplicaHealth::Healthy;
    }
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

/// Load + hot-set-affinity placement. Only the best available health
/// tier is eligible (Healthy, else Degraded, else Draining, else Down —
/// a request is *never* dropped for lack of a healthy replica); within
/// the tier the replica maximizing
/// `affinity_weight · overlap − load_weight · load` wins, ties to the
/// lowest index.
#[derive(Clone, Copy, Debug)]
pub struct FleetRouter {
    pub affinity_weight: f64,
    pub load_weight: f64,
}

impl FleetRouter {
    pub fn new(cfg: &FleetConfig) -> Self {
        Self {
            affinity_weight: cfg.affinity_weight,
            load_weight: cfg.load_weight,
        }
    }

    /// Pick the serving replica for one request. `overlaps[i]` is the
    /// hi-precision resident overlap of replica `i` with the request's
    /// expected expert set; `loads[i]` its in-flight plus already-assigned
    /// request count.
    pub fn pick(
        &self,
        states: &[ReplicaHealth],
        overlaps: &[usize],
        loads: &[usize],
    ) -> usize {
        let best_tier =
            states.iter().map(|h| h.tier()).min().unwrap_or(0);
        let mut best: Option<(f64, usize)> = None;
        for (i, h) in states.iter().enumerate() {
            if h.tier() != best_tier {
                continue;
            }
            let score = self.affinity_weight * overlaps[i] as f64
                - self.load_weight * loads[i] as f64;
            if best.map(|(bs, _)| score > bs).unwrap_or(true) {
                best = Some((score, i));
            }
        }
        best.map(|(_, i)| i).unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Fleet
// ---------------------------------------------------------------------------

/// Fleet-level outcome counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetStats {
    /// Replica drain events that stranded in-flight work (Down
    /// transitions and administrative drains).
    pub failovers: u64,
    /// Requests re-admitted through [`FrontDoor::readmit`] with their
    /// token position preserved.
    pub readmitted: u64,
}

/// N replicated engines behind one shared front door. See the module
/// docs for the serve-round flow; construct through [`FleetBuilder`].
pub struct Fleet {
    replicas: Vec<Engine>,
    fd: FrontDoor,
    cfg: FleetConfig,
    checker: HealthChecker,
    router: FleetRouter,
    faults: FaultPlan,
    round: usize,
    /// Per-replica in-flight decode batches (chunked streaming mode
    /// carries these across rounds; un-chunked mode empties them every
    /// round).
    active: Vec<Vec<ActiveRequest>>,
    /// Request id → (tenant index, effective lane) — failover needs the
    /// admission metadata of a stranded stream to re-admit it.
    meta: HashMap<u64, (usize, Lane)>,
    /// Engine admissions per replica (the snapshot's `fleet_served`).
    served_by_replica: Vec<u64>,
    stats: FleetStats,
    /// Fleet-owned routing model: samples each request's expected expert
    /// set for the router's affinity score. Separate RNG stream — never
    /// touches any replica engine's sampler state.
    sampler: RoutingSampler,
    rng: XorShiftRng,
    preset: ModelPreset,
    pub model: String,
    pub method: String,
    pub workload: String,
    seed: u64,
}

impl Fleet {
    pub fn builder() -> FleetBuilder {
        FleetBuilder::default()
    }

    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn config(&self) -> &FleetConfig {
        &self.cfg
    }

    pub fn frontdoor(&self) -> &FrontDoor {
        &self.fd
    }

    pub fn stats(&self) -> FleetStats {
        self.stats
    }

    pub fn health(&self) -> Vec<ReplicaHealth> {
        self.checker.states().to_vec()
    }

    /// Serve rounds completed so far (the health checker's clock).
    pub fn round(&self) -> usize {
        self.round
    }

    /// Fleet-wide modeled clock: the slowest replica's clock (replicas
    /// serve concurrently on independent modeled clocks).
    pub fn now(&self) -> f64 {
        self.replicas
            .iter()
            .map(|e| e.now())
            .fold(0.0f64, f64::max)
    }

    pub fn replica_metrics(&self, r: usize) -> &ServingMetrics {
        &self.replicas[r].metrics
    }

    /// Transition-pipeline counters summed across replicas (the bench
    /// harness reports these as deltas over the timed rounds).
    pub fn transition_totals(&self) -> TransitionTotals {
        let mut t = TransitionTotals::default();
        for e in &self.replicas {
            t.add(&e.backend.transition_totals());
        }
        t
    }

    /// Replace the scripted fault plan (scenario-independent driving).
    pub fn set_faults(&mut self, faults: FaultPlan) {
        self.faults = faults;
    }

    /// Switch every replica (and the fleet's affinity sampler) to a new
    /// workload profile.
    pub fn set_profile(&mut self, profile: &WorkloadProfile) {
        for e in &mut self.replicas {
            e.set_profile(profile);
        }
        self.sampler = RoutingSampler::new(
            profile,
            self.preset.n_layers_logical(),
            self.preset.n_experts,
            self.preset.top_k,
        );
        self.workload = profile.name.to_string();
    }

    /// Submit one request to the shared front door (never blocking; the
    /// typed [`Rejected`] is the backpressure signal).
    pub fn submit(
        &mut self,
        req: crate::workload::Request,
        tenant: &str,
        lane: Lane,
    ) -> std::result::Result<(), Rejected> {
        let now = self.now();
        self.fd.submit(req, tenant, lane, now)
    }

    /// Pin `tenant`'s QoS class at the shared front door and switch
    /// every replica's hotness-attribution class (scenario phase
    /// boundaries — DESIGN.md §15). Structurally a no-op when no
    /// non-degenerate [`QosConfig`] is armed.
    pub fn set_qos_class(&mut self, tenant: &str, class: QosClass) {
        self.fd.set_tenant_class(tenant, class);
        for e in &mut self.replicas {
            e.backend.set_active_class(class.index());
        }
    }

    /// Administratively drain a replica (elastic scale-in): it takes no
    /// new work and its in-flight streams fail over immediately.
    pub fn drain_replica(&mut self, r: usize) {
        self.checker.set_draining(r);
        self.failover(r);
    }

    /// Return a drained (or failed) replica to service (elastic
    /// scale-out).
    pub fn restore_replica(&mut self, r: usize) {
        self.checker.restore(r);
    }

    /// Re-admit every in-flight stream of replica `r` through the front
    /// door with its token position preserved: the remainder request
    /// keeps the original id and arrival, `output_len` drops to the
    /// tokens not yet generated. Prefill recomputes on the new replica
    /// (the KV cache died with the old one) — decode work is never
    /// repeated, so token conservation holds exactly.
    fn failover(&mut self, r: usize) {
        let stranded = std::mem::take(&mut self.active[r]);
        if stranded.is_empty() {
            return;
        }
        let names: Vec<String> = self
            .fd
            .tenant_served()
            .into_iter()
            .map(|(name, _)| name)
            .collect();
        let mut finished: Vec<u64> = Vec::new();
        for a in stranded {
            let remaining = a.req.output_len.saturating_sub(a.generated);
            if remaining == 0 {
                // the stream completed on the dying replica — settle its
                // QoS charge here since it will never re-enter a serve
                // round (readmitted remainders settle at completion, so
                // budget conservation holds exactly across failover)
                finished.push(a.req.id);
                continue;
            }
            let (tenant, lane) = self
                .meta
                .get(&a.req.id)
                .copied()
                .unwrap_or((0, Lane::Standard));
            let name =
                names.get(tenant).map(String::as_str).unwrap_or("default");
            let mut req = a.req;
            req.output_len = remaining;
            self.fd.readmit(req, name, lane);
            self.stats.readmitted += 1;
        }
        self.fd.settle(&finished);
        self.stats.failovers += 1;
    }

    /// One serve round: heartbeats → failover edges → route the drained
    /// queue across replicas → serve (continuations first in chunked
    /// mode) → fold outcomes back into the front door.
    pub fn drain(&mut self) -> Result<()> {
        let n = self.replicas.len();
        // 1. Heartbeats: scripted by the fault plan, graded by the
        // checker; the edge into Down fails the replica's streams over
        // *before* this round's routing, so they re-enter this round.
        for r in 0..n {
            let ok = self.faults.heartbeat_ok(r, self.round);
            let (before, after) = self.checker.observe(r, ok);
            if after == ReplicaHealth::Down && before != ReplicaHealth::Down {
                self.failover(r);
            }
        }
        // 2. Drain the shared queue and place each request.
        let (queued, served) = self.fd.take_queued();
        let mut assignments: Vec<Vec<QueuedRequest>> =
            (0..n).map(|_| Vec::new()).collect();
        if n == 1 {
            assignments[0] = queued;
        } else {
            let states = self.checker.states().to_vec();
            let mut overlaps = vec![0usize; n];
            let mut loads: Vec<usize> =
                self.active.iter().map(Vec::len).collect();
            for q in queued {
                let experts =
                    self.sampler.sample_topk(&mut self.rng, q.req.id, 0);
                for (i, e) in self.replicas.iter().enumerate() {
                    overlaps[i] = e.backend.resident_overlap(0, &experts);
                }
                let r = self.router.pick(&states, &overlaps, &loads);
                loads[r] += 1;
                assignments[r].push(q);
            }
        }
        // 3. Serve.
        match self.cfg.stream_chunk {
            None => self.serve_round_unchunked(assignments, &served)?,
            Some(chunk) => {
                self.serve_round_chunked(assignments, &served, chunk)
            }
        }
        self.round += 1;
        Ok(())
    }

    /// Un-chunked serve: every assigned request runs to completion inside
    /// its replica's [`SloScheduler`] drain — the exact shape of
    /// `ServeSession::drain`, per replica. With one replica this is
    /// byte-identical to the bare session path (`take_queued` +
    /// `scheduler_for` compose to `take_scheduled`).
    fn serve_round_unchunked(
        &mut self,
        mut assignments: Vec<Vec<QueuedRequest>>,
        served: &[u64],
    ) -> Result<()> {
        for (r, batch) in assignments.iter().enumerate() {
            self.served_by_replica[r] += batch.len() as u64;
        }
        // every assigned request completes inside this round, so its QoS
        // charge settles at the end of it (mirrors ServeSession::drain)
        let completed: Vec<u64> = assignments
            .iter()
            .flat_map(|b| b.iter().map(|q| q.req.id))
            .collect();
        if self.cfg.parallel_drain && self.replicas.len() > 1 {
            // Replicas are independent engines; serve them on scoped
            // threads and fold outcomes back in replica-index order, so
            // the result is byte-identical to the serial reference below.
            let fd = &self.fd;
            let scheds: Vec<Option<SloScheduler>> =
                std::thread::scope(|scope| {
                    let handles: Vec<_> = self
                        .replicas
                        .iter_mut()
                        .zip(assignments.drain(..))
                        .map(|(engine, batch)| {
                            scope.spawn(move || {
                                if batch.is_empty() {
                                    return None;
                                }
                                let (mut sched, reqs) =
                                    fd.scheduler_for(batch, served.to_vec());
                                engine.serve_with(&mut sched, reqs);
                                Some(sched)
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("replica serve panicked"))
                        .collect()
                });
            for sched in scheds.into_iter().flatten() {
                self.fd.absorb(&sched);
            }
        } else {
            for (r, batch) in assignments.into_iter().enumerate() {
                if batch.is_empty() {
                    continue;
                }
                let (mut sched, reqs) =
                    self.fd.scheduler_for(batch, served.to_vec());
                self.replicas[r].serve_with(&mut sched, reqs);
                self.fd.absorb(&sched);
            }
        }
        self.fd.settle(&completed);
        Ok(())
    }

    /// Chunked streaming serve: admit this round's arrivals, then run at
    /// most `chunk` lockstep decode rounds per replica; unfinished
    /// streams stay in the replica's active batch for the next round —
    /// the mid-stream surface failover needs. Admission accounting
    /// (per-lane TTFT, deadline misses, fair-share) folds back through
    /// the same [`FrontDoor::absorb`] path as the un-chunked mode.
    fn serve_round_chunked(
        &mut self,
        assignments: Vec<Vec<QueuedRequest>>,
        served: &[u64],
        chunk: usize,
    ) {
        for (r, batch) in assignments.into_iter().enumerate() {
            if batch.is_empty() && self.active[r].is_empty() {
                continue;
            }
            let mut sched = SloScheduler::new(self.fd.cfg().clone());
            sched.served_by_tenant = vec![0; served.len().max(1)];
            for q in batch {
                let arrival = q.req.arrival_s;
                let (tenant, lane, deadline) = (q.tenant, q.lane, q.deadline_s);
                self.meta.insert(q.req.id, (tenant, lane));
                let engine = &mut self.replicas[r];
                engine.admit(q.req, &mut self.active[r]);
                let ttft = engine
                    .metrics
                    .ttft
                    .samples()
                    .last()
                    .copied()
                    .unwrap_or(0.0);
                sched.lane_ttft[lane.index()].push(ttft);
                if arrival + ttft > deadline {
                    sched.deadline_miss[lane.index()] += 1;
                }
                if sched.served_by_tenant.len() <= tenant {
                    sched.served_by_tenant.resize(tenant + 1, 0);
                }
                sched.served_by_tenant[tenant] += 1;
                sched.admission_log.push((tenant, lane));
                self.served_by_replica[r] += 1;
            }
            let before: Vec<u64> =
                self.active[r].iter().map(|a| a.req.id).collect();
            for _ in 0..chunk {
                if self.active[r].is_empty() {
                    break;
                }
                self.replicas[r].decode_round(&mut self.active[r]);
            }
            // streams that left the active batch finished this round —
            // settle their QoS charges (a readmitted remainder settles
            // under its original id, refunding the original charge)
            let still: HashSet<u64> =
                self.active[r].iter().map(|a| a.req.id).collect();
            let done: Vec<u64> =
                before.into_iter().filter(|id| !still.contains(id)).collect();
            self.fd.settle(&done);
            self.replicas[r].metrics.duration_s = self.replicas[r].now();
            self.fd.absorb(&sched);
        }
    }

    /// Streams still in flight across the whole fleet (chunked mode).
    pub fn in_flight(&self) -> usize {
        self.active.iter().map(Vec::len).sum()
    }

    /// Run chunked continuations to completion (end-of-scenario flush):
    /// keeps serving rounds (heartbeats included) until no stream is in
    /// flight and the queue is empty. Bounded by `max_rounds` so a
    /// scripted total outage cannot spin forever.
    pub fn flush(&mut self, max_rounds: usize) -> Result<()> {
        for _ in 0..max_rounds {
            if self.in_flight() == 0 && self.fd.depth() == 0 {
                return Ok(());
            }
            self.drain()?;
        }
        if self.in_flight() == 0 && self.fd.depth() == 0 {
            Ok(())
        } else {
            bail!(
                "fleet flush did not converge in {max_rounds} rounds \
                 ({} in flight, queue depth {})",
                self.in_flight(),
                self.fd.depth()
            )
        }
    }

    /// Drive a scripted [`Scenario`] through the fleet — the same
    /// submit/drain loop as `ServeSession::run_scenario_frontdoor`
    /// (identical request generator seeding, so a 1-replica fleet
    /// reproduces the bare session byte for byte), plus the scenario's
    /// [`FaultPlan`] scripted into the health checker. Chunked
    /// configurations flush remaining streams before each phase mark.
    pub fn run_scenario(
        &mut self,
        scenario: &Scenario,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) -> Result<Vec<(String, MetricsSnapshot)>> {
        let Some(first) = scenario.phases.first() else {
            return Ok(Vec::new());
        };
        if !scenario.faults.is_empty() {
            self.faults = scenario.faults.clone();
        }
        let mut gen =
            RequestGenerator::new(first.profile.clone(), self.seed ^ 0xFD00);
        let mut marks = Vec::with_capacity(scenario.phases.len());
        for phase in &scenario.phases {
            self.set_profile(&phase.profile);
            gen.set_profile(phase.profile.clone());
            let tenant = phase
                .tenant
                .clone()
                .unwrap_or_else(|| phase.profile.name.to_string());
            if let Some(class) = phase.qos_class {
                self.set_qos_class(&tenant, class);
            }
            let b = Scenario::scaled_batch(batch, phase.load);
            for _ in 0..phase.rounds {
                let now = self.now();
                for _ in 0..b {
                    let req = gen.request(prompt_len, output_len, now);
                    // typed rejections are the backpressure signal — they
                    // land in the snapshot counters
                    let _ = self.fd.submit(req, &tenant, phase.lane, now);
                }
                self.drain()?;
            }
            if self.cfg.stream_chunk.is_some() {
                self.flush(4096)?;
            }
            marks.push((phase.name.clone(), self.snapshot()));
        }
        Ok(marks)
    }

    /// Shared snapshot scaffolding: everything except the residency /
    /// activation aggregates, which differ between the per-replica and
    /// fleet-level views.
    #[allow(clippy::too_many_arguments)]
    fn compose_snapshot(
        &self,
        m: &ServingMetrics,
        act: (f64, f64),
        hi_fraction: f64,
        migrated_bytes: u64,
        tier_resident: Vec<usize>,
        device_resident: Vec<Vec<usize>>,
        promo_queue_depth: Vec<usize>,
        drift: (u64, u64),
        qos_class_resolved: Vec<Vec<u64>>,
    ) -> MetricsSnapshot {
        let (qos_charged, qos_refunded, qos_downgraded, qos_budget_rejected) =
            if self.fd.qos_armed() {
                (
                    self.fd.qos_charged(),
                    self.fd.qos_refunded(),
                    self.fd.stats().qos_downgraded(),
                    self.fd.stats().budget_exhausted(),
                )
            } else {
                (Vec::new(), Vec::new(), 0, 0)
            };
        MetricsSnapshot {
            model: self.model.clone(),
            method: self.method.clone(),
            workload: self.workload.clone(),
            ttft_avg_s: m.ttft.avg(),
            ttft_p99_s: m.ttft.p99(),
            tpop_avg_s: m.tpop.avg(),
            tpop_p99_s: m.tpop.p99(),
            e2e_avg_s: m.e2e.avg(),
            e2e_p99_s: m.e2e.p99(),
            wait_p99_s: m.wait.p99(),
            throughput_tok_s: m.throughput(),
            decode_tokens: m.decode_tokens,
            prefill_tokens: m.prefill_tokens,
            duration_s: m.duration_s,
            hi_fraction,
            migrated_bytes,
            act_prefill: act.0,
            act_decode: act.1,
            tier_resident,
            device_resident,
            promo_queue_depth,
            drift_events: drift.0,
            drift_recovery_ticks: drift.1,
            fd_queue_depth: self.fd.depth() as u64,
            fd_lane_admitted: self.fd.stats().lane_admitted(),
            fd_lane_rejected: self.fd.stats().lane_rejected(),
            fd_lane_deadline_miss: self.fd.stats().lane_deadline_miss(),
            qos_class_resolved,
            qos_charged,
            qos_refunded,
            qos_downgraded,
            qos_budget_rejected,
            ..MetricsSnapshot::default()
        }
    }

    /// One replica's view, in exactly the shape a bare front-doored
    /// `ServeSession::snapshot` produces (fleet-level fields stay at
    /// their defaults) — the 1-replica byte-identity anchor.
    pub fn replica_snapshot(&self, r: usize) -> MetricsSnapshot {
        let e = &self.replicas[r];
        let b = e.backend.as_ref();
        self.compose_snapshot(
            &e.metrics,
            (e.activation.prefill_avg(), e.activation.decode_avg()),
            b.hi_fraction(),
            b.migrated_bytes(),
            b.tier_residency(),
            b.device_residency(),
            b.promo_queue_depth(),
            b.drift_stats(),
            b.class_tier_resolves(),
        )
    }

    /// The fleet-level snapshot: latency series concatenate in
    /// replica-index order, token counters add, duration is the slowest
    /// replica's span; residency rungs sum element-wise, per-device rows
    /// concatenate, and the per-replica health/served/failover state
    /// lands in the `fleet_*` fields.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let mut m = ServingMetrics::default();
        let mut pre: Vec<f64> = Vec::new();
        let mut dec: Vec<f64> = Vec::new();
        let mut migrated = 0u64;
        let mut tier: Vec<usize> = Vec::new();
        let mut devres: Vec<Vec<usize>> = Vec::new();
        let mut promo: Vec<usize> = Vec::new();
        let mut drift = (0u64, 0u64);
        let mut hi = Vec::new();
        let mut classed: Vec<Vec<u64>> = Vec::new();
        for e in &self.replicas {
            m.merge(&e.metrics);
            pre.extend_from_slice(&e.activation.prefill);
            dec.extend_from_slice(&e.activation.decode);
            let b = e.backend.as_ref();
            migrated += b.migrated_bytes();
            hi.push(b.hi_fraction());
            let t = b.tier_residency();
            if tier.len() < t.len() {
                tier.resize(t.len(), 0);
            }
            for (i, n) in t.into_iter().enumerate() {
                tier[i] += n;
            }
            devres.extend(b.device_residency());
            promo.extend(b.promo_queue_depth());
            let d = b.drift_stats();
            drift.0 += d.0;
            drift.1 += d.1;
            // per-class tier counters sum element-wise, like the rungs
            for (c, row) in b.class_tier_resolves().into_iter().enumerate() {
                if classed.len() <= c {
                    classed.resize(c + 1, Vec::new());
                }
                if classed[c].len() < row.len() {
                    classed[c].resize(row.len(), 0);
                }
                for (t, n) in row.into_iter().enumerate() {
                    classed[c][t] += n;
                }
            }
        }
        let mut s = self.compose_snapshot(
            &m,
            (mean(&pre), mean(&dec)),
            mean(&hi),
            migrated,
            tier,
            devres,
            promo,
            drift,
            classed,
        );
        s.fleet_replicas = self.replicas.len() as u64;
        s.fleet_health =
            self.checker.states().iter().map(|h| h.code()).collect();
        s.fleet_served = self.served_by_replica.clone();
        s.fleet_failovers = self.stats.failovers;
        s.fleet_readmitted = self.stats.readmitted;
        s
    }
}

/// Fluent, validating constructor for [`Fleet`] — the replicated
/// counterpart of `SessionBuilder`, with identical defaults so a
/// 1-replica fleet reproduces the default front-doored session.
pub struct FleetBuilder {
    model: String,
    method: String,
    workload: String,
    device: DeviceConfig,
    serving_cfg: ServingConfig,
    max_batch: usize,
    seed: u64,
    warmup: usize,
    track_activation: bool,
    registry: Option<BackendRegistry>,
    frontdoor: FrontDoorConfig,
    fleet: FleetConfig,
    faults: FaultPlan,
    qos: Option<QosConfig>,
}

impl Default for FleetBuilder {
    fn default() -> Self {
        Self {
            model: "qwen30b-sim".into(),
            method: "dynaexq".into(),
            workload: "text".into(),
            device: DeviceConfig::default(),
            serving_cfg: ServingConfig::default(),
            max_batch: 32,
            seed: 0xC0FFEE,
            warmup: 0,
            track_activation: true,
            registry: None,
            frontdoor: FrontDoorConfig::default(),
            fleet: FleetConfig::default(),
            faults: FaultPlan::none(),
            qos: None,
        }
    }
}

impl FleetBuilder {
    pub fn model(mut self, name: &str) -> Self {
        self.model = name.to_string();
        self
    }

    pub fn method(mut self, name: &str) -> Self {
        self.method = name.to_string();
        self
    }

    pub fn workload(mut self, name: &str) -> Self {
        self.workload = name.to_string();
        self
    }

    pub fn device(mut self, dev: DeviceConfig) -> Self {
        self.device = dev;
        self
    }

    pub fn serving_cfg(mut self, cfg: ServingConfig) -> Self {
        self.serving_cfg = cfg;
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn warmup(mut self, rounds: usize) -> Self {
        self.warmup = rounds;
        self
    }

    pub fn track_activation(mut self, on: bool) -> Self {
        self.track_activation = on;
        self
    }

    pub fn registry(mut self, registry: BackendRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    pub fn frontdoor(mut self, cfg: FrontDoorConfig) -> Self {
        self.frontdoor = cfg;
        self
    }

    /// Class-weighted allocation config (DESIGN.md §15): validated at
    /// build time against the serving HBM envelope, shared by the front
    /// door's budget ledger and every replica's coordinator.
    pub fn qos(mut self, cfg: QosConfig) -> Self {
        self.qos = Some(cfg);
        self
    }

    pub fn fleet_cfg(mut self, cfg: FleetConfig) -> Self {
        self.fleet = cfg;
        self
    }

    /// Convenience: replica count with the rest of [`FleetConfig`] at
    /// defaults already set.
    pub fn replicas(mut self, n: usize) -> Self {
        self.fleet.replicas = n;
        self
    }

    /// Scripted replica faults (a scenario's own plan overrides this
    /// when non-empty).
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// Validate everything and construct the fleet: every replica gets
    /// an identical engine (same method, config, and seed — replicas are
    /// deterministic twins whose residency only diverges with traffic).
    pub fn build(self) -> Result<Fleet> {
        self.fleet.validate().map_err(|e| anyhow!("fleet: {e}"))?;
        let preset = ModelPreset::by_name(&self.model).ok_or_else(|| {
            anyhow!(
                "unknown model {:?}; known models: {}",
                self.model,
                ModelPreset::all()
                    .iter()
                    .map(|p| p.name)
                    .collect::<Vec<_>>()
                    .join(", ")
            )
        })?;
        let profile =
            WorkloadProfile::by_name(&self.workload).ok_or_else(|| {
                anyhow!(
                    "unknown workload {:?}; known workloads: {}",
                    self.workload,
                    WorkloadProfile::all()
                        .iter()
                        .map(|p| p.name)
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
        if self.max_batch == 0 {
            bail!("max_batch must be ≥ 1");
        }
        let registry =
            self.registry.unwrap_or_else(BackendRegistry::with_builtins);
        let mut serving_cfg = self.serving_cfg;
        let mut frontdoor_cfg = self.frontdoor;
        if let Some(q) = self.qos {
            q.validate().map_err(|e| anyhow!("qos: {e}"))?;
            q.validate_budgets(serving_cfg.hbm_budget_bytes)
                .map_err(|e| anyhow!("qos: {e}"))?;
            frontdoor_cfg.qos = Some(q.clone());
            serving_cfg.qos = Some(q);
        }
        let fd = FrontDoor::new(frontdoor_cfg)
            .map_err(|e| anyhow!("front door: {e}"))?;
        let n = self.fleet.replicas;
        let mut replicas = Vec::with_capacity(n);
        for _ in 0..n {
            let backend = registry
                .build(
                    &self.method,
                    &BackendCtx::new(&preset, &serving_cfg, &self.device)
                        .with_profile(&profile)
                        .with_devices(self.fleet.devices_per_replica),
                )
                .map_err(|e| anyhow!(e))?;
            let mut engine = Engine::new(
                &preset,
                &profile,
                backend,
                &self.device,
                EngineConfig {
                    max_batch: self.max_batch,
                    seed: self.seed,
                    track_activation: self.track_activation,
                },
            );
            engine.warm(&profile, self.warmup);
            replicas.push(engine);
        }
        let sampler = RoutingSampler::new(
            &profile,
            preset.n_layers_logical(),
            preset.n_experts,
            preset.top_k,
        );
        Ok(Fleet {
            checker: HealthChecker::new(
                n,
                self.fleet.degraded_after,
                self.fleet.down_after,
            ),
            router: FleetRouter::new(&self.fleet),
            active: (0..n).map(|_| Vec::new()).collect(),
            served_by_replica: vec![0; n],
            meta: HashMap::new(),
            stats: FleetStats::default(),
            rng: XorShiftRng::new(self.seed ^ 0xF1EE7),
            sampler,
            replicas,
            fd,
            cfg: self.fleet,
            faults: self.faults,
            round: 0,
            preset,
            model: self.model,
            method: self.method,
            workload: self.workload,
            seed: self.seed,
        })
    }
}

// ---------------------------------------------------------------------------
// FleetBackend — the registry's `dynaexq-fleet` method
// ---------------------------------------------------------------------------

/// Backend-level replication: N sharded DynaExq stacks behind one
/// [`ResidencyBackend`] face. Routing records and resolutions hit the
/// *current* replica; every tick runs all replicas' control loops
/// (concurrently when wider than one — with a serial byte-identity
/// reference, [`FleetBackend::set_serial`]), polls the scripted
/// heartbeats, and re-picks the current replica by hi-precision overlap
/// with the last observed layer-0 expert set among non-`Down` replicas.
/// This is what the DXTR trace-replay conformance suite drives.
pub struct FleetBackend {
    replicas: Vec<DynaExqShardedBackend>,
    current: usize,
    checker: HealthChecker,
    faults: FaultPlan,
    round: usize,
    /// Layer-0 selections of the current iteration — the affinity signal
    /// for the next re-pick.
    last_routed: Vec<usize>,
    /// Force the serial tick path (byte-identity reference).
    serial: bool,
}

impl FleetBackend {
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
        devices_per_replica: usize,
        replicas: usize,
    ) -> Result<Self, String> {
        if replicas == 0 {
            return Err("fleet backend needs at least 1 replica".into());
        }
        let mut built = Vec::with_capacity(replicas);
        for _ in 0..replicas {
            built.push(DynaExqShardedBackend::new(
                preset,
                cfg,
                dev,
                devices_per_replica.max(1),
            )?);
        }
        Ok(Self {
            replicas: built,
            current: 0,
            checker: HealthChecker::new(replicas, 1, 2),
            faults: FaultPlan::none(),
            round: 0,
            last_routed: Vec::new(),
            serial: false,
        })
    }

    /// Script replica heartbeats (deterministic fault injection).
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Force the serial tick path — the byte-identity reference the
    /// concurrent path is tested against.
    pub fn set_serial(mut self, serial: bool) -> Self {
        self.serial = serial;
        self
    }

    /// Replica currently serving resolutions.
    pub fn current(&self) -> usize {
        self.current
    }

    pub fn health(&self) -> Vec<ReplicaHealth> {
        self.checker.states().to_vec()
    }

    fn tick_all(&mut self, now_s: f64) -> Vec<f64> {
        if self.serial || self.replicas.len() == 1 {
            return self.replicas.iter_mut().map(|b| b.tick(now_s)).collect();
        }
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .replicas
                .iter_mut()
                .map(|b| scope.spawn(move || b.tick(now_s)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("fleet replica tick panicked"))
                .collect()
        })
    }
}

impl ResidencyBackend for FleetBackend {
    fn name(&self) -> &'static str {
        "dynaexq-fleet"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        if layer == 0 {
            self.last_routed.clear();
            self.last_routed.extend_from_slice(experts);
        }
        self.replicas[self.current].record_routing(layer, experts);
    }

    fn resolve(
        &mut self,
        layer: usize,
        expert: usize,
        now_s: f64,
    ) -> (Precision, f64) {
        self.replicas[self.current].resolve(layer, expert, now_s)
    }

    fn tick(&mut self, now_s: f64) -> f64 {
        for r in 0..self.replicas.len() {
            let ok = self.faults.heartbeat_ok(r, self.round);
            self.checker.observe(r, ok);
        }
        let stalls = self.tick_all(now_s);
        let stall = stalls[self.current];
        // Re-pick the serving replica: best hi-precision overlap with
        // the last observed layer-0 expert set among non-Down replicas
        // (ties to the lowest index; total outage keeps the incumbent).
        let mut best: Option<(usize, usize)> = None;
        for (i, b) in self.replicas.iter().enumerate() {
            if self.checker.state(i) == ReplicaHealth::Down {
                continue;
            }
            let overlap = b.resident_overlap(0, &self.last_routed);
            if best.map(|(bo, _)| overlap > bo).unwrap_or(true) {
                best = Some((overlap, i));
            }
        }
        if let Some((_, i)) = best {
            self.current = i;
        }
        self.round += 1;
        stall
    }

    fn migrated_bytes(&self) -> u64 {
        self.replicas.iter().map(|b| b.migrated_bytes()).sum()
    }

    fn hi_fraction(&self) -> f64 {
        self.replicas[self.current].hi_fraction()
    }

    fn tier_fractions(&self) -> Vec<f64> {
        self.replicas[self.current].tier_fractions()
    }

    fn tier_residency(&self) -> Vec<usize> {
        let mut tier: Vec<usize> = Vec::new();
        for b in &self.replicas {
            let t = b.tier_residency();
            if tier.len() < t.len() {
                tier.resize(t.len(), 0);
            }
            for (i, n) in t.into_iter().enumerate() {
                tier[i] += n;
            }
        }
        tier
    }

    fn n_devices(&self) -> usize {
        self.replicas[self.current].n_devices()
    }

    fn device_of(&self, layer: usize, expert: usize) -> usize {
        self.replicas[self.current].device_of(layer, expert)
    }

    fn device_residency(&self) -> Vec<Vec<usize>> {
        self.replicas.iter().flat_map(|b| b.device_residency()).collect()
    }

    fn promo_queue_depth(&self) -> Vec<usize> {
        self.replicas.iter().flat_map(|b| b.promo_queue_depth()).collect()
    }

    fn drift_stats(&self) -> (u64, u64) {
        self.replicas.iter().fold((0, 0), |acc, b| {
            let d = b.drift_stats();
            (acc.0 + d.0, acc.1 + d.1)
        })
    }

    fn within_envelope(&self) -> bool {
        self.replicas.iter().all(|b| b.within_envelope())
    }

    fn sync_staging(&mut self) {
        for b in &mut self.replicas {
            b.sync_staging();
        }
    }

    fn transition_totals(&self) -> TransitionTotals {
        let mut t = TransitionTotals::default();
        for b in &self.replicas {
            t.add(&b.transition_totals());
        }
        t
    }

    fn resident_overlap(&self, layer: usize, experts: &[usize]) -> usize {
        self.replicas[self.current].resident_overlap(layer, experts)
    }

    fn set_active_class(&mut self, class: usize) {
        for b in &mut self.replicas {
            b.set_active_class(class);
        }
    }

    fn class_tier_resolves(&self) -> Vec<Vec<u64>> {
        let mut out: Vec<Vec<u64>> = Vec::new();
        for b in &self.replicas {
            for (c, row) in b.class_tier_resolves().into_iter().enumerate() {
                if out.len() <= c {
                    out.resize(c + 1, Vec::new());
                }
                if out[c].len() < row.len() {
                    out[c].resize(row.len(), 0);
                }
                for (t, n) in row.into_iter().enumerate() {
                    out[c][t] += n;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn health_checker_walks_degraded_to_down_and_back() {
        let mut hc = HealthChecker::new(2, 2, 3);
        assert_eq!(hc.state(0), ReplicaHealth::Healthy);
        assert_eq!(hc.observe(0, false).1, ReplicaHealth::Healthy); // 1 fail
        assert_eq!(hc.observe(0, false).1, ReplicaHealth::Degraded); // 2
        let (before, after) = hc.observe(0, false); // 3 → Down edge
        assert_eq!((before, after), (ReplicaHealth::Degraded, ReplicaHealth::Down));
        assert_eq!(hc.observe(0, false).0, ReplicaHealth::Down); // stays
        assert_eq!(hc.observe(0, true).1, ReplicaHealth::Healthy); // recovers
        assert_eq!(hc.state(1), ReplicaHealth::Healthy, "isolated per replica");
    }

    #[test]
    fn health_checker_draining_is_sticky_until_restore() {
        let mut hc = HealthChecker::new(1, 1, 2);
        hc.set_draining(0);
        assert_eq!(hc.observe(0, true).1, ReplicaHealth::Draining);
        assert_eq!(hc.observe(0, false).1, ReplicaHealth::Draining);
        hc.restore(0);
        assert_eq!(hc.state(0), ReplicaHealth::Healthy);
        // the pre-drain failure streak was cleared by restore
        assert_eq!(hc.observe(0, false).1, ReplicaHealth::Degraded);
    }

    #[test]
    fn router_scores_affinity_minus_load_within_best_tier() {
        let r = FleetRouter { affinity_weight: 1.0, load_weight: 4.0 };
        let healthy = [ReplicaHealth::Healthy, ReplicaHealth::Healthy];
        // equal load: higher overlap wins
        assert_eq!(r.pick(&healthy, &[2, 7], &[1, 1]), 1);
        // overlap cannot beat a 2-request load gap at weight 4
        assert_eq!(r.pick(&healthy, &[7, 2], &[3, 1]), 1);
        // ties go to the lowest index
        assert_eq!(r.pick(&healthy, &[3, 3], &[1, 1]), 0);
        // a Degraded replica is ineligible while a Healthy one exists…
        let mixed = [ReplicaHealth::Degraded, ReplicaHealth::Healthy];
        assert_eq!(r.pick(&mixed, &[100, 0], &[0, 50]), 1);
        // …but serves when it is the best tier left
        let worst = [ReplicaHealth::Down, ReplicaHealth::Degraded];
        assert_eq!(r.pick(&worst, &[0, 0], &[0, 0]), 1);
        // total outage still places the request (never dropped)
        let out = [ReplicaHealth::Down, ReplicaHealth::Down];
        assert_eq!(r.pick(&out, &[0, 0], &[0, 0]), 0);
    }

    #[test]
    fn fleet_backend_concurrent_tick_matches_serial() {
        let preset = ModelPreset::phi_sim();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        let build = |serial: bool| {
            FleetBackend::new(&preset, &cfg, &dev, 2, 2)
                .unwrap()
                .set_serial(serial)
        };
        let mut par = build(false);
        let mut ser = build(true);
        let mut now = 0.0;
        for round in 0..12 {
            let hot: Vec<usize> = (0..4).map(|i| (round + i) % 16).collect();
            for b in [&mut par, &mut ser] {
                b.record_routing(0, &hot);
                b.record_routing(1, &hot);
                for &e in &hot {
                    b.resolve(0, e, now);
                }
            }
            now += 0.06;
            let (sp, ss) = (par.tick(now), ser.tick(now));
            assert_eq!(sp, ss, "round {round} stall");
        }
        assert_eq!(par.current(), ser.current());
        assert_eq!(par.migrated_bytes(), ser.migrated_bytes());
        assert_eq!(par.tier_residency(), ser.tier_residency());
        assert_eq!(par.hi_fraction(), ser.hi_fraction());
        assert_eq!(par.transition_totals(), ser.transition_totals());
    }

    #[test]
    fn fleet_backend_fails_over_off_a_down_replica() {
        let preset = ModelPreset::phi_sim();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        let mut b = FleetBackend::new(&preset, &cfg, &dev, 1, 2)
            .unwrap()
            .with_faults(FaultPlan::fail(0, 0));
        assert_eq!(b.current(), 0);
        let mut now = 0.0;
        for _ in 0..3 {
            b.record_routing(0, &[0, 1]);
            b.resolve(0, 0, now);
            now += 0.06;
            b.tick(now);
        }
        // down_after = 2 consecutive failed heartbeats → replica 0 Down,
        // resolutions move to replica 1
        assert_eq!(b.health()[0], ReplicaHealth::Down);
        assert_eq!(b.current(), 1);
    }

    #[test]
    fn single_replica_fleet_serves_and_snapshots() {
        let mut f = Fleet::builder()
            .model("phi-sim")
            .method("dynaexq")
            .seed(9)
            .build()
            .unwrap();
        assert_eq!(f.replicas(), 1);
        let marks = f.run_scenario(&Scenario::steady(), 2, 16, 2).unwrap();
        assert_eq!(marks.len(), 1);
        let s = f.snapshot();
        assert_eq!(s.fleet_replicas, 1);
        assert_eq!(s.fleet_health, vec![0]);
        assert_eq!(s.fleet_failovers, 0);
        assert!(s.decode_tokens > 0);
        assert_eq!(MetricsSnapshot::decode(&s.encode()).unwrap(), s);
        // the per-replica view keeps the fleet fields at defaults
        let r0 = f.replica_snapshot(0);
        assert_eq!(r0.fleet_replicas, 0);
        assert_eq!(r0.decode_tokens, s.decode_tokens);
    }

    #[test]
    fn qos_fleet_charges_settle_across_scenario_and_failover() {
        use crate::config::{QosClass, QosConfig};
        let mut fleet_cfg = FleetConfig::default();
        fleet_cfg.replicas = 2;
        fleet_cfg.stream_chunk = Some(1);
        let mut f = Fleet::builder()
            .model("phi-sim")
            .method("dynaexq")
            .seed(11)
            .fleet_cfg(fleet_cfg)
            .qos(QosConfig::tiered())
            .build()
            .unwrap();
        assert!(f.frontdoor().qos_armed());
        let sc = Scenario::multi_tenant()
            .with_faults(FaultPlan::fail(1, 2).and_recover(1, 6));
        let marks = f.run_scenario(&sc, 2, 16, 2).unwrap();
        assert!(!marks.is_empty());
        let s = f.snapshot();
        // every admitted request finished (chunked phases flush), so the
        // per-class ledger balances exactly — including the streams that
        // failed over mid-decode and completed elsewhere
        assert_eq!(s.qos_charged, s.qos_refunded);
        assert!(s.qos_charged.iter().sum::<u64>() > 0);
        assert_eq!(s.qos_class_resolved.len(), QosClass::ALL.len());
        assert_eq!(MetricsSnapshot::decode(&s.encode()).unwrap(), s);
        // degenerate configs never arm the fleet's ledger
        let d = Fleet::builder()
            .model("phi-sim")
            .method("dynaexq")
            .qos(QosConfig::degenerate())
            .build()
            .unwrap();
        assert!(!d.frontdoor().qos_armed());
        // budgets beyond the serving envelope are refused at build time
        let err = Fleet::builder()
            .model("phi-sim")
            .qos(
                QosConfig::tiered()
                    .with_budget(QosClass::Premium, u64::MAX),
            )
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("qos"), "{err}");
        assert!(err.contains("envelope"), "{err}");
    }

    #[test]
    fn builder_validates_fleet_config_and_names() {
        let mut bad = FleetConfig::default();
        bad.replicas = 0;
        let err =
            Fleet::builder().fleet_cfg(bad).build().unwrap_err().to_string();
        assert!(err.contains("replicas"), "{err}");
        let err =
            Fleet::builder().model("gpt5").build().unwrap_err().to_string();
        assert!(err.contains("qwen30b-sim"), "{err}");
        let err = Fleet::builder()
            .method("magic")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("dynaexq"), "{err}");
    }
}
