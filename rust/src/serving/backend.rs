//! Residency backends: how each serving method decides expert precision
//! and what it costs on the critical path.
//!
//! The engine is method-agnostic: it asks the backend which precision an
//! expert executes at *now* and how many seconds of critical-path stall the
//! resolution incurred (0 for DynaExq and static PTQ; fetch-wait time for
//! offloading systems when the expert is not resident).

use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::coordinator::Coordinator;
use crate::model::Precision;

/// A serving method's residency behaviour.
pub trait ResidencyBackend: Send {
    fn name(&self) -> &'static str;

    /// Router outputs for one iteration at `layer` (one entry per
    /// (token, k) selection, duplicates included).
    fn record_routing(&mut self, layer: usize, experts: &[usize]);

    /// Precision the expert executes at plus critical-path stall seconds.
    fn resolve(&mut self, layer: usize, expert: usize, now_s: f64)
        -> (Precision, f64);

    /// Iteration boundary; returns an additional forced stall (only the
    /// blocking-transition ablation returns non-zero).
    fn tick(&mut self, now_s: f64) -> f64;

    /// Total bytes moved host→device so far (modeled).
    fn migrated_bytes(&self) -> u64;

    /// Fraction of resolutions served at the ladder's top rung
    /// (diagnostics).
    fn hi_fraction(&self) -> f64 {
        0.0
    }

    /// Fraction of resolutions served at each ladder rung, tier 0 first
    /// (empty when the backend does not track per-rung occupancy).
    fn tier_fractions(&self) -> Vec<f64> {
        Vec::new()
    }

    /// Published residency counts per ladder rung, tier 0 first (empty
    /// when the backend has no residency table).
    fn tier_residency(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Drive all pending residency work to completion and freeze the
    /// precision map (quality harnesses measure a *converged, pinned*
    /// configuration, like the paper's per-window pinning). Returns the
    /// modeled time at which the system is quiescent.
    fn quiesce(&mut self, now_s: f64) -> f64 {
        now_s
    }

    /// Calibration counts, if this backend records them (CountingBackend).
    fn counts_view(&self) -> Option<&[Vec<u64>]> {
        None
    }
}

// ---------------------------------------------------------------------------
// DynaExq
// ---------------------------------------------------------------------------

/// The paper's system: coordinator-driven online precision allocation
/// over the preset's ladder (2-rung presets behave exactly like the
/// original binary hi/lo system).
pub struct DynaExqBackend {
    pub coord: Coordinator,
    blocking: bool,
    resolves: u64,
    /// Resolutions served per rung, tier 0 first.
    tier_resolves: Vec<u64>,
}

impl DynaExqBackend {
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
    ) -> Result<Self, String> {
        Ok(Self::from_coordinator(
            Coordinator::new(preset, cfg, dev)?,
            cfg.blocking_transitions,
        ))
    }

    pub fn from_coordinator(coord: Coordinator, blocking: bool) -> Self {
        let n_tiers = coord.preset.ladder.n_tiers();
        Self { coord, blocking, resolves: 0, tier_resolves: vec![0; n_tiers] }
    }
}

impl ResidencyBackend for DynaExqBackend {
    fn name(&self) -> &'static str {
        "dynaexq"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        self.coord.record_routing(layer, experts);
    }

    fn resolve(
        &mut self,
        layer: usize,
        expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        // Stable-handle resolution: one atomic load, never a stall.
        let tier = self.coord.resolve_tier(layer, expert);
        self.resolves += 1;
        self.tier_resolves[tier] += 1;
        (self.coord.preset.ladder.tier(tier), 0.0)
    }

    fn tick(&mut self, now_s: f64) -> f64 {
        let report = self.coord.tick(now_s);
        if self.blocking && report.ran {
            // Ablation A3: synchronize the forward pass with the migration
            // stream, as a transition design without VER would.
            self.coord.pipeline.wait_staged();
            let stall =
                (self.coord.pipeline.migration_tail() - now_s).max(0.0);
            self.coord.pipeline.poll(now_s + stall);
            return stall;
        }
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        self.coord
            .pipeline
            .stats
            .migrated_bytes
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    fn hi_fraction(&self) -> f64 {
        if self.resolves == 0 {
            0.0
        } else {
            self.tier_resolves[0] as f64 / self.resolves as f64
        }
    }

    fn tier_fractions(&self) -> Vec<f64> {
        if self.resolves == 0 {
            return vec![0.0; self.tier_resolves.len()];
        }
        self.tier_resolves
            .iter()
            .map(|&n| n as f64 / self.resolves as f64)
            .collect()
    }

    fn tier_residency(&self) -> Vec<usize> {
        self.coord.handles.tier_counts()
    }

    fn quiesce(&mut self, now_s: f64) -> f64 {
        // Alternate policy updates and migration-event publication until
        // the target residency is materialized, then advance far enough
        // that no further update fires mid-measurement.
        let interval = self.coord.cfg.update_interval_ms / 1e3;
        let mut now = now_s;
        for _ in 0..8 {
            now += interval + 1e-9;
            self.coord.tick(now);
            self.coord.pipeline.wait_staged();
            now = now.max(self.coord.pipeline.migration_tail());
            self.coord.pipeline.poll(now);
        }
        now
    }
}

// ---------------------------------------------------------------------------
// Static PTQ
// ---------------------------------------------------------------------------

/// Uniform static quantization: every expert at `precision`, forever.
/// No transfers, no transitions — the paper's lowest-latency baseline.
pub struct StaticBackend {
    precision: Precision,
}

impl StaticBackend {
    pub fn new(precision: Precision) -> Self {
        Self { precision }
    }

    /// The paper's budget-driven choice: the ladder's base rung (Int4
    /// where it fits, Int2 for the 80B model, §5.3).
    pub fn for_preset(preset: &ModelPreset) -> Self {
        Self::new(preset.lo())
    }
}

impl ResidencyBackend for StaticBackend {
    fn name(&self) -> &'static str {
        "static-ptq"
    }

    fn record_routing(&mut self, _layer: usize, _experts: &[usize]) {}

    fn resolve(
        &mut self,
        _layer: usize,
        _expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        (self.precision, 0.0)
    }

    fn tick(&mut self, _now_s: f64) -> f64 {
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// Counting (calibration) backend
// ---------------------------------------------------------------------------

/// Fixed-precision backend that records per-(layer, expert) routing counts
/// — the offline calibration pass used to build static mixed-precision
/// maps (baseline A5) and for trace analysis.
pub struct CountingBackend {
    precision: Precision,
    counts: Vec<Vec<u64>>,
}

impl CountingBackend {
    pub fn new(n_layers: usize, n_experts: usize, precision: Precision) -> Self {
        Self { precision, counts: vec![vec![0; n_experts]; n_layers] }
    }

    /// The recorded traffic counts (consumed after the calibration run).
    pub fn counts(&self) -> &[Vec<u64>] {
        &self.counts
    }
}

impl ResidencyBackend for CountingBackend {
    fn name(&self) -> &'static str {
        "counting"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        let n = self.counts.len();
        let row = &mut self.counts[layer % n];
        for &e in experts {
            row[e] += 1;
        }
    }

    fn resolve(
        &mut self,
        _layer: usize,
        _expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        (self.precision, 0.0)
    }

    fn tick(&mut self, _now_s: f64) -> f64 {
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        0
    }

    fn counts_view(&self) -> Option<&[Vec<u64>]> {
        Some(&self.counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_backend_accumulates() {
        let mut b = CountingBackend::new(2, 4, Precision::Fp16);
        b.record_routing(0, &[1, 1, 3]);
        b.record_routing(1, &[0]);
        assert_eq!(b.counts()[0], vec![0, 2, 0, 1]);
        assert_eq!(b.counts()[1], vec![1, 0, 0, 0]);
        assert_eq!(b.resolve(0, 0, 0.0).0, Precision::Fp16);
    }

    #[test]
    fn static_backend_never_stalls_or_migrates() {
        let mut b = StaticBackend::for_preset(&ModelPreset::qwen30b_sim());
        for i in 0..100 {
            let (p, stall) = b.resolve(i % 4, i, i as f64);
            assert_eq!(p, Precision::Int4);
            assert_eq!(stall, 0.0);
        }
        assert_eq!(b.tick(5.0), 0.0);
        assert_eq!(b.migrated_bytes(), 0);
    }

    #[test]
    fn static_80b_uses_int2() {
        let b = StaticBackend::for_preset(&ModelPreset::qwen80b_sim());
        assert_eq!(b.precision, Precision::Int2);
    }

    #[test]
    fn dynaexq_backend_promotes_hot_experts() {
        let preset = ModelPreset::phi_sim();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        let mut b = DynaExqBackend::new(&preset, &cfg, &dev).unwrap();
        for _ in 0..200 {
            b.record_routing(0, &[1, 2]);
        }
        assert_eq!(b.tick(1.0), 0.0, "non-blocking by default");
        b.coord.pipeline.wait_staged();
        b.tick(100.0);
        let (p, stall) = b.resolve(0, 1, 100.0);
        assert_eq!(p, Precision::Fp16);
        assert_eq!(stall, 0.0);
        assert!(b.hi_fraction() > 0.0);
        assert!(b.migrated_bytes() > 0);
        // per-rung views agree with the scalar diagnostics
        let fr = b.tier_fractions();
        assert_eq!(fr.len(), 2);
        assert!((fr[0] - b.hi_fraction()).abs() < 1e-12);
        let res = b.tier_residency();
        assert_eq!(res.len(), 2);
        assert_eq!(res.iter().sum::<usize>(), 16 * preset.n_layers_logical());
        assert!(res[0] >= 2, "experts 1 and 2 published hot: {res:?}");
    }
}
