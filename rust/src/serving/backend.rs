//! Residency backends: how each serving method decides expert precision
//! and what it costs on the critical path.
//!
//! The engine is method-agnostic: it asks the backend which precision an
//! expert executes at *now* and how many seconds of critical-path stall the
//! resolution incurred (0 for DynaExq and static PTQ; fetch-wait time for
//! offloading systems when the expert is not resident).

use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::coordinator::Coordinator;
use crate::model::Precision;

/// A serving method's residency behaviour.
pub trait ResidencyBackend: Send {
    fn name(&self) -> &'static str;

    /// Router outputs for one iteration at `layer` (one entry per
    /// (token, k) selection, duplicates included).
    fn record_routing(&mut self, layer: usize, experts: &[usize]);

    /// Precision the expert executes at plus critical-path stall seconds.
    fn resolve(&mut self, layer: usize, expert: usize, now_s: f64)
        -> (Precision, f64);

    /// Iteration boundary; returns an additional forced stall (only the
    /// blocking-transition ablation returns non-zero).
    fn tick(&mut self, now_s: f64) -> f64;

    /// Total bytes moved host→device so far (modeled).
    fn migrated_bytes(&self) -> u64;

    /// Fraction of resolutions served at the high tier (diagnostics).
    fn hi_fraction(&self) -> f64 {
        0.0
    }

    /// Drive all pending residency work to completion and freeze the
    /// precision map (quality harnesses measure a *converged, pinned*
    /// configuration, like the paper's per-window pinning). Returns the
    /// modeled time at which the system is quiescent.
    fn quiesce(&mut self, now_s: f64) -> f64 {
        now_s
    }

    /// Calibration counts, if this backend records them (CountingBackend).
    fn counts_view(&self) -> Option<&[Vec<u64>]> {
        None
    }
}

// ---------------------------------------------------------------------------
// DynaExq
// ---------------------------------------------------------------------------

/// The paper's system: coordinator-driven online precision allocation.
pub struct DynaExqBackend {
    pub coord: Coordinator,
    blocking: bool,
    resolves: u64,
    hi_resolves: u64,
}

impl DynaExqBackend {
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
    ) -> Result<Self, String> {
        Ok(Self {
            coord: Coordinator::new(preset, cfg, dev)?,
            blocking: cfg.blocking_transitions,
            resolves: 0,
            hi_resolves: 0,
        })
    }

    pub fn from_coordinator(coord: Coordinator, blocking: bool) -> Self {
        Self { coord, blocking, resolves: 0, hi_resolves: 0 }
    }
}

impl ResidencyBackend for DynaExqBackend {
    fn name(&self) -> &'static str {
        "dynaexq"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        self.coord.record_routing(layer, experts);
    }

    fn resolve(
        &mut self,
        layer: usize,
        expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        // Stable-handle resolution: one atomic load, never a stall.
        let p = self.coord.resolve(layer, expert);
        self.resolves += 1;
        if p == self.coord.preset.hi {
            self.hi_resolves += 1;
        }
        (p, 0.0)
    }

    fn tick(&mut self, now_s: f64) -> f64 {
        let report = self.coord.tick(now_s);
        if self.blocking && report.ran {
            // Ablation A3: synchronize the forward pass with the migration
            // stream, as a transition design without VER would.
            self.coord.pipeline.wait_staged();
            let stall =
                (self.coord.pipeline.migration_tail() - now_s).max(0.0);
            self.coord.pipeline.poll(now_s + stall);
            return stall;
        }
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        self.coord
            .pipeline
            .stats
            .migrated_bytes
            .load(std::sync::atomic::Ordering::Relaxed)
    }

    fn hi_fraction(&self) -> f64 {
        if self.resolves == 0 {
            0.0
        } else {
            self.hi_resolves as f64 / self.resolves as f64
        }
    }

    fn quiesce(&mut self, now_s: f64) -> f64 {
        // Alternate policy updates and migration-event publication until
        // the target residency is materialized, then advance far enough
        // that no further update fires mid-measurement.
        let interval = self.coord.cfg.update_interval_ms / 1e3;
        let mut now = now_s;
        for _ in 0..8 {
            now += interval + 1e-9;
            self.coord.tick(now);
            self.coord.pipeline.wait_staged();
            now = now.max(self.coord.pipeline.migration_tail());
            self.coord.pipeline.poll(now);
        }
        now
    }
}

// ---------------------------------------------------------------------------
// Static PTQ
// ---------------------------------------------------------------------------

/// Uniform static quantization: every expert at `precision`, forever.
/// No transfers, no transitions — the paper's lowest-latency baseline.
pub struct StaticBackend {
    precision: Precision,
}

impl StaticBackend {
    pub fn new(precision: Precision) -> Self {
        Self { precision }
    }

    /// The paper's budget-driven choice: Int4 where it fits, Int2 for the
    /// 80B model (§5.3).
    pub fn for_preset(preset: &ModelPreset) -> Self {
        Self::new(preset.lo)
    }
}

impl ResidencyBackend for StaticBackend {
    fn name(&self) -> &'static str {
        "static-ptq"
    }

    fn record_routing(&mut self, _layer: usize, _experts: &[usize]) {}

    fn resolve(
        &mut self,
        _layer: usize,
        _expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        (self.precision, 0.0)
    }

    fn tick(&mut self, _now_s: f64) -> f64 {
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// Counting (calibration) backend
// ---------------------------------------------------------------------------

/// Fixed-precision backend that records per-(layer, expert) routing counts
/// — the offline calibration pass used to build static mixed-precision
/// maps (baseline A5) and for trace analysis.
pub struct CountingBackend {
    precision: Precision,
    counts: Vec<Vec<u64>>,
}

impl CountingBackend {
    pub fn new(n_layers: usize, n_experts: usize, precision: Precision) -> Self {
        Self { precision, counts: vec![vec![0; n_experts]; n_layers] }
    }

    /// The recorded traffic counts (consumed after the calibration run).
    pub fn counts(&self) -> &[Vec<u64>] {
        &self.counts
    }
}

impl ResidencyBackend for CountingBackend {
    fn name(&self) -> &'static str {
        "counting"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        let n = self.counts.len();
        let row = &mut self.counts[layer % n];
        for &e in experts {
            row[e] += 1;
        }
    }

    fn resolve(
        &mut self,
        _layer: usize,
        _expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        (self.precision, 0.0)
    }

    fn tick(&mut self, _now_s: f64) -> f64 {
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        0
    }

    fn counts_view(&self) -> Option<&[Vec<u64>]> {
        Some(&self.counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_backend_accumulates() {
        let mut b = CountingBackend::new(2, 4, Precision::Fp16);
        b.record_routing(0, &[1, 1, 3]);
        b.record_routing(1, &[0]);
        assert_eq!(b.counts()[0], vec![0, 2, 0, 1]);
        assert_eq!(b.counts()[1], vec![1, 0, 0, 0]);
        assert_eq!(b.resolve(0, 0, 0.0).0, Precision::Fp16);
    }

    #[test]
    fn static_backend_never_stalls_or_migrates() {
        let mut b = StaticBackend::for_preset(&ModelPreset::qwen30b_sim());
        for i in 0..100 {
            let (p, stall) = b.resolve(i % 4, i, i as f64);
            assert_eq!(p, Precision::Int4);
            assert_eq!(stall, 0.0);
        }
        assert_eq!(b.tick(5.0), 0.0);
        assert_eq!(b.migrated_bytes(), 0);
    }

    #[test]
    fn static_80b_uses_int2() {
        let b = StaticBackend::for_preset(&ModelPreset::qwen80b_sim());
        assert_eq!(b.precision, Precision::Int2);
    }

    #[test]
    fn dynaexq_backend_promotes_hot_experts() {
        let preset = ModelPreset::phi_sim();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        let mut b = DynaExqBackend::new(&preset, &cfg, &dev).unwrap();
        for _ in 0..200 {
            b.record_routing(0, &[1, 2]);
        }
        assert_eq!(b.tick(1.0), 0.0, "non-blocking by default");
        b.coord.pipeline.wait_staged();
        b.tick(100.0);
        let (p, stall) = b.resolve(0, 1, 100.0);
        assert_eq!(p, Precision::Fp16);
        assert_eq!(stall, 0.0);
        assert!(b.hi_fraction() > 0.0);
        assert!(b.migrated_bytes() > 0);
    }
}
