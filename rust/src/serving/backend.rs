//! Residency backends: how each serving method decides expert precision
//! and what it costs on the critical path.
//!
//! The engine is method-agnostic: it asks the backend which precision an
//! expert executes at *now* and how many seconds of critical-path stall the
//! resolution incurred (0 for DynaExq and static PTQ; fetch-wait time for
//! offloading systems when the expert is not resident).

use std::sync::Arc;

use crate::config::{DeviceConfig, ModelPreset, QosClass, ServingConfig};
use crate::coordinator::{Coordinator, DeviceGroup, TransitionTotals};
use crate::model::{Precision, PrecisionLadder};
use crate::util::lockorder::{LockRank, OrderedMutex};
use crate::workload::Trace;

/// Per-layer routing events buffered between iteration boundaries.
///
/// The engine's hot path calls `record_routing` once per layer per
/// iteration; locking the coordinator's hotness mutex on each of those
/// calls serializes the forward pass against the estimator. The DynaExq
/// backends buffer the events here instead and flush them at the next
/// `tick`/`quiesce` — one lock per iteration boundary, zero lock traffic
/// on the hot path, and count-identical hotness state at every point the
/// policy can read it (the batching contract of DESIGN.md §11).
#[derive(Default)]
struct RoutingBuffer {
    /// One buffer per logical layer (selections concatenate within an
    /// interval — hotness counts are additive).
    per_layer: Vec<Vec<usize>>,
    /// Layers touched since the last flush, in first-touch order.
    touched: Vec<usize>,
}

impl RoutingBuffer {
    fn new(n_layers: usize) -> Self {
        Self { per_layer: vec![Vec::new(); n_layers], touched: Vec::new() }
    }

    #[inline]
    fn record(&mut self, layer: usize, experts: &[usize]) {
        if experts.is_empty() {
            return; // an empty batch is a no-op on the estimator
        }
        let buf = &mut self.per_layer[layer];
        if buf.is_empty() {
            self.touched.push(layer);
        }
        buf.extend_from_slice(experts);
    }

    fn is_empty(&self) -> bool {
        self.touched.is_empty()
    }

    /// The buffered (layer, selections) batches in first-touch order.
    fn batches(&self) -> impl Iterator<Item = (usize, &[usize])> + '_ {
        self.touched.iter().map(|&l| (l, self.per_layer[l].as_slice()))
    }

    /// Reset after a flush; buffers keep their capacity.
    fn reset(&mut self) {
        for i in 0..self.touched.len() {
            let l = self.touched[i];
            self.per_layer[l].clear();
        }
        self.touched.clear();
    }
}

/// A serving method's residency behaviour.
pub trait ResidencyBackend: Send {
    fn name(&self) -> &'static str;

    /// Router outputs for one iteration at `layer` (one entry per
    /// (token, k) selection, duplicates included).
    fn record_routing(&mut self, layer: usize, experts: &[usize]);

    /// Precision the expert executes at plus critical-path stall seconds.
    fn resolve(&mut self, layer: usize, expert: usize, now_s: f64)
        -> (Precision, f64);

    /// Iteration boundary; returns an additional forced stall (only the
    /// blocking-transition ablation returns non-zero).
    fn tick(&mut self, now_s: f64) -> f64;

    /// Total bytes moved host→device so far (modeled).
    fn migrated_bytes(&self) -> u64;

    /// Fraction of resolutions served at the ladder's top rung
    /// (diagnostics).
    fn hi_fraction(&self) -> f64 {
        0.0
    }

    /// Fraction of resolutions served at each ladder rung, tier 0 first
    /// (empty when the backend does not track per-rung occupancy).
    fn tier_fractions(&self) -> Vec<f64> {
        Vec::new()
    }

    /// Published residency counts per ladder rung, tier 0 first (empty
    /// when the backend has no residency table).
    fn tier_residency(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Drive all pending residency work to completion and freeze the
    /// precision map (quality harnesses measure a *converged, pinned*
    /// configuration, like the paper's per-window pinning). Returns the
    /// modeled time at which the system is quiescent.
    fn quiesce(&mut self, now_s: f64) -> f64 {
        now_s
    }

    /// Calibration counts, if this backend records them (CountingBackend).
    fn counts_view(&self) -> Option<&[Vec<u64>]> {
        None
    }

    /// Number of devices the backend shards experts across (1 = the
    /// paper's single-GPU system). When this exceeds 1 the engine models
    /// per-device compute lanes for the MoE block.
    fn n_devices(&self) -> usize {
        1
    }

    /// Device owning `(layer, expert)` — always 0 for single-device
    /// backends.
    fn device_of(&self, _layer: usize, _expert: usize) -> usize {
        0
    }

    /// Published residency counts per device (tier 0 first within each
    /// device); empty when the backend has no residency table.
    fn device_residency(&self) -> Vec<Vec<usize>> {
        Vec::new()
    }

    /// In-flight transition count per device (the cross-device
    /// promotion-queue depth); empty without a transition pipeline.
    fn promo_queue_depth(&self) -> Vec<usize> {
        Vec::new()
    }

    /// `(change-point triggers, recovery intervals)` of the drift-aware
    /// hotness layer (DESIGN.md §10); `(0, 0)` for backends without one.
    fn drift_stats(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Whether every device's residency accounting currently fits inside
    /// its HBM envelope slice — the C1 standing invariant the scenario
    /// matrix asserts at every phase boundary. Trivially true for
    /// backends without a budget tracker.
    fn within_envelope(&self) -> bool {
        true
    }

    /// Block until host-side staging of every submitted transition is done
    /// (no-op for backends without a staging worker). The engine and the
    /// trace replayer call this at iteration boundaries *before*
    /// [`ResidencyBackend::tick`], so publication depends only on modeled
    /// completion events and every run is reproducible from its seed.
    /// Host-side waiting never adds modeled stall.
    fn sync_staging(&mut self) {}

    /// Transition-pipeline counter totals (promotions / demotions /
    /// deferred / rejected / published / evictions / migrated bytes),
    /// summed across devices for sharded groups — the allocation-visible
    /// proxy counters the wall-clock bench harness records per cell.
    /// All-zero for backends without a transition pipeline.
    fn transition_totals(&self) -> TransitionTotals {
        TransitionTotals::default()
    }

    /// How many of `experts` are currently resident at the ladder's *top*
    /// rung in `layer` — the fleet router's hot-set affinity signal
    /// (DESIGN.md §14): a replica whose hi-precision resident set covers
    /// a request's expected expert set serves it without promotion
    /// traffic. 0 for backends without a residency table (every replica
    /// then scores equal and routing degenerates to load balancing).
    fn resident_overlap(&self, _layer: usize, _experts: &[usize]) -> usize {
        0
    }

    /// Attribute subsequent routing records and resolutions to the QoS
    /// class at `class` (an index into [`QosClass::ALL`]) — a no-op for
    /// backends without an armed QoS config (DESIGN.md §15). Degenerate
    /// configs never arm, so the classic stack takes this default.
    fn set_active_class(&mut self, _class: usize) {}

    /// Resolutions served per `[class][tier]` since boot (class order =
    /// [`QosClass::ALL`], tier 0 first). Empty when QoS is unarmed, so
    /// snapshots of the classic stack stay byte-identical.
    fn class_tier_resolves(&self) -> Vec<Vec<u64>> {
        Vec::new()
    }
}

// ---------------------------------------------------------------------------
// DynaExq
// ---------------------------------------------------------------------------

/// The paper's system: coordinator-driven online precision allocation
/// over the preset's ladder (2-rung presets behave exactly like the
/// original binary hi/lo system).
pub struct DynaExqBackend {
    pub coord: Coordinator,
    blocking: bool,
    resolves: u64,
    /// Resolutions served per rung, tier 0 first.
    tier_resolves: Vec<u64>,
    /// Per-`[class][tier]` resolution counts — `Some` iff the coordinator
    /// armed a non-degenerate QoS config (DESIGN.md §15).
    class_resolves: Option<Vec<Vec<u64>>>,
    /// Class attributed to resolutions between `set_active_class` calls.
    active_class: usize,
    /// Routing events buffered since the last boundary; flushed under one
    /// hotness lock in `tick`/`quiesce` (DESIGN.md §11).
    buf: RoutingBuffer,
}

impl DynaExqBackend {
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
    ) -> Result<Self, String> {
        Ok(Self::from_coordinator(
            Coordinator::new(preset, cfg, dev)?,
            cfg.blocking_transitions,
        ))
    }

    pub fn from_coordinator(coord: Coordinator, blocking: bool) -> Self {
        let n_tiers = coord.preset.ladder.n_tiers();
        let n_layers = coord.preset.n_layers_logical();
        let class_resolves = coord
            .qos_armed()
            .then(|| vec![vec![0; n_tiers]; QosClass::ALL.len()]);
        Self {
            buf: RoutingBuffer::new(n_layers),
            coord,
            blocking,
            resolves: 0,
            tier_resolves: vec![0; n_tiers],
            class_resolves,
            active_class: QosClass::Standard.index(),
        }
    }

    /// Hand the buffered routing events to the coordinator's estimator
    /// under a single hotness lock.
    fn flush_routing(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        self.coord.record_layers(self.buf.batches());
        self.buf.reset();
    }
}

impl ResidencyBackend for DynaExqBackend {
    fn name(&self) -> &'static str {
        "dynaexq"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        // Lock-free on the hot path: events accumulate here and reach the
        // hotness estimator at the next iteration boundary — the earliest
        // point the policy could read them anyway.
        self.buf.record(layer, experts);
    }

    fn resolve(
        &mut self,
        layer: usize,
        expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        // Stable-handle resolution: one atomic load, never a stall.
        let tier = self.coord.resolve_tier(layer, expert);
        self.resolves += 1;
        self.tier_resolves[tier] += 1;
        if let Some(cr) = &mut self.class_resolves {
            cr[self.active_class][tier] += 1;
        }
        (self.coord.preset.ladder.tier(tier), 0.0)
    }

    fn tick(&mut self, now_s: f64) -> f64 {
        self.flush_routing();
        let report = self.coord.tick(now_s);
        if self.blocking && report.ran {
            // Ablation A3: synchronize the forward pass with the migration
            // stream, as a transition design without VER would.
            self.coord.pipeline.wait_staged();
            let stall =
                (self.coord.pipeline.migration_tail() - now_s).max(0.0);
            self.coord.pipeline.poll(now_s + stall);
            return stall;
        }
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        self.coord
            .pipeline
            .stats
            .migrated_bytes
            .load(std::sync::atomic::Ordering::Relaxed) // relaxed-ok: stat counter
    }

    fn hi_fraction(&self) -> f64 {
        if self.resolves == 0 {
            0.0
        } else {
            self.tier_resolves[0] as f64 / self.resolves as f64
        }
    }

    fn tier_fractions(&self) -> Vec<f64> {
        if self.resolves == 0 {
            return vec![0.0; self.tier_resolves.len()];
        }
        self.tier_resolves
            .iter()
            .map(|&n| n as f64 / self.resolves as f64)
            .collect()
    }

    fn tier_residency(&self) -> Vec<usize> {
        self.coord.handles.tier_counts()
    }

    fn quiesce(&mut self, now_s: f64) -> f64 {
        // Alternate policy updates and migration-event publication until
        // the target residency is materialized, then advance far enough
        // that no further update fires mid-measurement.
        self.flush_routing();
        let interval = self.coord.cfg.update_interval_ms / 1e3;
        let mut now = now_s;
        for _ in 0..8 {
            now += interval + 1e-9;
            self.coord.tick(now);
            self.coord.pipeline.wait_staged();
            now = now.max(self.coord.pipeline.migration_tail());
            self.coord.pipeline.poll(now);
        }
        now
    }

    fn device_residency(&self) -> Vec<Vec<usize>> {
        vec![self.coord.handles.tier_counts()]
    }

    fn promo_queue_depth(&self) -> Vec<usize> {
        vec![self.coord.pipeline.inflight_count()]
    }

    fn drift_stats(&self) -> (u64, u64) {
        self.coord.drift_stats()
    }

    fn within_envelope(&self) -> bool {
        self.coord.budget.within_envelope()
    }

    fn sync_staging(&mut self) {
        self.coord.pipeline.wait_staged();
    }

    fn transition_totals(&self) -> TransitionTotals {
        self.coord.pipeline.stats.totals()
    }

    fn resident_overlap(&self, layer: usize, experts: &[usize]) -> usize {
        experts
            .iter()
            .filter(|&&e| self.coord.resolve_tier(layer, e) == 0)
            .count()
    }

    fn set_active_class(&mut self, class: usize) {
        self.active_class = class.min(QosClass::ALL.len() - 1);
        self.coord.set_active_class(class);
    }

    fn class_tier_resolves(&self) -> Vec<Vec<u64>> {
        self.class_resolves.clone().unwrap_or_default()
    }
}

// ---------------------------------------------------------------------------
// DynaExq over a sharded device group
// ---------------------------------------------------------------------------

/// The coordinator stack sharded across a [`DeviceGroup`] (DESIGN.md §9):
/// every device owns its expert shard's budget tracker, per-rung pools,
/// and transition pipeline under its own slice of the HBM envelope, and
/// the waterfill policy runs per device over that device's expert subset.
/// A 1-device group behaves exactly like [`DynaExqBackend`]
/// (property-tested in `coordinator::group`). Transitions are always
/// non-blocking (VER) — the blocking ablation remains single-device.
pub struct DynaExqShardedBackend {
    pub group: Arc<DeviceGroup>,
    ladder: PrecisionLadder,
    resolves: u64,
    /// Resolutions served per rung, tier 0 first.
    tier_resolves: Vec<u64>,
    /// Per-`[class][tier]` resolution counts — `Some` iff the group's
    /// devices armed a non-degenerate QoS config (DESIGN.md §15).
    class_resolves: Option<Vec<Vec<u64>>>,
    /// Class attributed to resolutions between `set_active_class` calls.
    active_class: usize,
    /// Scratch: per-device local-id routing split.
    split: Vec<Vec<usize>>,
    /// Routing events buffered since the last boundary (global expert
    /// ids); split per device and flushed in `tick`/`quiesce`.
    buf: RoutingBuffer,
}

impl DynaExqShardedBackend {
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
        n_devices: usize,
    ) -> Result<Self, String> {
        Ok(Self::from_group(Arc::new(DeviceGroup::new(
            preset, cfg, dev, n_devices,
        )?)))
    }

    /// Wrap an existing group; the caller may keep its own `Arc` handle to
    /// inspect per-device state while the engine owns the backend.
    pub fn from_group(group: Arc<DeviceGroup>) -> Self {
        let ladder = group.devices[0].preset.ladder.clone();
        let n_tiers = ladder.n_tiers();
        let n_layers = group.devices[0].preset.n_layers_logical();
        let class_resolves = group.devices[0]
            .qos_armed()
            .then(|| vec![vec![0; n_tiers]; QosClass::ALL.len()]);
        Self {
            split: vec![Vec::new(); group.n_devices()],
            buf: RoutingBuffer::new(n_layers),
            group,
            ladder,
            resolves: 0,
            tier_resolves: vec![0; n_tiers],
            class_resolves,
            active_class: QosClass::Standard.index(),
        }
    }

    /// Split the buffered routing events per owning device and feed each
    /// device's estimator — per-boundary lock traffic instead of
    /// per-record.
    fn flush_routing(&mut self) {
        if self.buf.is_empty() {
            return;
        }
        for (layer, batch) in self.buf.batches() {
            self.group.record_routing_into(layer, batch, &mut self.split);
        }
        self.buf.reset();
    }
}

impl ResidencyBackend for DynaExqShardedBackend {
    fn name(&self) -> &'static str {
        "dynaexq-sharded"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        // Lock-free on the hot path (same batching contract as the
        // single-device backend, DESIGN.md §11).
        self.buf.record(layer, experts);
    }

    fn resolve(
        &mut self,
        layer: usize,
        expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        // Stable-handle resolution on the owning device — one atomic load,
        // never a stall (the handle lives in that device's table).
        let tier = self.group.resolve_tier(layer, expert);
        self.resolves += 1;
        self.tier_resolves[tier] += 1;
        if let Some(cr) = &mut self.class_resolves {
            cr[self.active_class][tier] += 1;
        }
        (self.ladder.tier(tier), 0.0)
    }

    fn tick(&mut self, now_s: f64) -> f64 {
        self.flush_routing();
        self.group.tick(now_s);
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        self.group.migrated_bytes()
    }

    fn hi_fraction(&self) -> f64 {
        if self.resolves == 0 {
            0.0
        } else {
            self.tier_resolves[0] as f64 / self.resolves as f64
        }
    }

    fn tier_fractions(&self) -> Vec<f64> {
        if self.resolves == 0 {
            return vec![0.0; self.tier_resolves.len()];
        }
        self.tier_resolves
            .iter()
            .map(|&n| n as f64 / self.resolves as f64)
            .collect()
    }

    fn tier_residency(&self) -> Vec<usize> {
        self.group.tier_counts()
    }

    fn quiesce(&mut self, now_s: f64) -> f64 {
        self.flush_routing();
        let interval = self.group.update_interval_s();
        let mut now = now_s;
        for _ in 0..8 {
            now += interval + 1e-9;
            self.group.tick(now);
            self.group.wait_staged();
            now = now.max(self.group.migration_tail());
            self.group.poll(now);
        }
        now
    }

    fn n_devices(&self) -> usize {
        self.group.n_devices()
    }

    fn device_of(&self, layer: usize, expert: usize) -> usize {
        self.group.device_of(layer, expert)
    }

    fn device_residency(&self) -> Vec<Vec<usize>> {
        self.group.device_tier_counts()
    }

    fn promo_queue_depth(&self) -> Vec<usize> {
        self.group.inflight_depths()
    }

    fn drift_stats(&self) -> (u64, u64) {
        self.group.drift_stats()
    }

    fn within_envelope(&self) -> bool {
        self.group.within_envelope()
    }

    fn sync_staging(&mut self) {
        self.group.wait_staged();
    }

    fn transition_totals(&self) -> TransitionTotals {
        self.group.transition_totals()
    }

    fn resident_overlap(&self, layer: usize, experts: &[usize]) -> usize {
        experts
            .iter()
            .filter(|&&e| self.group.resolve_tier(layer, e) == 0)
            .count()
    }

    fn set_active_class(&mut self, class: usize) {
        self.active_class = class.min(QosClass::ALL.len() - 1);
        for d in &self.group.devices {
            d.set_active_class(class);
        }
    }

    fn class_tier_resolves(&self) -> Vec<Vec<u64>> {
        self.class_resolves.clone().unwrap_or_default()
    }
}

// ---------------------------------------------------------------------------
// Recording wrapper
// ---------------------------------------------------------------------------

/// Wraps any backend and records every routing batch and iteration
/// boundary into a shared [`Trace`] while delegating behaviour unchanged —
/// this is how `DXTR` traces are captured from a live modeled engine (the
/// replay side lives in [`crate::workload::traces`]).
pub struct RecordingBackend {
    inner: Box<dyn ResidencyBackend>,
    trace: Arc<OrderedMutex<Trace>>,
    /// Routing events of the current iteration, appended to the shared
    /// trace under one lock at the next tick. Unlike [`RoutingBuffer`]
    /// this keeps the exact per-call event sequence (duplicates and empty
    /// batches included) so recorded traces stay byte-identical to the
    /// historical per-call recording.
    pending: Vec<(usize, Vec<usize>)>,
    /// Retired event buffers, reused to keep the wrapper allocation-free
    /// at steady state.
    free: Vec<Vec<usize>>,
}

impl RecordingBackend {
    /// Wrap `inner`; the returned handle reads the trace while (and after)
    /// the engine owns the backend.
    pub fn wrap(
        inner: Box<dyn ResidencyBackend>,
        n_layers: usize,
        n_experts: usize,
    ) -> (Self, Arc<OrderedMutex<Trace>>) {
        let trace = Arc::new(OrderedMutex::new(
            LockRank::Trace,
            Trace::new(n_layers, n_experts),
        ));
        (
            Self {
                inner,
                trace: trace.clone(),
                pending: Vec::new(),
                free: Vec::new(),
            },
            trace,
        )
    }

    /// Append the buffered routing events to the shared trace under one
    /// lock (in exact call order), optionally followed by the iteration
    /// boundary marker, and recycle the event buffers.
    fn flush_pending(&mut self, add_tick: bool) {
        {
            let mut t = self.trace.lock();
            for (layer, experts) in &self.pending {
                t.record(*layer, experts);
            }
            if add_tick {
                t.tick();
            }
        }
        for (_, mut buf) in self.pending.drain(..) {
            buf.clear();
            self.free.push(buf);
        }
    }
}

impl ResidencyBackend for RecordingBackend {
    fn name(&self) -> &'static str {
        "recording"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        let mut buf = self.free.pop().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(experts);
        self.pending.push((layer, buf));
        self.inner.record_routing(layer, experts);
    }

    fn resolve(
        &mut self,
        layer: usize,
        expert: usize,
        now_s: f64,
    ) -> (Precision, f64) {
        self.inner.resolve(layer, expert, now_s)
    }

    fn tick(&mut self, now_s: f64) -> f64 {
        // One trace lock per iteration boundary: the buffered routing
        // events in call order, then the boundary marker.
        self.flush_pending(true);
        self.inner.tick(now_s)
    }

    fn migrated_bytes(&self) -> u64 {
        self.inner.migrated_bytes()
    }

    fn hi_fraction(&self) -> f64 {
        self.inner.hi_fraction()
    }

    fn tier_fractions(&self) -> Vec<f64> {
        self.inner.tier_fractions()
    }

    fn tier_residency(&self) -> Vec<usize> {
        self.inner.tier_residency()
    }

    fn quiesce(&mut self, now_s: f64) -> f64 {
        // Events recorded since the last boundary reach the inner
        // backend's estimator through its quiesce flush — they must reach
        // the trace too (no boundary marker: historical per-call
        // recording added none here either).
        self.flush_pending(false);
        self.inner.quiesce(now_s)
    }

    fn counts_view(&self) -> Option<&[Vec<u64>]> {
        self.inner.counts_view()
    }

    fn n_devices(&self) -> usize {
        self.inner.n_devices()
    }

    fn device_of(&self, layer: usize, expert: usize) -> usize {
        self.inner.device_of(layer, expert)
    }

    fn device_residency(&self) -> Vec<Vec<usize>> {
        self.inner.device_residency()
    }

    fn promo_queue_depth(&self) -> Vec<usize> {
        self.inner.promo_queue_depth()
    }

    fn drift_stats(&self) -> (u64, u64) {
        self.inner.drift_stats()
    }

    fn within_envelope(&self) -> bool {
        self.inner.within_envelope()
    }

    fn sync_staging(&mut self) {
        self.inner.sync_staging()
    }

    fn transition_totals(&self) -> TransitionTotals {
        self.inner.transition_totals()
    }

    fn resident_overlap(&self, layer: usize, experts: &[usize]) -> usize {
        self.inner.resident_overlap(layer, experts)
    }

    fn set_active_class(&mut self, class: usize) {
        self.inner.set_active_class(class)
    }

    fn class_tier_resolves(&self) -> Vec<Vec<u64>> {
        self.inner.class_tier_resolves()
    }
}

// ---------------------------------------------------------------------------
// Static PTQ
// ---------------------------------------------------------------------------

/// Uniform static quantization: every expert at `precision`, forever.
/// No transfers, no transitions — the paper's lowest-latency baseline.
pub struct StaticBackend {
    precision: Precision,
}

impl StaticBackend {
    pub fn new(precision: Precision) -> Self {
        Self { precision }
    }

    /// The paper's budget-driven choice: the ladder's base rung (Int4
    /// where it fits, Int2 for the 80B model, §5.3).
    pub fn for_preset(preset: &ModelPreset) -> Self {
        Self::new(preset.lo())
    }
}

impl ResidencyBackend for StaticBackend {
    fn name(&self) -> &'static str {
        "static-ptq"
    }

    fn record_routing(&mut self, _layer: usize, _experts: &[usize]) {}

    fn resolve(
        &mut self,
        _layer: usize,
        _expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        (self.precision, 0.0)
    }

    fn tick(&mut self, _now_s: f64) -> f64 {
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// Counting (calibration) backend
// ---------------------------------------------------------------------------

/// Fixed-precision backend that records per-(layer, expert) routing counts
/// — the offline calibration pass used to build static mixed-precision
/// maps (baseline A5) and for trace analysis.
pub struct CountingBackend {
    precision: Precision,
    counts: Vec<Vec<u64>>,
}

impl CountingBackend {
    pub fn new(n_layers: usize, n_experts: usize, precision: Precision) -> Self {
        Self { precision, counts: vec![vec![0; n_experts]; n_layers] }
    }

    /// The recorded traffic counts (consumed after the calibration run).
    pub fn counts(&self) -> &[Vec<u64>] {
        &self.counts
    }
}

impl ResidencyBackend for CountingBackend {
    fn name(&self) -> &'static str {
        "counting"
    }

    fn record_routing(&mut self, layer: usize, experts: &[usize]) {
        let n = self.counts.len();
        let row = &mut self.counts[layer % n];
        for &e in experts {
            row[e] += 1;
        }
    }

    fn resolve(
        &mut self,
        _layer: usize,
        _expert: usize,
        _now_s: f64,
    ) -> (Precision, f64) {
        (self.precision, 0.0)
    }

    fn tick(&mut self, _now_s: f64) -> f64 {
        0.0
    }

    fn migrated_bytes(&self) -> u64 {
        0
    }

    fn counts_view(&self) -> Option<&[Vec<u64>]> {
        Some(&self.counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_backend_accumulates() {
        let mut b = CountingBackend::new(2, 4, Precision::Fp16);
        b.record_routing(0, &[1, 1, 3]);
        b.record_routing(1, &[0]);
        assert_eq!(b.counts()[0], vec![0, 2, 0, 1]);
        assert_eq!(b.counts()[1], vec![1, 0, 0, 0]);
        assert_eq!(b.resolve(0, 0, 0.0).0, Precision::Fp16);
    }

    #[test]
    fn static_backend_never_stalls_or_migrates() {
        let mut b = StaticBackend::for_preset(&ModelPreset::qwen30b_sim());
        for i in 0..100 {
            let (p, stall) = b.resolve(i % 4, i, i as f64);
            assert_eq!(p, Precision::Int4);
            assert_eq!(stall, 0.0);
        }
        assert_eq!(b.tick(5.0), 0.0);
        assert_eq!(b.migrated_bytes(), 0);
    }

    #[test]
    fn static_80b_uses_int2() {
        let b = StaticBackend::for_preset(&ModelPreset::qwen80b_sim());
        assert_eq!(b.precision, Precision::Int2);
    }

    #[test]
    fn dynaexq_backend_promotes_hot_experts() {
        let preset = ModelPreset::phi_sim();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        let mut b = DynaExqBackend::new(&preset, &cfg, &dev).unwrap();
        for _ in 0..200 {
            b.record_routing(0, &[1, 2]);
        }
        assert_eq!(b.tick(1.0), 0.0, "non-blocking by default");
        b.coord.pipeline.wait_staged();
        b.tick(100.0);
        let (p, stall) = b.resolve(0, 1, 100.0);
        assert_eq!(p, Precision::Fp16);
        assert_eq!(stall, 0.0);
        assert!(b.hi_fraction() > 0.0);
        assert!(b.migrated_bytes() > 0);
        // per-rung views agree with the scalar diagnostics
        let fr = b.tier_fractions();
        assert_eq!(fr.len(), 2);
        assert!((fr[0] - b.hi_fraction()).abs() < 1e-12);
        let res = b.tier_residency();
        assert_eq!(res.len(), 2);
        assert_eq!(res.iter().sum::<usize>(), 16 * preset.n_layers_logical());
        assert!(res[0] >= 2, "experts 1 and 2 published hot: {res:?}");
        // single-device view of the group accessors
        assert_eq!(b.n_devices(), 1);
        assert_eq!(b.device_of(0, 5), 0);
        assert_eq!(b.device_residency(), vec![res]);
        assert_eq!(b.promo_queue_depth().len(), 1);
    }

    #[test]
    fn sharded_backend_promotes_on_every_shard() {
        let preset = ModelPreset::phi_sim();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        let mut b =
            DynaExqShardedBackend::new(&preset, &cfg, &dev, 2).unwrap();
        assert_eq!(b.n_devices(), 2);
        assert_eq!(b.device_of(0, 4), 0);
        assert_eq!(b.device_of(0, 5), 1);
        // traffic splits across both shards (0, 2 → dev 0; 1, 3 → dev 1)
        for _ in 0..200 {
            b.record_routing(0, &[0, 1, 2, 3]);
        }
        assert_eq!(b.tick(1.0), 0.0, "sharded backend never stalls");
        b.sync_staging();
        b.tick(100.0);
        for e in 0..4 {
            let (p, stall) = b.resolve(0, e, 100.0);
            assert_eq!(p, Precision::Fp16, "expert {e}");
            assert_eq!(stall, 0.0);
        }
        assert!(b.hi_fraction() > 0.0);
        assert!(b.migrated_bytes() > 0);
        let fr = b.tier_fractions();
        assert!((fr[0] - b.hi_fraction()).abs() < 1e-12);
        // per-device residency partitions the group totals
        let per_dev = b.device_residency();
        assert_eq!(per_dev.len(), 2);
        let layers = preset.n_layers_logical();
        for (d, counts) in per_dev.iter().enumerate() {
            assert_eq!(counts.iter().sum::<usize>(), layers * 8, "device {d}");
        }
        assert_eq!(
            b.tier_residency().iter().sum::<usize>(),
            layers * preset.n_experts
        );
        assert_eq!(b.promo_queue_depth().len(), 2);
        assert!(b.group.within_envelope());
    }

    #[test]
    fn routing_buffer_flushes_at_iteration_boundary() {
        // The batching contract (DESIGN.md §11): hot-path record_routing
        // takes no lock; observations reach the estimator at the next
        // tick, which is also when the interval fold can first read them
        // — so policy outcomes are identical to per-call recording.
        let preset = ModelPreset::phi_sim();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        let mut b = DynaExqBackend::new(&preset, &cfg, &dev).unwrap();
        for _ in 0..100 {
            b.record_routing(0, &[3]);
        }
        assert_eq!(b.coord.hotness_score(0, 3), 0.0, "pre-boundary");
        b.tick(1.0); // past the update interval: flush + fold
        assert!(b.coord.hotness_score(0, 3) > 0.0, "post-boundary");
        assert_eq!(b.transition_totals().promotions, 1);
        // sharded flavour: split-by-device flush at the boundary
        let mut s =
            DynaExqShardedBackend::new(&preset, &cfg, &dev, 2).unwrap();
        for _ in 0..100 {
            s.record_routing(0, &[0, 1]);
        }
        s.tick(1.0);
        assert!(s.group.devices[0].hotness_score(0, 0) > 0.0);
        assert!(s.group.devices[1].hotness_score(0, 0) > 0.0);
        assert!(s.transition_totals().promotions >= 2);
    }

    #[test]
    fn qos_armed_backend_splits_resolves_by_class() {
        let preset = ModelPreset::phi_sim();
        let mut cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        // unarmed (default config): the per-class view stays empty and
        // class switches are no-ops — the classic stack is untouched
        let mut plain = DynaExqBackend::new(&preset, &cfg, &dev).unwrap();
        plain.set_active_class(0);
        plain.resolve(0, 0, 0.0);
        assert!(plain.class_tier_resolves().is_empty());
        // armed: every resolution lands on the active class's row
        cfg.qos = Some(crate::config::QosConfig::tiered());
        let mut b = DynaExqBackend::new(&preset, &cfg, &dev).unwrap();
        b.set_active_class(QosClass::Premium.index());
        b.resolve(0, 0, 0.0);
        b.resolve(0, 1, 0.0);
        b.set_active_class(QosClass::BestEffort.index());
        b.resolve(0, 2, 0.0);
        let cr = b.class_tier_resolves();
        assert_eq!(cr.len(), QosClass::ALL.len());
        assert_eq!(cr[QosClass::Premium.index()].iter().sum::<u64>(), 2);
        assert_eq!(cr[QosClass::BestEffort.index()].iter().sum::<u64>(), 1);
        assert_eq!(cr[QosClass::Standard.index()].iter().sum::<u64>(), 0);
        assert_eq!(cr.iter().flatten().sum::<u64>(), 3, "fully accounted");
        // the sharded flavour arms from the same config and forwards the
        // class switch to every device
        let mut s =
            DynaExqShardedBackend::new(&preset, &cfg, &dev, 2).unwrap();
        s.set_active_class(QosClass::Premium.index());
        s.resolve(0, 0, 0.0);
        let cr = s.class_tier_resolves();
        assert_eq!(cr[QosClass::Premium.index()].iter().sum::<u64>(), 1);
        for d in &s.group.devices {
            assert!(d.qos_armed());
        }
    }

    #[test]
    fn recording_backend_captures_trace_and_delegates() {
        let preset = ModelPreset::phi_sim();
        let (mut b, trace) = RecordingBackend::wrap(
            Box::new(StaticBackend::for_preset(&preset)),
            preset.n_layers_logical(),
            preset.n_experts,
        );
        b.record_routing(0, &[1, 1, 3]);
        assert_eq!(b.resolve(0, 1, 0.0).0, Precision::Int4);
        assert_eq!(b.tick(0.5), 0.0);
        b.record_routing(2, &[7]);
        b.tick(1.0);
        let t = trace.lock();
        assert_eq!(t.selections(), 4);
        assert_eq!(
            t.events
                .iter()
                .filter(|e| **e == crate::workload::TraceEvent::Tick)
                .count(),
            2
        );
        assert_eq!(t.n_experts, 16);
    }
}
