//! The method registry: one place where serving-method names become
//! [`ResidencyBackend`] instances.
//!
//! Every residency behaviour the system knows — the paper's system, its
//! baselines, and the calibration pass — is a named factory here, so the
//! CLI, the experiment harnesses, and the quality fixtures all construct
//! backends through the same table (DESIGN.md §4). Unknown names fail with
//! an error that enumerates what *is* registered; new methods (plug-in
//! policies, ablation variants) are one [`BackendRegistry::register`] call,
//! not another string match.
//!
//! Registered built-ins:
//!
//! | name                    | behaviour                                              |
//! |-------------------------|--------------------------------------------------------|
//! | `dynaexq`               | coordinator-driven online precision allocation (§3)    |
//! | `dynaexq-adaptive`      | same coordinator with the drift-aware hotness layer    |
//! |                         | (change-point → dropped α; sharded when `n_devices`>1) |
//! | `dynaexq-3tier`         | same coordinator over the full Fp16/Int4/Int2 ladder   |
//! | `dynaexq-sharded`       | coordinator sharded across a device group (per-device  |
//! |                         | envelopes; device count from `BackendCtx::n_devices`)  |
//! | `dynaexq-3tier-sharded` | sharded group over the full 3-rung ladder              |
//! | `static`                | uniform base-rung PTQ (paper's fastest baseline)       |
//! | `static-hi`             | uniform top-rung PTQ (quality reference tier)          |
//! | `fp16`                  | uniform FP16 (quality reference, Table 4)              |
//! | `static-map`            | offline-calibrated per-expert map (MxMoE/MoPEQ class)  |
//! | `dynaexq-fleet`         | replicated sharded stacks behind one backend face      |
//! |                         | (replica count from `BackendCtx::replicas`; heartbeat  |
//! |                         | failover re-picks the serving replica — DESIGN.md §14) |
//! | `expertflow`            | offloading/prefetching comparator (paper §5.3)         |
//! | `hobbit`                | reactive mixed-precision offloading (HOBBIT class)     |
//! | `counting`              | fixed precision + routing-count recording (calibration)|

use std::collections::BTreeMap;

use crate::baselines::{ExpertFlowBackend, HobbitBackend, StaticMapBackend};
use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::coordinator::Coordinator;
use crate::model::{Precision, PrecisionLadder};
use crate::util::XorShiftRng;
use crate::workload::{RoutingSampler, WorkloadProfile};

use super::backend::{
    CountingBackend, DynaExqBackend, DynaExqShardedBackend, ResidencyBackend,
    StaticBackend,
};
use super::fleet::FleetBackend;

/// Everything a backend factory may consult.
///
/// `preset`/`cfg`/`dev` are always present; `profile` and `calib_counts`
/// are optional inputs for methods that calibrate offline (`static-map`
/// synthesizes a calibration trace from `profile` when no explicit counts
/// are supplied).
pub struct BackendCtx<'a> {
    pub preset: &'a ModelPreset,
    pub cfg: &'a ServingConfig,
    pub dev: &'a DeviceConfig,
    /// Workload the session will serve (offline-calibration input).
    pub profile: Option<&'a WorkloadProfile>,
    /// Pre-recorded per-(layer, expert) routing counts; takes precedence
    /// over `profile` synthesis for `static-map`.
    pub calib_counts: Option<&'a [Vec<u64>]>,
    /// Device-group width for sharded methods (`dynaexq-sharded`,
    /// `dynaexq-3tier-sharded`); single-device methods ignore it. A
    /// 1-device group is the exact single-GPU system.
    pub n_devices: usize,
    /// Replica count for fleet methods (`dynaexq-fleet`); non-replicated
    /// methods ignore it. A 1-replica fleet is the exact sharded system.
    pub replicas: usize,
}

impl<'a> BackendCtx<'a> {
    pub fn new(
        preset: &'a ModelPreset,
        cfg: &'a ServingConfig,
        dev: &'a DeviceConfig,
    ) -> Self {
        Self {
            preset,
            cfg,
            dev,
            profile: None,
            calib_counts: None,
            n_devices: 1,
            replicas: 1,
        }
    }

    pub fn with_profile(mut self, profile: &'a WorkloadProfile) -> Self {
        self.profile = Some(profile);
        self
    }

    pub fn with_counts(mut self, counts: &'a [Vec<u64>]) -> Self {
        self.calib_counts = Some(counts);
        self
    }

    pub fn with_devices(mut self, n_devices: usize) -> Self {
        self.n_devices = n_devices;
        self
    }

    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }
}

/// A named backend constructor.
pub type BackendFactory = Box<
    dyn Fn(&BackendCtx) -> Result<Box<dyn ResidencyBackend>, String>
        + Send
        + Sync,
>;

/// Method name → factory. `BTreeMap` keeps enumeration (error messages,
/// `methods()`) deterministic and sorted.
pub struct BackendRegistry {
    factories: BTreeMap<&'static str, BackendFactory>,
}

impl Default for BackendRegistry {
    fn default() -> Self {
        Self::with_builtins()
    }
}

impl BackendRegistry {
    /// An empty registry (plug-in composition from scratch).
    pub fn empty() -> Self {
        Self { factories: BTreeMap::new() }
    }

    /// The standard registry: all built-in residency behaviours.
    pub fn with_builtins() -> Self {
        let mut r = Self::empty();
        r.register("static", |ctx| {
            Ok(Box::new(StaticBackend::new(ctx.preset.lo())))
        });
        r.register("static-hi", |ctx| {
            Ok(Box::new(StaticBackend::new(ctx.preset.hi())))
        });
        r.register("fp16", |_ctx| {
            Ok(Box::new(StaticBackend::new(Precision::Fp16)))
        });
        r.register("dynaexq", |ctx| {
            Ok(Box::new(DynaExqBackend::new(ctx.preset, ctx.cfg, ctx.dev)?))
        });
        r.register("dynaexq-adaptive", |ctx| {
            // The same coordinator stack with the drift-aware hotness
            // layer switched on (DESIGN.md §10): a change-point on the
            // per-layer routing distribution temporarily drops the EMA α
            // and rescales stale scores, so the waterfill re-converges to
            // a shifted hot set in bounded update intervals. Honors
            // `ctx.n_devices` — a wider context serves the adaptive
            // coordinator per device of a sharded group.
            let mut cfg = ctx.cfg.clone();
            cfg.adaptive_alpha = true;
            if ctx.n_devices > 1 {
                Ok(Box::new(DynaExqShardedBackend::new(
                    ctx.preset,
                    &cfg,
                    ctx.dev,
                    ctx.n_devices,
                )?))
            } else {
                Ok(Box::new(DynaExqBackend::new(ctx.preset, &cfg, ctx.dev)?))
            }
        });
        r.register("dynaexq-3tier", |ctx| {
            // The same coordinator over the full three-rung ladder: warm
            // experts get a middle rung before falling to the coldest one,
            // under the preset's unchanged HBM envelope (the tier-count
            // ablation compares this against the 2-rung `dynaexq`).
            let mut preset = ctx.preset.clone();
            preset.ladder = PrecisionLadder::full();
            Ok(Box::new(DynaExqBackend::new(&preset, ctx.cfg, ctx.dev)?))
        });
        r.register("dynaexq-sharded", |ctx| {
            // The coordinator stack sharded across ctx.n_devices devices:
            // per-device envelopes, pools, and migration streams
            // (DESIGN.md §9); a 1-device group reproduces `dynaexq`.
            Ok(Box::new(DynaExqShardedBackend::new(
                ctx.preset,
                ctx.cfg,
                ctx.dev,
                ctx.n_devices,
            )?))
        });
        r.register("dynaexq-3tier-sharded", |ctx| {
            let mut preset = ctx.preset.clone();
            preset.ladder = PrecisionLadder::full();
            Ok(Box::new(DynaExqShardedBackend::new(
                &preset,
                ctx.cfg,
                ctx.dev,
                ctx.n_devices,
            )?))
        });
        r.register("dynaexq-fleet", |ctx| {
            // Backend-level replication (DESIGN.md §14): ctx.replicas
            // sharded stacks behind one ResidencyBackend face; routing
            // hits the current replica, heartbeat failover re-picks it by
            // hot-set overlap. A 1-replica fleet is the sharded system.
            Ok(Box::new(FleetBackend::new(
                ctx.preset,
                ctx.cfg,
                ctx.dev,
                ctx.n_devices,
                ctx.replicas.max(1),
            )?))
        });
        r.register("expertflow", |ctx| {
            Ok(Box::new(ExpertFlowBackend::new(ctx.preset, ctx.cfg, ctx.dev)))
        });
        r.register("hobbit", |ctx| {
            Ok(Box::new(HobbitBackend::new(ctx.preset, ctx.cfg, ctx.dev)?))
        });
        r.register("static-map", |ctx| {
            let preset = ctx.preset;
            let layers = preset.n_layers_logical();
            let plan = Coordinator::plan_for(preset, ctx.cfg)?;
            let counts: Vec<Vec<u64>> = match ctx.calib_counts {
                Some(c) => c.to_vec(),
                None => {
                    // No recorded counts: calibrate offline against the
                    // session's workload (text if unspecified) by sampling
                    // the same routing model the engine will serve.
                    let text;
                    let profile = match ctx.profile {
                        Some(p) => p,
                        None => {
                            text = WorkloadProfile::text();
                            &text
                        }
                    };
                    synthesize_counts(profile, layers, preset)
                }
            };
            // Static maps are inherently two-tier: they consume the
            // ladder's top and bottom rungs.
            Ok(Box::new(StaticMapBackend::calibrated(
                layers,
                preset.n_experts,
                preset.hi(),
                preset.lo(),
                &counts,
                plan.n_hi_per_layer(),
            )))
        });
        r.register("counting", |ctx| {
            Ok(Box::new(CountingBackend::new(
                ctx.preset.n_layers_logical(),
                ctx.preset.n_experts,
                Precision::Fp16,
            )))
        });
        r
    }

    /// Register (or override) a method by name.
    pub fn register<F>(&mut self, name: &'static str, factory: F)
    where
        F: Fn(&BackendCtx) -> Result<Box<dyn ResidencyBackend>, String>
            + Send
            + Sync
            + 'static,
    {
        self.factories.insert(name, Box::new(factory));
    }

    /// All registered method names, sorted.
    pub fn methods(&self) -> Vec<&'static str> {
        self.factories.keys().copied().collect()
    }

    pub fn contains(&self, method: &str) -> bool {
        self.factories.contains_key(method)
    }

    /// Build the backend for `method`, or an error that enumerates every
    /// registered method.
    pub fn build(
        &self,
        method: &str,
        ctx: &BackendCtx,
    ) -> Result<Box<dyn ResidencyBackend>, String> {
        match self.factories.get(method) {
            Some(f) => f(ctx)
                .map_err(|e| format!("building method {method:?}: {e}")),
            None => Err(format!(
                "unknown method {method:?}; registered methods: {}",
                self.methods().join(", ")
            )),
        }
    }
}

/// Offline calibration without a recorded trace: sample the modeled router
/// for a handful of synthetic requests and count per-(layer, expert)
/// traffic — the same input `StaticMapBackend::calibrated` takes from a
/// real counting run.
fn synthesize_counts(
    profile: &WorkloadProfile,
    layers: usize,
    preset: &ModelPreset,
) -> Vec<Vec<u64>> {
    const CALIB_REQUESTS: u64 = 64;
    const TOKENS_PER_REQUEST: usize = 16;
    let sampler =
        RoutingSampler::new(profile, layers, preset.n_experts, preset.top_k);
    let mut rng = XorShiftRng::new(profile.seed ^ 0xCA11_B8A7E);
    let mut counts = vec![vec![0u64; preset.n_experts]; layers];
    for tag in 0..CALIB_REQUESTS {
        for (layer, row) in counts.iter_mut().enumerate() {
            for _ in 0..TOKENS_PER_REQUEST {
                for e in sampler.sample_topk(&mut rng, tag, layer) {
                    row[e] += 1;
                }
            }
        }
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_parts() -> (ModelPreset, ServingConfig, DeviceConfig) {
        (ModelPreset::phi_sim(), ServingConfig::default(), DeviceConfig::default())
    }

    #[test]
    fn builds_every_builtin() {
        let (p, cfg, dev) = ctx_parts();
        let r = BackendRegistry::with_builtins();
        assert_eq!(r.methods().len(), 13);
        for m in r.methods() {
            let b = r.build(m, &BackendCtx::new(&p, &cfg, &dev)).unwrap();
            assert!(!b.name().is_empty(), "{m}");
        }
    }

    #[test]
    fn adaptive_method_enables_drift_layer_at_any_width() {
        let (p, cfg, dev) = ctx_parts();
        let r = BackendRegistry::with_builtins();
        // 1-device: plain coordinator with the detector armed — drift
        // stats start at zero but the change-point machinery is live
        let mut b = r
            .build("dynaexq-adaptive", &BackendCtx::new(&p, &cfg, &dev))
            .unwrap();
        assert_eq!(b.n_devices(), 1);
        assert_eq!(b.drift_stats(), (0, 0));
        assert!(b.within_envelope());
        // a hard swap across update intervals must register a trigger
        let mut now = 0.0;
        for _ in 0..8 {
            for _ in 0..60 {
                b.record_routing(0, &[0, 1]);
            }
            now += 1.0;
            b.tick(now);
        }
        for _ in 0..8 {
            for _ in 0..60 {
                b.record_routing(0, &[8, 9]);
            }
            now += 1.0;
            b.tick(now);
        }
        assert!(b.drift_stats().0 >= 1, "swap must fire the change-point");
        // sharded: the adaptive coordinator runs per device
        let b2 = r
            .build(
                "dynaexq-adaptive",
                &BackendCtx::new(&p, &cfg, &dev).with_devices(2),
            )
            .unwrap();
        assert_eq!(b2.n_devices(), 2);
        assert_eq!(b2.drift_stats(), (0, 0));
        // the fixed-α method never reports drift
        let plain =
            r.build("dynaexq", &BackendCtx::new(&p, &cfg, &dev)).unwrap();
        assert_eq!(plain.drift_stats(), (0, 0));
    }

    #[test]
    fn sharded_methods_honor_device_count() {
        let (p, cfg, dev) = ctx_parts();
        let r = BackendRegistry::with_builtins();
        let mut b = r
            .build(
                "dynaexq-sharded",
                &BackendCtx::new(&p, &cfg, &dev).with_devices(2),
            )
            .unwrap();
        assert_eq!(b.n_devices(), 2);
        assert_eq!(b.device_residency().len(), 2);
        assert_eq!(b.resolve(0, 0, 0.0).0, p.lo(), "cold boot at base rung");
        // the 3-tier sharded variant lifts any preset onto the full ladder
        let b3 = r
            .build(
                "dynaexq-3tier-sharded",
                &BackendCtx::new(&p, &cfg, &dev).with_devices(2),
            )
            .unwrap();
        assert_eq!(b3.tier_residency().len(), 3);
        assert_eq!(b3.device_residency().len(), 2);
        // default context is a 1-device group (the single-GPU system)
        let b1 = r
            .build("dynaexq-sharded", &BackendCtx::new(&p, &cfg, &dev))
            .unwrap();
        assert_eq!(b1.n_devices(), 1);
    }

    #[test]
    fn three_tier_method_serves_full_ladder() {
        let (p, cfg, dev) = ctx_parts();
        let r = BackendRegistry::with_builtins();
        let mut b = r
            .build("dynaexq-3tier", &BackendCtx::new(&p, &cfg, &dev))
            .unwrap();
        // cold boot at the full ladder's base rung (Int2), even though the
        // phi preset's native pair bottoms out at Int4
        assert_eq!(b.resolve(0, 0, 0.0).0, Precision::Int2);
        assert_eq!(b.tier_residency().len(), 3);
        assert_eq!(b.tier_fractions().len(), 3);
    }

    #[test]
    fn unknown_method_enumerates_registered() {
        let (p, cfg, dev) = ctx_parts();
        let r = BackendRegistry::with_builtins();
        let err = r
            .build("nope", &BackendCtx::new(&p, &cfg, &dev))
            .unwrap_err();
        // stay in sync with the CLI's method list: everything the bench
        // harness drives must be registered AND enumerated in the error
        for m in crate::experiments::helpers::METHODS {
            assert!(r.contains(m), "helpers::METHODS entry {m:?} unregistered");
            assert!(err.contains(m), "error should list {m}: {err}");
        }
        assert!(err.contains("counting"), "error should list counting: {err}");
        assert!(err.contains("unknown method"), "error prefix: {err}");
    }

    #[test]
    fn static_map_calibrates_on_profile_hot_set() {
        let (p, cfg, dev) = ctx_parts();
        let r = BackendRegistry::with_builtins();
        let w = WorkloadProfile::text();
        let mut b = r
            .build(
                "static-map",
                &BackendCtx::new(&p, &cfg, &dev).with_profile(&w),
            )
            .unwrap();
        // The globally hottest expert of the calibration workload must be
        // pinned at the high tier.
        let sampler =
            RoutingSampler::new(&w, p.n_layers_logical(), p.n_experts, p.top_k);
        let hot = sampler.global_top(0, 1)[0];
        assert_eq!(b.resolve(0, hot, 0.0).0, p.hi());
    }

    #[test]
    fn explicit_counts_take_precedence() {
        let (p, cfg, dev) = ctx_parts();
        let mut cfg = cfg;
        cfg.n_hi_override = Some(1);
        let layers = p.n_layers_logical();
        let mut counts = vec![vec![0u64; p.n_experts]; layers];
        for row in counts.iter_mut() {
            row[5] = 1000; // expert 5 is the only trafficked expert
        }
        let r = BackendRegistry::with_builtins();
        let mut b = r
            .build(
                "static-map",
                &BackendCtx::new(&p, &cfg, &dev).with_counts(&counts),
            )
            .unwrap();
        assert_eq!(b.resolve(0, 5, 0.0).0, p.hi());
        assert_eq!(b.resolve(0, 0, 0.0).0, p.lo());
    }

    #[test]
    fn infeasible_budget_fails_construction() {
        let (p, mut cfg, dev) = ctx_parts();
        cfg.hbm_budget_bytes = 1; // cannot even hold the all-cold model
        let r = BackendRegistry::with_builtins();
        for m in ["dynaexq", "hobbit", "static-map"] {
            assert!(
                r.build(m, &BackendCtx::new(&p, &cfg, &dev)).is_err(),
                "{m} must reject an infeasible envelope"
            );
        }
    }

    #[test]
    fn custom_registration_overrides() {
        let (p, cfg, dev) = ctx_parts();
        let mut r = BackendRegistry::empty();
        assert!(r.build("static", &BackendCtx::new(&p, &cfg, &dev)).is_err());
        r.register("static", |ctx| {
            Ok(Box::new(StaticBackend::new(ctx.preset.hi())))
        });
        let mut b =
            r.build("static", &BackendCtx::new(&p, &cfg, &dev)).unwrap();
        assert_eq!(b.resolve(0, 0, 0.0).0, p.hi());
    }
}
