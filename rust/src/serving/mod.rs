//! Serving: the session front door, method registry, schedulers, KV cache,
//! and the two engines (DESIGN.md §4) —
//!
//! * [`session::ServeSession`] — the serving API: a validated
//!   `SessionBuilder` picks model/method/workload by name, builds either
//!   engine behind the [`session::SessionEngine`] trait, and exports a
//!   serializable [`session::MetricsSnapshot`].
//! * [`registry::BackendRegistry`] — method name → backend factory; the
//!   single place serving-method strings are interpreted.
//! * [`scheduler::Scheduler`] — admission/decode sequencing policies;
//!   [`scheduler::ClosedBatch`] and [`scheduler::ContinuousBatch`] are the
//!   paper's two measurement shapes, new policies are plug-ins.
//! * [`frontdoor::FrontDoor`] — the concurrent request front door
//!   (DESIGN.md §12): a bounded admission queue with per-tenant fair-share
//!   accounting and priority lanes, surfacing backpressure as typed
//!   [`frontdoor::Rejected`] values, paired with the SLO-aware
//!   [`frontdoor::SloScheduler`].
//! * [`fleet::Fleet`] — fleet-scale replication (DESIGN.md §14): N
//!   engine replicas behind one shared front door, with load/affinity
//!   routing ([`fleet::FleetRouter`]), a deterministic modeled health
//!   checker ([`fleet::HealthChecker`] driven by scripted
//!   [`crate::workload::FaultPlan`] heartbeats), and mid-stream failover
//!   that re-admits stranded requests with token position preserved.
//! * [`engine::Engine`] — the **modeled** serving engine: full continuous-
//!   batching loop over the device cost model (paper-scale dims), used by
//!   every performance experiment (TTFT/TPOP/latency/throughput sweeps).
//!   Routing comes from the workload sampler; numerics are not executed.
//! * [`numeric::NumericEngine`] — the **numeric** engine: real PJRT
//!   execution of the small simulated model (prefill + decode, KV cache,
//!   expert gather/scatter), used by every quality experiment and the
//!   end-to-end example. Timing is *also* tracked against the cost model so
//!   quality runs report both.
//!
//! Both engines drive residency through the same [`backend::ResidencyBackend`]
//! abstraction, which is where DynaExq and the baselines plug in.

pub mod backend;
pub mod engine;
pub mod fleet;
pub mod frontdoor;
pub mod kv_cache;
#[cfg(feature = "numeric")]
pub mod numeric;
pub mod registry;
pub mod scheduler;
pub mod session;

pub use backend::ResidencyBackend;
pub use engine::{ActiveRequest, Engine, EngineConfig};
pub use fleet::{
    Fleet, FleetBackend, FleetBuilder, FleetRouter, FleetStats,
    HealthChecker, ReplicaHealth,
};
pub use frontdoor::{FrontDoor, Rejected, SloScheduler};
#[cfg(feature = "numeric")]
pub use numeric::NumericEngine;
pub use registry::{BackendCtx, BackendRegistry};
pub use scheduler::{ClosedBatch, ContinuousBatch, Scheduler};
pub use session::{
    EngineKind, MetricsSnapshot, ServeSession, SessionBuilder, SessionEngine,
};
