//! Serving: the request loop, batcher, KV cache, and the two engines —
//!
//! * [`engine::Engine`] — the **modeled** serving engine: full continuous-
//!   batching loop over the device cost model (paper-scale dims), used by
//!   every performance experiment (TTFT/TPOP/latency/throughput sweeps).
//!   Routing comes from the workload sampler; numerics are not executed.
//! * [`numeric::NumericEngine`] — the **numeric** engine: real PJRT
//!   execution of the small simulated model (prefill + decode, KV cache,
//!   expert gather/scatter), used by every quality experiment and the
//!   end-to-end example. Timing is *also* tracked against the cost model so
//!   quality runs report both.
//!
//! Both engines drive residency through the same [`backend::ResidencyBackend`]
//! abstraction, which is where DynaExq and the two baselines plug in.

pub mod backend;
pub mod engine;
pub mod kv_cache;
pub mod numeric;

pub use backend::ResidencyBackend;
pub use engine::{Engine, EngineConfig};
pub use numeric::NumericEngine;
