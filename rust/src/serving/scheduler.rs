//! Scheduling policies for the modeled engine (DESIGN.md §4).
//!
//! The engine exposes two primitive operations — [`Engine::admit`] (prefill
//! one request into the running batch) and [`Engine::decode_round`] (one
//! lockstep decode iteration plus per-token bookkeeping) — and a
//! [`Scheduler`] decides *when* each happens. The paper's two measurement
//! shapes are the two built-ins:
//!
//! * [`ClosedBatch`] — all requests admitted up front, decode until drained
//!   (the batch-size sweeps of Figs. 6–9);
//! * [`ContinuousBatch`] — open-loop continuous batching: arrivals honored,
//!   admission while a slot under the batch cap is free, vLLM-style
//!   iteration scheduling (Fig. A7's load sweeps).
//!
//! SLO-aware admission, priority classes, or preemptive policies are new
//! `Scheduler` implementations, not engine rewrites.

use crate::workload::Request;

use super::engine::{ActiveRequest, Engine};

/// A policy that drives a set of requests through the engine to completion.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Serve `requests`; returns when every request has completed. The
    /// engine records all metrics; the scheduler only sequences admission
    /// and decode rounds.
    fn run(&mut self, engine: &mut Engine, requests: Vec<Request>);
}

/// Closed batch: every request is prefilled up front (in the given order,
/// TTFT measured from arrival so queueing behind earlier prefills is
/// included), then decode proceeds in lockstep until all outputs complete.
#[derive(Debug, Default, Clone, Copy)]
pub struct ClosedBatch;

impl Scheduler for ClosedBatch {
    fn name(&self) -> &'static str {
        "closed-batch"
    }

    fn run(&mut self, engine: &mut Engine, requests: Vec<Request>) {
        let mut active: Vec<ActiveRequest> = Vec::new();
        for req in requests {
            engine.admit(req, &mut active);
        }
        while !active.is_empty() {
            engine.decode_round(&mut active);
        }
    }
}

/// Open-loop continuous batching: requests arrive over time (`arrival_s`
/// honored); new arrivals are prefilled and join the decode batch as soon
/// as a slot under the batch cap frees up; the engine skips idle gaps
/// forward rather than spinning.
#[derive(Debug, Default, Clone, Copy)]
pub struct ContinuousBatch {
    /// Batch cap; `None` uses the engine's configured `max_batch`. A cap
    /// of 0 is treated as 1 (a zero cap could never admit anything and
    /// would spin forever).
    pub max_batch: Option<usize>,
}

impl Scheduler for ContinuousBatch {
    fn name(&self) -> &'static str {
        "continuous-batch"
    }

    fn run(&mut self, engine: &mut Engine, mut pending: Vec<Request>) {
        let cap = self.max_batch.unwrap_or_else(|| engine.max_batch()).max(1);
        pending
            .sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        pending.reverse(); // pop() takes the earliest
        let mut active: Vec<ActiveRequest> = Vec::new();

        while !pending.is_empty() || !active.is_empty() {
            // Admit every arrived request while capacity remains; if the
            // engine is idle, skip ahead to the next arrival.
            while active.len() < cap {
                let ready = pending
                    .last()
                    .map(|r| r.arrival_s <= engine.now())
                    .unwrap_or(false);
                let can_skip_ahead = active.is_empty() && !pending.is_empty();
                if !ready && !can_skip_ahead {
                    break;
                }
                let req = pending.pop().unwrap();
                engine.admit(req, &mut active);
            }
            if active.is_empty() {
                continue;
            }
            engine.decode_round(&mut active);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceConfig, ModelPreset};
    use crate::serving::backend::StaticBackend;
    use crate::serving::engine::EngineConfig;
    use crate::workload::{RequestGenerator, WorkloadProfile};

    fn engine(max_batch: usize, seed: u64) -> Engine {
        let preset = ModelPreset::phi_sim();
        Engine::new(
            &preset,
            &WorkloadProfile::text(),
            Box::new(StaticBackend::for_preset(&preset)),
            &DeviceConfig::default(),
            EngineConfig { max_batch, seed, track_activation: false },
        )
    }

    fn requests(n: usize, spacing_s: f64) -> Vec<Request> {
        let mut gen = RequestGenerator::new(WorkloadProfile::text(), 9);
        (0..n)
            .map(|i| gen.request(16, 4, i as f64 * spacing_s))
            .collect()
    }

    #[test]
    fn closed_batch_matches_serve_batch() {
        // The extracted scheduler must be byte-identical to the engine's
        // historical loop: same seed → same floats, not just close.
        let mut a = engine(8, 42);
        let mut b = engine(8, 42);
        a.serve_batch(requests(4, 0.0));
        b.serve_with(&mut ClosedBatch, requests(4, 0.0));
        assert_eq!(a.metrics.ttft.samples(), b.metrics.ttft.samples());
        assert_eq!(a.metrics.tpop.samples(), b.metrics.tpop.samples());
        assert_eq!(a.metrics.e2e.samples(), b.metrics.e2e.samples());
        assert_eq!(a.metrics.duration_s, b.metrics.duration_s);
    }

    #[test]
    fn continuous_batch_matches_serve_stream() {
        let mut a = engine(2, 7);
        let mut b = engine(2, 7);
        a.serve_stream(requests(6, 0.05));
        b.serve_with(&mut ContinuousBatch::default(), requests(6, 0.05));
        assert_eq!(a.metrics.ttft.samples(), b.metrics.ttft.samples());
        assert_eq!(a.metrics.e2e.samples(), b.metrics.e2e.samples());
        assert_eq!(a.metrics.duration_s, b.metrics.duration_s);
    }

    #[test]
    fn continuous_cap_override_binds() {
        // A tighter cap than the engine's must delay later arrivals more.
        let run = |cap: Option<usize>| {
            let mut e = engine(8, 3);
            e.serve_with(&mut ContinuousBatch { max_batch: cap }, requests(6, 0.01));
            e.metrics.ttft.max()
        };
        assert!(run(Some(1)) > run(None));
    }
}
