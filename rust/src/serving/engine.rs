//! The modeled serving engine: continuous batching over the device cost
//! model at paper-scale dims.
//!
//! Every performance experiment (Tables 1–2, Figures 1, 6–10) runs through
//! this loop. Routing outcomes are sampled from the workload profile
//! (preserving the statistics those experiments measure); per-op latencies
//! come from [`CostModel`]; expert residency and critical-path stalls come
//! from the configured [`ResidencyBackend`]. The compute stream and the
//! backend's transfer streams interact exactly as the paper describes:
//! non-blocking systems overlap, offloading systems wait.

use crate::config::{DeviceConfig, ModelPreset};
use crate::metrics::ServingMetrics;
use crate::sim::{Clock, CostModel, Stream};
use crate::util::XorShiftRng;
use crate::workload::{
    Request, RoutingSampler, Scenario, ScenarioPhase, WorkloadProfile,
};

use super::backend::ResidencyBackend;
use super::scheduler::{ClosedBatch, ContinuousBatch, Scheduler};

/// Engine knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Decode scheduling cap (paper sweeps 1–32).
    pub max_batch: usize,
    pub seed: u64,
    /// Record per-layer activation ratios (Tables 1–2).
    pub track_activation: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        Self { max_batch: 32, seed: 0xD15C0, track_activation: false }
    }
}

/// Activation-ratio samples (fraction of a layer's experts activated in one
/// iteration), split by phase.
#[derive(Clone, Debug, Default)]
pub struct ActivationStats {
    pub prefill: Vec<f64>,
    pub decode: Vec<f64>,
}

impl ActivationStats {
    pub fn prefill_avg(&self) -> f64 {
        crate::util::mean(&self.prefill)
    }

    pub fn decode_avg(&self) -> f64 {
        crate::util::mean(&self.decode)
    }
}

/// One admitted request in the decode batch — owned by the [`Scheduler`]
/// driving the engine, mutated by [`Engine::decode_round`].
pub struct ActiveRequest {
    pub req: Request,
    pub generated: usize,
    pub ctx: usize,
    /// Per-request prefill timestamp (tracing / SLO-aware schedulers).
    pub prefill_done_s: f64,
    pub last_token_s: f64,
}

/// The modeled engine.
pub struct Engine {
    pub preset: ModelPreset,
    pub cost: CostModel,
    pub backend: Box<dyn ResidencyBackend>,
    pub metrics: ServingMetrics,
    pub activation: ActivationStats,
    cfg: EngineConfig,
    sampler: RoutingSampler,
    clock: Clock,
    compute: Stream,
    rng: XorShiftRng,
    n_layers: usize,
    /// Scratch: per-expert token counts of the current (layer, iteration).
    counts: Vec<u32>,
    touched: Vec<usize>,
    /// Scratch: per-device compute lanes of the current layer (sharded
    /// backends run their shards in parallel; 1 lane = the classic sum).
    lanes: Vec<f64>,
    /// Scratch: one token's top-k routing picks (reused across every
    /// routed token — the engine allocates nothing per token).
    picked: Vec<usize>,
    /// Scratch: the current layer's flattened router trace (one entry per
    /// (token, k) selection), handed to the backend once per layer.
    routed: Vec<usize>,
}

impl Engine {
    pub fn new(
        preset: &ModelPreset,
        profile: &WorkloadProfile,
        backend: Box<dyn ResidencyBackend>,
        dev: &DeviceConfig,
        cfg: EngineConfig,
    ) -> Self {
        let n_layers = preset.n_layers_logical();
        Self {
            preset: preset.clone(),
            cost: CostModel::new(preset, dev.clone()),
            backend,
            metrics: ServingMetrics::default(),
            activation: ActivationStats::default(),
            sampler: RoutingSampler::new(
                profile,
                n_layers,
                preset.n_experts,
                preset.top_k,
            ),
            clock: Clock::new(),
            compute: Stream::new(),
            rng: XorShiftRng::new(cfg.seed),
            n_layers,
            counts: vec![0; preset.n_experts],
            touched: Vec::new(),
            lanes: Vec::new(),
            picked: Vec::with_capacity(preset.top_k),
            routed: Vec::new(),
            cfg,
        }
    }

    /// Switch the workload profile mid-run (shift experiments).
    pub fn set_profile(&mut self, profile: &WorkloadProfile) {
        self.sampler = RoutingSampler::new(
            profile,
            self.n_layers,
            self.preset.n_experts,
            self.preset.top_k,
        );
    }

    pub fn now(&self) -> f64 {
        self.clock.now()
    }

    /// Decode scheduling cap.
    pub fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }

    /// Serve a closed batch: all requests arrive at `clock.now`, prefill
    /// runs request-by-request, then decode proceeds in lockstep until all
    /// outputs complete. This is the paper's measurement harness shape for
    /// the batch-size sweeps. Equivalent to [`Engine::serve_with`] under
    /// [`ClosedBatch`].
    pub fn serve_batch(&mut self, requests: Vec<Request>) {
        self.serve_with(&mut ClosedBatch, requests);
    }

    /// Drive `requests` to completion under an arbitrary [`Scheduler`],
    /// then stamp the run duration.
    pub fn serve_with(
        &mut self,
        scheduler: &mut dyn Scheduler,
        requests: Vec<Request>,
    ) {
        scheduler.run(self, requests);
        self.metrics.duration_s = self.clock.now();
    }

    /// Admit one request into `active`: prefill it on the compute stream
    /// (TTFT is measured from *arrival*, so queueing behind the batch's
    /// earlier prefills is included — the paper's batched-TTFT rise),
    /// record its tokens, and give the backend its iteration-boundary tick.
    pub fn admit(&mut self, req: Request, active: &mut Vec<ActiveRequest>) {
        let arrival = req.arrival_s;
        let start = self.clock.now().max(arrival);
        let done = self.prefill(&req, start);
        self.metrics.ttft.record(done - arrival);
        self.metrics.prefill_tokens += req.prompt_len as u64;
        active.push(ActiveRequest {
            ctx: req.prompt_len,
            generated: 0,
            prefill_done_s: done,
            last_token_s: done,
            req,
        });
        self.tick_backend();
    }

    /// One lockstep decode iteration over `active` plus the per-token
    /// bookkeeping: TPOP recording, context/generated advance, completed-
    /// request retirement (E2E recording), and the backend tick.
    pub fn decode_round(&mut self, active: &mut Vec<ActiveRequest>) {
        let step_end = self.decode_step(active);
        let mut i = 0;
        while i < active.len() {
            // TPOP counts inter-token gaps from the second generated
            // token on (the first gap is prefill queueing, reported as
            // TTFT, not TPOP).
            if active[i].generated > 0 {
                self.metrics
                    .tpop
                    .record(step_end - active[i].last_token_s);
            }
            active[i].generated += 1;
            active[i].ctx += 1;
            active[i].last_token_s = step_end;
            self.metrics.decode_tokens += 1;
            if active[i].generated >= active[i].req.output_len {
                let r = active.swap_remove(i);
                self.metrics.e2e.record(step_end - r.req.arrival_s);
            } else {
                i += 1;
            }
        }
        self.tick_backend();
    }

    /// Iteration boundary: let the backend publish residency updates and
    /// charge any forced stall (blocking-transition ablation) to the clock.
    /// Host-side staging is quiesced first so publication depends only on
    /// *modeled* completion events — every serving run is then reproducible
    /// from its seed (staging adds no modeled stall; it overlaps on the
    /// host).
    fn tick_backend(&mut self) {
        let now = self.clock.now();
        self.backend.sync_staging();
        let stall = self.backend.tick(now);
        self.clock.advance_by(stall);
    }

    /// The MoE block of one layer at one modeled iteration — the single
    /// implementation shared by prefill and decode: sample top-k routing
    /// for every `(request id, tokens)` pair, feed the router trace to the
    /// backend, track activation ratios, resolve each touched expert's
    /// precision, and account expert compute with transfer overlap.
    ///
    /// Expert fetches (offloading backends) overlap the layer's compute:
    /// the GPU waits only for transfer time that extends past the end of
    /// the layer's expert execution. Returns `(layer_compute_s,
    /// added_stall_s)` for the caller's running compute/stall totals;
    /// `shared_tokens` is the token count each pinned shared expert runs
    /// (prompt length in prefill, batch size in decode).
    fn moe_layer(
        &mut self,
        layer: usize,
        routed_by: &[(u64, usize)],
        shared_tokens: usize,
        prefill: bool,
        layer_start: f64,
    ) -> (f64, f64) {
        self.counts.fill(0);
        self.touched.clear();
        self.routed.clear();
        for &(id, tokens) in routed_by {
            for _ in 0..tokens {
                // Scratch-buffer sampling: identical RNG stream and expert
                // order to the allocating path (asserted in bench_smoke),
                // with zero per-token allocation.
                self.sampler.sample_topk_into(
                    &mut self.rng,
                    id,
                    layer,
                    &mut self.picked,
                );
                for &e in &self.picked {
                    if self.counts[e] == 0 {
                        self.touched.push(e);
                    }
                    self.counts[e] += 1;
                    self.routed.push(e);
                }
            }
        }
        self.backend.record_routing(layer, &self.routed);
        if self.cfg.track_activation {
            let ratio =
                self.touched.len() as f64 / self.preset.n_experts as f64;
            if prefill {
                self.activation.prefill.push(ratio);
            } else {
                self.activation.decode.push(ratio);
            }
        }
        // Expert compute runs on per-device lanes: one lane is the classic
        // serial sum; a sharded backend executes each device's local
        // experts in parallel and the layer completes when the slowest
        // lane drains (expert parallelism). Shared experts are replicated
        // on every lane. With one lane the accumulation order is identical
        // to the historical loop, so single-device timings are bit-exact.
        let n_dev = self.backend.n_devices().max(1);
        self.lanes.clear();
        self.lanes.resize(n_dev, 0.0);
        let mut max_ready = layer_start;
        for idx in 0..self.touched.len() {
            let e = self.touched[idx];
            let (prec, stall) = self.backend.resolve(layer, e, layer_start);
            max_ready = max_ready.max(layer_start + stall);
            let lane =
                if n_dev == 1 { 0 } else { self.backend.device_of(layer, e) };
            let t = self.cost.expert_time(self.counts[e] as usize, prec);
            self.lanes[lane] += t;
        }
        if self.preset.n_shared > 0 {
            let t = self.cost.expert_time(shared_tokens, self.preset.hi());
            for _ in 0..self.preset.n_shared {
                for lane in self.lanes.iter_mut() {
                    *lane += t;
                }
            }
        }
        let layer_compute = self.lanes.iter().copied().fold(0.0f64, f64::max);
        let added_stall =
            (max_ready - (layer_start + layer_compute)).max(0.0);
        (layer_compute, added_stall)
    }

    /// Prefill one request; returns its completion (first-token) time.
    fn prefill(&mut self, req: &Request, start_s: f64) -> f64 {
        let t = req.prompt_len;
        let mut compute_s = self.cost.embed_time(t);
        let mut stall_s = 0.0;
        for layer in 0..self.n_layers {
            compute_s += self.cost.attn_prefill_time(t);
            compute_s += self.cost.router_time(t);
            // Sample routing for every prompt token.
            let layer_start = self.clock.now() + compute_s + stall_s;
            let (layer_compute, added_stall) =
                self.moe_layer(layer, &[(req.id, t)], t, true, layer_start);
            compute_s += layer_compute;
            stall_s += added_stall;
        }
        compute_s += self.cost.lm_head_time(1);
        let end = self
            .compute
            .schedule(start_s + stall_s, compute_s);
        self.metrics.wait.record(stall_s);
        self.clock.advance_to(end);
        end
    }

    /// One lockstep decode iteration over the active batch; returns its
    /// completion time.
    fn decode_step(&mut self, active: &mut [ActiveRequest]) -> f64 {
        let b = active.len();
        let mean_ctx =
            active.iter().map(|a| a.ctx).sum::<usize>() / b.max(1);
        // One routed token per active request, in admission order.
        let routed_by: Vec<(u64, usize)> =
            active.iter().map(|a| (a.req.id, 1)).collect();
        let mut compute_s = self.cost.embed_time(b);
        let mut stall_s = 0.0;
        for layer in 0..self.n_layers {
            compute_s += self.cost.attn_decode_time(b, mean_ctx);
            compute_s += self.cost.router_time(b);
            let layer_start = self.clock.now() + compute_s + stall_s;
            let (layer_compute, added_stall) =
                self.moe_layer(layer, &routed_by, b, false, layer_start);
            compute_s += layer_compute;
            stall_s += added_stall;
        }
        compute_s += self.cost.lm_head_time(b);
        let start = self.clock.now() + stall_s;
        let end = self.compute.schedule(start, compute_s);
        self.metrics.wait.record(stall_s);
        self.clock.advance_to(end);
        end
    }

    /// Warm to steady state and discard the warmup metrics (the paper
    /// measures converged serving, not cold start) — the one warmup
    /// protocol shared by the session builder and the experiment harnesses.
    pub fn warm(&mut self, profile: &WorkloadProfile, rounds: usize) {
        for _ in 0..rounds {
            self.serve_uniform(profile, 8, 128, 16);
        }
        self.metrics = Default::default();
        self.activation = Default::default();
    }

    /// Convenience: generate + serve one closed batch of identical shape.
    pub fn serve_uniform(
        &mut self,
        profile: &WorkloadProfile,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) {
        let mut gen = crate::workload::RequestGenerator::new(
            profile.clone(),
            self.cfg.seed ^ 0xBEEF,
        );
        let reqs = gen.batch(batch, prompt_len, output_len, self.clock.now());
        self.serve_batch(reqs);
    }

    /// Serve one scripted scenario phase: switch to its routing
    /// distribution and run `phase.rounds` closed batches at the
    /// load-scaled batch size. Backend state carries across phases — the
    /// boundary miscalibration is what scenarios measure.
    pub fn run_phase(
        &mut self,
        phase: &ScenarioPhase,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) {
        self.set_profile(&phase.profile);
        let b = Scenario::scaled_batch(batch, phase.load);
        for _ in 0..phase.rounds {
            self.serve_uniform(&phase.profile, b, prompt_len, output_len);
        }
    }

    /// Drive a whole [`Scenario`] (DESIGN.md §10) phase by phase. Callers
    /// needing phase-boundary hooks (the scenario-matrix invariant suite)
    /// iterate [`Engine::run_phase`] themselves.
    pub fn run_scenario(
        &mut self,
        scenario: &Scenario,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) {
        for phase in &scenario.phases {
            self.run_phase(phase, batch, prompt_len, output_len);
        }
    }

    /// Open-loop continuous batching: requests arrive over time
    /// (`arrival_s` honored); new arrivals are prefilled and join the
    /// decode batch as soon as a slot under `max_batch` frees up. Decode
    /// proceeds in lockstep over whoever is active — vLLM-style iteration
    /// scheduling over the modeled device. Equivalent to
    /// [`Engine::serve_with`] under [`ContinuousBatch`].
    pub fn serve_stream(&mut self, pending: Vec<Request>) {
        self.serve_with(&mut ContinuousBatch::default(), pending);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::serving::backend::{DynaExqBackend, StaticBackend};

    fn static_engine(batch_cap: usize) -> Engine {
        let preset = ModelPreset::qwen30b_sim();
        let profile = WorkloadProfile::text();
        Engine::new(
            &preset,
            &profile,
            Box::new(StaticBackend::for_preset(&preset)),
            &DeviceConfig::default(),
            EngineConfig {
                max_batch: batch_cap,
                seed: 42,
                track_activation: true,
            },
        )
    }

    #[test]
    fn serves_and_reports_metrics() {
        let mut e = static_engine(8);
        e.serve_uniform(&WorkloadProfile::text(), 4, 64, 8);
        assert_eq!(e.metrics.ttft.count(), 4);
        assert_eq!(e.metrics.e2e.count(), 4);
        assert_eq!(e.metrics.decode_tokens, 32);
        assert_eq!(e.metrics.prefill_tokens, 256);
        assert!(e.metrics.throughput() > 0.0);
        assert!(e.metrics.ttft.avg() > 0.0);
    }

    #[test]
    fn static_backend_never_waits() {
        let mut e = static_engine(8);
        e.serve_uniform(&WorkloadProfile::text(), 8, 128, 4);
        assert_eq!(e.metrics.wait.max(), 0.0);
    }

    #[test]
    fn prefill_activation_denser_than_decode() {
        // Tables 1–2 shape: prefill activates far more experts per layer.
        let mut e = static_engine(8);
        e.serve_uniform(&WorkloadProfile::text(), 4, 512, 16);
        let pre = e.activation.prefill_avg();
        let dec = e.activation.decode_avg();
        assert!(pre > 2.0 * dec, "prefill {pre} vs decode {dec}");
    }

    #[test]
    fn activation_grows_with_batch() {
        let ratio_at = |batch: usize| {
            let mut e = static_engine(batch);
            e.serve_uniform(&WorkloadProfile::text(), batch, 16, 16);
            e.activation.decode_avg()
        };
        let r1 = ratio_at(1);
        let r16 = ratio_at(16);
        assert!(r16 > 2.0 * r1, "batch 16 {r16} vs batch 1 {r1}");
    }

    #[test]
    fn stream_serving_honors_arrivals_and_capacity() {
        let mut e = static_engine(2); // max_batch = 2
        let mut gen = crate::workload::RequestGenerator::new(
            WorkloadProfile::text(),
            3,
        );
        let mut reqs = Vec::new();
        for i in 0..6 {
            reqs.push(gen.request(32, 8, i as f64 * 0.05));
        }
        e.serve_stream(reqs);
        assert_eq!(e.metrics.e2e.count(), 6);
        assert_eq!(e.metrics.decode_tokens, 48);
        // later arrivals must wait for capacity → TTFT tail exceeds head
        assert!(e.metrics.ttft.max() > e.metrics.ttft.p50());
    }

    #[test]
    fn stream_serving_idle_gap_skips_ahead() {
        let mut e = static_engine(4);
        let mut gen = crate::workload::RequestGenerator::new(
            WorkloadProfile::text(),
            4,
        );
        // second request arrives long after the first finishes
        let reqs = vec![gen.request(16, 4, 0.0), gen.request(16, 4, 1e3)];
        e.serve_stream(reqs);
        assert_eq!(e.metrics.e2e.count(), 2);
        // engine idles between them rather than spinning
        assert!(e.metrics.duration_s >= 1e3);
        // TTFT measured from arrival, not from idle start
        assert!(e.metrics.ttft.max() < 10.0);
    }

    #[test]
    fn sharded_lanes_run_expert_compute_in_parallel() {
        // Same model, same envelope, same traffic: a 2-device group splits
        // each layer's expert compute across lanes, so the modeled run
        // finishes sooner than the 1-device group (which is the exact
        // single-GPU system).
        use crate::serving::backend::DynaExqShardedBackend;
        let duration = |devices: usize| {
            let preset = ModelPreset::qwen30b_sim();
            let profile = WorkloadProfile::text();
            let backend = DynaExqShardedBackend::new(
                &preset,
                &ServingConfig::default(),
                &DeviceConfig::default(),
                devices,
            )
            .unwrap();
            let mut e = Engine::new(
                &preset,
                &profile,
                Box::new(backend),
                &DeviceConfig::default(),
                EngineConfig { max_batch: 8, seed: 77, track_activation: false },
            );
            e.serve_uniform(&profile, 8, 64, 16);
            e.metrics.duration_s
        };
        let one = duration(1);
        let two = duration(2);
        assert!(
            two < one,
            "2-device group must finish sooner: {two} vs {one}"
        );
    }

    #[test]
    fn steady_scenario_byte_identical_to_uniform_rounds() {
        // Acceptance anchor: the steady scenario on the classic 2-rung /
        // 1-device stack is *exactly* the historical serve_uniform loop —
        // same modeled clock, same metrics, same residency trajectory.
        let preset = ModelPreset::qwen30b_sim();
        let profile = WorkloadProfile::text();
        let cfg = ServingConfig::default();
        let build = || {
            let backend =
                DynaExqBackend::new(&preset, &cfg, &DeviceConfig::default())
                    .unwrap();
            Engine::new(
                &preset,
                &profile,
                Box::new(backend),
                &DeviceConfig::default(),
                EngineConfig { max_batch: 8, seed: 5, track_activation: true },
            )
        };
        let sc = crate::workload::Scenario::steady();
        let mut via_scenario = build();
        via_scenario.run_scenario(&sc, 4, 32, 8);
        let mut via_rounds = build();
        for _ in 0..sc.total_rounds() {
            via_rounds.serve_uniform(&profile, 4, 32, 8);
        }
        let (s, r) = (&via_scenario, &via_rounds);
        assert_eq!(s.metrics.duration_s, r.metrics.duration_s);
        assert_eq!(s.metrics.ttft.avg(), r.metrics.ttft.avg());
        assert_eq!(s.metrics.e2e.p99(), r.metrics.e2e.p99());
        assert_eq!(s.metrics.decode_tokens, r.metrics.decode_tokens);
        assert_eq!(s.backend.migrated_bytes(), r.backend.migrated_bytes());
        assert_eq!(s.backend.tier_residency(), r.backend.tier_residency());
        assert_eq!(s.backend.hi_fraction(), r.backend.hi_fraction());
    }

    #[test]
    fn dynaexq_converges_to_hot_residency() {
        let preset = ModelPreset::qwen30b_sim();
        let profile = WorkloadProfile::text();
        let cfg = ServingConfig::default();
        let backend =
            DynaExqBackend::new(&preset, &cfg, &DeviceConfig::default())
                .unwrap();
        let mut e = Engine::new(
            &preset,
            &profile,
            Box::new(backend),
            &DeviceConfig::default(),
            EngineConfig { max_batch: 8, seed: 7, track_activation: false },
        );
        for _ in 0..6 {
            e.serve_uniform(&profile, 8, 64, 16);
        }
        assert!(
            e.backend.hi_fraction() > 0.3,
            "hot traffic should increasingly hit the hi tier: {}",
            e.backend.hi_fraction()
        );
        assert_eq!(e.metrics.wait.max(), 0.0, "DynaExq never stalls");
    }
}
