//! The serving front door: `SessionBuilder` → [`ServeSession`].
//!
//! One fluent, validated entry point for every way this repo serves a
//! model (DESIGN.md §4): pick a model / method / workload by name, tune
//! the envelope and engine knobs, and get back a session that hides which
//! engine runs underneath —
//!
//! * [`EngineKind::Modeled`] — the cost-model [`Engine`] (paper-scale
//!   dims, every performance experiment);
//! * [`EngineKind::Numeric`] — the [`NumericEngine`] (real PJRT execution
//!   of the small model, quality experiments).
//!
//! Both sit behind the [`SessionEngine`] trait; methods come from the
//! [`BackendRegistry`], so `hobbit` or `static-map` are exactly as
//! reachable as `dynaexq`. Validation (unknown names enumerate the valid
//! set; infeasible HBM envelopes fail fast) happens in
//! [`SessionBuilder::build`], *before* any engine state is constructed.
//! Results export as a [`MetricsSnapshot`] — a flat, `key=value`-encoded
//! record (the repo's serde-free serialization, [`crate::config::kv`]).

use anyhow::{anyhow, bail, Result};

use crate::config::frontdoor::{FrontDoorConfig, Lane};
use crate::config::{kv, DeviceConfig, ModelPreset, QosConfig, ServingConfig};
use crate::metrics::ServingMetrics;
use crate::workload::{Request, RequestGenerator, Scenario, WorkloadProfile};

use super::backend::ResidencyBackend;
use super::engine::{ActivationStats, Engine, EngineConfig};
use super::frontdoor::{FrontDoor, Rejected};
use super::registry::{BackendCtx, BackendRegistry};
use super::scheduler::Scheduler;

#[cfg(feature = "numeric")]
use super::numeric::{NumericEngine, SeqState};
#[cfg(feature = "numeric")]
use crate::model::ModelWeights;
#[cfg(feature = "numeric")]
use crate::runtime::Runtime;
#[cfg(feature = "numeric")]
use crate::util::XorShiftRng;
#[cfg(feature = "numeric")]
use anyhow::Context;
#[cfg(feature = "numeric")]
use std::sync::Arc;

/// Which engine a session runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Cost-model engine at paper-scale dims (performance experiments).
    Modeled,
    /// Real PJRT execution of the small model (quality experiments;
    /// requires the `numeric` build feature).
    Numeric,
}

/// The engine behaviour a [`ServeSession`] needs, independent of whether
/// numerics are modeled or executed.
pub trait SessionEngine {
    fn kind(&self) -> EngineKind;

    /// Serve one closed batch of uniform shape.
    fn serve_closed(
        &mut self,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) -> Result<()>;

    /// Serve explicit requests (arrivals honored — modeled engine only).
    fn serve_requests(&mut self, requests: Vec<Request>) -> Result<()>;

    /// Serve explicit requests under a caller-chosen [`Scheduler`] (the
    /// front door's drain path — modeled engine only).
    fn serve_scheduled(
        &mut self,
        _scheduler: &mut dyn Scheduler,
        _requests: Vec<Request>,
    ) -> Result<()> {
        bail!(
            "scheduler-driven serving is modeled-engine only; build the \
             session with EngineKind::Modeled"
        )
    }

    /// Switch the live workload profile (shift experiments).
    fn set_profile(&mut self, profile: &WorkloadProfile);

    /// Attribute subsequent routing/resolution traffic to a QoS class
    /// (index into [`crate::config::QosClass::ALL`]). Default no-op:
    /// engines whose backend has no armed QoS config ignore it.
    fn set_active_class(&mut self, _class: usize) {}

    fn metrics(&self) -> &ServingMetrics;
    fn reset_metrics(&mut self);
    fn backend(&self) -> &dyn ResidencyBackend;
    /// Activation-ratio samples, when the engine tracks them.
    fn activation(&self) -> Option<&ActivationStats>;
    /// Modeled clock.
    fn now(&self) -> f64;
}

// ---------------------------------------------------------------------------
// Modeled engine adapter
// ---------------------------------------------------------------------------

struct ModeledSession {
    engine: Engine,
    profile: WorkloadProfile,
}

impl SessionEngine for ModeledSession {
    fn kind(&self) -> EngineKind {
        EngineKind::Modeled
    }

    fn serve_closed(
        &mut self,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) -> Result<()> {
        self.engine
            .serve_uniform(&self.profile, batch, prompt_len, output_len);
        Ok(())
    }

    fn serve_requests(&mut self, requests: Vec<Request>) -> Result<()> {
        self.engine.serve_stream(requests);
        Ok(())
    }

    fn serve_scheduled(
        &mut self,
        scheduler: &mut dyn Scheduler,
        requests: Vec<Request>,
    ) -> Result<()> {
        self.engine.serve_with(scheduler, requests);
        Ok(())
    }

    fn set_profile(&mut self, profile: &WorkloadProfile) {
        self.engine.set_profile(profile);
        self.profile = profile.clone();
    }

    fn set_active_class(&mut self, class: usize) {
        self.engine.backend.set_active_class(class);
    }

    fn metrics(&self) -> &ServingMetrics {
        &self.engine.metrics
    }

    fn reset_metrics(&mut self) {
        self.engine.metrics = Default::default();
        self.engine.activation = Default::default();
    }

    fn backend(&self) -> &dyn ResidencyBackend {
        self.engine.backend.as_ref()
    }

    fn activation(&self) -> Option<&ActivationStats> {
        Some(&self.engine.activation)
    }

    fn now(&self) -> f64 {
        self.engine.now()
    }
}

// ---------------------------------------------------------------------------
// Numeric engine adapter
// ---------------------------------------------------------------------------

#[cfg(feature = "numeric")]
struct NumericSession {
    engine: NumericEngine,
    profile: WorkloadProfile,
    rng: XorShiftRng,
    metrics: ServingMetrics,
    next_tag: u64,
}

#[cfg(feature = "numeric")]
impl SessionEngine for NumericSession {
    fn kind(&self) -> EngineKind {
        EngineKind::Numeric
    }

    fn serve_closed(
        &mut self,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) -> Result<()> {
        // Closed-batch shape mirrored from the modeled engine: prefill
        // request-by-request (TTFT from batch arrival), then lockstep
        // decode. The numeric engine advances the same modeled clock.
        let arrival = self.engine.now();
        let mut seqs: Vec<SeqState> = Vec::with_capacity(batch);
        for _ in 0..batch {
            let prompt = self.profile.sample_prompt(&mut self.rng, prompt_len);
            let tag = self.next_tag;
            self.next_tag += 1;
            let (kv, _logits) = self
                .engine
                .prefill(&prompt, tag)
                .context("numeric prefill")?;
            self.metrics.ttft.record(self.engine.now() - arrival);
            self.metrics.prefill_tokens += prompt_len as u64;
            seqs.push(SeqState {
                kv,
                last_token: *prompt.last().unwrap(),
                tag,
                generated: Vec::new(),
            });
        }
        let mut last_token_s = self.engine.now();
        for step in 0..output_len {
            self.engine.decode_step(&mut seqs).context("numeric decode")?;
            let now = self.engine.now();
            if step > 0 {
                for _ in 0..batch {
                    self.metrics.tpop.record(now - last_token_s);
                }
            }
            last_token_s = now;
            self.metrics.decode_tokens += batch as u64;
        }
        let done = self.engine.now();
        for _ in 0..batch {
            self.metrics.e2e.record(done - arrival);
        }
        self.metrics.duration_s = done;
        Ok(())
    }

    fn serve_requests(&mut self, _requests: Vec<Request>) -> Result<()> {
        bail!(
            "open-loop serving is modeled-engine only; build the session \
             with EngineKind::Modeled"
        )
    }

    fn set_profile(&mut self, profile: &WorkloadProfile) {
        self.profile = profile.clone();
    }

    fn metrics(&self) -> &ServingMetrics {
        &self.metrics
    }

    fn reset_metrics(&mut self) {
        self.metrics = Default::default();
    }

    fn backend(&self) -> &dyn ResidencyBackend {
        self.engine.backend.as_ref()
    }

    fn activation(&self) -> Option<&ActivationStats> {
        None
    }

    fn now(&self) -> f64 {
        self.engine.now()
    }
}

// ---------------------------------------------------------------------------
// MetricsSnapshot
// ---------------------------------------------------------------------------

/// A flat, serializable record of one serving session's outcome.
///
/// Encodes to the repo's `key=value;...` text format (see
/// [`crate::config::kv`]) and decodes back losslessly — f64 fields use
/// Rust's shortest-roundtrip `Display`.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct MetricsSnapshot {
    pub model: String,
    pub method: String,
    pub workload: String,
    pub ttft_avg_s: f64,
    pub ttft_p99_s: f64,
    pub tpop_avg_s: f64,
    pub tpop_p99_s: f64,
    pub e2e_avg_s: f64,
    pub e2e_p99_s: f64,
    pub wait_p99_s: f64,
    pub throughput_tok_s: f64,
    pub decode_tokens: u64,
    pub prefill_tokens: u64,
    pub duration_s: f64,
    /// Fraction of expert resolutions served at the ladder's top rung.
    pub hi_fraction: f64,
    pub migrated_bytes: u64,
    /// Mean per-layer activation ratios (0 when untracked).
    pub act_prefill: f64,
    pub act_decode: f64,
    /// Published residency counts per ladder rung, tier 0 first (empty
    /// for backends without a residency table). Encoded `a|b|c`.
    pub tier_resident: Vec<usize>,
    /// Published residency per device of a sharded group, tier 0 first
    /// within each device (empty when the backend exposes no per-device
    /// residency). Encoded `a|b|c/d|e|f` — devices `/`-separated, rungs
    /// `|`-separated.
    pub device_resident: Vec<Vec<usize>>,
    /// In-flight transition count per device — the cross-device
    /// promotion-queue depth (empty without a transition pipeline).
    /// Encoded `a|b`.
    pub promo_queue_depth: Vec<usize>,
    /// Change-point triggers observed by the drift-aware hotness layer
    /// (0 for fixed-α methods — DESIGN.md §10).
    pub drift_events: u64,
    /// Update intervals spent at the dropped (reactive) α recovering from
    /// those triggers.
    pub drift_recovery_ticks: u64,
    /// Admission-queue depth at snapshot time (0 when the session has no
    /// front door — DESIGN.md §12).
    pub fd_queue_depth: u64,
    /// Front-door admissions per lane, [`Lane::index`] order
    /// (interactive|standard|batch). Encoded `a|b|c`; empty without a
    /// front door.
    pub fd_lane_admitted: Vec<u64>,
    /// Typed rejections per lane (same order/encoding).
    pub fd_lane_rejected: Vec<u64>,
    /// Served requests whose TTFT blew the lane's SLO deadline (same
    /// order/encoding).
    pub fd_lane_deadline_miss: Vec<u64>,
    /// Replica count of the fleet the snapshot aggregates (0 for
    /// non-fleet sessions and per-replica views — DESIGN.md §14).
    pub fleet_replicas: u64,
    /// Per-replica health at snapshot time, `ReplicaHealth::code` values
    /// (0 healthy, 1 degraded, 2 down, 3 draining). Encoded `a|b`; empty
    /// without a fleet.
    pub fleet_health: Vec<u64>,
    /// Engine admissions per replica (readmissions after failover land
    /// on the replica that finished the stream). Same encoding.
    pub fleet_served: Vec<u64>,
    /// Replica drain events that stranded in-flight work (Down
    /// transitions and administrative drains).
    pub fleet_failovers: u64,
    /// Requests re-admitted through the front door with token position
    /// preserved.
    pub fleet_readmitted: u64,
    /// Expert resolutions per `[class][tier]` (class order =
    /// `QosClass::ALL`, tier 0 first within each class). Encoded like
    /// `device_resident` — classes `/`-separated, rungs `|`-separated.
    /// Empty without an armed QoS config (DESIGN.md §15), so classic
    /// snapshots stay byte-identical.
    pub qos_class_resolved: Vec<Vec<u64>>,
    /// Bytes of modeled hi-precision occupancy charged per class at
    /// admission (`QosClass::ALL` order). Encoded `a|b|c`; empty unarmed.
    pub qos_charged: Vec<u64>,
    /// Bytes refunded per class at drain settlement (same encoding).
    pub qos_refunded: Vec<u64>,
    /// Admissions that demoted their tenant to best-effort pricing.
    pub qos_downgraded: u64,
    /// Submissions rejected as `Rejected::BudgetExhausted`.
    pub qos_budget_rejected: u64,
}

impl MetricsSnapshot {
    /// Render per-device residency rows in the snapshot's wire/display
    /// form: rungs `|`-joined within a device, devices `/`-joined — the
    /// single definition of the format [`MetricsSnapshot::decode`] parses
    /// (reports, ablation A9, and the examples render through it too).
    pub fn encode_per_device(rows: &[Vec<usize>]) -> String {
        rows.iter()
            .map(|dev| {
                dev.iter()
                    .map(|n| n.to_string())
                    .collect::<Vec<_>>()
                    .join("|")
            })
            .collect::<Vec<_>>()
            .join("/")
    }

    /// `key=value;...` encoding (order fixed for diff-friendliness).
    pub fn encode(&self) -> String {
        format!(
            "model={};method={};workload={};ttft_avg_s={};ttft_p99_s={};\
             tpop_avg_s={};tpop_p99_s={};e2e_avg_s={};e2e_p99_s={};\
             wait_p99_s={};throughput_tok_s={};decode_tokens={};\
             prefill_tokens={};duration_s={};hi_fraction={};\
             migrated_bytes={};act_prefill={};act_decode={};\
             tier_resident={};device_resident={};promo_queue_depth={};\
             drift_events={};drift_recovery_ticks={};fd_queue_depth={};\
             fd_lane_admitted={};fd_lane_rejected={};\
             fd_lane_deadline_miss={};fleet_replicas={};fleet_health={};\
             fleet_served={};fleet_failovers={};fleet_readmitted={};\
             qos_class_resolved={};qos_charged={};qos_refunded={};\
             qos_downgraded={};qos_budget_rejected={}",
            self.model,
            self.method,
            self.workload,
            self.ttft_avg_s,
            self.ttft_p99_s,
            self.tpop_avg_s,
            self.tpop_p99_s,
            self.e2e_avg_s,
            self.e2e_p99_s,
            self.wait_p99_s,
            self.throughput_tok_s,
            self.decode_tokens,
            self.prefill_tokens,
            self.duration_s,
            self.hi_fraction,
            self.migrated_bytes,
            self.act_prefill,
            self.act_decode,
            self.tier_resident
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join("|"),
            Self::encode_per_device(&self.device_resident),
            self.promo_queue_depth
                .iter()
                .map(|n| n.to_string())
                .collect::<Vec<_>>()
                .join("|"),
            self.drift_events,
            self.drift_recovery_ticks,
            self.fd_queue_depth,
            Self::encode_u64_list(&self.fd_lane_admitted),
            Self::encode_u64_list(&self.fd_lane_rejected),
            Self::encode_u64_list(&self.fd_lane_deadline_miss),
            self.fleet_replicas,
            Self::encode_u64_list(&self.fleet_health),
            Self::encode_u64_list(&self.fleet_served),
            self.fleet_failovers,
            self.fleet_readmitted,
            self.qos_class_resolved
                .iter()
                .map(|row| Self::encode_u64_list(row))
                .collect::<Vec<_>>()
                .join("/"),
            Self::encode_u64_list(&self.qos_charged),
            Self::encode_u64_list(&self.qos_refunded),
            self.qos_downgraded,
            self.qos_budget_rejected,
        )
    }

    fn encode_u64_list(xs: &[u64]) -> String {
        xs.iter().map(|n| n.to_string()).collect::<Vec<_>>().join("|")
    }

    /// Parse an [`MetricsSnapshot::encode`] string back.
    pub fn decode(s: &str) -> Result<Self> {
        let m = kv::parse_kv(s);
        let text = |key: &str| -> Result<String> {
            m.get(key).cloned().ok_or_else(|| anyhow!("missing key {key:?}"))
        };
        fn num<T: std::str::FromStr>(
            m: &std::collections::BTreeMap<String, String>,
            key: &str,
        ) -> Result<T> {
            kv::get_parse(m, key)
                .ok_or_else(|| anyhow!("missing/invalid key {key:?}"))
        }
        Ok(Self {
            model: text("model")?,
            method: text("method")?,
            workload: text("workload")?,
            ttft_avg_s: num(&m, "ttft_avg_s")?,
            ttft_p99_s: num(&m, "ttft_p99_s")?,
            tpop_avg_s: num(&m, "tpop_avg_s")?,
            tpop_p99_s: num(&m, "tpop_p99_s")?,
            e2e_avg_s: num(&m, "e2e_avg_s")?,
            e2e_p99_s: num(&m, "e2e_p99_s")?,
            wait_p99_s: num(&m, "wait_p99_s")?,
            throughput_tok_s: num(&m, "throughput_tok_s")?,
            decode_tokens: num(&m, "decode_tokens")?,
            prefill_tokens: num(&m, "prefill_tokens")?,
            duration_s: num(&m, "duration_s")?,
            hi_fraction: num(&m, "hi_fraction")?,
            migrated_bytes: num(&m, "migrated_bytes")?,
            act_prefill: num(&m, "act_prefill")?,
            act_decode: num(&m, "act_decode")?,
            tier_resident: {
                let raw = text("tier_resident")?;
                raw.split('|')
                    .filter(|s| !s.is_empty())
                    .map(|s| {
                        s.parse().map_err(|_| {
                            anyhow!("invalid tier_resident entry {s:?}")
                        })
                    })
                    .collect::<Result<Vec<usize>>>()?
            },
            device_resident: {
                let raw = text("device_resident")?;
                raw.split('/')
                    .filter(|s| !s.is_empty())
                    .map(|dev| {
                        dev.split('|')
                            .filter(|s| !s.is_empty())
                            .map(|s| {
                                s.parse().map_err(|_| {
                                    anyhow!(
                                        "invalid device_resident entry {s:?}"
                                    )
                                })
                            })
                            .collect::<Result<Vec<usize>>>()
                    })
                    .collect::<Result<Vec<Vec<usize>>>>()?
            },
            promo_queue_depth: {
                let raw = text("promo_queue_depth")?;
                raw.split('|')
                    .filter(|s| !s.is_empty())
                    .map(|s| {
                        s.parse().map_err(|_| {
                            anyhow!("invalid promo_queue_depth entry {s:?}")
                        })
                    })
                    .collect::<Result<Vec<usize>>>()?
            },
            drift_events: num(&m, "drift_events")?,
            drift_recovery_ticks: num(&m, "drift_recovery_ticks")?,
            fd_queue_depth: num(&m, "fd_queue_depth")?,
            fd_lane_admitted: Self::decode_u64_list(
                &text("fd_lane_admitted")?,
                "fd_lane_admitted",
            )?,
            fd_lane_rejected: Self::decode_u64_list(
                &text("fd_lane_rejected")?,
                "fd_lane_rejected",
            )?,
            fd_lane_deadline_miss: Self::decode_u64_list(
                &text("fd_lane_deadline_miss")?,
                "fd_lane_deadline_miss",
            )?,
            fleet_replicas: num(&m, "fleet_replicas")?,
            fleet_health: Self::decode_u64_list(
                &text("fleet_health")?,
                "fleet_health",
            )?,
            fleet_served: Self::decode_u64_list(
                &text("fleet_served")?,
                "fleet_served",
            )?,
            fleet_failovers: num(&m, "fleet_failovers")?,
            fleet_readmitted: num(&m, "fleet_readmitted")?,
            qos_class_resolved: {
                let raw = text("qos_class_resolved")?;
                raw.split('/')
                    .filter(|s| !s.is_empty())
                    .map(|row| {
                        Self::decode_u64_list(row, "qos_class_resolved")
                    })
                    .collect::<Result<Vec<Vec<u64>>>>()?
            },
            qos_charged: Self::decode_u64_list(
                &text("qos_charged")?,
                "qos_charged",
            )?,
            qos_refunded: Self::decode_u64_list(
                &text("qos_refunded")?,
                "qos_refunded",
            )?,
            qos_downgraded: num(&m, "qos_downgraded")?,
            qos_budget_rejected: num(&m, "qos_budget_rejected")?,
        })
    }

    fn decode_u64_list(raw: &str, key: &str) -> Result<Vec<u64>> {
        raw.split('|')
            .filter(|s| !s.is_empty())
            .map(|s| s.parse().map_err(|_| anyhow!("invalid {key} entry {s:?}")))
            .collect()
    }

    /// Snapshot of a backend-only run (trace replay): the latency series
    /// stay empty; residency/migration fields come straight from the
    /// backend. This is what the trace-replay conformance suite compares
    /// byte for byte across replays.
    pub fn from_replay(
        model: &str,
        method: &str,
        workload: &str,
        backend: &dyn super::backend::ResidencyBackend,
        end_s: f64,
    ) -> Self {
        let (drift_events, drift_recovery_ticks) = backend.drift_stats();
        Self {
            model: model.into(),
            method: method.into(),
            workload: workload.into(),
            duration_s: end_s,
            hi_fraction: backend.hi_fraction(),
            migrated_bytes: backend.migrated_bytes(),
            tier_resident: backend.tier_residency(),
            device_resident: backend.device_residency(),
            promo_queue_depth: backend.promo_queue_depth(),
            drift_events,
            drift_recovery_ticks,
            qos_class_resolved: backend.class_tier_resolves(),
            ..Self::default()
        }
    }
}

// ---------------------------------------------------------------------------
// ServeSession + SessionBuilder
// ---------------------------------------------------------------------------

/// A live serving session: one model × method × workload on one engine,
/// optionally fronted by a bounded admission queue (DESIGN.md §12).
pub struct ServeSession {
    inner: Box<dyn SessionEngine>,
    pub model: String,
    pub method: String,
    pub workload: String,
    frontdoor: Option<FrontDoor>,
    seed: u64,
}

impl ServeSession {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::default()
    }

    pub fn kind(&self) -> EngineKind {
        self.inner.kind()
    }

    /// Serve one closed batch of uniform shape.
    pub fn serve_closed(
        &mut self,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) -> Result<&ServingMetrics> {
        self.inner.serve_closed(batch, prompt_len, output_len)?;
        Ok(self.inner.metrics())
    }

    /// Serve `rounds` closed batches of the same shape.
    pub fn serve_rounds(
        &mut self,
        rounds: usize,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) -> Result<&ServingMetrics> {
        for _ in 0..rounds {
            self.inner.serve_closed(batch, prompt_len, output_len)?;
        }
        Ok(self.inner.metrics())
    }

    /// Serve explicit requests, arrivals honored (modeled engine only).
    pub fn serve_requests(
        &mut self,
        requests: Vec<Request>,
    ) -> Result<&ServingMetrics> {
        self.inner.serve_requests(requests)?;
        Ok(self.inner.metrics())
    }

    /// Drive a scripted [`Scenario`] (DESIGN.md §10): each phase switches
    /// the live routing distribution and serves `phase.rounds` closed
    /// batches at the phase's load-scaled batch size. Returns one
    /// `(phase name, cumulative snapshot)` per phase boundary — the
    /// scenario-matrix suite asserts its standing invariants on exactly
    /// these boundaries. The backend keeps all state across phases: the
    /// miscalibration at each boundary is what the scenario measures.
    pub fn run_scenario(
        &mut self,
        scenario: &Scenario,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) -> Result<Vec<(String, MetricsSnapshot)>> {
        let mut marks = Vec::with_capacity(scenario.phases.len());
        for phase in &scenario.phases {
            self.inner.set_profile(&phase.profile);
            self.workload = phase.profile.name.to_string();
            if let Some(class) = phase.qos_class {
                // inert without an armed QoS config (trait default no-op)
                self.inner.set_active_class(class.index());
            }
            let b = Scenario::scaled_batch(batch, phase.load);
            for _ in 0..phase.rounds {
                self.inner.serve_closed(b, prompt_len, output_len)?;
            }
            marks.push((phase.name.clone(), self.snapshot()));
        }
        Ok(marks)
    }

    /// The front door, when the session was built with one.
    pub fn frontdoor(&self) -> Option<&FrontDoor> {
        self.frontdoor.as_ref()
    }

    /// Submit one request to the front door (never blocking). The outer
    /// `Result` is a usage error — the session has no front door; the
    /// inner one is the admission outcome: `Ok(())` queued, `Err` a typed
    /// [`Rejected`] the caller can surface or retry on.
    pub fn submit(
        &mut self,
        req: Request,
        tenant: &str,
        lane: Lane,
    ) -> Result<std::result::Result<(), Rejected>> {
        let now = self.inner.now();
        let fd = self.frontdoor.as_ref().ok_or_else(|| {
            anyhow!(
                "session has no front door; build with \
                 SessionBuilder::frontdoor(FrontDoorConfig)"
            )
        })?;
        Ok(fd.submit(req, tenant, lane, now))
    }

    /// Drain the admission queue through the SLO-aware scheduler: every
    /// queued request is served (modeled engine), per-lane TTFT and
    /// deadline-miss accounting folds back into the front door. A drain
    /// of an empty queue is a no-op.
    pub fn drain(&mut self) -> Result<&ServingMetrics> {
        let fd = self.frontdoor.as_ref().ok_or_else(|| {
            anyhow!(
                "session has no front door; build with \
                 SessionBuilder::frontdoor(FrontDoorConfig)"
            )
        })?;
        let (mut sched, reqs) = fd.take_scheduled();
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        if !reqs.is_empty() {
            self.inner.serve_scheduled(&mut sched, reqs)?;
        }
        fd.absorb(&sched);
        // every drained request ran to completion: refund its QoS charge
        // (a no-op without an armed config)
        fd.settle(&ids);
        Ok(self.inner.metrics())
    }

    /// Drive a scripted [`Scenario`] through the front door: each phase's
    /// rounds submit `scaled_batch` requests under the phase's tenant and
    /// lane (defaulting to the profile name / Standard), then drain.
    /// Returns one `(phase name, cumulative snapshot)` per phase boundary
    /// — the front-door invariant suite asserts fairness, no-starvation
    /// and token conservation on exactly these boundaries.
    pub fn run_scenario_frontdoor(
        &mut self,
        scenario: &Scenario,
        batch: usize,
        prompt_len: usize,
        output_len: usize,
    ) -> Result<Vec<(String, MetricsSnapshot)>> {
        if self.frontdoor.is_none() {
            bail!(
                "session has no front door; build with \
                 SessionBuilder::frontdoor(FrontDoorConfig)"
            );
        }
        let Some(first) = scenario.phases.first() else {
            return Ok(Vec::new());
        };
        let mut gen = RequestGenerator::new(first.profile.clone(), self.seed ^ 0xFD00);
        let mut marks = Vec::with_capacity(scenario.phases.len());
        for phase in &scenario.phases {
            self.inner.set_profile(&phase.profile);
            self.workload = phase.profile.name.to_string();
            gen.set_profile(phase.profile.clone());
            let tenant = phase
                .tenant
                .clone()
                .unwrap_or_else(|| phase.profile.name.to_string());
            if let Some(class) = phase.qos_class {
                // pin the phase's tenant to its class and attribute the
                // phase's traffic to it — both inert without an armed
                // QoS config (DESIGN.md §15)
                if let Some(fd) = &self.frontdoor {
                    fd.set_tenant_class(&tenant, class);
                }
                self.inner.set_active_class(class.index());
            }
            let b = Scenario::scaled_batch(batch, phase.load);
            for _ in 0..phase.rounds {
                let now = self.inner.now();
                for _ in 0..b {
                    let req = gen.request(prompt_len, output_len, now);
                    // typed rejections are the scenario's backpressure
                    // signal — they land in the snapshot counters
                    let _ = self.submit(req, &tenant, phase.lane)?;
                }
                self.drain()?;
            }
            marks.push((phase.name.clone(), self.snapshot()));
        }
        Ok(marks)
    }

    /// Switch the live workload (shift experiments). The method keeps any
    /// state it built on the old workload — that miscalibration is exactly
    /// what the shift experiments measure.
    pub fn set_workload(&mut self, name: &str) -> Result<()> {
        let p = WorkloadProfile::by_name(name).ok_or_else(|| {
            anyhow!(
                "unknown workload {name:?}; known workloads: {}",
                workload_names().join(", ")
            )
        })?;
        self.inner.set_profile(&p);
        self.workload = name.to_string();
        Ok(())
    }

    pub fn metrics(&self) -> &ServingMetrics {
        self.inner.metrics()
    }

    pub fn reset_metrics(&mut self) {
        self.inner.reset_metrics()
    }

    pub fn backend(&self) -> &dyn ResidencyBackend {
        self.inner.backend()
    }

    pub fn now(&self) -> f64 {
        self.inner.now()
    }

    /// Everything measured so far, as one flat serializable record.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.metrics();
        let b = self.inner.backend();
        let (act_prefill, act_decode) = match self.inner.activation() {
            Some(a) => (a.prefill_avg(), a.decode_avg()),
            None => (0.0, 0.0),
        };
        let (drift_events, drift_recovery_ticks) = b.drift_stats();
        let fd = &self.frontdoor;
        let (fd_queue_depth, fd_adm, fd_rej, fd_miss) = match fd {
            Some(fd) => (
                fd.depth() as u64,
                fd.stats().lane_admitted(),
                fd.stats().lane_rejected(),
                fd.stats().lane_deadline_miss(),
            ),
            None => (0, Vec::new(), Vec::new(), Vec::new()),
        };
        let (qos_charged, qos_refunded, qos_downgraded, qos_budget_rejected) =
            match fd {
                Some(fd) if fd.qos_armed() => (
                    fd.qos_charged(),
                    fd.qos_refunded(),
                    fd.stats().qos_downgraded(),
                    fd.stats().budget_exhausted(),
                ),
                _ => (Vec::new(), Vec::new(), 0, 0),
            };
        MetricsSnapshot {
            model: self.model.clone(),
            method: self.method.clone(),
            workload: self.workload.clone(),
            ttft_avg_s: m.ttft.avg(),
            ttft_p99_s: m.ttft.p99(),
            tpop_avg_s: m.tpop.avg(),
            tpop_p99_s: m.tpop.p99(),
            e2e_avg_s: m.e2e.avg(),
            e2e_p99_s: m.e2e.p99(),
            wait_p99_s: m.wait.p99(),
            throughput_tok_s: m.throughput(),
            decode_tokens: m.decode_tokens,
            prefill_tokens: m.prefill_tokens,
            duration_s: m.duration_s,
            hi_fraction: b.hi_fraction(),
            migrated_bytes: b.migrated_bytes(),
            act_prefill,
            act_decode,
            tier_resident: b.tier_residency(),
            device_resident: b.device_residency(),
            promo_queue_depth: b.promo_queue_depth(),
            drift_events,
            drift_recovery_ticks,
            fd_queue_depth,
            fd_lane_admitted: fd_adm,
            fd_lane_rejected: fd_rej,
            fd_lane_deadline_miss: fd_miss,
            qos_class_resolved: b.class_tier_resolves(),
            qos_charged,
            qos_refunded,
            qos_downgraded,
            qos_budget_rejected,
            // fleet_* fields stay at their defaults: a bare session is
            // not a fleet (Fleet::snapshot fills them — DESIGN.md §14)
            ..MetricsSnapshot::default()
        }
    }

    /// Human-readable session report.
    pub fn report(&self) -> String {
        let s = self.snapshot();
        let tiers = if s.tier_resident.is_empty() {
            String::new()
        } else {
            format!(
                " | resident/rung {}",
                s.tier_resident
                    .iter()
                    .map(|n| n.to_string())
                    .collect::<Vec<_>>()
                    .join("/")
            )
        };
        let devices = if s.device_resident.len() > 1 {
            format!(
                " | devices {}",
                MetricsSnapshot::encode_per_device(&s.device_resident)
            )
        } else {
            String::new()
        };
        let drift = if s.drift_events > 0 {
            format!(
                " | drift {}x ({} recovery ticks)",
                s.drift_events, s.drift_recovery_ticks
            )
        } else {
            String::new()
        };
        let fd = if s.fd_lane_admitted.is_empty() {
            String::new()
        } else {
            format!(
                "\nfront door: queue {} | admitted {} | rejected {} \
                 | deadline-miss {}",
                s.fd_queue_depth,
                MetricsSnapshot::encode_u64_list(&s.fd_lane_admitted),
                MetricsSnapshot::encode_u64_list(&s.fd_lane_rejected),
                MetricsSnapshot::encode_u64_list(&s.fd_lane_deadline_miss),
            )
        };
        format!(
            "{}\nactivation: prefill {:.1}% decode {:.1}% | hi-tier {:.1}% \
             | migrated {:.2} GB | wait p99 {:.4}s{tiers}{devices}{drift}{fd}",
            self.inner.metrics().summary(),
            s.act_prefill * 100.0,
            s.act_decode * 100.0,
            s.hi_fraction * 100.0,
            s.migrated_bytes as f64 / 1e9,
            s.wait_p99_s,
        )
    }
}

fn model_names() -> Vec<&'static str> {
    ModelPreset::all().iter().map(|p| p.name).collect()
}

fn workload_names() -> Vec<&'static str> {
    WorkloadProfile::all().iter().map(|p| p.name).collect()
}

/// Fluent, validating constructor for [`ServeSession`].
pub struct SessionBuilder {
    model: String,
    method: String,
    workload: String,
    device: DeviceConfig,
    serving_cfg: ServingConfig,
    max_batch: usize,
    seed: u64,
    warmup: usize,
    track_activation: bool,
    kind: EngineKind,
    registry: Option<BackendRegistry>,
    devices: usize,
    frontdoor: Option<FrontDoorConfig>,
    qos: Option<QosConfig>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        Self {
            model: "qwen30b-sim".into(),
            method: "dynaexq".into(),
            workload: "text".into(),
            device: DeviceConfig::default(),
            serving_cfg: ServingConfig::default(),
            max_batch: 32,
            seed: 0xC0FFEE,
            warmup: 0,
            track_activation: true,
            kind: EngineKind::Modeled,
            registry: None,
            devices: 1,
            frontdoor: None,
            qos: None,
        }
    }
}

impl SessionBuilder {
    pub fn model(mut self, name: &str) -> Self {
        self.model = name.to_string();
        self
    }

    pub fn method(mut self, name: &str) -> Self {
        self.method = name.to_string();
        self
    }

    pub fn workload(mut self, name: &str) -> Self {
        self.workload = name.to_string();
        self
    }

    pub fn device(mut self, dev: DeviceConfig) -> Self {
        self.device = dev;
        self
    }

    pub fn serving_cfg(mut self, cfg: ServingConfig) -> Self {
        self.serving_cfg = cfg;
        self
    }

    /// Decode scheduling cap (paper sweeps 1–32).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Warmup rounds before measurement (adaptive methods converge first;
    /// warmup metrics are discarded).
    pub fn warmup(mut self, rounds: usize) -> Self {
        self.warmup = rounds;
        self
    }

    pub fn track_activation(mut self, on: bool) -> Self {
        self.track_activation = on;
        self
    }

    /// Run on the numeric engine (real PJRT execution) instead of the
    /// modeled one.
    pub fn numeric(mut self) -> Self {
        self.kind = EngineKind::Numeric;
        self
    }

    pub fn engine_kind(mut self, kind: EngineKind) -> Self {
        self.kind = kind;
        self
    }

    /// Use a custom method registry (plug-in backends).
    pub fn registry(mut self, registry: BackendRegistry) -> Self {
        self.registry = Some(registry);
        self
    }

    /// Front the session with a bounded admission queue (DESIGN.md §12):
    /// enables [`ServeSession::submit`]/[`ServeSession::drain`] and the
    /// SLO-aware drain scheduler. Modeled engine only.
    pub fn frontdoor(mut self, cfg: FrontDoorConfig) -> Self {
        self.frontdoor = Some(cfg);
        self
    }

    /// Class-weighted QoS allocation (DESIGN.md §15): the config lands on
    /// both the coordinator's waterfill (class-weighted hotness) and the
    /// front door's budget ledger, when the session has one. A
    /// [`QosConfig::is_degenerate`] config never arms either — the
    /// session stays byte-identical to the classic stack.
    pub fn qos(mut self, cfg: QosConfig) -> Self {
        self.qos = Some(cfg);
        self
    }

    /// Serve with an `n`-device expert-sharded group (DESIGN.md §9).
    /// Consumed by the sharded methods (`dynaexq-sharded`,
    /// `dynaexq-3tier-sharded`); single-device methods ignore it. A
    /// 1-device group is exactly the single-GPU system.
    pub fn devices(mut self, n: usize) -> Self {
        self.devices = n;
        self
    }

    /// Validate everything, construct the backend + engine, run warmup.
    /// All name and feasibility errors surface here, before any engine
    /// state exists.
    pub fn build(self) -> Result<ServeSession> {
        let preset = ModelPreset::by_name(&self.model).ok_or_else(|| {
            anyhow!(
                "unknown model {:?}; known models: {}",
                self.model,
                model_names().join(", ")
            )
        })?;
        let profile =
            WorkloadProfile::by_name(&self.workload).ok_or_else(|| {
                anyhow!(
                    "unknown workload {:?}; known workloads: {}",
                    self.workload,
                    workload_names().join(", ")
                )
            })?;
        if self.max_batch == 0 {
            bail!("max_batch must be ≥ 1");
        }
        if self.devices == 0 {
            bail!("devices must be ≥ 1 (1 = the single-GPU system)");
        }
        let registry =
            self.registry.unwrap_or_else(BackendRegistry::with_builtins);
        let mut serving_cfg = self.serving_cfg;
        let mut frontdoor_cfg = self.frontdoor;
        if let Some(q) = self.qos {
            q.validate().map_err(|e| anyhow!("qos: {e}"))?;
            // budgets check against the *full* session envelope here —
            // the coordinator only sees per-device slices of it
            q.validate_budgets(serving_cfg.hbm_budget_bytes)
                .map_err(|e| anyhow!("qos: {e}"))?;
            if let Some(fd) = &mut frontdoor_cfg {
                fd.qos = Some(q.clone());
            }
            serving_cfg.qos = Some(q);
        }
        let frontdoor = match frontdoor_cfg {
            Some(cfg) => {
                if self.kind != EngineKind::Modeled {
                    bail!(
                        "the front door drains through the modeled engine; \
                         EngineKind::Numeric sessions cannot take one"
                    );
                }
                Some(FrontDoor::new(cfg).map_err(|e| anyhow!("front door: {e}"))?)
            }
            None => None,
        };

        let inner: Box<dyn SessionEngine> = match self.kind {
            EngineKind::Modeled => {
                let backend = registry
                    .build(
                        &self.method,
                        &BackendCtx::new(
                            &preset,
                            &serving_cfg,
                            &self.device,
                        )
                        .with_profile(&profile)
                        .with_devices(self.devices),
                    )
                    .map_err(|e| anyhow!(e))?;
                let mut engine = Engine::new(
                    &preset,
                    &profile,
                    backend,
                    &self.device,
                    EngineConfig {
                        max_batch: self.max_batch,
                        seed: self.seed,
                        track_activation: self.track_activation,
                    },
                );
                engine.warm(&profile, self.warmup);
                Box::new(ModeledSession { engine, profile: profile.clone() })
            }
            #[cfg(not(feature = "numeric"))]
            EngineKind::Numeric => {
                bail!(
                    "this build has no PJRT runtime: rebuild with \
                     `--features numeric` for EngineKind::Numeric sessions"
                )
            }
            #[cfg(feature = "numeric")]
            EngineKind::Numeric => {
                // The backend manages the *executed* layer count; budget
                // plans stay at paper scale via cfg.n_hi_override when the
                // caller needs deployment-matched hot fractions.
                let exec = preset.executed_scale();
                let backend = registry
                    .build(
                        &self.method,
                        &BackendCtx::new(
                            &exec,
                            &serving_cfg,
                            &self.device,
                        )
                        .with_profile(&profile)
                        .with_devices(self.devices),
                    )
                    .map_err(|e| anyhow!(e))?;
                let weights = Arc::new(ModelWeights::generate(
                    &exec,
                    0xDA7A ^ exec.n_experts as u64,
                ));
                let rt = Arc::new(Runtime::load_default()?);
                let engine = NumericEngine::new(rt, weights, backend)?;
                let mut s = NumericSession {
                    engine,
                    rng: XorShiftRng::new(profile.seed ^ self.seed),
                    profile: profile.clone(),
                    metrics: ServingMetrics::default(),
                    next_tag: 0,
                };
                if self.warmup > 0 {
                    // Route warmup traffic so adaptive methods converge,
                    // then freeze the residency map (window pinning).
                    let mut wrng = XorShiftRng::new(profile.seed ^ 0xE7A1);
                    for i in 0..self.warmup {
                        let p = profile.sample_prompt(&mut wrng, 32);
                        let _ = s.engine.prefill(&p, 1000 + i as u64)?;
                    }
                    s.engine.quiesce();
                }
                Box::new(s)
            }
        };
        Ok(ServeSession {
            inner,
            model: self.model,
            method: self.method,
            workload: self.workload,
            frontdoor,
            seed: self.seed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_kv_roundtrip() {
        let s = MetricsSnapshot {
            model: "qwen30b-sim".into(),
            method: "dynaexq".into(),
            workload: "text".into(),
            ttft_avg_s: 0.123456789,
            ttft_p99_s: 1.5,
            tpop_avg_s: 0.033,
            tpop_p99_s: 0.05,
            e2e_avg_s: 2.25,
            e2e_p99_s: 3.125,
            wait_p99_s: 0.0,
            throughput_tok_s: 812.5,
            decode_tokens: 4096,
            prefill_tokens: 65536,
            duration_s: 12.75,
            hi_fraction: 0.375,
            migrated_bytes: 9_437_184,
            act_prefill: 0.61,
            act_decode: 0.07,
            tier_resident: vec![12, 34, 466],
            device_resident: vec![vec![6, 17, 233], vec![6, 17, 233]],
            promo_queue_depth: vec![3, 0],
            drift_events: 5,
            drift_recovery_ticks: 20,
            fd_queue_depth: 7,
            fd_lane_admitted: vec![10, 20, 30],
            fd_lane_rejected: vec![1, 0, 2],
            fd_lane_deadline_miss: vec![0, 0, 4],
            fleet_replicas: 2,
            fleet_health: vec![0, 2],
            fleet_served: vec![41, 19],
            fleet_failovers: 1,
            fleet_readmitted: 3,
            qos_class_resolved: vec![vec![9, 1], vec![4, 6], vec![0, 12]],
            qos_charged: vec![40960, 20480, 0],
            qos_refunded: vec![40960, 0, 0],
            qos_downgraded: 2,
            qos_budget_rejected: 1,
        };
        let decoded = MetricsSnapshot::decode(&s.encode()).unwrap();
        assert_eq!(decoded, s);
        // backends without a residency table (and sessions without a
        // front door, fleet, or armed QoS config) encode empty lists
        let mut none = s.clone();
        none.tier_resident = Vec::new();
        none.device_resident = Vec::new();
        none.promo_queue_depth = Vec::new();
        none.fd_lane_admitted = Vec::new();
        none.fd_lane_rejected = Vec::new();
        none.fd_lane_deadline_miss = Vec::new();
        none.fleet_health = Vec::new();
        none.fleet_served = Vec::new();
        none.qos_class_resolved = Vec::new();
        none.qos_charged = Vec::new();
        none.qos_refunded = Vec::new();
        assert_eq!(MetricsSnapshot::decode(&none.encode()).unwrap(), none);
    }

    #[test]
    fn snapshot_decode_rejects_missing_keys() {
        assert!(MetricsSnapshot::decode("model=x;method=y").is_err());
        // dropping any single key — including the drift fields — must be
        // a decode error, never a silent default
        let full = MetricsSnapshot::default().encode();
        for key in full.split(';').map(|kv| kv.split('=').next().unwrap()) {
            let without: String = full
                .split(';')
                .filter(|kv| !kv.starts_with(&format!("{key}=")))
                .collect::<Vec<_>>()
                .join(";");
            assert!(
                MetricsSnapshot::decode(&without).is_err(),
                "decode must reject a snapshot missing {key:?}"
            );
        }
    }

    #[test]
    fn prop_snapshot_roundtrips_randomized_encodings() {
        // The wire format can't silently rot: random field values —
        // including the `a|b|c` tier list, the `a|b/c|d` per-device
        // rows, and the drift counters — encode and decode losslessly.
        use crate::testutil::prop::Prop;
        let mut prop = Prop::new("snapshot_kv_roundtrip");
        prop.run(25, |rng| {
            let vec_of = |rng: &mut crate::util::XorShiftRng, n: usize| {
                (0..n).map(|_| rng.below(1000)).collect::<Vec<usize>>()
            };
            let tiers = rng.below(4);
            let devices = rng.below(3);
            let s = MetricsSnapshot {
                model: "qwen30b-sim".into(),
                method: "dynaexq-adaptive".into(),
                workload: "math".into(),
                ttft_avg_s: rng.range_f64(0.0, 10.0),
                ttft_p99_s: rng.range_f64(0.0, 10.0),
                tpop_avg_s: rng.range_f64(0.0, 1.0),
                tpop_p99_s: rng.range_f64(0.0, 1.0),
                e2e_avg_s: rng.range_f64(0.0, 100.0),
                e2e_p99_s: rng.range_f64(0.0, 100.0),
                wait_p99_s: rng.range_f64(0.0, 1.0),
                throughput_tok_s: rng.range_f64(0.0, 1e4),
                decode_tokens: rng.next_u64() % (1 << 40),
                prefill_tokens: rng.next_u64() % (1 << 40),
                duration_s: rng.range_f64(0.0, 1e4),
                hi_fraction: rng.next_f64(),
                migrated_bytes: rng.next_u64() % (1 << 50),
                act_prefill: rng.next_f64(),
                act_decode: rng.next_f64(),
                tier_resident: vec_of(rng, tiers),
                device_resident: (0..devices)
                    .map(|_| vec_of(rng, tiers.max(1)))
                    .collect(),
                promo_queue_depth: vec_of(rng, devices),
                drift_events: rng.next_u64() % 1000,
                drift_recovery_ticks: rng.next_u64() % 10_000,
                fd_queue_depth: rng.next_u64() % 1000,
                fd_lane_admitted: (0..rng.below(4))
                    .map(|_| rng.next_u64() % 10_000)
                    .collect(),
                fd_lane_rejected: (0..rng.below(4))
                    .map(|_| rng.next_u64() % 10_000)
                    .collect(),
                fd_lane_deadline_miss: (0..rng.below(4))
                    .map(|_| rng.next_u64() % 10_000)
                    .collect(),
                fleet_replicas: rng.next_u64() % 8,
                fleet_health: (0..rng.below(4))
                    .map(|_| rng.next_u64() % 4)
                    .collect(),
                fleet_served: (0..rng.below(4))
                    .map(|_| rng.next_u64() % 10_000)
                    .collect(),
                fleet_failovers: rng.next_u64() % 100,
                fleet_readmitted: rng.next_u64() % 1000,
                qos_class_resolved: (0..rng.below(4))
                    .map(|_| {
                        (0..tiers.max(1))
                            .map(|_| rng.next_u64() % 10_000)
                            .collect()
                    })
                    .collect(),
                qos_charged: (0..rng.below(4))
                    .map(|_| rng.next_u64() % (1 << 40))
                    .collect(),
                qos_refunded: (0..rng.below(4))
                    .map(|_| rng.next_u64() % (1 << 40))
                    .collect(),
                qos_downgraded: rng.next_u64() % 1000,
                qos_budget_rejected: rng.next_u64() % 1000,
            };
            assert_eq!(MetricsSnapshot::decode(&s.encode()).unwrap(), s);
        });
    }

    #[test]
    fn builder_rejects_unknown_names_with_enumeration() {
        let err = ServeSession::builder()
            .model("gpt5")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("qwen30b-sim"), "{err}");
        assert!(err.contains("phi-sim"), "{err}");

        let err = ServeSession::builder()
            .workload("poetry")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("text"), "{err}");
        assert!(err.contains("code"), "{err}");

        let err = ServeSession::builder()
            .method("magic")
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("dynaexq"), "{err}");
        assert!(err.contains("hobbit"), "{err}");
    }

    #[test]
    fn builder_rejects_infeasible_budget() {
        let mut cfg = ServingConfig::default();
        cfg.hbm_budget_bytes = 1_000_000; // can't hold the all-cold model
        let err = ServeSession::builder()
            .model("qwen30b-sim")
            .method("dynaexq")
            .serving_cfg(cfg)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("infeasible"), "{err}");
    }

    #[test]
    fn builder_rejects_zero_batch() {
        assert!(ServeSession::builder().max_batch(0).build().is_err());
    }

    #[test]
    fn builder_rejects_zero_devices() {
        let err = ServeSession::builder()
            .method("dynaexq-sharded")
            .devices(0)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("devices"), "{err}");
    }

    #[test]
    fn sharded_session_reports_per_device_residency() {
        // The sharded scenario end to end: builder → registry method →
        // device group → per-device snapshot fields.
        let mut s = ServeSession::builder()
            .model("phi-sim")
            .method("dynaexq-sharded")
            .devices(2)
            .workload("text")
            .seed(7)
            .warmup(1)
            .build()
            .unwrap();
        s.serve_closed(4, 32, 4).unwrap();
        let snap = s.snapshot();
        assert_eq!(snap.device_resident.len(), 2, "{snap:?}");
        let layers = ModelPreset::phi_sim().n_layers_logical();
        for (d, counts) in snap.device_resident.iter().enumerate() {
            assert_eq!(counts.iter().sum::<usize>(), layers * 8, "device {d}");
        }
        assert_eq!(snap.tier_resident.iter().sum::<usize>(), layers * 16);
        assert_eq!(snap.promo_queue_depth.len(), 2);
        assert_eq!(MetricsSnapshot::decode(&snap.encode()).unwrap(), snap);
        assert!(s.report().contains("devices"), "{}", s.report());
    }

    #[test]
    fn modeled_session_serves_and_snapshots() {
        let mut s = ServeSession::builder()
            .model("phi-sim")
            .method("static")
            .workload("text")
            .seed(11)
            .build()
            .unwrap();
        assert_eq!(s.kind(), EngineKind::Modeled);
        s.serve_rounds(2, 2, 32, 4).unwrap();
        let snap = s.snapshot();
        assert_eq!(snap.decode_tokens, 16);
        assert_eq!(snap.prefill_tokens, 128);
        assert!(snap.throughput_tok_s > 0.0);
        assert_eq!(MetricsSnapshot::decode(&snap.encode()).unwrap(), snap);
        assert!(s.report().contains("tok/s"));
    }

    #[test]
    fn three_tier_session_reports_per_rung_residency() {
        // The 3-tier scenario end to end: builder → registry method →
        // coordinator ladder → per-rung snapshot counts.
        let mut s = ServeSession::builder()
            .model("qwen30b-sim")
            .method("dynaexq-3tier")
            .workload("text")
            .seed(5)
            .warmup(1)
            .build()
            .unwrap();
        s.serve_closed(4, 32, 4).unwrap();
        let snap = s.snapshot();
        assert_eq!(snap.tier_resident.len(), 3, "{snap:?}");
        let layers = ModelPreset::qwen30b_sim().n_layers_logical();
        assert_eq!(
            snap.tier_resident.iter().sum::<usize>(),
            layers * 128,
            "every expert accounted at exactly one rung"
        );
        assert!(
            snap.tier_resident[0] > 0 || snap.tier_resident[1] > 0,
            "warm traffic lifts experts off the base rung: {snap:?}"
        );
        assert_eq!(MetricsSnapshot::decode(&snap.encode()).unwrap(), snap);
        // the native 3-rung preset is also reachable by name
        let s3 = ServeSession::builder()
            .model("qwen30b-3tier")
            .method("dynaexq")
            .build()
            .unwrap();
        assert_eq!(s3.snapshot().tier_resident.len(), 3);
    }

    #[test]
    fn session_runs_scripted_scenario() {
        let mut s = ServeSession::builder()
            .model("phi-sim")
            .method("dynaexq-adaptive")
            .seed(13)
            .warmup(1)
            .build()
            .unwrap();
        let sc = Scenario::swap();
        let marks = s.run_scenario(&sc, 2, 16, 2).unwrap();
        assert_eq!(marks.len(), sc.phases.len());
        // phase marks carry the phase names and the live workload tracks
        // the last phase's profile
        assert_eq!(marks[0].0, "text");
        assert_eq!(marks[1].0, "code");
        assert_eq!(s.workload, "code");
        assert_eq!(marks[1].1.workload, "code");
        // cumulative token accounting: 8 rounds × batch 2 × 2 tokens
        assert_eq!(marks[1].1.decode_tokens, 32);
        // every boundary snapshot survives the kv roundtrip
        for (name, snap) in &marks {
            assert_eq!(
                MetricsSnapshot::decode(&snap.encode()).unwrap(),
                *snap,
                "{name}"
            );
        }
        // load multipliers scale the served batch (diurnal ramp: loads
        // 0.5/1/2/1/0.5 × 2 rounds at base batch 2 → 2+4+8+4+2 = 20
        // requests of 2 tokens each)
        let mut d = ServeSession::builder()
            .model("phi-sim")
            .method("static")
            .seed(13)
            .build()
            .unwrap();
        let marks = d.run_scenario(&Scenario::diurnal(), 2, 16, 2).unwrap();
        assert_eq!(marks.last().unwrap().1.decode_tokens, 2 * 20);
    }

    #[test]
    fn frontdoor_session_round_trips_submit_drain() {
        let mut s = ServeSession::builder()
            .model("phi-sim")
            .method("static")
            .seed(3)
            .frontdoor(FrontDoorConfig::default())
            .build()
            .unwrap();
        let mut gen = RequestGenerator::new(WorkloadProfile::text(), 5);
        for i in 0..4 {
            let now = s.now();
            let lane = Lane::ALL[i % 3];
            let outcome =
                s.submit(gen.request(16, 2, now), "t0", lane).unwrap();
            assert_eq!(outcome, Ok(()));
        }
        assert_eq!(s.frontdoor().unwrap().depth(), 4);
        s.drain().unwrap();
        let snap = s.snapshot();
        assert_eq!(snap.fd_queue_depth, 0);
        assert_eq!(snap.fd_lane_admitted.iter().sum::<u64>(), 4);
        assert_eq!(snap.decode_tokens, 8);
        assert_eq!(MetricsSnapshot::decode(&snap.encode()).unwrap(), snap);
        assert!(s.report().contains("front door"), "{}", s.report());

        // sessions without a front door reject the APIs with a usage
        // error (not a typed rejection)
        let mut plain = ServeSession::builder()
            .model("phi-sim")
            .method("static")
            .build()
            .unwrap();
        assert!(plain
            .submit(gen.request(16, 2, 0.0), "t0", Lane::Standard)
            .is_err());
        assert!(plain.drain().is_err());

        // the drain path is modeled-engine only
        let err = ServeSession::builder()
            .frontdoor(FrontDoorConfig::default())
            .engine_kind(EngineKind::Numeric)
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("modeled"), "{err}");
    }

    #[test]
    fn qos_session_charges_and_reports() {
        use crate::config::QosClass;
        let mut s = ServeSession::builder()
            .model("phi-sim")
            .method("dynaexq")
            .seed(9)
            .frontdoor(FrontDoorConfig::default())
            .qos(QosConfig::tiered().pin("t0", QosClass::Premium))
            .build()
            .unwrap();
        assert!(s.frontdoor().unwrap().qos_armed());
        let mut gen = RequestGenerator::new(WorkloadProfile::text(), 5);
        for _ in 0..3 {
            let now = s.now();
            let outcome = s
                .submit(gen.request(16, 2, now), "t0", Lane::Standard)
                .unwrap();
            assert_eq!(outcome, Ok(()));
        }
        s.drain().unwrap();
        let snap = s.snapshot();
        let pi = QosClass::Premium.index();
        // charged at admission, refunded in full by the drain settlement
        assert_eq!(snap.qos_charged[pi], 3 * 2048 * 18);
        assert_eq!(snap.qos_charged, snap.qos_refunded);
        // every resolution is attributed to some class
        let b = s.backend();
        let per_class: u64 =
            snap.qos_class_resolved.iter().flatten().sum();
        let hi: f64 = b.hi_fraction(); // just touch the backend view
        assert!(hi >= 0.0);
        assert!(per_class > 0, "{snap:?}");
        assert_eq!(MetricsSnapshot::decode(&snap.encode()).unwrap(), snap);

        // degenerate configs never arm: snapshot QoS fields stay empty
        let mut d = ServeSession::builder()
            .model("phi-sim")
            .method("dynaexq")
            .seed(9)
            .frontdoor(FrontDoorConfig::default())
            .qos(QosConfig::degenerate())
            .build()
            .unwrap();
        assert!(!d.frontdoor().unwrap().qos_armed());
        d.serve_closed(2, 16, 2).unwrap();
        let dsnap = d.snapshot();
        assert!(dsnap.qos_class_resolved.is_empty());
        assert!(dsnap.qos_charged.is_empty());

        // invalid configs are refused at build time with the qos prefix
        let err = ServeSession::builder()
            .model("phi-sim")
            .qos(
                QosConfig::tiered()
                    .with_budget(QosClass::Premium, u64::MAX),
            )
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("qos"), "{err}");
        assert!(err.contains("envelope"), "{err}");
    }

    #[test]
    fn session_workload_shift() {
        let mut s = ServeSession::builder()
            .model("phi-sim")
            .method("dynaexq")
            .warmup(1)
            .build()
            .unwrap();
        s.set_workload("code").unwrap();
        s.serve_closed(2, 16, 2).unwrap();
        assert_eq!(s.workload, "code");
        assert!(s.set_workload("nope").is_err());
    }
}
