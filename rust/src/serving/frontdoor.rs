//! The concurrent request front door (DESIGN.md §12): bounded admission,
//! per-tenant fair share, and SLO-aware continuous batching.
//!
//! Three pieces, all configured by
//! [`FrontDoorConfig`](crate::config::frontdoor::FrontDoorConfig):
//!
//! * [`FrontDoor`] — a bounded admission queue in front of the engine.
//!   [`FrontDoor::submit`] is **never blocking**: it either enqueues the
//!   request or returns a typed [`Rejected`] immediately (Nexus-style
//!   backpressure). Every method takes `&self` — producers on separate
//!   threads submit against one shared door; the admission decision runs
//!   under a single fine-grained lock around the queue itself while all
//!   per-tenant occupancy/served/rejected accounting stays on lock-free
//!   atomic counters (DESIGN.md §13).
//! * [`SloScheduler`] — a [`Scheduler`] that composes with the engine
//!   exactly like [`ContinuousBatch`](super::scheduler::ContinuousBatch)
//!   (same admit/decode-round loop shape), but picks the next admission
//!   by `(starvation-aged lane rank, fair-share count, deadline,
//!   arrival, submission order)`. In the degenerate configuration —
//!   every request one default-class tenant, unbounded limits — the
//!   selection collapses to arrival order and the scheduler is
//!   **byte-identical** to `ContinuousBatch` (property-tested by
//!   `tests/frontdoor_props.rs`).
//! * [`FrontDoorStats`] — the per-lane admission / rejection /
//!   deadline-miss counters surfaced through
//!   [`MetricsSnapshot`](super::session::MetricsSnapshot) and the bench
//!   matrix's per-lane columns.
//!
//! The serve cycle is `submit*; drain` — [`FrontDoor::take_scheduled`]
//! hands the queued batch plus a tagged [`SloScheduler`] to the engine,
//! and [`FrontDoor::absorb`] folds the serve-side outcome (per-lane TTFT
//! samples, deadline misses, per-tenant service) back into the door's
//! cumulative accounting.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use crate::config::frontdoor::{FrontDoorConfig, Lane, LimitAction};
use crate::config::qos::{QosClass, QosConfig};
use crate::util::lockorder::{LockRank, OrderedMutex, OrderedRwLock};
use crate::workload::Request;

use super::engine::{ActiveRequest, Engine};
use super::scheduler::Scheduler;

/// Typed, non-blocking backpressure: why a submission was turned away.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejected {
    /// The bounded admission queue is at capacity.
    QueueFull,
    /// The tenant is over its hard limit (or over its soft limit with
    /// [`LimitAction::Reject`]).
    TenantOverLimit,
    /// The submit-time completion estimate already exceeds the request's
    /// SLO deadline — admitting it could only waste service.
    DeadlineInfeasible,
    /// The tenant's QoS class has no hi-precision budget left for this
    /// request's modeled occupancy (and the configured budget action is
    /// [`LimitAction::Reject`], or the best-effort fallback is exhausted
    /// too). Only emitted with an armed [`QosConfig`] (DESIGN.md §15).
    BudgetExhausted,
}

impl fmt::Display for Rejected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Rejected::QueueFull => "queue-full",
            Rejected::TenantOverLimit => "tenant-over-limit",
            Rejected::DeadlineInfeasible => "deadline-infeasible",
            Rejected::BudgetExhausted => "budget-exhausted",
        })
    }
}

/// One queued request with its admission metadata.
#[derive(Clone, Debug)]
pub struct QueuedRequest {
    pub req: Request,
    /// Index into the door's tenant table.
    pub tenant: usize,
    /// Effective lane (soft-limit demotion already applied).
    pub lane: Lane,
    /// SLO deadline: `arrival + lane ttft budget`.
    pub deadline_s: f64,
}

/// Per-lane admission-outcome counters (lock-free: all `AtomicU64` at
/// relaxed ordering — counts, not synchronization).
#[derive(Debug, Default)]
struct LaneCounters {
    admitted: AtomicU64,
    rejected: AtomicU64,
    deadline_miss: AtomicU64,
}

/// Cumulative front-door statistics: per-lane outcomes plus per-kind
/// rejection totals.
#[derive(Debug, Default)]
pub struct FrontDoorStats {
    lanes: [LaneCounters; 3],
    queue_full: AtomicU64,
    tenant_over_limit: AtomicU64,
    deadline_infeasible: AtomicU64,
    soft_overages: AtomicU64,
    demoted: AtomicU64,
    readmitted: AtomicU64,
    budget_exhausted: AtomicU64,
    qos_downgraded: AtomicU64,
}

impl FrontDoorStats {
    /// Requests admitted to the queue per lane ([`Lane::index`] order).
    pub fn lane_admitted(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.admitted.load(Relaxed)).collect() // relaxed-ok: stat counter
    }

    /// Requests rejected per lane ([`Lane::index`] order).
    pub fn lane_rejected(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.rejected.load(Relaxed)).collect() // relaxed-ok: stat counter
    }

    /// Served requests whose TTFT blew the lane deadline, per lane.
    pub fn lane_deadline_miss(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.deadline_miss.load(Relaxed)).collect() // relaxed-ok: stat counter
    }

    /// Rejection totals by kind:
    /// `(queue_full, tenant_over_limit, deadline_infeasible)`.
    pub fn rejection_kinds(&self) -> (u64, u64, u64) {
        (
            self.queue_full.load(Relaxed), // relaxed-ok: stat counter
            self.tenant_over_limit.load(Relaxed), // relaxed-ok: stat counter
            self.deadline_infeasible.load(Relaxed), // relaxed-ok: stat counter
        )
    }

    /// Soft-limit overages observed (warn/demote/reject alike).
    pub fn soft_overages(&self) -> u64 {
        self.soft_overages.load(Relaxed) // relaxed-ok: stat counter
    }

    /// Admissions demoted to the batch lane by [`LimitAction::Demote`].
    pub fn demoted(&self) -> u64 {
        self.demoted.load(Relaxed) // relaxed-ok: stat counter
    }

    /// Mid-stream failover re-admissions ([`FrontDoor::readmit`]) — these
    /// are *not* counted in the per-lane `admitted` totals (the request
    /// was admitted exactly once, at first submission).
    pub fn readmitted(&self) -> u64 {
        self.readmitted.load(Relaxed) // relaxed-ok: stat counter
    }

    /// Submissions turned away as [`Rejected::BudgetExhausted`] — kept
    /// out of [`FrontDoorStats::rejection_kinds`] so the classic
    /// three-kind totals stay byte-stable without an armed QoS config.
    pub fn budget_exhausted(&self) -> u64 {
        self.budget_exhausted.load(Relaxed) // relaxed-ok: stat counter
    }

    /// Admissions that demoted their tenant to best-effort pricing
    /// ([`LimitAction::Downgrade`] — soft-limit or budget-exhaustion
    /// flavour alike).
    pub fn qos_downgraded(&self) -> u64 {
        self.qos_downgraded.load(Relaxed) // relaxed-ok: stat counter
    }
}

/// Lock-free per-tenant accounting (first-appearance tenant table).
#[derive(Debug)]
struct TenantState {
    name: String,
    /// Requests currently sitting in the admission queue.
    queued: AtomicU64,
    /// Requests admitted into the engine across all drains.
    served: AtomicU64,
    /// Submissions rejected.
    rejected: AtomicU64,
}

/// First-appearance tenant table behind one `RwLock`: submissions from
/// known tenants take the read lock (the counters themselves are
/// atomics); only a *new* tenant name takes the write lock, once.
#[derive(Debug, Default)]
struct TenantTable {
    list: Vec<TenantState>,
    idx: HashMap<String, usize>,
}

/// Per-class precision-budget accounting (DESIGN.md §15), present only
/// when a non-degenerate [`QosConfig`] armed the door. Every admitted
/// request *charges* its modeled hi-precision occupancy —
/// `hi_bytes_per_token × (prompt + output)` — against its tenant's class
/// at submit time and *refunds* exactly that amount when the drain
/// settles its completion; re-admissions never re-charge (the charge map
/// is keyed by request id), so charges and refunds balance exactly
/// across failover.
struct QosLedger {
    cfg: QosConfig,
    /// Effective class per tenant index — seeded from the config's pins
    /// on first touch, then mutated by `Downgrade` demotions and
    /// scenario-phase pins.
    class_of: HashMap<usize, QosClass>,
    /// Bytes charged / refunded per class ([`QosClass::ALL`] order).
    charged: [u64; 3],
    refunded: [u64; 3],
    /// Outstanding charges by request id → `(class index, bytes)`.
    charges: HashMap<u64, (usize, u64)>,
}

impl QosLedger {
    fn new(cfg: QosConfig) -> Self {
        Self {
            cfg,
            class_of: HashMap::new(),
            charged: [0; 3],
            refunded: [0; 3],
            charges: HashMap::new(),
        }
    }

    /// Effective class of tenant `t` (first touch derives it from the
    /// config's pins by name).
    fn class(&mut self, t: usize, name: &str) -> QosClass {
        let cfg = &self.cfg;
        *self.class_of.entry(t).or_insert_with(|| cfg.class_of(name))
    }

    /// Would charging `cost` bytes to `class` exceed its budget?
    /// Unbudgeted classes never exhaust.
    fn exhausted(&self, class: QosClass, cost: u64) -> bool {
        let i = class.index();
        match self.cfg.class(class).budget_bytes {
            Some(b) => self.charged[i] - self.refunded[i] + cost > b,
            None => false,
        }
    }

    fn charge(&mut self, id: u64, class: QosClass, cost: u64) {
        self.charged[class.index()] += cost;
        self.charges.insert(id, (class.index(), cost));
    }
}

/// The bounded, fair, SLO-aware admission queue.
///
/// Concurrency seam (DESIGN.md §13): every method takes `&self`, so
/// producers on separate threads share one door. The admission decision —
/// tenant limits, queue bound, deadline feasibility, push — runs under a
/// single fine-grained lock around the queue itself, which serializes
/// submissions: the queue bound stays strict and each submission's
/// outcome is exactly what the serial path would decide at its
/// lock-acquisition position. All counters remain lock-free atomics;
/// single-producer behaviour is byte-identical to the old `&mut self`
/// path.
pub struct FrontDoor {
    cfg: FrontDoorConfig,
    queue: OrderedMutex<Vec<QueuedRequest>>,
    tenants: OrderedRwLock<TenantTable>,
    stats: FrontDoorStats,
    /// Per-lane TTFT samples absorbed from drained schedulers
    /// ([`Lane::index`] order) — the bench per-lane p50/p95 source.
    /// Only the drain loop writes it; a plain mutex suffices.
    lane_ttft: OrderedMutex<[Vec<f64>; 3]>,
    /// Precision-budget ledger — `Some` iff the config carries a
    /// non-degenerate [`QosConfig`]; structurally absent otherwise, so
    /// the classic admission path is byte-identical (DESIGN.md §15).
    qos: Option<OrderedMutex<QosLedger>>,
}

impl FrontDoor {
    /// Validate the configuration and build an empty door.
    pub fn new(cfg: FrontDoorConfig) -> Result<Self, String> {
        cfg.validate()?;
        let qos = cfg
            .qos
            .as_ref()
            .filter(|q| !q.is_degenerate())
            .map(|q| {
                OrderedMutex::new(LockRank::QosLedger, QosLedger::new(q.clone()))
            });
        Ok(Self {
            cfg,
            queue: OrderedMutex::new(LockRank::FrontDoorQueue, Vec::new()),
            tenants: OrderedRwLock::new(
                LockRank::FrontDoorTenants,
                TenantTable::default(),
            ),
            stats: FrontDoorStats::default(),
            lane_ttft: OrderedMutex::new(
                LockRank::LaneTtft,
                [Vec::new(), Vec::new(), Vec::new()],
            ),
            qos,
        })
    }

    pub fn cfg(&self) -> &FrontDoorConfig {
        &self.cfg
    }

    /// Current admission-queue depth.
    pub fn depth(&self) -> usize {
        self.queue.lock().len()
    }

    pub fn stats(&self) -> &FrontDoorStats {
        &self.stats
    }

    /// TTFT samples served on a lane so far (drained rounds only).
    pub fn lane_ttft(&self, lane: Lane) -> Vec<f64> {
        self.lane_ttft.lock()[lane.index()].clone()
    }

    /// Cumulative engine admissions per tenant, in first-appearance
    /// order: `(tenant name, served)`.
    pub fn tenant_served(&self) -> Vec<(String, u64)> {
        self.tenants
            .read()
            .list
            .iter()
            .map(|t| (t.name.clone(), t.served.load(Relaxed))) // relaxed-ok: stat counter
            .collect()
    }

    /// Resolve (or first-appearance-insert) a tenant name. Fast path is
    /// a read lock; the write lock is taken only for a name never seen
    /// before, with a re-check under it (two threads racing the same new
    /// name must agree on one index).
    fn tenant_id(&self, name: &str) -> usize {
        if let Some(&i) = self.tenants.read().idx.get(name) {
            return i;
        }
        let mut tab = self.tenants.write();
        if let Some(&i) = tab.idx.get(name) {
            return i;
        }
        let i = tab.list.len();
        tab.list.push(TenantState {
            name: name.to_string(),
            queued: AtomicU64::new(0),
            served: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
        });
        tab.idx.insert(name.to_string(), i);
        i
    }

    fn reject_with(
        &self,
        tenant: &TenantState,
        lane: Lane,
        why: Rejected,
    ) -> Rejected {
        tenant.rejected.fetch_add(1, Relaxed); // relaxed-ok: stat counter
        self.stats.lanes[lane.index()].rejected.fetch_add(1, Relaxed); // relaxed-ok: stat counter
        let kind = match why {
            Rejected::QueueFull => &self.stats.queue_full,
            Rejected::TenantOverLimit => &self.stats.tenant_over_limit,
            Rejected::DeadlineInfeasible => &self.stats.deadline_infeasible,
            Rejected::BudgetExhausted => &self.stats.budget_exhausted,
        };
        kind.fetch_add(1, Relaxed); // relaxed-ok: stat counter
        why
    }

    /// Whether a non-degenerate [`QosConfig`] armed the budget ledger.
    pub fn qos_armed(&self) -> bool {
        self.qos.is_some()
    }

    /// Bytes charged per class so far ([`QosClass::ALL`] order); empty
    /// when QoS is unarmed.
    pub fn qos_charged(&self) -> Vec<u64> {
        self.qos
            .as_ref()
            .map(|q| q.lock().charged.to_vec())
            .unwrap_or_default()
    }

    /// Bytes refunded per class so far ([`QosClass::ALL`] order); empty
    /// when QoS is unarmed.
    pub fn qos_refunded(&self) -> Vec<u64> {
        self.qos
            .as_ref()
            .map(|q| q.lock().refunded.to_vec())
            .unwrap_or_default()
    }

    /// Outstanding (charged − refunded) bytes per class; empty unarmed.
    pub fn qos_outstanding(&self) -> Vec<u64> {
        self.qos
            .as_ref()
            .map(|q| {
                let q = q.lock();
                QosClass::ALL
                    .iter()
                    .map(|c| {
                        q.charged[c.index()] - q.refunded[c.index()]
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Pin a tenant's effective QoS class — the scenario DSL's per-phase
    /// class tags land here. A no-op when QoS is unarmed.
    pub fn set_tenant_class(&self, tenant: &str, class: QosClass) {
        if let Some(q) = &self.qos {
            let t = self.tenant_id(tenant);
            q.lock().class_of.insert(t, class);
        }
    }

    /// The tenant's current effective class (`None` when QoS is unarmed).
    pub fn tenant_class(&self, tenant: &str) -> Option<QosClass> {
        let q = self.qos.as_ref()?;
        let t = self.tenant_id(tenant);
        Some(q.lock().class(t, tenant))
    }

    /// Drain-side settlement: refund the modeled hi-precision occupancy
    /// of completed requests. Ids without an outstanding charge (already
    /// settled, or admitted while QoS was unarmed) are ignored — combined
    /// with charge-on-first-admission-only this keeps charges and refunds
    /// exactly balanced across mid-stream failover re-admissions.
    pub fn settle(&self, ids: &[u64]) {
        if let Some(q) = &self.qos {
            let mut q = q.lock();
            for id in ids {
                if let Some((class, cost)) = q.charges.remove(id) {
                    q.refunded[class] += cost;
                }
            }
        }
    }

    /// Non-blocking admission. Checks run in a fixed order so the
    /// rejection kind is deterministic: tenant hard limit → tenant soft
    /// limit (configured action) → queue bound → deadline feasibility →
    /// QoS class budget (armed configs only). On success the request is
    /// queued under its effective lane (a `Demote` soft action moves it
    /// to [`Lane::Batch`]) and its modeled hi-precision occupancy is
    /// charged to its tenant's class.
    ///
    /// Thread-safe: the whole check sequence runs under the queue lock,
    /// so concurrent producers serialize and every bound stays strict —
    /// the queue can never exceed `queue_capacity` and a tenant can
    /// never exceed its hard limit, under any interleaving.
    pub fn submit(
        &self,
        req: Request,
        tenant: &str,
        lane: Lane,
        now_s: f64,
    ) -> Result<(), Rejected> {
        let t = self.tenant_id(tenant);
        let tenants = self.tenants.read();
        let ten = &tenants.list[t];
        let mut queue = self.queue.lock();
        let occupancy = ten.queued.load(Relaxed) as usize; // relaxed-ok: writes serialized by queue lock
        let limits = self.cfg.tenant_limits;
        if occupancy >= limits.hard_limit {
            return Err(self.reject_with(ten, lane, Rejected::TenantOverLimit));
        }
        // Soft-limit outcomes are *decided* here but only *counted* at
        // actual admission: a demoted submission that the queue bound or
        // deadline check then rejects must not inflate the soft-overage /
        // demotion counters (it never landed in any lane).
        let mut lane = lane;
        let mut soft_overage = false;
        let mut demoted = false;
        let mut soft_downgrade = false;
        if occupancy >= limits.soft_limit {
            soft_overage = true;
            match limits.soft_action {
                LimitAction::Warn => {}
                LimitAction::Demote => {
                    if lane != Lane::Batch {
                        demoted = true;
                        lane = Lane::Batch;
                    }
                }
                LimitAction::Downgrade => {
                    // keep the requested lane; the tenant's QoS class
                    // drops to best-effort pricing instead — exactly
                    // Warn when no QoS config is armed (DESIGN.md §15)
                    soft_downgrade = true;
                }
                LimitAction::Reject => {
                    return Err(self.reject_with(
                        ten,
                        lane,
                        Rejected::TenantOverLimit,
                    ));
                }
            }
        }
        if queue.len() >= self.cfg.queue_capacity {
            return Err(self.reject_with(ten, lane, Rejected::QueueFull));
        }
        let deadline_s = self.cfg.deadline(lane, req.arrival_s);
        if self.cfg.est_service_s > 0.0 {
            let start = now_s.max(req.arrival_s)
                + queue.len() as f64 * self.cfg.est_service_s;
            if start + self.cfg.est_service_s > deadline_s {
                return Err(self.reject_with(
                    ten,
                    lane,
                    Rejected::DeadlineInfeasible,
                ));
            }
        }
        // QoS budget — deliberately the LAST check: a submission rejected
        // for any other reason is never charged, so conservation reduces
        // to admitted-versus-settled (DESIGN.md §15).
        let mut ledger = self.qos.as_ref().map(|q| q.lock());
        let mut charge = None;
        let mut budget_downgrade = false;
        if let Some(ql) = ledger.as_deref_mut() {
            let mut class = ql.class(t, tenant);
            if soft_downgrade {
                class = QosClass::BestEffort;
            }
            let tokens = (req.prompt_len + req.output_len) as u64;
            let cost = ql.cfg.hi_bytes_per_token * tokens;
            if ql.exhausted(class, cost) {
                let downgrade = ql.cfg.budget_action
                    == LimitAction::Downgrade
                    && class != QosClass::BestEffort;
                if !downgrade {
                    return Err(self.reject_with(
                        ten,
                        lane,
                        Rejected::BudgetExhausted,
                    ));
                }
                class = QosClass::BestEffort;
                if ql.exhausted(class, cost) {
                    return Err(self.reject_with(
                        ten,
                        lane,
                        Rejected::BudgetExhausted,
                    ));
                }
                budget_downgrade = true;
            }
            charge = Some((class, cost));
        }
        if soft_overage {
            self.stats.soft_overages.fetch_add(1, Relaxed); // relaxed-ok: stat counter
        }
        if demoted {
            self.stats.demoted.fetch_add(1, Relaxed); // relaxed-ok: stat counter
        }
        if let (Some(ql), Some((class, cost))) = (ledger.as_deref_mut(), charge)
        {
            if soft_downgrade || budget_downgrade {
                // the demotion is persistent: future submissions price
                // at best-effort until a phase pin restores the class
                ql.class_of.insert(t, QosClass::BestEffort);
                self.stats.qos_downgraded.fetch_add(1, Relaxed); // relaxed-ok: stat counter
            }
            ql.charge(req.id, class, cost);
        }
        drop(ledger);
        ten.queued.fetch_add(1, Relaxed); // relaxed-ok: updated under queue lock
        self.stats.lanes[lane.index()].admitted.fetch_add(1, Relaxed); // relaxed-ok: stat counter
        queue.push(QueuedRequest { req, tenant: t, lane, deadline_s });
        Ok(())
    }

    /// Failover re-admission (DESIGN.md §14): return a request that was
    /// already admitted once — and whose replica died mid-stream — to the
    /// queue under its original tenant and effective lane. Unlike
    /// [`FrontDoor::submit`] this is **never rejected and never
    /// re-counted**: the request passed admission control when it first
    /// arrived, so the queue bound, tenant limits, per-lane `admitted`
    /// counters, and soft-limit counters are all bypassed — only the
    /// dedicated `readmitted` counter moves. Exactly-once completion
    /// across failover depends on this path never dropping a request.
    pub fn readmit(&self, req: Request, tenant: &str, lane: Lane) {
        let t = self.tenant_id(tenant);
        let tenants = self.tenants.read();
        let ten = &tenants.list[t];
        let mut queue = self.queue.lock();
        let deadline_s = self.cfg.deadline(lane, req.arrival_s);
        ten.queued.fetch_add(1, Relaxed); // relaxed-ok: updated under queue lock
        self.stats.readmitted.fetch_add(1, Relaxed); // relaxed-ok: stat counter
        queue.push(QueuedRequest { req, tenant: t, lane, deadline_s });
    }

    /// Drain the queue: every queued request leaves, paired with an
    /// [`SloScheduler`] tagged with its lane/deadline/tenant metadata and
    /// seeded with the cumulative fair-share history. Drive the pair
    /// through `Engine::serve_with`, then fold the outcome back with
    /// [`FrontDoor::absorb`]. The queue lock is held only for the
    /// `mem::take` — producers stall for a pointer swap, not the drain.
    pub fn take_scheduled(&self) -> (SloScheduler, Vec<Request>) {
        let (queued, served) = self.take_queued();
        self.scheduler_for(queued, served)
    }

    /// The raw half of [`FrontDoor::take_scheduled`]: empty the queue and
    /// snapshot the cumulative fair-share history, without building a
    /// scheduler. The fleet router partitions the returned batch across
    /// replicas and builds one per-replica scheduler per subset via
    /// [`FrontDoor::scheduler_for`]; a single-replica caller that feeds
    /// the whole batch straight back is byte-identical to
    /// `take_scheduled`.
    pub fn take_queued(&self) -> (Vec<QueuedRequest>, Vec<u64>) {
        let queued = std::mem::take(&mut *self.queue.lock());
        let tenants = self.tenants.read();
        for q in &queued {
            tenants.list[q.tenant].queued.fetch_sub(1, Relaxed); // relaxed-ok: balanced under queue lock's drain
        }
        let served: Vec<u64> =
            tenants.list.iter().map(|t| t.served.load(Relaxed)).collect(); // relaxed-ok: stat counter
        (queued, served)
    }

    /// Build the drain pair for a (possibly partitioned) queued batch:
    /// an [`SloScheduler`] tagged with the batch's lane/deadline/tenant
    /// metadata and seeded with `base_served`, plus the bare requests in
    /// queue order.
    pub fn scheduler_for(
        &self,
        queued: Vec<QueuedRequest>,
        base_served: Vec<u64>,
    ) -> (SloScheduler, Vec<Request>) {
        let sched =
            SloScheduler::for_queued(self.cfg.clone(), &queued, base_served);
        let reqs = queued.into_iter().map(|q| q.req).collect();
        (sched, reqs)
    }

    /// Fold a drained scheduler's serve-side outcome back into the
    /// door's cumulative accounting (per-tenant service, per-lane TTFT
    /// samples, deadline misses).
    pub fn absorb(&self, sched: &SloScheduler) {
        let tenants = self.tenants.read();
        for (t, &n) in sched.served_by_tenant.iter().enumerate() {
            if t < tenants.list.len() {
                tenants.list[t].served.fetch_add(n, Relaxed); // relaxed-ok: stat counter
            }
        }
        drop(tenants);
        let mut ttft = self.lane_ttft.lock();
        for lane in Lane::ALL {
            let i = lane.index();
            ttft[i].extend_from_slice(&sched.lane_ttft[i]);
            self.stats.lanes[i]
                .deadline_miss
                .fetch_add(sched.deadline_miss[i], Relaxed); // relaxed-ok: stat counter
        }
    }
}

/// Lane/deadline/tenant metadata of one tagged request.
#[derive(Clone, Copy, Debug)]
struct Tag {
    lane: Lane,
    deadline_s: f64,
    tenant: usize,
}

/// A pending request inside the scheduler's selection loop.
struct Entry {
    req: Request,
    tag: Tag,
    /// Position in the input vector — the final tie-breaker, so equal
    /// keys preserve submission order (and match `ContinuousBatch`'s
    /// stable sort in the degenerate configuration).
    seq: u64,
}

/// Selection key: smaller admits first. Fields in order — starvation-aged
/// lane rank, fair-share count, SLO deadline, arrival, submission order.
type Key = (usize, u64, f64, f64, u64);

fn key_lt(a: &Key, b: &Key) -> bool {
    (a.0, a.1)
        .cmp(&(b.0, b.1))
        .then(a.2.total_cmp(&b.2))
        .then(a.3.total_cmp(&b.3))
        .then(a.4.cmp(&b.4))
        .is_lt()
}

/// Deadline/SLO-aware continuous batching. Drives the engine through the
/// exact [`ContinuousBatch`](super::scheduler::ContinuousBatch) loop
/// shape — admit while a slot under the cap is free, skip ahead when
/// idle, decode a round — but chooses *which* pending request each free
/// slot takes by priority lane (with starvation aging), per-tenant
/// fair-share counts, and SLO deadlines.
pub struct SloScheduler {
    /// Batch cap; `None` uses the engine's configured `max_batch`
    /// (mirrors `ContinuousBatch`).
    pub max_batch: Option<usize>,
    cfg: FrontDoorConfig,
    /// Request id → admission metadata. Untagged requests serve as the
    /// single default tenant in the [`Lane::Standard`] class.
    tags: HashMap<u64, Tag>,
    /// Cumulative pre-drain per-tenant admissions (fair-share history).
    base_served: Vec<u64>,
    /// Engine admissions per tenant during this run.
    pub served_by_tenant: Vec<u64>,
    /// Admission order this run: one `(tenant, lane)` per engine
    /// admission — what the fairness-band property inspects.
    pub admission_log: Vec<(usize, Lane)>,
    /// TTFT samples per lane this run ([`Lane::index`] order).
    pub lane_ttft: [Vec<f64>; 3],
    /// Served requests whose TTFT blew their deadline, per lane.
    pub deadline_miss: [u64; 3],
}

impl SloScheduler {
    /// A bare scheduler: no tags, so every request is the single
    /// default tenant in the Standard class — with
    /// [`FrontDoorConfig::unbounded`] this is the degenerate
    /// configuration that is byte-identical to `ContinuousBatch`.
    pub fn new(cfg: FrontDoorConfig) -> Self {
        Self {
            max_batch: None,
            cfg,
            tags: HashMap::new(),
            base_served: vec![0],
            served_by_tenant: vec![0],
            admission_log: Vec::new(),
            lane_ttft: [Vec::new(), Vec::new(), Vec::new()],
            deadline_miss: [0; 3],
        }
    }

    /// Scheduler for a drained queue: per-request metadata keyed by
    /// request id (ids must be unique within one drain — the
    /// `RequestGenerator` guarantees it), fair-share counts seeded from
    /// the door's cumulative history.
    pub fn for_queued(
        cfg: FrontDoorConfig,
        queued: &[QueuedRequest],
        base_served: Vec<u64>,
    ) -> Self {
        let mut s = Self::new(cfg);
        let n = base_served.len().max(1);
        s.base_served = base_served;
        s.base_served.resize(n, 0);
        s.served_by_tenant = vec![0; n];
        for q in queued {
            s.tags.insert(
                q.req.id,
                Tag { lane: q.lane, deadline_s: q.deadline_s, tenant: q.tenant },
            );
        }
        s
    }

    fn key(&self, e: &Entry, now: f64) -> Key {
        // a request queued past the starvation age is promoted to rank 0
        // regardless of lane (infinite age → strict lane priority)
        let aged = now - e.req.arrival_s >= self.cfg.starvation_age_s;
        let rank = if aged { 0 } else { e.tag.lane.index() };
        let fair = if self.cfg.fair_share {
            self.base_served[e.tag.tenant]
                + self.served_by_tenant[e.tag.tenant]
        } else {
            0
        };
        (rank, fair, e.tag.deadline_s, e.req.arrival_s, e.seq)
    }

    /// Pick the pending index to admit next: best key among arrived
    /// requests; if none has arrived and the engine is idle, skip ahead
    /// to the earliest arrival (ties broken by lane, deadline,
    /// submission order). `None` → no admission this slot.
    fn pick(
        &self,
        pending: &[Entry],
        now: f64,
        engine_idle: bool,
    ) -> Option<usize> {
        let mut best: Option<(Key, usize)> = None;
        for (i, e) in pending.iter().enumerate() {
            if e.req.arrival_s > now {
                continue;
            }
            let k = self.key(e, now);
            if best.as_ref().map(|(bk, _)| key_lt(&k, bk)).unwrap_or(true) {
                best = Some((k, i));
            }
        }
        if let Some((_, i)) = best {
            return Some(i);
        }
        if !engine_idle || pending.is_empty() {
            return None;
        }
        let mut best: Option<((f64, usize, f64, u64), usize)> = None;
        for (i, e) in pending.iter().enumerate() {
            let k =
                (e.req.arrival_s, e.tag.lane.index(), e.tag.deadline_s, e.seq);
            let better = match &best {
                None => true,
                Some((bk, _)) => k
                    .0
                    .total_cmp(&bk.0)
                    .then(k.1.cmp(&bk.1))
                    .then(k.2.total_cmp(&bk.2))
                    .then(k.3.cmp(&bk.3))
                    .is_lt(),
            };
            if better {
                best = Some((k, i));
            }
        }
        best.map(|(_, i)| i)
    }
}

impl Scheduler for SloScheduler {
    fn name(&self) -> &'static str {
        "slo"
    }

    fn run(&mut self, engine: &mut Engine, requests: Vec<Request>) {
        let cap = self.max_batch.unwrap_or_else(|| engine.max_batch()).max(1);
        let mut pending: Vec<Entry> = requests
            .into_iter()
            .enumerate()
            .map(|(seq, req)| {
                let tag =
                    self.tags.get(&req.id).copied().unwrap_or_else(|| Tag {
                        lane: Lane::Standard,
                        deadline_s: self
                            .cfg
                            .deadline(Lane::Standard, req.arrival_s),
                        tenant: 0,
                    });
                Entry { req, tag, seq: seq as u64 }
            })
            .collect();
        // every tagged tenant index must be addressable in the counters
        let max_t = pending.iter().map(|e| e.tag.tenant).max().unwrap_or(0);
        if self.served_by_tenant.len() <= max_t {
            self.served_by_tenant.resize(max_t + 1, 0);
            self.base_served.resize(max_t + 1, 0);
        }
        let mut active: Vec<ActiveRequest> = Vec::new();
        while !pending.is_empty() || !active.is_empty() {
            while active.len() < cap {
                let Some(i) =
                    self.pick(&pending, engine.now(), active.is_empty())
                else {
                    break;
                };
                // swap_remove is safe: selection re-scans the whole slice
                let e = pending.swap_remove(i);
                let arrival = e.req.arrival_s;
                let Tag { lane, deadline_s, tenant } = e.tag;
                engine.admit(e.req, &mut active);
                // the admission just recorded exactly one TTFT sample
                let ttft = engine
                    .metrics
                    .ttft
                    .samples()
                    .last()
                    .copied()
                    .unwrap_or(0.0);
                self.lane_ttft[lane.index()].push(ttft);
                if arrival + ttft > deadline_s {
                    self.deadline_miss[lane.index()] += 1;
                }
                self.served_by_tenant[tenant] += 1;
                self.admission_log.push((tenant, lane));
            }
            if active.is_empty() {
                continue;
            }
            engine.decode_round(&mut active);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::frontdoor::TenantLimits;
    use crate::workload::{RequestGenerator, WorkloadProfile};

    fn gen() -> RequestGenerator {
        RequestGenerator::new(WorkloadProfile::text(), 7)
    }

    #[test]
    fn submit_accounts_per_tenant_and_lane() {
        let fd = FrontDoor::new(FrontDoorConfig::default()).unwrap();
        let mut g = gen();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0).unwrap();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
        fd.submit(g.request(8, 2, 0.0), "b", Lane::Batch, 0.0).unwrap();
        assert_eq!(fd.depth(), 3);
        assert_eq!(fd.stats().lane_admitted(), vec![1, 1, 1]);
        assert_eq!(fd.stats().lane_rejected(), vec![0, 0, 0]);
        let (sched, reqs) = fd.take_scheduled();
        assert_eq!(reqs.len(), 3);
        assert_eq!(fd.depth(), 0);
        assert_eq!(sched.served_by_tenant.len(), 2);
    }

    #[test]
    fn rejected_kinds_and_display() {
        for (r, s) in [
            (Rejected::QueueFull, "queue-full"),
            (Rejected::TenantOverLimit, "tenant-over-limit"),
            (Rejected::DeadlineInfeasible, "deadline-infeasible"),
            (Rejected::BudgetExhausted, "budget-exhausted"),
        ] {
            assert_eq!(r.to_string(), s);
        }
    }

    #[test]
    fn full_queue_rejects_typed_not_blocking() {
        let cfg = FrontDoorConfig {
            queue_capacity: 2,
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::new(cfg).unwrap();
        let mut g = gen();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
        assert_eq!(
            fd.submit(g.request(8, 2, 0.0), "b", Lane::Standard, 0.0),
            Err(Rejected::QueueFull)
        );
        assert_eq!(fd.stats().rejection_kinds(), (1, 0, 0));
        assert_eq!(fd.stats().lane_rejected(), vec![0, 1, 0]);
    }

    #[test]
    fn soft_limit_demotes_to_batch_lane() {
        let cfg = FrontDoorConfig {
            tenant_limits: TenantLimits {
                soft_limit: 1,
                soft_action: LimitAction::Demote,
                hard_limit: 10,
            },
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::new(cfg).unwrap();
        let mut g = gen();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0).unwrap();
        // second interactive submission is over the soft limit → demoted
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0).unwrap();
        assert_eq!(fd.stats().demoted(), 1);
        assert_eq!(fd.stats().soft_overages(), 1);
        assert_eq!(fd.stats().lane_admitted(), vec![1, 0, 1]);
        let (sched, reqs) = fd.take_scheduled();
        let demoted = sched.tags.get(&reqs[1].id).unwrap();
        assert_eq!(demoted.lane, Lane::Batch);
    }

    #[test]
    fn demoted_then_rejected_submission_counts_nothing() {
        // The soft limit demotes, but the queue is already full: the
        // rejection must not bump soft_overages/demoted — the request
        // never landed in any lane.
        let cfg = FrontDoorConfig {
            queue_capacity: 1,
            tenant_limits: TenantLimits {
                soft_limit: 1,
                soft_action: LimitAction::Demote,
                hard_limit: 10,
            },
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::new(cfg).unwrap();
        let mut g = gen();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0).unwrap();
        assert_eq!(
            fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0),
            Err(Rejected::QueueFull)
        );
        assert_eq!(fd.stats().soft_overages(), 0);
        assert_eq!(fd.stats().demoted(), 0);
        // the rejection is charged to the effective (demoted) lane
        assert_eq!(fd.stats().lane_rejected(), vec![0, 0, 1]);
        assert_eq!(fd.stats().lane_admitted(), vec![1, 0, 0]);
    }

    #[test]
    fn readmit_bypasses_admission_counters_and_never_drops() {
        let cfg = FrontDoorConfig {
            queue_capacity: 1,
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::new(cfg).unwrap();
        let mut g = gen();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0).unwrap();
        // queue at capacity, but a failover re-admission is never dropped
        // and never double-counts the lane admission
        fd.readmit(g.request(8, 2, 0.0), "a", Lane::Interactive);
        assert_eq!(fd.depth(), 2);
        assert_eq!(fd.stats().readmitted(), 1);
        assert_eq!(fd.stats().lane_admitted(), vec![1, 0, 0]);
        let (sched, reqs) = fd.take_scheduled();
        assert_eq!(reqs.len(), 2);
        assert_eq!(fd.depth(), 0);
        // tenant queued-occupancy balanced: a fresh submission is
        // admitted again, not soft-limited by a phantom count
        drop(sched);
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
        assert_eq!(fd.depth(), 1);
    }

    #[test]
    fn take_queued_halves_compose_to_take_scheduled() {
        let fd = FrontDoor::new(FrontDoorConfig::default()).unwrap();
        let mut g = gen();
        for i in 0..4 {
            fd.submit(g.request(8, 2, 0.0), "a", Lane::ALL[i % 3], 0.0)
                .unwrap();
        }
        let (queued, served) = fd.take_queued();
        assert_eq!(queued.len(), 4);
        assert_eq!(fd.depth(), 0);
        let (sched, reqs) = fd.scheduler_for(queued, served);
        assert_eq!(reqs.len(), 4);
        assert_eq!(sched.served_by_tenant.len(), 1);
    }

    #[test]
    fn rejected_config_surfaces_validation_error() {
        let cfg =
            FrontDoorConfig { queue_capacity: 0, ..FrontDoorConfig::default() };
        assert!(FrontDoor::new(cfg).unwrap_err().contains("queue_capacity"));
    }

    #[test]
    fn degenerate_qos_config_never_arms_the_ledger() {
        let cfg = FrontDoorConfig {
            qos: Some(QosConfig::degenerate()),
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::new(cfg).unwrap();
        assert!(!fd.qos_armed());
        assert!(fd.qos_charged().is_empty());
        assert_eq!(fd.tenant_class("a"), None);
        let mut g = gen();
        let req = g.request(8, 2, 0.0);
        let id = req.id;
        fd.submit(req, "a", Lane::Standard, 0.0).unwrap();
        fd.settle(&[id]); // a no-op, never a panic
        assert_eq!(fd.stats().budget_exhausted(), 0);
        assert_eq!(fd.stats().qos_downgraded(), 0);
    }

    #[test]
    fn qos_budget_charges_settles_and_rejects_typed() {
        // hi_bytes_per_token 2048 × (8 + 2) tokens = 20480 per request;
        // a premium budget of two requests' worth admits 2, rejects 1
        let qos = QosConfig::tiered()
            .with_budget(QosClass::Premium, 2 * 20480)
            .pin("a", QosClass::Premium);
        let cfg =
            FrontDoorConfig { qos: Some(qos), ..FrontDoorConfig::default() };
        let fd = FrontDoor::new(cfg).unwrap();
        assert!(fd.qos_armed());
        assert_eq!(fd.tenant_class("a"), Some(QosClass::Premium));
        let mut g = gen();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
        let pi = QosClass::Premium.index();
        assert_eq!(fd.qos_charged()[pi], 2 * 20480);
        assert_eq!(
            fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0),
            Err(Rejected::BudgetExhausted)
        );
        assert_eq!(fd.stats().budget_exhausted(), 1);
        // the classic three-kind totals never count the new kind
        assert_eq!(fd.stats().rejection_kinds(), (0, 0, 0));
        // rejected submissions were never charged
        assert_eq!(fd.qos_charged()[pi], 2 * 20480);
        // drain + settle refunds exactly what was charged
        let (_, reqs) = fd.take_scheduled();
        let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
        fd.settle(&ids);
        assert_eq!(fd.qos_charged(), fd.qos_refunded());
        assert_eq!(fd.qos_outstanding(), vec![0, 0, 0]);
        // budget headroom is restored
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
    }

    #[test]
    fn budget_downgrade_admits_at_best_effort_pricing() {
        let qos = QosConfig::tiered()
            .with_budget(QosClass::Premium, 20480)
            .pin("a", QosClass::Premium)
            .on_exhausted(LimitAction::Downgrade);
        let cfg =
            FrontDoorConfig { qos: Some(qos), ..FrontDoorConfig::default() };
        let fd = FrontDoor::new(cfg).unwrap();
        let mut g = gen();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
        // over budget: admitted anyway, demoted to best-effort pricing
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
        assert_eq!(fd.stats().budget_exhausted(), 0);
        assert_eq!(fd.stats().qos_downgraded(), 1);
        assert_eq!(fd.tenant_class("a"), Some(QosClass::BestEffort));
        assert_eq!(fd.qos_charged()[QosClass::Premium.index()], 20480);
        assert_eq!(fd.qos_charged()[QosClass::BestEffort.index()], 20480);
        // the demotion is persistent: the next submission prices at
        // best-effort without touching the premium budget again
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Standard, 0.0).unwrap();
        assert_eq!(fd.qos_charged()[QosClass::BestEffort.index()], 2 * 20480);
        assert_eq!(fd.stats().qos_downgraded(), 1, "already demoted");
    }

    #[test]
    fn soft_downgrade_keeps_lane_and_is_warn_without_qos() {
        let limits = TenantLimits {
            soft_limit: 1,
            soft_action: LimitAction::Downgrade,
            hard_limit: 10,
        };
        // unarmed: exactly Warn — same lane, only the overage counted
        let cfg = FrontDoorConfig {
            tenant_limits: limits,
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::new(cfg).unwrap();
        let mut g = gen();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0).unwrap();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0).unwrap();
        assert_eq!(fd.stats().lane_admitted(), vec![2, 0, 0]);
        assert_eq!(fd.stats().soft_overages(), 1);
        assert_eq!(fd.stats().demoted(), 0);
        assert_eq!(fd.stats().qos_downgraded(), 0);
        // armed: same lane, but the tenant drops to best-effort pricing
        let cfg = FrontDoorConfig {
            tenant_limits: limits,
            qos: Some(QosConfig::tiered().pin("a", QosClass::Premium)),
            ..FrontDoorConfig::default()
        };
        let fd = FrontDoor::new(cfg).unwrap();
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0).unwrap();
        assert_eq!(fd.tenant_class("a"), Some(QosClass::Premium));
        fd.submit(g.request(8, 2, 0.0), "a", Lane::Interactive, 0.0).unwrap();
        assert_eq!(fd.stats().lane_admitted(), vec![2, 0, 0]);
        assert_eq!(fd.stats().qos_downgraded(), 1);
        assert_eq!(fd.tenant_class("a"), Some(QosClass::BestEffort));
    }
}
