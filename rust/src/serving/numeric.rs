//! The numeric engine: real PJRT execution of the simulated MoE model.
//!
//! Drives prefill + batched decode through the AOT executables (embed,
//! attention, router, per-precision expert FFN, lm_head), with the rust
//! side owning everything the paper's coordinator owns: routing dispatch,
//! per-expert gather/scatter, residual combine, KV-cache management, and —
//! through the [`ResidencyBackend`] — the precision each expert executes
//! at. Used by every quality experiment and the end-to-end example.
//!
//! The modeled clock still advances (via the cost model at paper-scale
//! dims) so the backend's time-based policies (update intervals, migration
//! completion events) behave exactly as in the modeled engine.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{
    ModelPreset, BATCH_BUCKETS, D_MODEL, EXPERT_TOKEN_BUCKETS, FF_DIM, S_MAX,
    TOKEN_BUCKETS, VOCAB,
};
use crate::model::{ModelWeights, Precision};
use crate::runtime::{to_f32, to_i32, Runtime};
use crate::sim::CostModel;
use crate::util::next_bucket;

use super::backend::ResidencyBackend;
use super::kv_cache::KvCache;

/// One sequence being decoded.
pub struct SeqState {
    pub kv: KvCache,
    pub last_token: i32,
    pub tag: u64,
    pub generated: Vec<i32>,
}

/// Output of a full generate call.
pub struct GenOutput {
    /// Greedy-decoded tokens.
    pub tokens: Vec<i32>,
    /// Teacher-forced logits over the prompt, row-major `[T, VOCAB]`.
    pub prompt_logits: Vec<f32>,
}

/// Cached device-resident weight buffers for one expert at one tier
/// (staged once; per-call uploads carry only activations — the perf-pass
/// optimization recorded in EXPERIMENTS.md §Perf).
enum ExpertLits {
    Fp([xla::PjRtBuffer; 3]),
    /// packed-weight/scale triples; U8Buffer keeps the aliased host
    /// literal alive (see runtime::buffer_u8)
    Quant(
        crate::runtime::U8Buffer,
        xla::PjRtBuffer,
        crate::runtime::U8Buffer,
        xla::PjRtBuffer,
        crate::runtime::U8Buffer,
        xla::PjRtBuffer,
    ),
}

/// The engine.
pub struct NumericEngine {
    rt: Arc<Runtime>,
    pub weights: Arc<ModelWeights>,
    pub backend: Box<dyn ResidencyBackend>,
    pub preset: ModelPreset,
    cost: CostModel,
    clock_s: f64,
    // cached device-resident weights ------------------------------------
    embed_table: xla::PjRtBuffer,
    final_g: xla::PjRtBuffer,
    wout: xla::PjRtBuffer,
    layer_lits: Vec<LayerLits>,
    expert_lits: HashMap<(usize, usize, Precision), ExpertLits>,
    shared_lits: Vec<Vec<[xla::PjRtBuffer; 3]>>,
}

struct LayerLits {
    attn_g: xla::PjRtBuffer,
    wq: xla::PjRtBuffer,
    wk: xla::PjRtBuffer,
    wv: xla::PjRtBuffer,
    wo: xla::PjRtBuffer,
    moe_g: xla::PjRtBuffer,
    wr: xla::PjRtBuffer,
}

impl NumericEngine {
    /// Build the engine. `backend` must be configured for the *executed*
    /// layer count (`preset.executed_scale()` when using a Coordinator).
    pub fn new(
        rt: Arc<Runtime>,
        weights: Arc<ModelWeights>,
        backend: Box<dyn ResidencyBackend>,
    ) -> Result<Self> {
        let preset = weights.preset.clone();
        let d = D_MODEL;
        let layer_lits = weights
            .layers
            .iter()
            .map(|l| -> Result<LayerLits> {
                Ok(LayerLits {
                    attn_g: rt.buffer_f32(&l.attn_g, &[d])?,
                    wq: rt.buffer_f32(&l.wq, &[d, d])?,
                    wk: rt.buffer_f32(&l.wk, &[d, d])?,
                    wv: rt.buffer_f32(&l.wv, &[d, d])?,
                    wo: rt.buffer_f32(&l.wo, &[d, d])?,
                    moe_g: rt.buffer_f32(&l.moe_g, &[d])?,
                    wr: rt.buffer_f32(&l.wr, &[d, preset.n_experts])?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let shared_lits = weights
            .layers
            .iter()
            .map(|l| {
                l.shared
                    .iter()
                    .map(|e| {
                        Ok([
                            rt.buffer_f32(&e.w1, &[d, FF_DIM])?,
                            rt.buffer_f32(&e.w3, &[d, FF_DIM])?,
                            rt.buffer_f32(&e.w2, &[FF_DIM, d])?,
                        ])
                    })
                    .collect::<Result<Vec<_>>>()
            })
            .collect::<Result<Vec<_>>>()?;
        let cost = CostModel::new(&preset, crate::config::DeviceConfig::default());
        Ok(Self {
            embed_table: rt.buffer_f32(&weights.embed, &[VOCAB, d])?,
            final_g: rt.buffer_f32(&weights.final_g, &[d])?,
            wout: rt.buffer_f32(&weights.wout, &[d, VOCAB])?,
            layer_lits,
            expert_lits: HashMap::new(),
            shared_lits,
            rt,
            weights,
            backend,
            preset,
            cost,
            clock_s: 0.0,
        })
    }

    pub fn now(&self) -> f64 {
        self.clock_s
    }

    /// Converge + freeze the backend's residency map (quality harnesses
    /// measure a pinned configuration, mirroring the paper's window
    /// pinning). Advances the modeled clock to the quiescent point.
    pub fn quiesce(&mut self) {
        self.clock_s = self.backend.quiesce(self.clock_s);
    }

    /// Calibration counts, when driven by a `CountingBackend`.
    pub fn backend_counts(&self) -> Option<&[Vec<u64>]> {
        self.backend.counts_view()
    }

    fn expert_lit(
        &mut self,
        layer: usize,
        expert: usize,
        p: Precision,
    ) -> Result<&ExpertLits> {
        let key = (layer, expert, p);
        if !self.expert_lits.contains_key(&key) {
            let e = &self.weights.layers[layer].experts[expert];
            let d = D_MODEL;
            let f = FF_DIM;
            let lits = match p {
                Precision::Fp16 => ExpertLits::Fp([
                    self.rt.buffer_f32(&e.w1, &[d, f])?,
                    self.rt.buffer_f32(&e.w3, &[d, f])?,
                    self.rt.buffer_f32(&e.w2, &[f, d])?,
                ]),
                _ => {
                    let q = e.packed(p);
                    let pk = p.pack();
                    ExpertLits::Quant(
                        self.rt.buffer_u8(&q[0].data, &[d / pk, f])?,
                        self.rt.buffer_f32(&q[0].scales, &[f])?,
                        self.rt.buffer_u8(&q[1].data, &[d / pk, f])?,
                        self.rt.buffer_f32(&q[1].scales, &[f])?,
                        self.rt.buffer_u8(&q[2].data, &[f / pk, d])?,
                        self.rt.buffer_f32(&q[2].scales, &[d])?,
                    )
                }
            };
            self.expert_lits.insert(key, lits);
        }
        Ok(self.expert_lits.get(&key).unwrap())
    }

    /// Run one expert FFN over `rows` (flat `[n, D]`); returns `[n, D]`.
    fn run_expert_rows(
        &mut self,
        layer: usize,
        expert: ExpertRef,
        p: Precision,
        rows: &[f32],
        n: usize,
    ) -> Result<Vec<f32>> {
        debug_assert_eq!(rows.len(), n * D_MODEL);
        let mut out = Vec::with_capacity(n * D_MODEL);
        let max_b = *EXPERT_TOKEN_BUCKETS.last().unwrap();
        let mut start = 0;
        while start < n {
            let chunk = (n - start).min(max_b);
            let tb = next_bucket(EXPERT_TOKEN_BUCKETS, chunk);
            let mut x = vec![0f32; tb * D_MODEL];
            x[..chunk * D_MODEL].copy_from_slice(
                &rows[start * D_MODEL..(start + chunk) * D_MODEL],
            );
            let xl = self.rt.buffer_f32(&x, &[tb, D_MODEL])?;
            let name = format!("expert_{}_t{tb}", p.tag());
            let result = match expert {
                ExpertRef::Routed(e) => {
                    // split borrows: stage buffers without holding &mut
                    self.expert_lit(layer, e, p)?;
                    let lits = self.expert_lits.get(&(layer, e, p)).unwrap();
                    match lits {
                        ExpertLits::Fp([w1, w3, w2]) => self
                            .rt
                            .execute_buffers(&name, &[&xl, w1, w3, w2])?,
                        ExpertLits::Quant(w1, s1, w3, s3, w2, s2) => {
                            self.rt.execute_buffers(
                                &name,
                                &[&xl, w1, s1, w3, s3, w2, s2],
                            )?
                        }
                    }
                }
                ExpertRef::Shared(s) => {
                    let w = &self.shared_lits[layer][s];
                    self.rt.execute_buffers(
                        &format!("expert_fp16_t{tb}"),
                        &[&xl, &w[0], &w[1], &w[2]],
                    )?
                }
            };
            let y = to_f32(&result[0])?;
            out.extend_from_slice(&y[..chunk * D_MODEL]);
            start += chunk;
        }
        Ok(out)
    }

    /// MoE block: route, dispatch to experts (through the backend's
    /// precision decisions), combine. `x` is the padded `[tb, D]` hidden
    /// state *after* attention; only the first `t` rows are real.
    fn moe_block(
        &mut self,
        layer: usize,
        x: &mut [f32],
        tb: usize,
        t: usize,
        tag: u64,
    ) -> Result<()> {
        let ll = &self.layer_lits[layer];
        let xl = self.rt.buffer_f32(&x[..tb * D_MODEL], &[tb, D_MODEL])?;
        let name =
            format!("router_{}_t{tb}", self.preset.router_key());
        let out = self
            .rt
            .execute_buffers(&name, &[&xl, &ll.moe_g, &ll.wr])?;
        let xn = to_f32(&out[0])?;
        let idx = to_i32(&out[1])?;
        let wts = to_f32(&out[2])?;
        let k = self.preset.top_k;

        // Group real-token rows by expert.
        let mut groups: HashMap<usize, Vec<(usize, f32)>> = HashMap::new();
        let mut routed = Vec::with_capacity(t * k);
        for row in 0..t {
            for kk in 0..k {
                let e = idx[row * k + kk] as usize;
                let w = wts[row * k + kk];
                groups.entry(e).or_default().push((row, w));
                routed.push(e);
            }
        }
        self.backend.record_routing(layer, &routed);
        let _ = tag;

        let mut expert_ids: Vec<usize> = groups.keys().copied().collect();
        expert_ids.sort_unstable(); // determinism
        for e in expert_ids {
            let items = &groups[&e];
            let (prec, stall) = self.backend.resolve(layer, e, self.clock_s);
            self.clock_s += stall;
            self.clock_s += self.cost.expert_time(items.len(), prec);
            let mut rows = Vec::with_capacity(items.len() * D_MODEL);
            for &(row, _) in items {
                rows.extend_from_slice(&xn[row * D_MODEL..(row + 1) * D_MODEL]);
            }
            let y = self.run_expert_rows(
                layer,
                ExpertRef::Routed(e),
                prec,
                &rows,
                items.len(),
            )?;
            for (i, &(row, w)) in items.iter().enumerate() {
                for dcol in 0..D_MODEL {
                    x[row * D_MODEL + dcol] += w * y[i * D_MODEL + dcol];
                }
            }
        }

        // Shared experts: every token, pinned at the ladder's top rung.
        for s in 0..self.preset.n_shared {
            self.clock_s += self.cost.expert_time(t, self.preset.hi());
            let y = self.run_expert_rows(
                layer,
                ExpertRef::Shared(s),
                Precision::Fp16,
                &xn[..t * D_MODEL],
                t,
            )?;
            for row in 0..t {
                for dcol in 0..D_MODEL {
                    x[row * D_MODEL + dcol] += y[row * D_MODEL + dcol];
                }
            }
        }
        Ok(())
    }

    /// Prefill one prompt; returns (kv, prompt logits `[T, VOCAB]`).
    pub fn prefill(
        &mut self,
        prompt: &[i32],
        tag: u64,
    ) -> Result<(KvCache, Vec<f32>)> {
        let t = prompt.len();
        if t < 4 {
            bail!("prompt must be ≥ 4 tokens (prefill buckets)");
        }
        let max_t = *TOKEN_BUCKETS.last().unwrap();
        if t > max_t {
            bail!("numeric prefill capped at {max_t} tokens (got {t})");
        }
        let tb = next_bucket(TOKEN_BUCKETS, t);
        let mut tokens = prompt.to_vec();
        tokens.resize(tb, 0);

        self.clock_s += self.cost.embed_time(t);
        let tok_buf = self.rt.buffer_i32(&tokens, &[tb])?;
        let out = self.rt.execute_buffers(
            &format!("embed_t{tb}"),
            &[&tok_buf, &self.embed_table],
        )?;
        let mut x = to_f32(&out[0])?;

        let mut kv = KvCache::new(self.preset.n_layers);
        for layer in 0..self.preset.n_layers {
            self.clock_s += self.cost.attn_prefill_time(t)
                + self.cost.router_time(t);
            let ll = &self.layer_lits[layer];
            let xl = self.rt.buffer_f32(&x, &[tb, D_MODEL])?;
            let out = self.rt.execute_buffers(
                &format!("attn_prefill_t{tb}"),
                &[&xl, &ll.attn_g, &ll.wq, &ll.wk, &ll.wv, &ll.wo],
            )?;
            x = to_f32(&out[0])?;
            let kx = to_f32(&out[1])?;
            let vx = to_f32(&out[2])?;
            kv.write_prefill(layer, &kx, &vx, t);
            self.moe_block(layer, &mut x, tb, t, tag)?;
        }
        kv.set_len(t);

        self.clock_s += self.cost.lm_head_time(t);
        let xb = self.rt.buffer_f32(&x, &[tb, D_MODEL])?;
        let out = self.rt.execute_buffers(
            &format!("lm_head_t{tb}"),
            &[&xb, &self.final_g, &self.wout],
        )?;
        let logits = to_f32(&out[0])?;
        let stall = self.backend.tick(self.clock_s);
        self.clock_s += stall;
        Ok((kv, logits[..t * VOCAB].to_vec()))
    }

    /// One lockstep decode step over up to 8 sequences; appends one token
    /// to each.
    pub fn decode_step(&mut self, seqs: &mut [SeqState]) -> Result<Vec<i32>> {
        let b = seqs.len();
        if b == 0 {
            return Ok(Vec::new());
        }
        let max_b = *BATCH_BUCKETS.last().unwrap();
        if b > max_b {
            bail!("decode batch capped at {max_b} (got {b})");
        }
        let bb = next_bucket(BATCH_BUCKETS, b);
        let tb = next_bucket(TOKEN_BUCKETS, b);

        // Embedding of each sequence's last token.
        let mut tokens: Vec<i32> = seqs.iter().map(|s| s.last_token).collect();
        tokens.resize(tb, 0);
        self.clock_s += self.cost.embed_time(b);
        let tok_buf = self.rt.buffer_i32(&tokens, &[tb])?;
        let out = self.rt.execute_buffers(
            &format!("embed_t{tb}"),
            &[&tok_buf, &self.embed_table],
        )?;
        let xe = to_f32(&out[0])?;
        let mut xb = vec![0f32; bb * D_MODEL];
        xb[..b * D_MODEL].copy_from_slice(&xe[..b * D_MODEL]);

        let stride = S_MAX * D_MODEL;
        let mut pos: Vec<i32> = seqs.iter().map(|s| s.kv.len() as i32).collect();
        pos.resize(bb, 0);
        let mean_ctx =
            seqs.iter().map(|s| s.kv.len()).sum::<usize>() / b;

        let mut snap_k = vec![0f32; bb * stride];
        let mut snap_v = vec![0f32; bb * stride];
        for layer in 0..self.preset.n_layers {
            self.clock_s += self.cost.attn_decode_time(b, mean_ctx)
                + self.cost.router_time(b);
            snap_k[b * stride..].fill(0.0);
            snap_v[b * stride..].fill(0.0);
            for (row, s) in seqs.iter().enumerate() {
                s.kv.gather_into(layer, &mut snap_k, &mut snap_v, row);
            }
            let ll = &self.layer_lits[layer];
            let dims3 = [bb, S_MAX, D_MODEL];
            let xbb = self.rt.buffer_f32(&xb, &[bb, D_MODEL])?;
            let kb = self.rt.buffer_f32(&snap_k, &dims3)?;
            let vb = self.rt.buffer_f32(&snap_v, &dims3)?;
            let pb = self.rt.buffer_i32(&pos, &[bb])?;
            let out = self.rt.execute_buffers(
                &format!("attn_decode_b{bb}"),
                &[&xbb, &ll.attn_g, &ll.wq, &ll.wk, &ll.wv, &ll.wo, &kb, &vb, &pb],
            )?;
            xb = to_f32(&out[0])?;
            let new_k = to_f32(&out[1])?;
            let new_v = to_f32(&out[2])?;
            for (row, s) in seqs.iter_mut().enumerate() {
                s.kv.scatter_from(layer, &new_k, &new_v, row);
            }
            // MoE over the batch rows, padded to the token bucket.
            let mut xt = vec![0f32; tb * D_MODEL];
            xt[..b * D_MODEL].copy_from_slice(&xb[..b * D_MODEL]);
            // all rows share no tag; use per-seq tags via majority — routing
            // dispatch happens per row anyway, tag only matters for modeled
            // sampling, which the numeric engine does not use.
            self.moe_block(layer, &mut xt, tb, b, seqs[0].tag)?;
            xb[..b * D_MODEL].copy_from_slice(&xt[..b * D_MODEL]);
        }
        for s in seqs.iter_mut() {
            s.kv.advance();
        }

        self.clock_s += self.cost.lm_head_time(b);
        let mut xt = vec![0f32; tb * D_MODEL];
        xt[..b * D_MODEL].copy_from_slice(&xb[..b * D_MODEL]);
        let xtb = self.rt.buffer_f32(&xt, &[tb, D_MODEL])?;
        let out = self.rt.execute_buffers(
            &format!("lm_head_t{tb}"),
            &[&xtb, &self.final_g, &self.wout],
        )?;
        let logits = to_f32(&out[0])?;
        let mut next = Vec::with_capacity(b);
        for (row, s) in seqs.iter_mut().enumerate() {
            let slice = &logits[row * VOCAB..(row + 1) * VOCAB];
            let tok = argmax(slice) as i32;
            s.last_token = tok;
            s.generated.push(tok);
            next.push(tok);
        }
        let stall = self.backend.tick(self.clock_s);
        self.clock_s += stall;
        Ok(next)
    }

    /// Full request: prefill + greedy decode.
    pub fn generate(
        &mut self,
        prompt: &[i32],
        output_len: usize,
        tag: u64,
    ) -> Result<GenOutput> {
        let (kv, prompt_logits) = self.prefill(prompt, tag)?;
        let last = *prompt.last().context("empty prompt")?;
        let mut seqs = vec![SeqState {
            kv,
            last_token: last,
            tag,
            generated: Vec::new(),
        }];
        for _ in 0..output_len {
            self.decode_step(&mut seqs)?;
        }
        Ok(finish_generate(seqs, prompt_logits))
    }
}

/// Fold a finished decode run into its output. A run that completes with
/// zero sequences (an empty request set — every sequence retired or none
/// admitted) yields an empty token list instead of panicking on
/// `pop().unwrap()`.
fn finish_generate(mut seqs: Vec<SeqState>, prompt_logits: Vec<f32>) -> GenOutput {
    GenOutput {
        tokens: seqs.pop().map(|s| s.generated).unwrap_or_default(),
        prompt_logits,
    }
}

/// Which expert weights to run.
#[derive(Clone, Copy, Debug)]
enum ExpertRef {
    Routed(usize),
    Shared(usize),
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmax(&[-1.0, -5.0]), 0);
        assert_eq!(argmax(&[2.0, 2.0]), 0, "first wins ties");
    }

    #[test]
    fn empty_request_set_yields_empty_output() {
        // Regression: a run completing with zero sequences panicked on
        // `seqs.pop().unwrap()`; it is now an empty/zero result. (No PJRT
        // runtime needed — the fold is pure.)
        let out = finish_generate(Vec::new(), vec![0.25; 4]);
        assert!(out.tokens.is_empty());
        assert_eq!(out.prompt_logits, vec![0.25; 4]);
        // the non-empty path still returns the surviving sequence
        let seqs = vec![SeqState {
            kv: KvCache::new(1),
            last_token: 7,
            tag: 0,
            generated: vec![7, 8, 9],
        }];
        let out = finish_generate(seqs, Vec::new());
        assert_eq!(out.tokens, vec![7, 8, 9]);
    }
}
