//! Per-request KV cache for the numeric engine.
//!
//! Each sequence owns `[S_MAX, D]` K and V buffers per layer; the decode
//! executable consumes/produces padded `[B, S_MAX, D]` snapshots that the
//! batch assembler gathers from and scatters back to these buffers. In the
//! budget model this storage lives inside `M_fixed` (§3.3), disjoint from
//! the expert pools.

use crate::config::{D_MODEL, S_MAX};

/// KV state of one sequence.
#[derive(Clone, Debug)]
pub struct KvCache {
    n_layers: usize,
    /// Per layer, row-major `[S_MAX, D]`.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    len: usize,
}

impl KvCache {
    pub fn new(n_layers: usize) -> Self {
        Self {
            n_layers,
            k: (0..n_layers).map(|_| vec![0.0; S_MAX * D_MODEL]).collect(),
            v: (0..n_layers).map(|_| vec![0.0; S_MAX * D_MODEL]).collect(),
            len: 0,
        }
    }

    /// Current context length (tokens with valid K/V rows).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Install prefill K/V (`rows` tokens, row-major `[rows, D]`) at layer.
    pub fn write_prefill(&mut self, layer: usize, k: &[f32], v: &[f32], rows: usize) {
        assert!(rows <= S_MAX, "prompt exceeds S_MAX");
        assert!(k.len() >= rows * D_MODEL && v.len() >= rows * D_MODEL);
        self.k[layer][..rows * D_MODEL].copy_from_slice(&k[..rows * D_MODEL]);
        self.v[layer][..rows * D_MODEL].copy_from_slice(&v[..rows * D_MODEL]);
    }

    /// Mark the context length after prefill (call once per request).
    pub fn set_len(&mut self, len: usize) {
        assert!(len <= S_MAX);
        self.len = len;
    }

    /// Copy this sequence's K/V of `layer` into row `row` of a padded
    /// batch snapshot `[batch, S_MAX, D]`.
    pub fn gather_into(&self, layer: usize, snapshot_k: &mut [f32], snapshot_v: &mut [f32], row: usize) {
        let stride = S_MAX * D_MODEL;
        snapshot_k[row * stride..(row + 1) * stride]
            .copy_from_slice(&self.k[layer]);
        snapshot_v[row * stride..(row + 1) * stride]
            .copy_from_slice(&self.v[layer]);
    }

    /// Write back row `row` of an updated batch snapshot.
    pub fn scatter_from(&mut self, layer: usize, snapshot_k: &[f32], snapshot_v: &[f32], row: usize) {
        let stride = S_MAX * D_MODEL;
        self.k[layer]
            .copy_from_slice(&snapshot_k[row * stride..(row + 1) * stride]);
        self.v[layer]
            .copy_from_slice(&snapshot_v[row * stride..(row + 1) * stride]);
    }

    /// The decode step appended one token (after all layers scattered).
    pub fn advance(&mut self) {
        assert!(self.len < S_MAX, "KV cache full");
        self.len += 1;
    }

    /// Raw K rows (tests).
    pub fn k_row(&self, layer: usize, pos: usize) -> &[f32] {
        &self.k[layer][pos * D_MODEL..(pos + 1) * D_MODEL]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_rows_land_in_place() {
        let mut c = KvCache::new(2);
        let k: Vec<f32> = (0..3 * D_MODEL).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..3 * D_MODEL).map(|i| -(i as f32)).collect();
        c.write_prefill(1, &k, &v, 3);
        c.set_len(3);
        assert_eq!(c.len(), 3);
        assert_eq!(c.k_row(1, 2)[0], (2 * D_MODEL) as f32);
        assert_eq!(c.k_row(0, 2)[0], 0.0, "other layers untouched");
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut c = KvCache::new(1);
        let k: Vec<f32> = (0..D_MODEL).map(|i| i as f32).collect();
        c.write_prefill(0, &k, &k, 1);
        c.set_len(1);
        let stride = S_MAX * D_MODEL;
        let mut snap_k = vec![0.0; 2 * stride];
        let mut snap_v = vec![0.0; 2 * stride];
        c.gather_into(0, &mut snap_k, &mut snap_v, 1);
        assert_eq!(snap_k[stride], 0.0);
        assert_eq!(snap_k[stride + 1], 1.0);
        // mutate + scatter back
        snap_k[stride] = 99.0;
        c.scatter_from(0, &snap_k, &snap_v, 1);
        assert_eq!(c.k_row(0, 0)[0], 99.0);
        c.advance();
        assert_eq!(c.len(), 2);
    }

    #[test]
    #[should_panic(expected = "S_MAX")]
    fn overlong_prefill_rejected() {
        let mut c = KvCache::new(1);
        let k = vec![0.0; (S_MAX + 1) * D_MODEL];
        c.write_prefill(0, &k, &k, S_MAX + 1);
    }
}
