//! Small shared utilities: deterministic RNG, bucket selection, math,
//! ranked lock wrappers.

pub mod lockorder;
pub mod rng;

pub use rng::XorShiftRng;

/// Round `n` up to the smallest bucket ≥ `n`; falls back to the largest
/// bucket (callers must then split the work — see the engine's chunking).
pub fn next_bucket(buckets: &[usize], n: usize) -> usize {
    debug_assert!(!buckets.is_empty());
    for &b in buckets {
        if b >= n {
            return b;
        }
    }
    *buckets.last().unwrap()
}

/// Integer ceil-div.
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// Mean of an f64 slice (0.0 when empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// p-th percentile (nearest-rank: `⌈p/100·n⌉`-th smallest) of an unsorted
/// slice; 0.0 when empty. Non-finite samples (NaN/±inf) are excluded
/// before ranking — a lane with zero traffic or a poisoned sample must
/// never leak NaN into a report (the bench schema rejects it).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let b = [1, 4, 16, 64];
        assert_eq!(next_bucket(&b, 1), 1);
        assert_eq!(next_bucket(&b, 2), 4);
        assert_eq!(next_bucket(&b, 16), 16);
        assert_eq!(next_bucket(&b, 17), 64);
        assert_eq!(next_bucket(&b, 1000), 64); // caller chunks
    }

    #[test]
    fn ceil_div_works() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 99.0), 99.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_never_emits_non_finite() {
        // Empty input is 0.0 by contract (a lane with no traffic), and
        // non-finite samples neither panic the sort nor poison the rank.
        assert_eq!(percentile(&[], 95.0), 0.0);
        assert_eq!(percentile(&[f64::NAN], 50.0), 0.0);
        assert_eq!(
            percentile(&[f64::NAN, 2.0, 1.0, f64::INFINITY], 50.0),
            1.0
        );
        assert_eq!(
            percentile(&[f64::NEG_INFINITY, 3.0, f64::NAN], 100.0),
            3.0
        );
        for p in [0.0, 50.0, 95.0, 100.0] {
            assert!(percentile(&[f64::NAN, f64::INFINITY], p).is_finite());
        }
    }

    #[test]
    fn mean_empty() {
        assert_eq!(mean(&[]), 0.0);
        assert!((mean(&[1.0, 2.0, 3.0]) - 2.0).abs() < 1e-12);
    }
}
