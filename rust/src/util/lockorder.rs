//! Ranked lock-order enforcement (DESIGN.md §16).
//!
//! Every blocking lock in this crate is an [`OrderedMutex`] or
//! [`OrderedRwLock`] carrying a [`LockRank`]. The rank table below is the
//! *canonical, machine-checked* form of the DESIGN.md §13 lock table: a
//! thread may only acquire a lock whose rank is **strictly greater** than
//! every rank it already holds. In audited builds (`debug_assertions` or
//! the `lock-audit` feature) any out-of-order or re-entrant acquisition
//! panics at the acquisition site with the full held-rank stack, turning
//! what used to be a prose contract — and a latent deadlock — into an
//! immediate, attributable failure. In unaudited release builds the
//! wrappers compile down to the bare `std::sync` primitives plus one
//! branch on a `const`.
//!
//! The `dynaexq-lint` static-analysis binary (tools/lint) closes the
//! loop: constructing a raw `std::sync::Mutex`/`RwLock` anywhere outside
//! this module fails the `static-analysis` CI job, so new shared state
//! cannot silently opt out of the rank discipline.
//!
//! ## Poison policy
//!
//! All acquisitions recover from poisoning via
//! [`PoisonError::into_inner`] instead of panicking. Rationale: every
//! critical section in this crate either (a) guards monotone counters and
//! append-only sample buffers, for which a panicked writer leaves valid
//! (at worst slightly stale) state, or (b) performs multi-step updates
//! whose intermediate states are themselves valid values of the guarded
//! type (queue pushes, map inserts, free-list splices). Propagating the
//! poison instead would let one panicked producer thread permanently
//! wedge the front door's admission queue — the exact availability
//! failure §12's non-blocking contract forbids. Recoveries are counted
//! ([`poison_recoveries`]) so a test or operator can still observe that a
//! panic happened under a lock.

use std::cell::RefCell;
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{
    Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard,
    RwLockWriteGuard,
};

/// Whether acquisitions are rank-checked in this build. True under
/// `debug_assertions` or the `lock-audit` cargo feature; release builds
/// without the feature skip the thread-local bookkeeping entirely.
pub const AUDIT: bool = cfg!(any(debug_assertions, feature = "lock-audit"));

/// The canonical lock ranks (DESIGN.md §16), in required acquisition
/// order: a thread holding rank `r` may only acquire ranks `> r`.
///
/// The ordering follows the real nesting chains of the serving stack:
///
/// * admission: `FrontDoorTenants` (read) → `FrontDoorQueue` →
///   `QosLedger`; the drain side adds `LaneTtft` after the tenant read
///   guard is released;
/// * policy tick: `UpdateClock` → `Hotness` → `QosScores` → `Drift`,
///   then — still under the hotness/score guards — the transition
///   pipeline: `PipelineInner` → `HandleEntry` / `Pool`;
/// * `Trace` and `RuntimeExes` are leaf locks never held across another
///   acquisition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum LockRank {
    /// `serving::frontdoor` tenant table (`OrderedRwLock`): read on every
    /// submission, written once per first-appearing tenant name.
    FrontDoorTenants = 0,
    /// `serving::frontdoor` bounded admission queue — the single
    /// serialization point of the whole check chain (DESIGN.md §12).
    FrontDoorQueue = 1,
    /// `serving::frontdoor` per-class precision-budget ledger
    /// (DESIGN.md §15), charged under the queue lock.
    QosLedger = 2,
    /// `serving::frontdoor` per-lane TTFT sample buffers (drain side).
    LaneTtft = 3,
    /// Reserved for `serving::fleet` health/replica state (DESIGN.md
    /// §14). The fleet's checker and replica tables are exclusively
    /// owned (`&mut`) today; this rank pins their position in the order
    /// for when cross-thread fleet state appears (the GEMQ-style global
    /// budgeting plane on the roadmap).
    FleetHealth = 4,
    /// `coordinator` update-interval gate (`next_update_s`).
    UpdateClock = 5,
    /// `coordinator` hotness estimator — the serial fold/plan state the
    /// sharded counters merge into at each boundary (DESIGN.md §13).
    Hotness = 6,
    /// `coordinator` class-weighted score plane (DESIGN.md §15), folded
    /// under the hotness guard at the same boundary.
    QosScores = 7,
    /// `coordinator` drift detector (DESIGN.md §10), consulted under the
    /// hotness + score guards.
    Drift = 8,
    /// The transition pipeline's migration stream / in-flight list /
    /// eviction queue (`Mutex<PipelineInner>`, DESIGN.md §13).
    PipelineInner = 9,
    /// Per-expert residency entry state (`HandleTable`), taken under the
    /// pipeline lock during admission and publication.
    HandleEntry = 10,
    /// Per-rung block-pool free lists, taken under the pipeline lock on
    /// the eviction-drain and allocation paths.
    Pool = 11,
    /// The recording backend's shared `DXTR` trace (leaf).
    Trace = 12,
    /// The PJRT runtime's lazy executable cache (leaf; `numeric` builds).
    RuntimeExes = 13,
}

thread_local! {
    /// Ranks currently held by this thread, in acquisition order.
    static HELD: RefCell<Vec<LockRank>> = const { RefCell::new(Vec::new()) };
}

/// Poisoned acquisitions recovered so far, process-wide.
static POISON_RECOVERIES: AtomicU64 = AtomicU64::new(0);

/// How many poisoned lock acquisitions the poison policy has recovered
/// (observability: a non-zero value means some thread panicked while
/// holding an ordered lock and the state was adopted as-is).
pub fn poison_recoveries() -> u64 {
    POISON_RECOVERIES.load(Ordering::Relaxed) // relaxed-ok: monotone diagnostic counter
}

/// The calling thread's held-rank stack (diagnostics/tests). Empty in
/// unaudited builds.
pub fn held_ranks() -> Vec<LockRank> {
    if !AUDIT {
        return Vec::new();
    }
    HELD.with(|h| h.borrow().clone())
}

/// Rank-check an acquisition and push it onto the thread's stack.
/// Panics (audited builds) on any acquisition that is not strictly
/// ascending — including re-entrant acquisition of the same rank, which
/// would self-deadlock on a non-reentrant `std` lock.
fn acquire(rank: LockRank) {
    if !AUDIT {
        return;
    }
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(&worst) = held.iter().find(|&&r| r >= rank) {
            if worst == rank {
                panic!(
                    "lock-order violation: re-entrant acquisition of \
                     {rank:?} (held: {:?})",
                    &**held
                );
            }
            panic!(
                "lock-order violation: acquiring {rank:?} while holding \
                 {worst:?} (held: {:?})",
                &**held
            );
        }
        held.push(rank);
    });
}

/// Pop the most recent occurrence of `rank` from the thread's stack.
/// Guards may drop in any order, so this removes by value, not LIFO.
/// Never panics — it runs from `Drop`, possibly during unwinding.
fn release(rank: LockRank) {
    if !AUDIT {
        return;
    }
    HELD.with(|h| {
        let mut held = h.borrow_mut();
        if let Some(pos) = held.iter().rposition(|&r| r == rank) {
            held.remove(pos);
        }
    });
}

/// A `std::sync::Mutex` that enforces the [`LockRank`] acquisition order
/// and the crate poison policy (recover-and-continue; see module docs).
pub struct OrderedMutex<T> {
    rank: LockRank,
    inner: Mutex<T>,
}

impl<T> OrderedMutex<T> {
    pub fn new(rank: LockRank, value: T) -> Self {
        Self { rank, inner: Mutex::new(value) }
    }

    /// Acquire the lock. Audited builds panic on a rank violation
    /// *before* blocking, so an inversion is reported even when it
    /// happens not to deadlock in this interleaving.
    pub fn lock(&self) -> OrderedMutexGuard<'_, T> {
        acquire(self.rank);
        let inner = self.inner.lock().unwrap_or_else(|e| {
            // relaxed-ok: monotone diagnostic counter
            POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone diagnostic counter
            e.into_inner()
        });
        OrderedMutexGuard { inner, rank: self.rank }
    }

    pub fn rank(&self) -> LockRank {
        self.rank
    }

    /// Consume the lock, returning the inner value (poison recovered).
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
    }
}

pub struct OrderedMutexGuard<'a, T> {
    inner: MutexGuard<'a, T>,
    rank: LockRank,
}

impl<T> Deref for OrderedMutexGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for OrderedMutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T> Drop for OrderedMutexGuard<'_, T> {
    fn drop(&mut self) {
        release(self.rank);
    }
}

/// A `std::sync::RwLock` under the same rank discipline. Read and write
/// acquisitions are checked identically: a read-under-read re-entry on
/// the same rank panics too, since a writer queued between the two reads
/// deadlocks exactly like a mutex re-entry.
pub struct OrderedRwLock<T> {
    rank: LockRank,
    inner: RwLock<T>,
}

impl<T> OrderedRwLock<T> {
    pub fn new(rank: LockRank, value: T) -> Self {
        Self { rank, inner: RwLock::new(value) }
    }

    pub fn read(&self) -> OrderedReadGuard<'_, T> {
        acquire(self.rank);
        let inner = self.inner.read().unwrap_or_else(|e| {
            // relaxed-ok: monotone diagnostic counter
            POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone diagnostic counter
            e.into_inner()
        });
        OrderedReadGuard { inner, rank: self.rank }
    }

    pub fn write(&self) -> OrderedWriteGuard<'_, T> {
        acquire(self.rank);
        let inner = self.inner.write().unwrap_or_else(|e| {
            // relaxed-ok: monotone diagnostic counter
            POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed); // relaxed-ok: monotone diagnostic counter
            e.into_inner()
        });
        OrderedWriteGuard { inner, rank: self.rank }
    }

    pub fn rank(&self) -> LockRank {
        self.rank
    }
}

pub struct OrderedReadGuard<'a, T> {
    inner: RwLockReadGuard<'a, T>,
    rank: LockRank,
}

impl<T> Deref for OrderedReadGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> Drop for OrderedReadGuard<'_, T> {
    fn drop(&mut self) {
        release(self.rank);
    }
}

pub struct OrderedWriteGuard<'a, T> {
    inner: RwLockWriteGuard<'a, T>,
    rank: LockRank,
}

impl<T> Deref for OrderedWriteGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for OrderedWriteGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

impl<T> Drop for OrderedWriteGuard<'_, T> {
    fn drop(&mut self) {
        release(self.rank);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    // The should-panic cases only fire in audited builds; `cargo test
    // --release` without `lock-audit` compiles the checks out, so they
    // are ignored there rather than failing.
    macro_rules! audited {
        () => {
            if !AUDIT {
                return;
            }
        };
    }

    #[test]
    fn correct_order_succeeds_and_derefs() {
        let a = OrderedMutex::new(LockRank::FrontDoorQueue, vec![1u32]);
        let b = OrderedMutex::new(LockRank::PipelineInner, 7u32);
        {
            let mut ga = a.lock();
            ga.push(2);
            let mut gb = b.lock();
            *gb += 1;
            assert_eq!(*gb, 8);
            assert_eq!(ga.len(), 2);
            if AUDIT {
                assert_eq!(
                    held_ranks(),
                    vec![LockRank::FrontDoorQueue, LockRank::PipelineInner]
                );
            }
        }
        assert!(held_ranks().is_empty(), "stack must unwind on drop");
        // sequential re-acquisition after release is not re-entrancy
        assert_eq!(a.lock().len(), 2);
        assert_eq!(a.rank(), LockRank::FrontDoorQueue);
        assert_eq!(a.into_inner(), vec![1, 2]);
    }

    #[test]
    fn inversion_panics_under_audit() {
        audited!();
        let low = OrderedMutex::new(LockRank::Hotness, ());
        let high = OrderedMutex::new(LockRank::PipelineInner, ());
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _g_high = high.lock();
            let _g_low = low.lock(); // descending: must panic
        }))
        .expect_err("inverted acquisition must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(
            msg.contains("lock-order violation")
                && msg.contains("Hotness")
                && msg.contains("PipelineInner"),
            "unexpected panic message: {msg}"
        );
        assert!(held_ranks().is_empty(), "unwind must clear the stack");
    }

    #[test]
    fn reentrancy_panics_under_audit() {
        audited!();
        // two *distinct* locks of the same rank model the real hazard:
        // e.g. two per-expert HandleEntry locks held at once.
        let a = OrderedMutex::new(LockRank::HandleEntry, ());
        let b = OrderedMutex::new(LockRank::HandleEntry, ());
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _ga = a.lock();
            let _gb = b.lock();
        }))
        .expect_err("same-rank nesting must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("re-entrant"), "unexpected message: {msg}");
        assert!(held_ranks().is_empty());
    }

    #[test]
    fn rwlock_read_read_reentry_panics_under_audit() {
        audited!();
        let t = OrderedRwLock::new(LockRank::FrontDoorTenants, 1u32);
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _r1 = t.read();
            let _r2 = t.read(); // a queued writer between these deadlocks
        }))
        .expect_err("read-under-read must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("re-entrant"), "unexpected message: {msg}");
    }

    #[test]
    fn rwlock_ascending_read_then_lock_ok() {
        let t = OrderedRwLock::new(LockRank::FrontDoorTenants, 5u32);
        let q = OrderedMutex::new(LockRank::FrontDoorQueue, 0u32);
        {
            let r = t.read();
            let mut g = q.lock();
            *g += *r;
        }
        {
            let mut w = t.write();
            *w += 1;
        }
        assert_eq!(*t.read(), 6);
        assert_eq!(*q.lock(), 5);
        assert_eq!(t.rank(), LockRank::FrontDoorTenants);
        assert!(held_ranks().is_empty());
    }

    #[test]
    fn rank_stack_unwinds_when_a_guard_holder_panics() {
        audited!();
        let m = OrderedMutex::new(LockRank::Pool, 0u32);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = m.lock();
            panic!("holder dies");
        }));
        assert!(held_ranks().is_empty(), "guard drop must pop its rank");
        // the poison policy adopts the state; a lower rank is acquirable
        // again because the stack really unwound
        let low = OrderedMutex::new(LockRank::UpdateClock, ());
        let _g = low.lock();
        assert_eq!(*m.lock(), 0);
    }

    #[test]
    fn poison_is_recovered_and_counted() {
        let m = std::sync::Arc::new(OrderedMutex::new(LockRank::Trace, 3u32));
        let before = poison_recoveries();
        let m2 = m.clone();
        let joined = std::thread::spawn(move || {
            let mut g = m2.lock();
            *g = 4;
            panic!("poison the lock");
        })
        .join();
        assert!(joined.is_err());
        // recover-and-continue: the write that completed before the
        // panic is adopted, nothing wedges
        assert_eq!(*m.lock(), 4);
        assert!(poison_recoveries() > before, "recovery must be counted");
    }

    #[test]
    fn out_of_order_drop_releases_correct_ranks() {
        let a = OrderedMutex::new(LockRank::UpdateClock, ());
        let b = OrderedMutex::new(LockRank::Hotness, ());
        let ga = a.lock();
        let gb = b.lock();
        drop(ga); // drop in acquisition order, not reverse
        if AUDIT {
            assert_eq!(held_ranks(), vec![LockRank::Hotness]);
        }
        // Drift > Hotness is still acquirable
        let c = OrderedMutex::new(LockRank::Drift, ());
        let _gc = c.lock();
        drop(gb);
        if AUDIT {
            assert_eq!(held_ranks(), vec![LockRank::Drift]);
        }
    }

    #[test]
    fn rank_table_is_strictly_ordered() {
        use LockRank::*;
        let table = [
            FrontDoorTenants,
            FrontDoorQueue,
            QosLedger,
            LaneTtft,
            FleetHealth,
            UpdateClock,
            Hotness,
            QosScores,
            Drift,
            PipelineInner,
            HandleEntry,
            Pool,
            Trace,
            RuntimeExes,
        ];
        for w in table.windows(2) {
            assert!(w[0] < w[1], "{:?} must rank below {:?}", w[0], w[1]);
        }
    }
}
