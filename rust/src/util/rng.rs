//! Deterministic xorshift64* RNG.
//!
//! The vendored crate set has no `rand`; this is the single source of
//! randomness for weight generation, workload synthesis, and the property
//! test driver, keeping every experiment reproducible from a seed.

/// xorshift64* PRNG (Vigna 2016). Not cryptographic; plenty for simulation.
#[derive(Clone, Debug)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    /// Create from a seed; seed 0 is remapped (xorshift fixpoint).
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut r = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShiftRng::new(42);
        let mut b = XorShiftRng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = XorShiftRng::new(1);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = XorShiftRng::new(7);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / n as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n as f64;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = XorShiftRng::new(3);
        let w = [0.0, 0.0, 10.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.weighted(&w), 2);
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = XorShiftRng::new(9);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn zero_seed_ok() {
        let mut r = XorShiftRng::new(0);
        assert_ne!(r.next_u64(), 0);
    }
}
