//! Synthetic MoE model: precision tiers, host-side weight store, and the
//! rust mirror of the python quantizer.
//!
//! The paper prepares expert weights **offline** into kernel-ready high- and
//! low-precision layouts kept in pinned host memory; promotion copies the
//! prepared bytes host→device without on-the-fly repacking (§4). This module
//! is that preparation step: deterministic seeded weights for the three
//! simulated models, pre-quantized at every tier the model's config uses.

pub mod quant;
pub mod weights;

pub use weights::{ExpertWeights, LayerWeights, ModelWeights};

use crate::config::{D_MODEL, FF_DIM};

/// Precision tier of an expert version.
///
/// `Fp16` *executes* as f32 on the CPU PJRT plugin (tier semantics are what
/// the mechanism needs), but is *accounted* at 2 bytes/param so memory
/// budgets keep the paper's ratios.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    Int2,
    Int4,
    Fp16,
}

impl Precision {
    /// Bits per weight.
    pub fn bits(self) -> usize {
        match self {
            Precision::Fp16 => 16,
            Precision::Int4 => 4,
            Precision::Int2 => 2,
        }
    }

    /// Packing factor along the contraction axis (values per byte).
    pub fn pack(self) -> usize {
        match self {
            Precision::Fp16 => 1,
            Precision::Int4 => 2,
            Precision::Int2 => 4,
        }
    }

    /// Artifact-name component (`fp16` / `int4` / `int2`), matching aot.py.
    pub fn tag(self) -> &'static str {
        match self {
            Precision::Fp16 => "fp16",
            Precision::Int4 => "int4",
            Precision::Int2 => "int2",
        }
    }

    pub fn from_tag(tag: &str) -> Option<Self> {
        match tag {
            "fp16" => Some(Precision::Fp16),
            "int4" => Some(Precision::Int4),
            "int2" => Some(Precision::Int2),
            _ => None,
        }
    }
}

/// A validated precision ladder: the ordered tier list a model serves
/// through, highest fidelity first (tier 0 = hottest rung, last tier =
/// the always-resident base rung).
///
/// The original DynaExq formulation is the 2-rung special case
/// (`hi`/`lo`); every preset is expressed as a ladder and the coordinator
/// generalizes budget planning, residency, and the transition pipeline to
/// N rungs. Invariant: rungs are strictly descending in fidelity, so
/// per-expert byte sizes strictly decrease down the ladder.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PrecisionLadder {
    tiers: Vec<Precision>,
}

impl PrecisionLadder {
    /// Validate and build a ladder. Errors on an empty list or any
    /// non-strictly-descending adjacent pair (which would also make two
    /// rungs byte-identical and degenerate the budget waterfill).
    pub fn new(tiers: Vec<Precision>) -> Result<Self, String> {
        if tiers.is_empty() {
            return Err("precision ladder must have at least one rung".into());
        }
        for w in tiers.windows(2) {
            if w[0] <= w[1] {
                return Err(format!(
                    "precision ladder must be strictly descending: \
                     {:?} is not above {:?}",
                    w[0], w[1]
                ));
            }
        }
        Ok(Self { tiers })
    }

    /// The classic DynaExq hi/lo pair as a 2-rung ladder.
    pub fn two_tier(hi: Precision, lo: Precision) -> Self {
        Self::new(vec![hi, lo]).expect("hi must be above lo")
    }

    /// The full three-rung ladder over every supported precision.
    pub fn full() -> Self {
        Self::new(vec![Precision::Fp16, Precision::Int4, Precision::Int2])
            .expect("static ladder")
    }

    pub fn n_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// All rungs, highest fidelity first.
    pub fn tiers(&self) -> &[Precision] {
        &self.tiers
    }

    /// Precision of rung `tier` (panics out of range, like indexing).
    #[inline]
    pub fn tier(&self, tier: usize) -> Precision {
        self.tiers[tier]
    }

    /// Index of the base (coldest, always-resident) rung.
    #[inline]
    pub fn base_tier(&self) -> usize {
        self.tiers.len() - 1
    }

    /// Highest-fidelity rung (the classic `hi`).
    #[inline]
    pub fn top(&self) -> Precision {
        self.tiers[0]
    }

    /// Base rung precision (the classic `lo`).
    #[inline]
    pub fn base(&self) -> Precision {
        *self.tiers.last().unwrap()
    }

    /// Rung index of a precision, if it is on the ladder.
    pub fn tier_of(&self, p: Precision) -> Option<usize> {
        self.tiers.iter().position(|&t| t == p)
    }
}

/// Parameter count of one expert (w1 [D,F] + w3 [D,F] + w2 [F,D]).
pub const EXPERT_PARAMS: usize = 3 * D_MODEL * FF_DIM;

/// Accounted bytes of one expert's weights at precision `p`
/// (packed weights + per-output-channel scales for the int tiers).
pub fn expert_bytes(p: Precision) -> usize {
    match p {
        Precision::Fp16 => EXPERT_PARAMS * 2,
        _ => {
            let scales = (FF_DIM + FF_DIM + D_MODEL) * 4;
            EXPERT_PARAMS / p.pack() + scales
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_ordering_matches_fidelity() {
        assert!(Precision::Fp16 > Precision::Int4);
        assert!(Precision::Int4 > Precision::Int2);
    }

    #[test]
    fn tags_roundtrip() {
        for p in [Precision::Fp16, Precision::Int4, Precision::Int2] {
            assert_eq!(Precision::from_tag(p.tag()), Some(p));
        }
        assert_eq!(Precision::from_tag("int8"), None);
    }

    #[test]
    fn ladder_validation() {
        let l = PrecisionLadder::full();
        assert_eq!(l.n_tiers(), 3);
        assert_eq!(l.top(), Precision::Fp16);
        assert_eq!(l.base(), Precision::Int2);
        assert_eq!(l.base_tier(), 2);
        assert_eq!(l.tier_of(Precision::Int4), Some(1));
        let two = PrecisionLadder::two_tier(Precision::Fp16, Precision::Int4);
        assert_eq!(two.tiers(), &[Precision::Fp16, Precision::Int4]);
        assert!(PrecisionLadder::new(vec![]).is_err());
        assert!(PrecisionLadder::new(vec![
            Precision::Int4,
            Precision::Int4
        ])
        .is_err());
        assert!(PrecisionLadder::new(vec![
            Precision::Int2,
            Precision::Fp16
        ])
        .is_err());
        assert!(
            PrecisionLadder::new(vec![Precision::Int4]).is_ok(),
            "single-rung ladder is legal (static residency)"
        );
    }

    #[test]
    fn byte_accounting() {
        // 24576 params: fp16 = 49152; int4 = 12288 + 1280; int2 = 6144 + 1280
        assert_eq!(EXPERT_PARAMS, 24576);
        assert_eq!(expert_bytes(Precision::Fp16), 49152);
        assert_eq!(expert_bytes(Precision::Int4), 13568);
        assert_eq!(expert_bytes(Precision::Int2), 7424);
        assert!(expert_bytes(Precision::Fp16) > expert_bytes(Precision::Int4));
        assert!(expert_bytes(Precision::Int4) > expert_bytes(Precision::Int2));
    }
}
