//! Synthetic MoE model: precision tiers, host-side weight store, and the
//! rust mirror of the python quantizer.
//!
//! The paper prepares expert weights **offline** into kernel-ready high- and
//! low-precision layouts kept in pinned host memory; promotion copies the
//! prepared bytes host→device without on-the-fly repacking (§4). This module
//! is that preparation step: deterministic seeded weights for the three
//! simulated models, pre-quantized at every tier the model's config uses.

pub mod quant;
pub mod weights;

pub use weights::{ExpertWeights, LayerWeights, ModelWeights};

use crate::config::{D_MODEL, FF_DIM};

/// Precision tier of an expert version.
///
/// `Fp16` *executes* as f32 on the CPU PJRT plugin (tier semantics are what
/// the mechanism needs), but is *accounted* at 2 bytes/param so memory
/// budgets keep the paper's ratios.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    Int2,
    Int4,
    Fp16,
}

impl Precision {
    /// Bits per weight.
    pub fn bits(self) -> usize {
        match self {
            Precision::Fp16 => 16,
            Precision::Int4 => 4,
            Precision::Int2 => 2,
        }
    }

    /// Packing factor along the contraction axis (values per byte).
    pub fn pack(self) -> usize {
        match self {
            Precision::Fp16 => 1,
            Precision::Int4 => 2,
            Precision::Int2 => 4,
        }
    }

    /// Artifact-name component (`fp16` / `int4` / `int2`), matching aot.py.
    pub fn tag(self) -> &'static str {
        match self {
            Precision::Fp16 => "fp16",
            Precision::Int4 => "int4",
            Precision::Int2 => "int2",
        }
    }

    pub fn from_tag(tag: &str) -> Option<Self> {
        match tag {
            "fp16" => Some(Precision::Fp16),
            "int4" => Some(Precision::Int4),
            "int2" => Some(Precision::Int2),
            _ => None,
        }
    }
}

/// Parameter count of one expert (w1 [D,F] + w3 [D,F] + w2 [F,D]).
pub const EXPERT_PARAMS: usize = 3 * D_MODEL * FF_DIM;

/// Accounted bytes of one expert's weights at precision `p`
/// (packed weights + per-output-channel scales for the int tiers).
pub fn expert_bytes(p: Precision) -> usize {
    match p {
        Precision::Fp16 => EXPERT_PARAMS * 2,
        _ => {
            let scales = (FF_DIM + FF_DIM + D_MODEL) * 4;
            EXPERT_PARAMS / p.pack() + scales
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_ordering_matches_fidelity() {
        assert!(Precision::Fp16 > Precision::Int4);
        assert!(Precision::Int4 > Precision::Int2);
    }

    #[test]
    fn tags_roundtrip() {
        for p in [Precision::Fp16, Precision::Int4, Precision::Int2] {
            assert_eq!(Precision::from_tag(p.tag()), Some(p));
        }
        assert_eq!(Precision::from_tag("int8"), None);
    }

    #[test]
    fn byte_accounting() {
        // 24576 params: fp16 = 49152; int4 = 12288 + 1280; int2 = 6144 + 1280
        assert_eq!(EXPERT_PARAMS, 24576);
        assert_eq!(expert_bytes(Precision::Fp16), 49152);
        assert_eq!(expert_bytes(Precision::Int4), 13568);
        assert_eq!(expert_bytes(Precision::Int2), 7424);
        assert!(expert_bytes(Precision::Fp16) > expert_bytes(Precision::Int4));
        assert!(expert_bytes(Precision::Int4) > expert_bytes(Precision::Int2));
    }
}
