//! Rust mirror of `python/compile/quant.py` — **bit-exact** packing contract.
//!
//! * per-output-channel symmetric scales: `s[n] = max|W[:, n]| / qmax`
//! * stored codes `u = clip(round(w/s + bias), 0, 2^bits − 1)`
//!   - int4: integer levels, bias 8, qmax 7
//!   - int2: half-integer levels, bias 1.5, qmax 1.5
//!     (levels {−1.5, −0.5, +0.5, +1.5}·s)
//! * packed little-endian along the contraction axis K
//!   (int4: `b[k,n] = u[2k+1]<<4 | u[2k]`; int2: four codes per byte)
//!
//! The layout is what the L1 Pallas dequant-GEMM consumes; the pinned byte
//! patterns in the tests here match `python/tests/test_quant.py` exactly.

use super::Precision;

/// Quantization parameters per tier.
fn params(p: Precision) -> (usize, f32, f32) {
    // (bits, qmax, bias)
    match p {
        Precision::Int4 => (4, 7.0, 8.0),
        Precision::Int2 => (2, 1.5, 1.5),
        Precision::Fp16 => panic!("fp16 tier is not packed"),
    }
}

/// Packed quantized matrix: `data[K/pack, N]` row-major + `scales[N]`.
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub data: Vec<u8>,
    pub scales: Vec<f32>,
    /// Logical (unpacked) contraction dim.
    pub k: usize,
    pub n: usize,
    pub precision: Precision,
}

impl PackedMatrix {
    /// Packed byte rows (K / pack).
    pub fn rows(&self) -> usize {
        self.k / self.precision.pack()
    }

    /// Total payload bytes (packed data + scales).
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }
}

/// Quantize a row-major `w[K, N]` at tier `p` (Int4 or Int2).
pub fn quantize(w: &[f32], k: usize, n: usize, p: Precision) -> PackedMatrix {
    assert_eq!(w.len(), k * n);
    let (bits, qmax, bias) = params(p);
    let pack = p.pack();
    assert_eq!(k % pack, 0, "K={k} not divisible by pack={pack}");
    let umax = (1u32 << bits) - 1;

    // per-output-channel scales
    let mut scales = vec![0f32; n];
    for col in 0..n {
        let mut absmax = 0f32;
        for row in 0..k {
            absmax = absmax.max(w[row * n + col].abs());
        }
        scales[col] = if absmax > 0.0 { absmax / qmax } else { 1.0 };
    }

    let mut data = vec![0u8; (k / pack) * n];
    for row in 0..k {
        for col in 0..n {
            let q = (w[row * n + col] / scales[col] + bias).round();
            let u = q.clamp(0.0, umax as f32) as u8;
            let byte_row = row / pack;
            let shift = bits * (row % pack);
            data[byte_row * n + col] |= u << shift;
        }
    }
    PackedMatrix { data, scales, k, n, precision: p }
}

/// Dequantize back to row-major f32 (tests + the quality oracle).
pub fn dequantize(m: &PackedMatrix) -> Vec<f32> {
    let (bits, _, bias) = params(m.precision);
    let pack = m.precision.pack();
    let mask = ((1u32 << bits) - 1) as u8;
    let mut out = vec![0f32; m.k * m.n];
    for row in 0..m.k {
        let byte_row = row / pack;
        let shift = bits * (row % pack);
        for col in 0..m.n {
            let u = (m.data[byte_row * m.n + col] >> shift) & mask;
            out[row * m.n + col] = (u as f32 - bias) * m.scales[col];
        }
    }
    out
}

/// Relative Frobenius reconstruction error.
pub fn quant_error(w: &[f32], k: usize, n: usize, p: Precision) -> f64 {
    let m = quantize(w, k, n, p);
    let wq = dequantize(&m);
    let mut num = 0f64;
    let mut den = 0f64;
    for i in 0..w.len() {
        let d = (w[i] - wq[i]) as f64;
        num += d * d;
        den += (w[i] as f64) * (w[i] as f64);
    }
    if den == 0.0 {
        0.0
    } else {
        (num / den).sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn int4_pinned_byte_matches_python() {
        // test_quant.py::test_int4_known_bytes — w = [-7s, 7s]:
        // absmax = 7s → scale s; u = [round(-7+8), round(7+8)] = [1, 15]
        // → byte = 15<<4 | 1 = 0xF1
        let s = 0.5f32;
        let w = [-7.0 * s, 7.0 * s];
        let m = quantize(&w, 2, 1, Precision::Int4);
        assert_eq!(m.data, vec![0xF1]);
        assert!((m.scales[0] - s).abs() < 1e-6);
    }

    #[test]
    fn int2_pinned_byte_matches_python() {
        // test_quant.py::test_int2_known_bytes — u=[0,1,2,3] → 0xE4
        let w = [-1.5f32, -0.5, 0.5, 1.5];
        let m = quantize(&w, 4, 1, Precision::Int2);
        assert_eq!(m.data, vec![0xE4]);
        assert!((m.scales[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zero_column_scale_one() {
        let w = vec![0f32; 8 * 3];
        let m = quantize(&w, 8, 3, Precision::Int4);
        assert!(m.scales.iter().all(|&s| s == 1.0));
        assert!(dequantize(&m).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn prop_error_bounded_by_half_step() {
        // property: |w − wq| ≤ s/2 + eps elementwise, any shape/seed/tier
        let mut prop = Prop::new("quant_half_step");
        prop.run(60, |rng| {
            let k = *[4usize, 8, 16, 64].iter().nth(rng.below(4)).unwrap();
            let n = 1 + rng.below(24);
            let p = if rng.below(2) == 0 { Precision::Int4 } else { Precision::Int2 };
            let amp = rng.range_f64(0.01, 10.0) as f32;
            let w: Vec<f32> =
                (0..k * n).map(|_| rng.normal_f32() * amp).collect();
            let m = quantize(&w, k, n, p);
            let wq = dequantize(&m);
            for row in 0..k {
                for col in 0..n {
                    let d = (w[row * n + col] - wq[row * n + col]).abs();
                    assert!(
                        d <= m.scales[col] * 0.5 + 1e-5,
                        "tier {:?} k={k} n={n} d={d} s={}",
                        p,
                        m.scales[col]
                    );
                }
            }
        });
    }

    #[test]
    fn prop_int4_beats_int2() {
        let mut prop = Prop::new("quant_tier_order");
        prop.run(20, |rng| {
            let w: Vec<f32> = (0..64 * 16).map(|_| rng.normal_f32()).collect();
            let e4 = quant_error(&w, 64, 16, Precision::Int4);
            let e2 = quant_error(&w, 64, 16, Precision::Int2);
            assert!(e4 < e2, "int4 {e4} should beat int2 {e2}");
        });
    }

    #[test]
    fn bytes_accounting_matches_model() {
        let w = vec![0.1f32; crate::config::D_MODEL * crate::config::FF_DIM];
        let m4 = quantize(
            &w,
            crate::config::D_MODEL,
            crate::config::FF_DIM,
            Precision::Int4,
        );
        assert_eq!(
            m4.bytes(),
            crate::config::D_MODEL * crate::config::FF_DIM / 2
                + crate::config::FF_DIM * 4
        );
    }
}
