//! Deterministic synthetic weights + the pinned-host-memory weight store.
//!
//! All weight versions are prepared **offline** (at engine construction):
//! full-precision f32, plus packed Int4/Int2 versions for every expert, so
//! runtime promotion is a pure copy of prepared bytes — exactly the paper's
//! "avoid on-the-fly repacking during promotion" rule (§3.4).

use crate::config::{ModelPreset, D_MODEL, FF_DIM, VOCAB};
use crate::util::XorShiftRng;

use super::quant::{quantize, PackedMatrix};
use super::Precision;

/// One expert's prepared weight versions (host copies).
#[derive(Clone, Debug)]
pub struct ExpertWeights {
    /// Row-major f32: w1 [D, F], w3 [D, F], w2 [F, D].
    pub w1: Vec<f32>,
    pub w3: Vec<f32>,
    pub w2: Vec<f32>,
    /// Packed versions, prepared offline: (w1, w3, w2) per tier.
    pub int4: [PackedMatrix; 3],
    pub int2: [PackedMatrix; 3],
}

impl ExpertWeights {
    fn generate(rng: &mut XorShiftRng) -> Self {
        let std_in = 1.0 / (D_MODEL as f32).sqrt();
        let std_out = 1.0 / (FF_DIM as f32).sqrt();
        let gen = |rng: &mut XorShiftRng, n: usize, std: f32| -> Vec<f32> {
            (0..n).map(|_| rng.normal_f32() * std).collect()
        };
        let w1 = gen(rng, D_MODEL * FF_DIM, std_in);
        let w3 = gen(rng, D_MODEL * FF_DIM, std_in);
        let w2 = gen(rng, FF_DIM * D_MODEL, std_out);
        let q = |w: &[f32], k: usize, n: usize, p: Precision| quantize(w, k, n, p);
        Self {
            int4: [
                q(&w1, D_MODEL, FF_DIM, Precision::Int4),
                q(&w3, D_MODEL, FF_DIM, Precision::Int4),
                q(&w2, FF_DIM, D_MODEL, Precision::Int4),
            ],
            int2: [
                q(&w1, D_MODEL, FF_DIM, Precision::Int2),
                q(&w3, D_MODEL, FF_DIM, Precision::Int2),
                q(&w2, FF_DIM, D_MODEL, Precision::Int2),
            ],
            w1,
            w3,
            w2,
        }
    }

    /// The packed version at tier `p` (panics for Fp16 — use the f32 fields).
    pub fn packed(&self, p: Precision) -> &[PackedMatrix; 3] {
        match p {
            Precision::Int4 => &self.int4,
            Precision::Int2 => &self.int2,
            Precision::Fp16 => panic!("fp16 has no packed form"),
        }
    }
}

/// Per-layer weights: attention, router, experts, shared experts.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub attn_g: Vec<f32>,  // [D]
    pub wq: Vec<f32>,      // [D, D]
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub moe_g: Vec<f32>,   // [D]
    pub wr: Vec<f32>,      // [D, E]
    pub experts: Vec<ExpertWeights>,
    pub shared: Vec<ExpertWeights>,
}

/// Whole-model host weight store ("pinned host memory").
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub preset: ModelPreset,
    pub embed: Vec<f32>,   // [V, D]
    pub layers: Vec<LayerWeights>,
    pub final_g: Vec<f32>, // [D]
    pub wout: Vec<f32>,    // [D, V]
}

impl ModelWeights {
    /// Generate the full model deterministically from `seed`.
    ///
    /// Router columns get a small per-expert bias spread so routing is
    /// naturally skewed (the paper's heavy-tailed utilization, Obs. 2);
    /// *which* experts are hot still depends on the input distribution,
    /// which is what shifts across workload profiles.
    pub fn generate(preset: &ModelPreset, seed: u64) -> Self {
        let mut rng = XorShiftRng::new(seed);
        let d_std = 1.0 / (D_MODEL as f32).sqrt();
        let gen = |rng: &mut XorShiftRng, n: usize, std: f32| -> Vec<f32> {
            (0..n).map(|_| rng.normal_f32() * std).collect()
        };
        let ones = |n: usize| vec![1.0f32; n];

        let embed = gen(&mut rng, VOCAB * D_MODEL, 1.0);
        let mut layers = Vec::with_capacity(preset.n_layers);
        for _ in 0..preset.n_layers {
            let mut wr = gen(&mut rng, D_MODEL * preset.n_experts, d_std * 2.0);
            // Per-expert router gain: a heavy-ish tail over experts.
            for e in 0..preset.n_experts {
                let gain = 1.0 + 1.5 * rng.next_f32() * rng.next_f32();
                for row in 0..D_MODEL {
                    wr[row * preset.n_experts + e] *= gain;
                }
            }
            layers.push(LayerWeights {
                attn_g: ones(D_MODEL),
                wq: gen(&mut rng, D_MODEL * D_MODEL, d_std),
                wk: gen(&mut rng, D_MODEL * D_MODEL, d_std),
                wv: gen(&mut rng, D_MODEL * D_MODEL, d_std),
                wo: gen(&mut rng, D_MODEL * D_MODEL, d_std),
                moe_g: ones(D_MODEL),
                wr,
                experts: (0..preset.n_experts)
                    .map(|_| ExpertWeights::generate(&mut rng))
                    .collect(),
                shared: (0..preset.n_shared)
                    .map(|_| ExpertWeights::generate(&mut rng))
                    .collect(),
            });
        }
        Self {
            preset: preset.clone(),
            embed,
            layers,
            final_g: ones(D_MODEL),
            wout: gen(&mut rng, D_MODEL * VOCAB, d_std),
        }
    }

    /// Total prepared host bytes across all versions (diagnostics).
    pub fn host_bytes(&self) -> usize {
        let per_expert = super::EXPERT_PARAMS * 4
            + super::expert_bytes(Precision::Int4)
            + super::expert_bytes(Precision::Int2);
        let experts: usize = self
            .layers
            .iter()
            .map(|l| (l.experts.len() + l.shared.len()) * per_expert)
            .sum();
        experts + (self.embed.len() + self.wout.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_preset() -> ModelPreset {
        let mut p = ModelPreset::phi_sim();
        p.n_layers = 2;
        p
    }

    #[test]
    fn deterministic_generation() {
        let p = tiny_preset();
        let a = ModelWeights::generate(&p, 11);
        let b = ModelWeights::generate(&p, 11);
        assert_eq!(a.layers[0].wq, b.layers[0].wq);
        assert_eq!(
            a.layers[1].experts[3].int4[0].data,
            b.layers[1].experts[3].int4[0].data
        );
        let c = ModelWeights::generate(&p, 12);
        assert_ne!(a.layers[0].wq, c.layers[0].wq);
    }

    #[test]
    fn shapes() {
        let p = tiny_preset();
        let m = ModelWeights::generate(&p, 1);
        assert_eq!(m.embed.len(), VOCAB * D_MODEL);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[0].experts.len(), p.n_experts);
        assert_eq!(m.layers[0].wr.len(), D_MODEL * p.n_experts);
        let e = &m.layers[0].experts[0];
        assert_eq!(e.w1.len(), D_MODEL * FF_DIM);
        assert_eq!(e.int4[0].rows(), D_MODEL / 2);
        assert_eq!(e.int2[2].rows(), FF_DIM / 4);
    }

    #[test]
    fn shared_experts_present_for_80b() {
        let mut p = ModelPreset::qwen80b_sim();
        p.n_layers = 1;
        p.n_experts = 8; // shrink for test speed
        let m = ModelWeights::generate(&p, 5);
        assert_eq!(m.layers[0].shared.len(), 1);
    }

    #[test]
    fn packed_versions_reconstruct() {
        let p = tiny_preset();
        let m = ModelWeights::generate(&p, 3);
        let e = &m.layers[0].experts[0];
        let wq4 = super::super::quant::dequantize(&e.int4[0]);
        // int4 reconstruction should be close-ish
        let mut err = 0f64;
        let mut den = 0f64;
        for i in 0..e.w1.len() {
            err += ((e.w1[i] - wq4[i]) as f64).powi(2);
            den += (e.w1[i] as f64).powi(2);
        }
        assert!((err / den).sqrt() < 0.2);
    }
}
