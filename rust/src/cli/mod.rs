//! Hand-rolled CLI argument parsing (clap is not in the offline crate set).
//!
//! Grammar: `dynaexq <subcommand> [--flag value]... [--switch]...`

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (after argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(sub) = it.next() {
            if sub.starts_with('-') {
                return Err(format!("expected subcommand, got flag {sub}"));
            }
            out.subcommand = sub;
        }
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {arg:?}"));
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                }
                _ => out.switches.push(name.to_string()),
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Option<T> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn full_grammar() {
        let a = parse("report --exp t1 --batch 32 --verbose");
        assert_eq!(a.subcommand, "report");
        assert_eq!(a.get("exp"), Some("t1"));
        assert_eq!(a.get_parse::<usize>("batch"), Some(32));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.get_or("model", "qwen30b-sim"), "qwen30b-sim");
    }

    #[test]
    fn trailing_switch() {
        let a = parse("serve --fast");
        assert!(a.has("fast"));
    }

    #[test]
    fn rejects_positional() {
        assert!(
            Args::parse(["serve".into(), "oops".into()]).is_err()
        );
    }

    #[test]
    fn empty_ok() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.subcommand, "");
    }
}
