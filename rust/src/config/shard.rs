//! Expert sharding across a device group (DESIGN.md §9).
//!
//! A [`ShardPlan`] is the static placement map of a multi-device serving
//! group: it assigns every `(layer, expert)` of a model to exactly one
//! device. The built-in policy is *striped* placement (`expert mod
//! n_devices`), which balances shard sizes to within one expert and keeps
//! the map O(1) in both directions. Invariants (property-tested):
//!
//! * **partition** — every expert maps to exactly one device, and the
//!   per-device shard sizes sum to `n_experts`;
//! * **round-trip** — `global_of(device_of(e), local_of(e)) == e`, and
//!   local ids are dense in `0..shard_size(device)`;
//! * **layer-uniform** — placement depends only on the expert id, so every
//!   layer shards identically and per-device coordinators manage dense
//!   local id ranges without per-layer tables.

/// Static `(layer, expert) → device` placement for a serving group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    n_devices: usize,
    n_experts: usize,
}

impl ShardPlan {
    /// Striped placement of `n_experts` across `n_devices`.
    pub fn striped(n_experts: usize, n_devices: usize) -> Result<Self, String> {
        if n_devices == 0 {
            return Err("a device group needs at least one device".into());
        }
        if n_devices > n_experts {
            return Err(format!(
                "cannot shard {n_experts} experts across {n_devices} \
                 devices: every device must own at least one expert"
            ));
        }
        Ok(Self { n_devices, n_experts })
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    /// Device owning `(layer, expert)`. Placement is layer-uniform: the
    /// layer participates in the signature (future plans may stripe per
    /// layer) but not in the built-in policy.
    #[inline]
    pub fn device_of(&self, _layer: usize, expert: usize) -> usize {
        debug_assert!(expert < self.n_experts);
        expert % self.n_devices
    }

    /// The expert's dense id within its owning device's shard.
    #[inline]
    pub fn local_of(&self, expert: usize) -> usize {
        expert / self.n_devices
    }

    /// Inverse of ([`ShardPlan::device_of`], [`ShardPlan::local_of`]).
    #[inline]
    pub fn global_of(&self, device: usize, local: usize) -> usize {
        local * self.n_devices + device
    }

    /// Number of experts resident on `device`.
    pub fn shard_size(&self, device: usize) -> usize {
        debug_assert!(device < self.n_devices);
        self.n_experts / self.n_devices
            + usize::from(device < self.n_experts % self.n_devices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn one_device_is_identity() {
        let s = ShardPlan::striped(128, 1).unwrap();
        for e in [0usize, 1, 63, 127] {
            assert_eq!(s.device_of(0, e), 0);
            assert_eq!(s.local_of(e), e);
            assert_eq!(s.global_of(0, e), e);
        }
        assert_eq!(s.shard_size(0), 128);
    }

    #[test]
    fn rejects_degenerate_groups() {
        assert!(ShardPlan::striped(16, 0).is_err());
        let err = ShardPlan::striped(4, 5).unwrap_err();
        assert!(err.contains("at least one expert"), "{err}");
        assert!(ShardPlan::striped(4, 4).is_ok());
    }

    #[test]
    fn striped_balances_within_one() {
        let s = ShardPlan::striped(10, 3).unwrap();
        let sizes: Vec<usize> = (0..3).map(|d| s.shard_size(d)).collect();
        assert_eq!(sizes, vec![4, 3, 3]);
        assert_eq!(sizes.iter().sum::<usize>(), 10);
    }

    #[test]
    fn prop_partition_and_roundtrip() {
        let mut prop = Prop::new("shard_partition_roundtrip");
        prop.run(60, |rng| {
            let e = 1 + rng.below(512);
            let d = 1 + rng.below(e);
            let s = ShardPlan::striped(e, d).unwrap();
            // partition: sizes sum to E
            let total: usize = (0..d).map(|dev| s.shard_size(dev)).sum();
            assert_eq!(total, e);
            // round-trip + dense local ids, identical at every layer
            let mut seen = vec![vec![false; s.shard_size(0).max(1)]; d];
            for expert in 0..e {
                let dev = s.device_of(rng.below(64), expert);
                let local = s.local_of(expert);
                assert!(dev < d);
                assert!(local < s.shard_size(dev), "{expert} -> {dev}/{local}");
                assert_eq!(s.global_of(dev, local), expert);
                if local < seen[dev].len() {
                    assert!(!seen[dev][local], "local id reused");
                    seen[dev][local] = true;
                }
            }
        });
    }
}
