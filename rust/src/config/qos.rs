//! QoS classes that price precision (DESIGN.md §15).
//!
//! The Mixture-of-Experts-with-Mixture-of-Precisions framing (PAPERS.md)
//! treats expert precision as a quality-of-service dial. A [`QosConfig`]
//! couples the front door's per-tenant accounting (DESIGN.md §12) to the
//! waterfill allocator (§5): every tenant belongs to a [`QosClass`] whose
//! **hotness weight** scales its routed-token counts before the per-layer
//! waterfill ranks experts, and whose optional **precision budget** caps
//! the modeled hi-precision bytes the class's tenants may hold in flight
//! at the front door.
//!
//! Two invariants shape the design:
//!
//! 1. **Degenerate collapse.** A config where every class has the *same*
//!    weight and *no* class has a budget ([`QosConfig::is_degenerate`])
//!    must be byte-identical to running with no QoS at all. Every consumer
//!    therefore arms the QoS path only for non-degenerate configs — the
//!    weighted score plane, the per-class resolve counters, and the
//!    front-door ledger are *structurally absent*, never multiplied by 1.
//! 2. **Determinism.** Class weights enter the plan only through the
//!    per-expert score plane folded at the iteration boundary, so a fixed
//!    request stream with fixed class tags yields a byte-stable residency
//!    trajectory (the same contract the unweighted plan keeps).

use super::frontdoor::LimitAction;

/// A front-door tenant's service class, best first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum QosClass {
    /// Paying traffic: hot experts win hi-precision residency.
    Premium,
    /// The default class for unpinned tenants.
    Standard,
    /// Discounted traffic that rides the base rung when contended.
    BestEffort,
}

impl QosClass {
    /// Every class, presentation order (also the index order used by the
    /// per-class count planes and kv snapshot rows).
    pub const ALL: [QosClass; 3] =
        [QosClass::Premium, QosClass::Standard, QosClass::BestEffort];

    /// Stable index into per-class tables.
    pub fn index(self) -> usize {
        match self {
            QosClass::Premium => 0,
            QosClass::Standard => 1,
            QosClass::BestEffort => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            QosClass::Premium => "premium",
            QosClass::Standard => "standard",
            QosClass::BestEffort => "best-effort",
        }
    }

    pub fn by_name(name: &str) -> Option<QosClass> {
        match name {
            "premium" => Some(QosClass::Premium),
            "standard" => Some(QosClass::Standard),
            "best-effort" => Some(QosClass::BestEffort),
            _ => None,
        }
    }
}

impl std::fmt::Display for QosClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One class's pricing: how hard its traffic pulls on the waterfill and
/// how many modeled hi-precision bytes its tenants may hold in flight.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClassSpec {
    /// Multiplier on the class's routed-token counts before the EMA fold
    /// feeding the waterfill. Must be finite and positive.
    pub weight: f64,
    /// Per-tenant cap on outstanding modeled hi-precision bytes at the
    /// front door; `None` = unmetered.
    pub budget_bytes: Option<u64>,
}

/// The validated QoS policy: per-class pricing plus tenant pins.
#[derive(Clone, Debug, PartialEq)]
pub struct QosConfig {
    /// Pricing per class, indexed by [`QosClass::index`].
    pub classes: [ClassSpec; 3],
    /// Explicit tenant → class pins; unpinned tenants get
    /// [`QosConfig::default_class`].
    pub tenants: Vec<(String, QosClass)>,
    /// Class for tenants without a pin.
    pub default_class: QosClass,
    /// Modeled hi-precision bytes one in-flight token pins at the front
    /// door — the unit the budget charge is denominated in. A request
    /// costs `hi_bytes_per_token × (prompt_len + output_len)`.
    pub hi_bytes_per_token: u64,
    /// What budget exhaustion does: [`LimitAction::Reject`] surfaces
    /// `Rejected::BudgetExhausted`; [`LimitAction::Downgrade`] demotes the
    /// tenant to best-effort pricing and admits. `Warn`/`Demote` behave
    /// like `Reject` (they have no budget meaning).
    pub budget_action: LimitAction,
}

impl Default for QosConfig {
    fn default() -> Self {
        Self::degenerate()
    }
}

impl QosConfig {
    /// The identity policy: one effective class, no budgets. Collapses
    /// byte-identically to running without QoS ([`QosConfig::is_degenerate`]).
    pub fn degenerate() -> Self {
        Self {
            classes: [ClassSpec { weight: 1.0, budget_bytes: None }; 3],
            tenants: Vec::new(),
            default_class: QosClass::Standard,
            hi_bytes_per_token: 2048,
            budget_action: LimitAction::Reject,
        }
    }

    /// The canonical tiered policy: premium pulls 4× standard's weight,
    /// best-effort a quarter. No budgets — pure precision pricing.
    pub fn tiered() -> Self {
        let mut q = Self::degenerate();
        q.classes[QosClass::Premium.index()].weight = 4.0;
        q.classes[QosClass::Standard.index()].weight = 1.0;
        q.classes[QosClass::BestEffort.index()].weight = 0.25;
        q
    }

    /// Set one class's weight (builder style).
    pub fn with_weight(mut self, class: QosClass, weight: f64) -> Self {
        self.classes[class.index()].weight = weight;
        self
    }

    /// Set one class's budget (builder style).
    pub fn with_budget(mut self, class: QosClass, bytes: u64) -> Self {
        self.classes[class.index()].budget_bytes = Some(bytes);
        self
    }

    /// Pin a tenant to a class (builder style).
    pub fn pin(mut self, tenant: &str, class: QosClass) -> Self {
        self.tenants.push((tenant.to_string(), class));
        self
    }

    /// Set the budget-exhaustion action (builder style).
    pub fn on_exhausted(mut self, action: LimitAction) -> Self {
        self.budget_action = action;
        self
    }

    /// Whether this config is the identity policy: every class weighted
    /// equally and no class metered. Consumers treat a degenerate config
    /// exactly like no config — the QoS path is structurally skipped, so
    /// the collapse is byte-identical, not merely numerically close.
    pub fn is_degenerate(&self) -> bool {
        let w = self.classes[0].weight;
        self.classes.iter().all(|c| c.weight == w)
            && self.classes.iter().all(|c| c.budget_bytes.is_none())
    }

    /// The spec for `class`.
    pub fn class(&self, class: QosClass) -> &ClassSpec {
        &self.classes[class.index()]
    }

    /// The class `tenant` bills to: the last matching pin, else the
    /// default class.
    pub fn class_of(&self, tenant: &str) -> QosClass {
        self.tenants
            .iter()
            .rev()
            .find(|(t, _)| t == tenant)
            .map(|&(_, c)| c)
            .unwrap_or(self.default_class)
    }

    /// Per-class weights in [`QosClass::index`] order.
    pub fn weights(&self) -> [f64; 3] {
        [
            self.classes[0].weight,
            self.classes[1].weight,
            self.classes[2].weight,
        ]
    }

    /// Structural validity: finite positive weights, a positive charge
    /// unit, positive budgets, no duplicate tenant pins.
    pub fn validate(&self) -> Result<(), String> {
        for class in QosClass::ALL {
            let spec = self.class(class);
            if !spec.weight.is_finite() || spec.weight <= 0.0 {
                return Err(format!(
                    "qos class {}: weight must be finite and positive, \
                     got {}",
                    class, spec.weight
                ));
            }
            if spec.budget_bytes == Some(0) {
                return Err(format!(
                    "qos class {class}: budget must be positive bytes"
                ));
            }
        }
        if self.hi_bytes_per_token == 0 {
            return Err(
                "qos hi_bytes_per_token must be at least 1".to_string()
            );
        }
        for (i, (t, _)) in self.tenants.iter().enumerate() {
            if self.tenants[..i].iter().any(|(u, _)| u == t) {
                return Err(format!("qos tenant {t:?} pinned twice"));
            }
        }
        Ok(())
    }

    /// Budgets must fit the serving envelope they price: a per-tenant cap
    /// larger than the whole HBM budget can never bind and is almost
    /// certainly a unit error.
    pub fn validate_budgets(&self, envelope_bytes: u64) -> Result<(), String> {
        for class in QosClass::ALL {
            if let Some(b) = self.class(class).budget_bytes {
                if b > envelope_bytes {
                    return Err(format!(
                        "qos class {class}: budget {b} B exceeds the HBM \
                         envelope ({envelope_bytes} B)"
                    ));
                }
            }
        }
        Ok(())
    }

    /// Parse a CLI spec: comma-separated `class=weight[:budget_bytes]`
    /// parts over the degenerate defaults, plus `default=<class>` and
    /// `action=reject|downgrade`. Examples:
    /// `premium=4`, `premium=4:2e9,best-effort=0.25,action=downgrade`.
    pub fn parse_spec(spec: &str) -> Result<QosConfig, String> {
        let mut q = QosConfig::degenerate();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part.split_once('=').ok_or_else(|| {
                format!(
                    "bad qos part {part:?}; expected class=weight[:budget], \
                     default=<class>, or action=<reject|downgrade>"
                )
            })?;
            let (key, val) = (key.trim(), val.trim());
            if key == "action" {
                q.budget_action = match val {
                    "reject" => LimitAction::Reject,
                    "downgrade" => LimitAction::Downgrade,
                    other => {
                        return Err(format!(
                            "unknown qos action {other:?}; known actions: \
                             reject, downgrade"
                        ))
                    }
                };
                continue;
            }
            if key == "default" {
                q.default_class = QosClass::by_name(val).ok_or_else(|| {
                    format!(
                        "unknown qos class {val:?}; known classes: premium, \
                         standard, best-effort"
                    )
                })?;
                continue;
            }
            let class = QosClass::by_name(key).ok_or_else(|| {
                format!(
                    "unknown qos class {key:?}; known classes: premium, \
                     standard, best-effort"
                )
            })?;
            let (weight, budget) = match val.split_once(':') {
                Some((w, b)) => (w, Some(b)),
                None => (val, None),
            };
            let w: f64 = weight.parse().map_err(|_| {
                format!("bad qos weight {weight:?} for class {class}")
            })?;
            q.classes[class.index()].weight = w;
            if let Some(b) = budget {
                let bytes: f64 = b.parse().map_err(|_| {
                    format!("bad qos budget {b:?} for class {class}")
                })?;
                if !bytes.is_finite() || bytes < 1.0 {
                    return Err(format!(
                        "qos class {class}: budget must be at least 1 byte, \
                         got {b:?}"
                    ));
                }
                q.classes[class.index()].budget_bytes = Some(bytes as u64);
            }
        }
        q.validate()?;
        Ok(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn classes_roundtrip_names_and_indices() {
        for (i, c) in QosClass::ALL.into_iter().enumerate() {
            assert_eq!(c.index(), i);
            assert_eq!(QosClass::by_name(c.name()), Some(c));
            assert_eq!(format!("{c}"), c.name());
        }
        assert_eq!(QosClass::by_name("platinum"), None);
    }

    #[test]
    fn degenerate_and_tiered_shapes() {
        let d = QosConfig::degenerate();
        assert!(d.is_degenerate());
        assert!(d.validate().is_ok());
        // equal weights at any value stay degenerate; a budget never does
        let scaled = QosConfig {
            classes: [ClassSpec { weight: 3.0, budget_bytes: None }; 3],
            ..QosConfig::degenerate()
        };
        assert!(scaled.is_degenerate());
        let t = QosConfig::tiered();
        assert!(!t.is_degenerate());
        assert!(t.validate().is_ok());
        assert!(t.class(QosClass::Premium).weight
            > t.class(QosClass::BestEffort).weight);
        let metered =
            QosConfig::degenerate().with_budget(QosClass::Standard, 1 << 30);
        assert!(!metered.is_degenerate());
    }

    #[test]
    fn class_of_pins_and_defaults() {
        let q = QosConfig::tiered()
            .pin("acme", QosClass::Premium)
            .pin("crawler", QosClass::BestEffort);
        assert_eq!(q.class_of("acme"), QosClass::Premium);
        assert_eq!(q.class_of("crawler"), QosClass::BestEffort);
        assert_eq!(q.class_of("anyone-else"), QosClass::Standard);
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let bad = QosConfig::degenerate().with_weight(QosClass::Premium, 0.0);
        assert!(bad.validate().unwrap_err().contains("premium"));
        let bad =
            QosConfig::degenerate().with_weight(QosClass::BestEffort, -2.0);
        assert!(bad.validate().unwrap_err().contains("best-effort"));
        let bad = QosConfig::degenerate()
            .with_weight(QosClass::Standard, f64::NAN);
        assert!(bad.validate().is_err());
        let mut bad = QosConfig::degenerate();
        bad.hi_bytes_per_token = 0;
        assert!(bad.validate().unwrap_err().contains("hi_bytes_per_token"));
        let mut bad = QosConfig::degenerate();
        bad.classes[0].budget_bytes = Some(0);
        assert!(bad.validate().is_err());
        let bad = QosConfig::degenerate()
            .pin("a", QosClass::Premium)
            .pin("a", QosClass::Standard);
        assert!(bad.validate().unwrap_err().contains("pinned twice"));
    }

    #[test]
    fn budget_envelope_check() {
        let q = QosConfig::degenerate().with_budget(QosClass::Premium, 100);
        assert!(q.validate_budgets(1000).is_ok());
        let err = q.validate_budgets(10).unwrap_err();
        assert!(err.contains("exceeds the HBM envelope"), "{err}");
    }

    #[test]
    fn spec_parses_and_rejects() {
        let q = QosConfig::parse_spec("premium=4").unwrap();
        assert_eq!(q.class(QosClass::Premium).weight, 4.0);
        assert!(!q.is_degenerate());
        let q = QosConfig::parse_spec(
            "premium=4:2e9, best-effort=0.25, action=downgrade, \
             default=best-effort",
        )
        .unwrap();
        assert_eq!(
            q.class(QosClass::Premium).budget_bytes,
            Some(2_000_000_000)
        );
        assert_eq!(q.class(QosClass::BestEffort).weight, 0.25);
        assert_eq!(q.budget_action, LimitAction::Downgrade);
        assert_eq!(q.default_class, QosClass::BestEffort);
        // empty spec is the degenerate identity
        assert!(QosConfig::parse_spec("").unwrap().is_degenerate());
        // unknown names enumerate the valid set
        let err = QosConfig::parse_spec("gold=2").unwrap_err();
        assert!(err.contains("known classes"), "{err}");
        let err = QosConfig::parse_spec("default=gold").unwrap_err();
        assert!(err.contains("known classes"), "{err}");
        let err = QosConfig::parse_spec("action=explode").unwrap_err();
        assert!(err.contains("known actions"), "{err}");
        assert!(QosConfig::parse_spec("premium").is_err());
        assert!(QosConfig::parse_spec("premium=fast").is_err());
        assert!(QosConfig::parse_spec("premium=4:lots").is_err());
        assert!(QosConfig::parse_spec("premium=4:0.2").is_err());
        // parsed weights still validate
        assert!(QosConfig::parse_spec("premium=-1").is_err());
        assert!(QosConfig::parse_spec("premium=0").is_err());
    }

    #[test]
    fn prop_parse_spec_never_panics_and_errors_enumerate() {
        // Seeded fuzz over near-miss specs: every outcome is Ok or a
        // descriptive Err — no panic, and unknown class names always
        // enumerate the valid set.
        let mut prop = Prop::new("qos_parse_fuzz");
        let classes = ["premium", "standard", "best-effort", "gold", ""];
        let weights = ["1", "4.5", "-3", "0", "nan", "1e400", "x", ""];
        let budgets = ["", ":1e9", ":0", ":-5", ":junk", ":9e18"];
        prop.run(200, |rng| {
            let mut parts = Vec::new();
            for _ in 0..rng.below(4) {
                let c = classes[rng.below(classes.len())];
                let w = weights[rng.below(weights.len())];
                let b = budgets[rng.below(budgets.len())];
                parts.push(format!("{c}={w}{b}"));
            }
            let spec = parts.join(",");
            match QosConfig::parse_spec(&spec) {
                Ok(q) => assert!(q.validate().is_ok(), "spec {spec:?}"),
                Err(e) => {
                    assert!(!e.is_empty());
                    if e.contains("unknown qos class") {
                        assert!(e.contains(
                            "premium, standard, best-effort"
                        ));
                    }
                }
            }
        });
    }

    #[test]
    fn prop_validate_never_panics_on_random_configs() {
        let mut prop = Prop::new("qos_validate_fuzz");
        prop.run(200, |rng| {
            let mut q = QosConfig::degenerate();
            for c in QosClass::ALL {
                q.classes[c.index()].weight = match rng.below(5) {
                    0 => -rng.range_f64(0.0, 10.0),
                    1 => 0.0,
                    2 => f64::NAN,
                    3 => f64::INFINITY,
                    _ => rng.range_f64(0.1, 8.0),
                };
                if rng.below(3) == 0 {
                    q.classes[c.index()].budget_bytes =
                        Some(rng.below(1 << 20) as u64 * 1024);
                }
            }
            q.hi_bytes_per_token = rng.below(4) as u64;
            let _ = q.validate();
            let _ = q.validate_budgets(1 << 30);
            let _ = q.is_degenerate();
        });
    }
}
