//! Minimal `key=value` / `key=value;key=value` parsing used by the artifact
//! manifest and CLI overrides (the offline crate set has no serde/TOML).

use std::collections::BTreeMap;

/// Parse `a=1;b=x` (or comma-separated) into a map. Empty segments ignored.
/// Ordered map so that any serialization of the result is deterministic.
pub fn parse_kv(s: &str) -> BTreeMap<String, String> {
    let mut m = BTreeMap::new();
    for part in s.split([';', ',']) {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((k, v)) = part.split_once('=') {
            m.insert(k.trim().to_string(), v.trim().to_string());
        }
    }
    m
}

/// Fetch + parse a typed value from a kv map.
pub fn get_parse<T: std::str::FromStr>(
    m: &BTreeMap<String, String>,
    key: &str,
) -> Option<T> {
    m.get(key).and_then(|v| v.parse().ok())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_mixed_separators() {
        let m = parse_kv("op=router;tokens=16,experts=128");
        assert_eq!(m["op"], "router");
        assert_eq!(get_parse::<usize>(&m, "tokens"), Some(16));
        assert_eq!(get_parse::<usize>(&m, "experts"), Some(128));
    }

    #[test]
    fn ignores_garbage() {
        let m = parse_kv(";;a=1;novalue;  b = 2 ");
        assert_eq!(m.len(), 2);
        assert_eq!(m["a"], "1");
        assert_eq!(m["b"], "2");
    }

    #[test]
    fn missing_key_none() {
        let m = parse_kv("a=1");
        assert_eq!(get_parse::<usize>(&m, "zz"), None);
        assert_eq!(get_parse::<usize>(&m, "a"), Some(1));
    }
}
