//! Configuration: model presets (mirroring `python/compile/configs.py`),
//! serving/policy parameters, and the simulated device.
//!
//! A tiny `key=value` text format (see [`kv`]) replaces serde/TOML (not in
//! the offline crate set); presets cover the paper's three evaluation models.

pub mod fleet;
pub mod frontdoor;
pub mod kv;
pub mod qos;
pub mod shard;

pub use fleet::FleetConfig;
pub use frontdoor::{FrontDoorConfig, Lane};
pub use qos::{QosClass, QosConfig};
pub use shard::ShardPlan;

use crate::model::{Precision, PrecisionLadder};

/// Core tensor dims — must match `python/compile/configs.py`.
pub const D_MODEL: usize = 64;
pub const N_HEADS: usize = 4;
pub const HEAD_DIM: usize = D_MODEL / N_HEADS;
pub const FF_DIM: usize = 128;
pub const VOCAB: usize = 256;
pub const S_MAX: usize = 512;

/// Token-count buckets compiled for flat-token ops.
pub const TOKEN_BUCKETS: &[usize] = &[1, 4, 16, 64, 256];
/// Batch buckets compiled for the decode-step attention op.
pub const BATCH_BUCKETS: &[usize] = &[1, 4, 8];
/// Token buckets compiled for the per-expert FFN op.
pub const EXPERT_TOKEN_BUCKETS: &[usize] = &[1, 4, 16, 64];

/// Routing structure of one simulated MoE model (paper Table 3).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelPreset {
    pub name: &'static str,
    /// Executed transformer layers in this reproduction.
    pub n_layers: usize,
    /// Experts per MoE layer.
    pub n_experts: usize,
    /// Router top-k.
    pub top_k: usize,
    /// Always-on shared experts per layer (run at the top rung).
    pub n_shared: usize,
    /// Precision ladder the model serves through, highest rung first.
    /// The classic hi/lo presets are 2-rung ladders.
    pub ladder: PrecisionLadder,
    /// Layer count of the paper's real model (reporting metadata only).
    pub paper_layers: usize,
}

impl ModelPreset {
    /// Qwen3-30B-A3B analogue: 128 experts, top-8, FP16 hot / INT4 cold.
    pub fn qwen30b_sim() -> Self {
        Self {
            name: "qwen30b-sim",
            n_layers: 4,
            n_experts: 128,
            top_k: 8,
            n_shared: 0,
            ladder: PrecisionLadder::two_tier(
                Precision::Fp16,
                Precision::Int4,
            ),
            paper_layers: 48,
        }
    }

    /// Qwen3-30B analogue on the full three-rung ladder: warm experts get
    /// an INT4 middle rung between FP16-hot and INT2-cold, so the same HBM
    /// envelope covers a deeper fidelity gradient (the new 3-tier serving
    /// scenario).
    pub fn qwen30b_3tier() -> Self {
        Self {
            name: "qwen30b-3tier",
            ladder: PrecisionLadder::full(),
            ..Self::qwen30b_sim()
        }
    }

    /// Qwen3-Next-80B analogue: 512 experts, top-10, one shared expert,
    /// INT4 hot / INT2 cold (the paper serves the 80B from an Int4 base).
    pub fn qwen80b_sim() -> Self {
        Self {
            name: "qwen80b-sim",
            n_layers: 4,
            n_experts: 512,
            top_k: 10,
            n_shared: 1,
            ladder: PrecisionLadder::two_tier(
                Precision::Int4,
                Precision::Int2,
            ),
            paper_layers: 48,
        }
    }

    /// Phi-3.5-MoE analogue: 16 experts, top-2, FP16 hot / INT4 cold.
    pub fn phi_sim() -> Self {
        Self {
            name: "phi-sim",
            n_layers: 4,
            n_experts: 16,
            top_k: 2,
            n_shared: 0,
            ladder: PrecisionLadder::two_tier(
                Precision::Fp16,
                Precision::Int4,
            ),
            paper_layers: 32,
        }
    }

    /// All presets, in the paper's table order (plus the 3-tier scenario).
    pub fn all() -> Vec<Self> {
        vec![
            Self::qwen30b_sim(),
            Self::qwen30b_3tier(),
            Self::qwen80b_sim(),
            Self::phi_sim(),
        ]
    }

    /// Look up a preset by name.
    pub fn by_name(name: &str) -> Option<Self> {
        Self::all().into_iter().find(|p| p.name == name)
    }

    /// Router artifact key (`e{experts}k{topk}`), matching aot.py.
    pub fn router_key(&self) -> String {
        format!("e{}k{}", self.n_experts, self.top_k)
    }

    /// A copy whose logical layer count equals the *executed* layer count —
    /// used when a Coordinator manages the numeric (small) model directly.
    pub fn executed_scale(&self) -> Self {
        let mut p = self.clone();
        p.paper_layers = p.n_layers;
        p
    }

    /// Bytes of one expert's weights at `p` (three matrices + scales),
    /// matching the packed layout of `model::quant`.
    pub fn expert_bytes(&self, p: Precision) -> usize {
        crate::model::expert_bytes(p)
    }

    /// Top rung of the ladder (the classic `hi` tier).
    #[inline]
    pub fn hi(&self) -> Precision {
        self.ladder.top()
    }

    /// Base rung of the ladder (the classic `lo` tier).
    #[inline]
    pub fn lo(&self) -> Precision {
        self.ladder.base()
    }
}

/// Drift-detection / adaptive-α parameters (DESIGN.md §10): a windowed
/// change-point detector over the per-layer routing distribution that, on
/// a trigger, temporarily drops the EMA α and rescales stale scores so
/// the waterfill re-converges to the new hot set in bounded intervals.
#[derive(Clone, Debug)]
pub struct DriftConfig {
    /// Update intervals per comparison window (consecutive windows are
    /// compared at each boundary).
    pub window: u64,
    /// Base total-variation distance a window pair must exceed to
    /// trigger, on top of the sampling-noise floor.
    pub threshold: f64,
    /// Noise-floor coefficient: the effective threshold is
    /// `threshold + noise_coeff · sqrt(E / min(window counts))`, so a
    /// steady workload never triggers on sampling noise alone.
    pub noise_coeff: f64,
    /// The dropped (reactive) EMA α used while recovering from a trigger.
    pub alpha: f64,
    /// Update intervals the dropped α stays in effect after a trigger.
    pub recovery_intervals: u64,
    /// Multiplier applied to all smoothed scores at the trigger instant —
    /// stale hotness must not outvote the post-drift traffic.
    pub stale_decay: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        Self {
            window: 3,
            threshold: 0.25,
            noise_coeff: 2.0,
            alpha: 0.1,
            recovery_intervals: 4,
            stale_decay: 0.25,
        }
    }
}

impl DriftConfig {
    /// Validate parameter ranges. The adaptive coordinator surfaces these
    /// as construction errors, like every other infeasible config.
    pub fn validate(&self) -> Result<(), String> {
        if self.window < 1 {
            return Err("drift.window must be at least 1 interval".into());
        }
        if self.recovery_intervals < 1 {
            return Err(
                "drift.recovery_intervals must be at least 1 (a trigger \
                 without reactive intervals only decays scores)"
                    .into(),
            );
        }
        if !(0.0..1.0).contains(&self.alpha) {
            return Err(format!("drift.alpha {} outside [0, 1)", self.alpha));
        }
        if !(0.0..=1.0).contains(&self.stale_decay) {
            return Err(format!(
                "drift.stale_decay {} outside [0, 1]",
                self.stale_decay
            ));
        }
        if self.threshold < 0.0 || self.noise_coeff < 0.0 {
            return Err(
                "drift.threshold and drift.noise_coeff must be non-negative"
                    .into(),
            );
        }
        Ok(())
    }
}

/// Policy + mechanism parameters of the DynaExq control loop (§3).
#[derive(Clone, Debug)]
pub struct ServingConfig {
    /// EMA smoothing factor α ∈ [0, 1): `S ← αS + (1−α)c`.
    pub ema_alpha: f64,
    /// Update interval T_u in modeled milliseconds.
    pub update_interval_ms: f64,
    /// Hysteresis margin: a candidate must beat the weakest resident's score
    /// by this relative margin to trigger a swap (0 disables hysteresis).
    pub hysteresis_margin: f64,
    /// Max concurrent in-flight promotions (admission/backpressure).
    pub max_inflight_promotions: usize,
    /// Hard HBM envelope in bytes (the paper's 48 GB A6000).
    pub hbm_budget_bytes: usize,
    /// Reserved bytes for non-expert state (KV cache, activations,
    /// non-expert params, runtime) — `M_fixed` of §3.3.
    pub fixed_bytes: usize,
    /// Force the per-layer capacity of the ladder's top rung instead of
    /// deriving it from the budget (quality sweeps, Fig. 3). The override
    /// is still validated against the HBM envelope.
    pub n_hi_override: Option<usize>,
    /// Maximum decode steps per scheduling quantum.
    pub max_batch: usize,
    /// If true, transitions block the forward pass (ablation A3).
    pub blocking_transitions: bool,
    /// Pool block granularity in bytes (ablation A4).
    pub pool_block_bytes: usize,
    /// Enable the drift-aware hotness layer (the `dynaexq-adaptive`
    /// registry method; off by default so the classic fixed-α stack stays
    /// byte-identical).
    pub adaptive_alpha: bool,
    /// Change-point detector parameters (consulted only when
    /// `adaptive_alpha` is set).
    pub drift: DriftConfig,
    /// QoS class weighting for the waterfill (DESIGN.md §15). `None` — or
    /// a [`QosConfig::is_degenerate`] config — keeps the classic
    /// tenant-blind plan byte-identically.
    pub qos: Option<QosConfig>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            ema_alpha: 0.8,
            update_interval_ms: 50.0,
            hysteresis_margin: 0.1,
            max_inflight_promotions: 64,
            hbm_budget_bytes: 48_000_000_000, // RTX A6000: 48 GB
            // non-expert params + KV cache + activations + runtime
            fixed_bytes: 14_000_000_000,
            max_batch: 32,
            blocking_transitions: false,
            pool_block_bytes: 0, // 0 → derived from expert size
            n_hi_override: None,
            adaptive_alpha: false,
            drift: DriftConfig::default(),
            qos: None,
        }
    }
}

/// Simulated device (A6000-class, DESIGN.md §2): used by `sim::Device`.
#[derive(Clone, Debug)]
pub struct DeviceConfig {
    /// Host↔device bandwidth in bytes/s (PCIe 4.0 x16 ≈ 25 GB/s effective).
    pub pcie_bytes_per_s: f64,
    /// Device memory bandwidth in bytes/s (A6000 ≈ 768 GB/s).
    pub hbm_bytes_per_s: f64,
    /// Achieved dense compute throughput in FLOP/s. The A6000 peaks at
    /// ≈155 fp16 TFLOPs, but the paper serves through a PyTorch/HF
    /// Transformers stack whose MoE path reaches a small fraction of peak;
    /// 15 TFLOP/s effective keeps modeled latencies in the paper's regime
    /// (its Fig. 10 TTFTs are seconds, not milliseconds).
    pub flops_per_s: f64,
    /// Fixed per-kernel launch overhead in seconds (eager-mode dispatch).
    pub launch_overhead_s: f64,
    /// Aggregate host-interconnect bandwidth shared by every device of a
    /// group (root-complex / host-memory ceiling). A device's migration
    /// stream gets `min(pcie_bytes_per_s, host_agg_bytes_per_s / n)` in an
    /// n-device group — see [`crate::sim::cost::migration_link_bytes_per_s`].
    pub host_agg_bytes_per_s: f64,
}

impl Default for DeviceConfig {
    fn default() -> Self {
        Self {
            pcie_bytes_per_s: 25e9,
            hbm_bytes_per_s: 768e9,
            flops_per_s: 15e12,
            launch_overhead_s: 30e-6,
            // two full PCIe 4.0 x16 links' worth of host bandwidth: 2-way
            // groups keep full per-link speed, wider groups contend
            host_agg_bytes_per_s: 50e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_structure() {
        let q30 = ModelPreset::qwen30b_sim();
        assert_eq!(q30.n_experts, 128);
        assert_eq!(q30.top_k, 8);
        let q80 = ModelPreset::qwen80b_sim();
        assert_eq!(q80.n_experts, 512);
        assert_eq!(q80.top_k, 10);
        assert_eq!(q80.n_shared, 1);
        assert_eq!(q80.hi(), Precision::Int4);
        assert_eq!(q80.lo(), Precision::Int2);
        let phi = ModelPreset::phi_sim();
        assert_eq!(phi.n_experts, 16);
        assert_eq!(phi.top_k, 2);
    }

    #[test]
    fn three_tier_preset_shares_structure_with_qwen30b() {
        let q3 = ModelPreset::qwen30b_3tier();
        let q30 = ModelPreset::qwen30b_sim();
        assert_eq!(q3.n_experts, q30.n_experts);
        assert_eq!(q3.top_k, q30.top_k);
        assert_eq!(q3.paper_layers, q30.paper_layers);
        assert_eq!(q3.ladder.n_tiers(), 3);
        assert_eq!(q3.hi(), Precision::Fp16);
        assert_eq!(q3.lo(), Precision::Int2);
        assert_eq!(q3.ladder.tier(1), Precision::Int4);
    }

    #[test]
    fn by_name_roundtrip() {
        for p in ModelPreset::all() {
            assert_eq!(ModelPreset::by_name(p.name).unwrap(), p);
        }
        assert!(ModelPreset::by_name("nope").is_none());
    }

    #[test]
    fn router_keys_match_aot() {
        assert_eq!(ModelPreset::qwen30b_sim().router_key(), "e128k8");
        assert_eq!(ModelPreset::qwen80b_sim().router_key(), "e512k10");
        assert_eq!(ModelPreset::phi_sim().router_key(), "e16k2");
    }

    #[test]
    fn drift_defaults_sane_and_off() {
        let cfg = ServingConfig::default();
        assert!(!cfg.adaptive_alpha, "adaptive layer must default off");
        let d = &cfg.drift;
        assert!(d.window >= 1);
        assert!((0.0..1.0).contains(&d.threshold));
        assert!(d.noise_coeff >= 0.0);
        assert!((0.0..1.0).contains(&d.alpha));
        assert!(d.alpha < cfg.ema_alpha, "recovery α must be more reactive");
        assert!((0.0..=1.0).contains(&d.stale_decay));
        assert!(d.recovery_intervals >= 1);
        assert!(d.validate().is_ok());
        let mut bad = d.clone();
        bad.window = 0;
        assert!(bad.validate().unwrap_err().contains("drift.window"));
        let mut bad = d.clone();
        bad.alpha = 1.0;
        assert!(bad.validate().is_err());
        let mut bad = d.clone();
        bad.recovery_intervals = 0;
        assert!(bad.validate().unwrap_err().contains("recovery_intervals"));
    }

    #[test]
    fn dims_match_python() {
        assert_eq!(D_MODEL, 64);
        assert_eq!(FF_DIM, 128);
        assert_eq!(VOCAB, 256);
        assert_eq!(S_MAX, 512);
        assert_eq!(TOKEN_BUCKETS, &[1, 4, 16, 64, 256]);
    }
}
