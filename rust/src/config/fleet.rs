//! Fleet configuration (DESIGN.md §14): how many replicated serving
//! groups stand behind the shared front door, how the modeled health
//! checker grades heartbeats, and how the router weighs load against
//! hot-set affinity when placing admitted requests.

/// Parameters of a replicated serving fleet.
///
/// A fleet is `replicas` independent engine instances (each backed by a
/// `devices_per_replica`-wide `DeviceGroup`) behind one `FrontDoor`. The
/// health checker polls one modeled heartbeat per replica per serve
/// round; `degraded_after` consecutive failures mark a replica
/// `Degraded` (still serving, deprioritized by the router) and
/// `down_after` mark it `Down` (drained; in-flight work fails over).
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// Number of replicated serving groups. 1 reduces the fleet to a
    /// plain session (byte-identical, property-tested).
    pub replicas: usize,
    /// Devices inside each replica's `DeviceGroup`.
    pub devices_per_replica: usize,
    /// Consecutive heartbeat failures before a replica is `Degraded`.
    pub degraded_after: u32,
    /// Consecutive heartbeat failures before a replica is `Down`
    /// (must be ≥ `degraded_after`).
    pub down_after: u32,
    /// Router score weight on hot-set affinity (overlap between a
    /// request's expected expert set and a replica's hi-precision
    /// residents, via `ResidencyBackend::resident_overlap`).
    pub affinity_weight: f64,
    /// Router score weight on replica load (assigned + pending work).
    pub load_weight: f64,
    /// Decode-stream chunk size in tokens. `None` serves each request
    /// to completion within its round (no mid-stream failover surface);
    /// `Some(c)` yields after every `c` decode tokens so a replica
    /// failure strands resumable partial streams.
    pub stream_chunk: Option<usize>,
    /// Serve the replicas of one drain round on concurrent threads
    /// (un-chunked mode only; replicas are independent engines, outcomes
    /// fold back in replica-index order). Off by default: the serial
    /// path is the byte-identity reference the concurrent path is
    /// property-tested against (PR 7 determinism rule).
    pub parallel_drain: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        Self {
            replicas: 1,
            devices_per_replica: 1,
            degraded_after: 1,
            down_after: 2,
            affinity_weight: 1.0,
            load_weight: 4.0,
            stream_chunk: None,
            parallel_drain: false,
        }
    }
}

impl FleetConfig {
    /// Validate parameter ranges; the fleet builder surfaces these as
    /// construction errors like every other infeasible config.
    pub fn validate(&self) -> Result<(), String> {
        if self.replicas < 1 {
            return Err("fleet.replicas must be at least 1".into());
        }
        if self.devices_per_replica < 1 {
            return Err("fleet.devices_per_replica must be at least 1".into());
        }
        if self.degraded_after < 1 {
            return Err("fleet.degraded_after must be at least 1".into());
        }
        if self.down_after < self.degraded_after {
            return Err(format!(
                "fleet.down_after {} below degraded_after {} (a replica \
                 cannot go Down before it is Degraded)",
                self.down_after, self.degraded_after
            ));
        }
        if !self.affinity_weight.is_finite() || self.affinity_weight < 0.0 {
            return Err(format!(
                "fleet.affinity_weight {} must be finite and non-negative",
                self.affinity_weight
            ));
        }
        if !self.load_weight.is_finite() || self.load_weight < 0.0 {
            return Err(format!(
                "fleet.load_weight {} must be finite and non-negative",
                self.load_weight
            ));
        }
        if let Some(c) = self.stream_chunk {
            if c < 1 {
                return Err(
                    "fleet.stream_chunk must be at least 1 token".into()
                );
            }
        }
        Ok(())
    }

    /// Convenience: a chunked-streaming copy (failover tests).
    pub fn with_chunk(mut self, tokens: usize) -> Self {
        self.stream_chunk = Some(tokens);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates_and_reduces_to_single_session() {
        let cfg = FleetConfig::default();
        assert!(cfg.validate().is_ok());
        assert_eq!(cfg.replicas, 1);
        assert!(cfg.stream_chunk.is_none());
    }

    #[test]
    fn validate_rejects_bad_ranges() {
        let mut bad = FleetConfig::default();
        bad.replicas = 0;
        assert!(bad.validate().unwrap_err().contains("replicas"));

        let mut bad = FleetConfig::default();
        bad.devices_per_replica = 0;
        assert!(bad.validate().unwrap_err().contains("devices_per_replica"));

        let mut bad = FleetConfig::default();
        bad.degraded_after = 0;
        assert!(bad.validate().unwrap_err().contains("degraded_after"));

        let mut bad = FleetConfig::default();
        bad.degraded_after = 3;
        bad.down_after = 2;
        assert!(bad.validate().unwrap_err().contains("down_after"));

        let mut bad = FleetConfig::default();
        bad.affinity_weight = f64::NAN;
        assert!(bad.validate().unwrap_err().contains("affinity_weight"));

        let mut bad = FleetConfig::default();
        bad.load_weight = -1.0;
        assert!(bad.validate().unwrap_err().contains("load_weight"));

        let mut bad = FleetConfig::default();
        bad.stream_chunk = Some(0);
        assert!(bad.validate().unwrap_err().contains("stream_chunk"));
    }

    #[test]
    fn with_chunk_sets_streaming() {
        let cfg = FleetConfig::default().with_chunk(2);
        assert_eq!(cfg.stream_chunk, Some(2));
        assert!(cfg.validate().is_ok());
    }
}
