//! Front-door configuration: admission bounds, per-tenant limits, and
//! SLO classes (DESIGN.md §12).
//!
//! The request front door ([`crate::serving::frontdoor`]) is configured
//! entirely here so every bound is validated *before* any queue state
//! exists — mirroring how [`super::DriftConfig`] gates the drift layer.
//! Three priority [`Lane`]s carry one [`SloClass`] each (TTFT/TPOT
//! budgets); [`TenantLimits`] are Nexus-style soft/hard caps with a
//! configurable soft-limit [`LimitAction`].

/// Priority lane of a request class, highest priority first.
///
/// `index()` doubles as the scheduling rank (0 preempts 1 preempts 2)
/// and as the position of the lane in every per-lane counter vector
/// (`fd_lane_*` snapshot fields, bench per-lane totals).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Lane {
    /// Latency-critical traffic (chat turns): tightest budgets.
    Interactive,
    /// The default class.
    #[default]
    Standard,
    /// Throughput traffic (offline eval, batch jobs): widest budgets.
    Batch,
}

impl Lane {
    /// All lanes in rank order — the index of a lane here is its
    /// scheduling rank and its slot in per-lane counter vectors.
    pub const ALL: [Lane; 3] = [Lane::Interactive, Lane::Standard, Lane::Batch];

    /// Scheduling rank and counter-vector slot (0 = highest priority).
    pub fn index(self) -> usize {
        match self {
            Lane::Interactive => 0,
            Lane::Standard => 1,
            Lane::Batch => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Lane::Interactive => "interactive",
            Lane::Standard => "standard",
            Lane::Batch => "batch",
        }
    }

    pub fn by_name(name: &str) -> Option<Lane> {
        Lane::ALL.into_iter().find(|l| l.name() == name)
    }
}

impl std::fmt::Display for Lane {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-lane SLO budgets. Infinite budgets are legal (a lane without a
/// deadline); zero or negative budgets are rejected by
/// [`FrontDoorConfig::validate`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloClass {
    /// Time-to-first-token budget (seconds, measured from arrival). The
    /// admission deadline of a request is `arrival + ttft_budget_s`.
    pub ttft_budget_s: f64,
    /// Time-per-output-token budget (seconds) — reporting metadata for
    /// the per-lane bench columns; decode is lockstep, so the front door
    /// enforces deadlines on TTFT only.
    pub tpot_budget_s: f64,
}

/// What happens when a tenant crosses its *soft* queue-occupancy limit
/// (the hard limit always rejects — Nexus-style two-level limits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LimitAction {
    /// Count the overage and admit anyway.
    Warn,
    /// Admit, but demote the request to the [`Lane::Batch`] lane.
    Demote,
    /// Reject with `Rejected::TenantOverLimit`.
    Reject,
    /// Admit, but demote the *tenant* to best-effort QoS pricing
    /// (DESIGN.md §15). As a soft-limit action it behaves like
    /// [`LimitAction::Warn`] plus the class demotion; as a QoS
    /// budget-exhaustion action it admits instead of rejecting. Without
    /// an armed [`super::qos::QosConfig`] it is exactly `Warn`.
    Downgrade,
}

/// Per-tenant queue-occupancy limits (applied to every tenant; the
/// accounting is per tenant, the bounds are uniform).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TenantLimits {
    /// Occupancy at which `soft_action` starts applying.
    pub soft_limit: usize,
    /// What a soft-limit overage does.
    pub soft_action: LimitAction,
    /// Occupancy at which submissions are rejected outright.
    pub hard_limit: usize,
}

impl TenantLimits {
    /// No limits: `usize::MAX` caps, warn-only soft action. The
    /// degenerate configuration of the equivalence property.
    pub fn unbounded() -> Self {
        Self {
            soft_limit: usize::MAX,
            soft_action: LimitAction::Warn,
            hard_limit: usize::MAX,
        }
    }
}

/// Validated configuration of the request front door.
#[derive(Clone, Debug, PartialEq)]
pub struct FrontDoorConfig {
    /// Bound on the admission queue (total across tenants); a full queue
    /// yields `Rejected::QueueFull`, never blocking.
    pub queue_capacity: usize,
    /// Per-tenant occupancy limits.
    pub tenant_limits: TenantLimits,
    /// One SLO class per lane, indexed by [`Lane::index`].
    pub classes: [SloClass; 3],
    /// Estimated per-request service time used by the submit-time
    /// deadline-feasibility check: a request whose estimated completion
    /// (`max(now, arrival) + (queue_depth + 1) × est_service_s`) already
    /// exceeds its deadline is rejected as `DeadlineInfeasible`. Zero
    /// disables the check.
    pub est_service_s: f64,
    /// Queue age (seconds) past which a request is promoted to rank 0
    /// regardless of lane — the anti-starvation valve. Infinite disables
    /// aging (strict lane priority).
    pub starvation_age_s: f64,
    /// Order same-rank admissions least-served-tenant-first. Off, ties
    /// fall straight through to deadline/arrival order.
    pub fair_share: bool,
    /// QoS classes that price precision (DESIGN.md §15): per-tenant class
    /// pins, class hotness weights, and per-tenant precision budgets
    /// charged at admission. `None` — or a degenerate config — keeps the
    /// PR 8 front door byte-identically.
    pub qos: Option<super::qos::QosConfig>,
}

impl Default for FrontDoorConfig {
    fn default() -> Self {
        Self {
            queue_capacity: 1024,
            tenant_limits: TenantLimits {
                soft_limit: 256,
                soft_action: LimitAction::Warn,
                hard_limit: 512,
            },
            classes: [
                // interactive: chat-turn budgets
                SloClass { ttft_budget_s: 0.5, tpot_budget_s: 0.05 },
                // standard: the default class
                SloClass { ttft_budget_s: 2.5, tpot_budget_s: 0.25 },
                // batch: effectively throughput-only
                SloClass { ttft_budget_s: 30.0, tpot_budget_s: 2.0 },
            ],
            est_service_s: 0.0,
            starvation_age_s: 2.0,
            fair_share: true,
            qos: None,
        }
    }
}

impl FrontDoorConfig {
    /// The degenerate configuration: unbounded queue and tenant limits,
    /// infinite budgets, aging off. With every request in one
    /// default-class tenant, scheduling through this config is
    /// byte-identical to `ContinuousBatch` (property-tested).
    pub fn unbounded() -> Self {
        let inf = SloClass {
            ttft_budget_s: f64::INFINITY,
            tpot_budget_s: f64::INFINITY,
        };
        Self {
            queue_capacity: usize::MAX,
            tenant_limits: TenantLimits::unbounded(),
            classes: [inf; 3],
            est_service_s: 0.0,
            starvation_age_s: f64::INFINITY,
            fair_share: true,
            qos: None,
        }
    }

    /// The SLO class of a lane.
    pub fn class(&self, lane: Lane) -> SloClass {
        self.classes[lane.index()]
    }

    /// Admission deadline of a request arriving at `arrival_s` on `lane`.
    pub fn deadline(&self, lane: Lane, arrival_s: f64) -> f64 {
        arrival_s + self.class(lane).ttft_budget_s
    }

    /// Every bound checked before any queue state exists (the
    /// [`super::DriftConfig::validate`] idiom).
    pub fn validate(&self) -> Result<(), String> {
        if self.queue_capacity < 1 {
            return Err("frontdoor.queue_capacity must be at least 1".into());
        }
        let t = &self.tenant_limits;
        if t.hard_limit < 1 {
            return Err("frontdoor.hard_limit must be at least 1".into());
        }
        if t.soft_limit > t.hard_limit {
            return Err(format!(
                "frontdoor.soft_limit {} exceeds hard_limit {}",
                t.soft_limit, t.hard_limit
            ));
        }
        let bad_budget = |b: f64| b.is_nan() || b <= 0.0;
        for lane in Lane::ALL {
            let c = self.class(lane);
            if bad_budget(c.ttft_budget_s) || bad_budget(c.tpot_budget_s) {
                return Err(format!(
                    "frontdoor.{} budgets must be positive (ttft {}, \
                     tpot {})",
                    lane.name(),
                    c.ttft_budget_s,
                    c.tpot_budget_s
                ));
            }
        }
        if !self.est_service_s.is_finite() || self.est_service_s < 0.0 {
            return Err(format!(
                "frontdoor.est_service_s {} must be finite and non-negative",
                self.est_service_s
            ));
        }
        if self.starvation_age_s.is_nan() || self.starvation_age_s <= 0.0 {
            return Err(format!(
                "frontdoor.starvation_age_s {} must be positive \
                 (infinite disables aging)",
                self.starvation_age_s
            ));
        }
        if let Some(q) = &self.qos {
            q.validate()?;
        }
        Ok(())
    }

    /// Parse a CLI `--slo` class spec: comma-separated
    /// `lane=ttft:tpot` pairs (seconds), e.g.
    /// `interactive=0.2:0.02,batch=60:5`. Unnamed lanes keep their
    /// defaults.
    pub fn parse_slo_spec(spec: &str) -> Result<[SloClass; 3], String> {
        let mut classes = FrontDoorConfig::default().classes;
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (lane_s, budgets) = part.split_once('=').ok_or_else(|| {
                format!(
                    "slo spec part {part:?} must be lane=ttft:tpot (seconds)"
                )
            })?;
            let lane = Lane::by_name(lane_s.trim()).ok_or_else(|| {
                format!(
                    "unknown lane {:?}; known lanes: interactive, standard, \
                     batch",
                    lane_s.trim()
                )
            })?;
            let (ttft_s, tpot_s) =
                budgets.split_once(':').ok_or_else(|| {
                    format!(
                        "slo spec part {part:?} must be lane=ttft:tpot \
                         (seconds)"
                    )
                })?;
            let ttft: f64 = ttft_s.trim().parse().map_err(|_| {
                format!("invalid ttft budget {:?}", ttft_s.trim())
            })?;
            let tpot: f64 = tpot_s.trim().parse().map_err(|_| {
                format!("invalid tpot budget {:?}", tpot_s.trim())
            })?;
            classes[lane.index()] =
                SloClass { ttft_budget_s: ttft, tpot_budget_s: tpot };
        }
        Ok(classes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lanes_roundtrip_names_and_ranks() {
        for (rank, lane) in Lane::ALL.into_iter().enumerate() {
            assert_eq!(lane.index(), rank);
            assert_eq!(Lane::by_name(lane.name()), Some(lane));
            assert_eq!(lane.to_string(), lane.name());
        }
        assert!(Lane::by_name("vip").is_none());
        assert_eq!(Lane::default(), Lane::Standard);
    }

    #[test]
    fn default_and_unbounded_validate() {
        FrontDoorConfig::default().validate().unwrap();
        FrontDoorConfig::unbounded().validate().unwrap();
        // interactive budgets are tighter than batch budgets
        let d = FrontDoorConfig::default();
        assert!(
            d.class(Lane::Interactive).ttft_budget_s
                < d.class(Lane::Batch).ttft_budget_s
        );
        assert_eq!(
            d.deadline(Lane::Standard, 1.0),
            1.0 + d.class(Lane::Standard).ttft_budget_s
        );
    }

    #[test]
    fn validate_rejects_bad_bounds() {
        let mut c = FrontDoorConfig::default();
        c.queue_capacity = 0;
        assert!(c.validate().unwrap_err().contains("queue_capacity"));

        let mut c = FrontDoorConfig::default();
        c.tenant_limits.soft_limit = 10;
        c.tenant_limits.hard_limit = 5;
        assert!(c.validate().unwrap_err().contains("soft_limit"));

        let mut c = FrontDoorConfig::default();
        c.tenant_limits.hard_limit = 0;
        c.tenant_limits.soft_limit = 0;
        assert!(c.validate().unwrap_err().contains("hard_limit"));

        let mut c = FrontDoorConfig::default();
        c.classes[0].ttft_budget_s = 0.0;
        assert!(c.validate().unwrap_err().contains("interactive"));

        let mut c = FrontDoorConfig::default();
        c.classes[2].tpot_budget_s = f64::NAN;
        assert!(c.validate().is_err());

        let mut c = FrontDoorConfig::default();
        c.est_service_s = f64::INFINITY;
        assert!(c.validate().unwrap_err().contains("est_service_s"));

        let mut c = FrontDoorConfig::default();
        c.starvation_age_s = 0.0;
        assert!(c.validate().unwrap_err().contains("starvation_age_s"));

        let mut c = FrontDoorConfig::default();
        c.qos = Some(
            super::super::qos::QosConfig::degenerate()
                .with_weight(super::super::qos::QosClass::Premium, -1.0),
        );
        assert!(c.validate().unwrap_err().contains("premium"));
    }

    #[test]
    fn slo_spec_parses_and_rejects() {
        let classes = FrontDoorConfig::parse_slo_spec(
            "interactive=0.2:0.02, batch=60:5",
        )
        .unwrap();
        assert_eq!(classes[Lane::Interactive.index()].ttft_budget_s, 0.2);
        assert_eq!(classes[Lane::Interactive.index()].tpot_budget_s, 0.02);
        assert_eq!(classes[Lane::Batch.index()].ttft_budget_s, 60.0);
        // unnamed lanes keep their defaults
        assert_eq!(
            classes[Lane::Standard.index()],
            FrontDoorConfig::default().class(Lane::Standard)
        );
        assert!(FrontDoorConfig::parse_slo_spec("vip=1:1")
            .unwrap_err()
            .contains("known lanes"));
        assert!(FrontDoorConfig::parse_slo_spec("interactive=1").is_err());
        assert!(FrontDoorConfig::parse_slo_spec("interactive=a:b").is_err());
        assert!(FrontDoorConfig::parse_slo_spec("nonsense").is_err());
    }
}
