//! # DynaExq
//!
//! Runtime-aware mixed-precision serving for Mixture-of-Experts inference
//! under a hard HBM envelope — a reproduction of *"Dynamic Expert
//! Quantization for Scalable Mixture-of-Experts Inference"* (cs.PF 2025).
//!
//! DynaExq treats single-GPU MoE serving as an **online, budget-constrained
//! precision allocation** problem over an N-rung precision ladder: experts
//! that dominate runtime traffic hold the highest rungs, warm experts a
//! middle rung, the rest fall to the always-resident base rung (the
//! paper's binary hi/lo split is the 2-rung special case), and tier moves
//! happen asynchronously through stable expert handles so the forward pass
//! always executes on a fully materialized expert version.
//!
//! ## Layering (see DESIGN.md)
//!
//! * **L4 (serving front door)** — [`serving::session::ServeSession`]:
//!   a builder-validated session API over a pluggable
//!   [`serving::registry::BackendRegistry`] (method name → residency
//!   backend) and [`serving::scheduler::Scheduler`] (closed-batch /
//!   continuous-batching admission policies).
//! * **L3 (this crate)** — the coordinator: serving engine, continuous
//!   batcher, [`coordinator::ver`] (versioned expert residency),
//!   deterministic [`coordinator::pools`], [`coordinator::budget`],
//!   the non-blocking [`coordinator::pipeline`], and the online
//!   [`coordinator::policy`] (hotness EMA + budget-feasible top-n +
//!   hysteresis).
//! * **L2/L1 (python, build-time only)** — the JAX MoE model and Pallas
//!   dequant-GEMM kernels, AOT-lowered to HLO text under `artifacts/`,
//!   loaded and executed by [`runtime`] via the PJRT CPU client.
//!
//! The GPU (an RTX A6000-class device in the paper) is substituted by the
//! [`sim`] cost model — capacities, PCIe bandwidth and stream overlap are
//! modeled in bytes/seconds while all numerics execute for real on CPU.

pub mod baselines;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod quality;
#[cfg(feature = "numeric")]
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod testutil;
pub mod util;
pub mod workload;

pub use config::frontdoor::{FrontDoorConfig, Lane};
pub use config::{DeviceConfig, ModelPreset, ServingConfig, ShardPlan};
pub use coordinator::{Coordinator, DeviceGroup};
pub use model::PrecisionLadder;
pub use serving::engine::Engine;
pub use serving::frontdoor::{FrontDoor, Rejected, SloScheduler};
#[cfg(feature = "numeric")]
pub use serving::numeric::NumericEngine;
pub use serving::registry::{BackendCtx, BackendRegistry};
pub use serving::session::{MetricsSnapshot, ServeSession, SessionBuilder};
pub use workload::{Scenario, ScenarioPhase};
