//! DynaExq CLI: the leader entrypoint.
//!
//! Subcommands:
//! * `serve`   — run a modeled serving session and print metrics
//! * `bench`   — wall-clock serving benchmark matrix → BENCH_serving.json
//! * `report`  — regenerate one paper table/figure (`--exp t1|t2|f1|f2|f3|
//!   t4|f6|f7|f8|f9|f10|a1..a11`)
//! * `quality` — numeric quality run for one model/method
//! * `trace`   — dump routing-trace statistics for a workload
//!
//! Run `dynaexq help` for flags.

use dynaexq::cli::Args;
use dynaexq::experiments;

const HELP: &str = "\
dynaexq — runtime-aware mixed-precision MoE serving (paper reproduction)

USAGE:
    dynaexq <subcommand> [--flag value]...

SUBCOMMANDS:
    serve    Run a modeled serving session (SessionBuilder API).
               --model qwen30b-sim|qwen30b-3tier|qwen80b-sim|phi-sim
                                                         (default qwen30b-sim)
               --method dynaexq|dynaexq-adaptive|dynaexq-3tier|
                        dynaexq-sharded|dynaexq-3tier-sharded|static|
                        static-hi|fp16|static-map|expertflow|hobbit|counting
                                                         (default dynaexq)
               --workload text|math|code                 (default text)
               --scenario steady|swap|rotation|burst|multi-tenant|diurnal
                          (scripted multi-phase workload; overrides
                           --workload/--rounds, prints per-phase timeline)
               --batch N (default 8)  --prompt N (default 512)
               --output N (default 64) --rounds N (default 4)
               --seed S --warmup N (default 2)
               --devices N (default 1; sharded methods serve an N-device
                            expert-sharded group with per-device envelopes)
               --frontdoor  (route requests through the bounded admission
                            queue + SLO-aware scheduler — DESIGN.md §12;
                            typed rejections print with the report)
               --tenants N  (default 2; round-robin tenants under
                            --frontdoor without a scenario)
               --slo lane=ttft:tpot[,...]  (per-lane budgets in seconds,
                            lanes interactive|standard|batch, e.g.
                            interactive=0.2:0.02,batch=60:5)
               --queue-cap N --tenant-cap N  (front-door bounds)
               --replicas N  (N>1 serves a replicated fleet behind one
                            shared front door — DESIGN.md §14: load/
                            affinity routing, modeled health checks,
                            mid-stream failover; --devices then counts
                            devices per replica)
               --fail-replica idx@round[:recover][,...]  (scripted
                            heartbeat faults for the fleet health
                            checker, e.g. 0@2:5 downs replica 0 at
                            round 2 and recovers it at round 5;
                            implies --replicas 2)
               --chunk N  (fleet streaming chunk: decode rounds per
                            serve round; keeps requests in flight so
                            failover can catch them mid-stream)
               --parallel-drain  (serve fleet replicas on threads;
                            byte-identical to the serial path)
               --qos tiered | class=weight[:budget_bytes][,...]
                            (class-weighted allocation + per-tenant
                            hi-precision budgets — DESIGN.md §15;
                            classes premium|standard|best-effort, e.g.
                            premium=8:2000000000,best-effort=0.25;
                            `tiered` is the canned 4/1/0.25 ladder;
                            needs --frontdoor, --scenario, or
                            --replicas)
               --kv   (also print the machine-readable metrics snapshot)
    bench    Wall-clock serving benchmark matrix (DESIGN.md §11): every
             bench method × scripted scenario × {1,2}-device groups ×
             batch {1,8,32} × {direct, front-door}, timed on the host
             clock; emits the machine-readable perf trajectory
             BENCH_serving.json (front-door cells carry per-lane p50/p95
             TTFT, typed-rejection totals, and admission-path submit
             p50/p95, fanned out over a producer-thread axis {1,4}, a
             fleet-replica axis {1,2}, and a QoS axis {off, tiered}).
               --smoke  (smallest cell triple — the CI job)
               --model ...   (default qwen30b-sim; phi-sim under --smoke)
               --out path    (default BENCH_serving.json)
               --prompt N --output N --seed S
               --producers N  (override the producer-thread axis with a
                            single count; front-door cells only)
               --filter key=value[,...]  (narrow axes: method, scenario,
                            devices, batch, frontdoor, producers,
                            replicas, qos — re-run single cells without
                            the full matrix)
    report   Regenerate a paper table/figure.
               --exp t1|t2|t4|f1|f2|f3|f6..f10|a1..a11|all  [--fast]
    quality  Numeric quality run (real PJRT execution; needs a build with
             --features numeric).
               --model ... --method fp16|static|dynaexq
               --prompts N (default 8) --prompt-len N (default 64)
    trace    Router traces: statistics, recording, replay.
               --model ... --workload ... --iters N
               --record out.dxtr [--batch B --seed S]
                 [--scenario <name>]  (record a scripted scenario; --iters
                                      then counts iterations per round and
                                      defaults to 8 instead of 500)
               --replay in.dxtr [--method <any registered method>]
                 [--devices N]  (header must match the model's shape)
    help     This text.
";

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_str() {
        "serve" => experiments::cmd_serve(&args),
        "bench" => experiments::cmd_bench(&args),
        "report" => experiments::cmd_report(&args),
        "quality" => experiments::cmd_quality(&args),
        "trace" => experiments::cmd_trace(&args),
        "help" | "" => {
            print!("{HELP}");
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
