//! PJRT runtime: loads AOT artifacts (HLO text) and executes them on the
//! CPU client. This is the only module that touches the `xla` crate.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables compile lazily on first use and
//! are cached for the life of the process (one compiled executable per
//! (op, precision, bucket) — precision switching never recompiles).

pub mod artifacts;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::util::lockorder::{LockRank, OrderedMutex};

pub use artifacts::{ArtifactMeta, Manifest};

/// Cumulative runtime counters (observability + perf accounting).
#[derive(Debug, Default)]
pub struct RuntimeStats {
    pub compiles: AtomicU64,
    pub executions: AtomicU64,
    pub exec_nanos: AtomicU64,
}

impl RuntimeStats {
    pub fn snapshot(&self) -> (u64, u64, f64) {
        (
            self.compiles.load(Ordering::Relaxed), // relaxed-ok: stat counter snapshot
            self.executions.load(Ordering::Relaxed), // relaxed-ok: stat counter snapshot
            self.exec_nanos.load(Ordering::Relaxed) as f64 / 1e9, // relaxed-ok: stat counter snapshot
        )
    }
}

/// The PJRT runtime: client + manifest + lazy executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    #[allow(dead_code)] // artifact root, kept for diagnostics
    dir: PathBuf,
    exes: OrderedMutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    pub stats: RuntimeStats,
}

impl Runtime {
    /// Load the artifact directory (checks manifest dims against the crate).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        manifest.check_dims()?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self {
            client,
            manifest,
            dir: dir.to_path_buf(),
            exes: OrderedMutex::new(LockRank::RuntimeExes, HashMap::new()),
            stats: RuntimeStats::default(),
        })
    }

    /// Default artifact dir: `$DYNAEXQ_ARTIFACTS` or `./artifacts`.
    pub fn load_default() -> Result<Self> {
        let dir = std::env::var("DYNAEXQ_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(Path::new(&dir))
    }

    /// Fetch (compiling + caching on first use) an executable by unit name.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.lock().get(name) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            meta.file.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| {
            anyhow::anyhow!("parsing {}: {e:?}", meta.file.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(self.client.compile(&comp).map_err(|e| {
            anyhow::anyhow!("compiling {name}: {e:?}")
        })?);
        self.stats.compiles.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        self.exes.lock().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute a unit with literal args; returns the flattened output tuple
    /// (units are lowered with `return_tuple=True`).
    pub fn execute(
        &self,
        name: &str,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = args.iter().collect();
        self.execute_refs(name, &refs)
    }

    /// Execute with borrowed literal args (avoids moving cached weight
    /// literals on the hot path).
    pub fn execute_refs(
        &self,
        name: &str,
        args: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let t0 = std::time::Instant::now();
        let result = exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        self.stats.executions.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        self.stats
            .exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed); // relaxed-ok: stat counter
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name}: {e:?}"))
    }

    /// Execute with device-resident buffer args (hot path: weight buffers
    /// staged once via [`Runtime::buffer_f32`]/[`Runtime::buffer_u8`] skip
    /// the per-call literal→device transfer).
    pub fn execute_buffers(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(name)?;
        let t0 = std::time::Instant::now();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} result: {e:?}"))?;
        self.stats.executions.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        self.stats
            .exec_nanos
            .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed); // relaxed-ok: stat counter
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name}: {e:?}"))
    }

    /// Stage an f32 tensor on the device.
    pub fn buffer_f32(
        &self,
        data: &[f32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("buffer_f32 {dims:?}: {e:?}"))
    }

    /// Stage an i32 tensor on the device.
    pub fn buffer_i32(
        &self,
        data: &[i32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("buffer_i32 {dims:?}: {e:?}"))
    }

    /// Stage a packed u8 tensor on the device.
    ///
    /// Two crate quirks force the shape of this API:
    /// * `buffer_from_host_raw_bytes` passes `ElementType as i32` where the
    ///   C API expects `PrimitiveType` values (U8 → discriminant 5 → S64!),
    ///   so the raw-bytes path would mis-type the buffer;
    /// * `buffer_from_host_literal` (the workaround) zero-copies: the
    ///   buffer aliases the literal's storage, so the literal must stay
    ///   alive — [`U8Buffer`] owns both.
    pub fn buffer_u8(&self, data: &[u8], dims: &[usize]) -> Result<U8Buffer> {
        let idims: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        let lit = lit_u8(data, &idims)?;
        let buf = self
            .client
            .buffer_from_host_literal(None, &lit)
            .map_err(|e| anyhow::anyhow!("buffer_u8 {dims:?}: {e:?}"))?;
        Ok(U8Buffer { _keepalive: lit, buf })
    }

    /// Number of compiled (cached) executables.
    pub fn compiled_count(&self) -> usize {
        self.exes.lock().len()
    }

    /// Pre-compile a set of units (warmup; avoids first-request jitter).
    pub fn warmup<'a, I: IntoIterator<Item = &'a str>>(&self, names: I) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }
}

/// A device-staged u8 buffer owning the host literal it may alias.
pub struct U8Buffer {
    _keepalive: xla::Literal,
    pub buf: xla::PjRtBuffer,
}

impl std::ops::Deref for U8Buffer {
    type Target = xla::PjRtBuffer;

    fn deref(&self) -> &xla::PjRtBuffer {
        &self.buf
    }
}

// ---------------------------------------------------------------------------
// Literal construction / extraction helpers
// ---------------------------------------------------------------------------

/// f32 literal with shape `dims`.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    debug_assert_eq!(
        data.len() as i64,
        dims.iter().product::<i64>(),
        "shape/data mismatch"
    );
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("lit_f32 reshape {dims:?}: {e:?}"))
}

/// i32 literal with shape `dims`.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow::anyhow!("lit_i32 reshape {dims:?}: {e:?}"))
}

/// u8 literal with shape `dims` (packed quantized weights).
///
/// `Literal::vec1` lacks a u8 impl, so this goes through the untyped-bytes
/// constructor with an explicit U8 element type.
pub fn lit_u8(data: &[u8], dims: &[i64]) -> Result<xla::Literal> {
    let udims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U8,
        &udims,
        data,
    )
    .map_err(|e| anyhow::anyhow!("lit_u8 {dims:?}: {e:?}"))
}

/// 1-D i32 literal.
pub fn lit_i32_1d(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

/// Extract an f32 vec from a literal.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_f32: {e:?}"))
}

/// Extract an i32 vec from a literal.
pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    lit.to_vec::<i32>()
        .map_err(|e| anyhow::anyhow!("to_i32: {e:?}"))
}

/// Stage a literal on the device (caller keeps the literal alive if the
/// client chooses zero-copy semantics).
impl Runtime {
    pub fn buffer_from_literal(
        &self,
        lit: &xla::Literal,
    ) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow::anyhow!("buffer_from_literal: {e:?}"))
    }
}
