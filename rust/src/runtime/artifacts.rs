//! Artifact manifest: the contract emitted by `python -m compile.aot`.
//!
//! Format (tab-separated, one AOT unit per line):
//! ```text
//! #dims	d=64 f=128 v=256 s_max=512 heads=4
//! expert_int4_t16	expert_int4_t16.hlo.txt	op=expert_ffn;precision=int4;tokens=16
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::kv::parse_kv;

/// One AOT unit: a named HLO-text file plus its metadata.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub meta: BTreeMap<String, String>,
}

impl ArtifactMeta {
    pub fn op(&self) -> &str {
        self.meta.get("op").map(String::as_str).unwrap_or("")
    }

    pub fn usize_meta(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(|v| v.parse().ok())
    }
}

/// Parsed manifest: all units + the core dims they were compiled for.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub units: BTreeMap<String, ArtifactMeta>,
    pub dims: BTreeMap<String, String>,
}

impl Manifest {
    /// Load `manifest.txt` from the artifact directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut units = BTreeMap::new();
        let mut dims = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("#dims") {
                for part in rest.split_whitespace() {
                    if let Some((k, v)) = part.split_once('=') {
                        dims.insert(k.to_string(), v.to_string());
                    }
                }
                continue;
            }
            if line.starts_with('#') {
                continue;
            }
            let mut cols = line.split('\t');
            let (name, file, kv) = match (cols.next(), cols.next(), cols.next()) {
                (Some(n), Some(f), Some(k)) => (n, f, k),
                _ => bail!("manifest line {} malformed: {line:?}", ln + 1),
            };
            units.insert(
                name.to_string(),
                ArtifactMeta {
                    name: name.to_string(),
                    file: dir.join(file),
                    meta: parse_kv(kv),
                },
            );
        }
        if units.is_empty() {
            bail!("manifest has no units");
        }
        Ok(Self { units, dims })
    }

    /// Sanity-check the manifest dims against this crate's compiled-in dims.
    pub fn check_dims(&self) -> Result<()> {
        let want = [
            ("d", crate::config::D_MODEL),
            ("f", crate::config::FF_DIM),
            ("v", crate::config::VOCAB),
            ("s_max", crate::config::S_MAX),
            ("heads", crate::config::N_HEADS),
        ];
        for (k, v) in want {
            match self.dims.get(k).and_then(|s| s.parse::<usize>().ok()) {
                Some(got) if got == v => {}
                Some(got) => bail!(
                    "artifact dim mismatch: {k}={got} but crate expects {v}; \
                     re-run `make artifacts`"
                ),
                None => bail!("manifest missing dim {k}"),
            }
        }
        Ok(())
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        self.units
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "#dims\td=64 f=128 v=256 s_max=512 heads=4\n\
        embed_t1\tembed_t1.hlo.txt\top=embed;tokens=1\n\
        expert_int4_t16\texpert_int4_t16.hlo.txt\top=expert_ffn;precision=int4;tokens=16\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert_eq!(m.units.len(), 2);
        m.check_dims().unwrap();
        let u = m.get("expert_int4_t16").unwrap();
        assert_eq!(u.op(), "expert_ffn");
        assert_eq!(u.usize_meta("tokens"), Some(16));
        assert_eq!(u.file, Path::new("/a/expert_int4_t16.hlo.txt"));
    }

    #[test]
    fn rejects_bad_dims() {
        let text = "#dims\td=32 f=128 v=256 s_max=512 heads=4\nx\tx.hlo\top=x\n";
        let m = Manifest::parse(text, Path::new("/a")).unwrap();
        assert!(m.check_dims().is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(Manifest::parse("#dims\td=64\n", Path::new("/")).is_err());
    }

    #[test]
    fn missing_unit_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/a")).unwrap();
        assert!(m.get("nope").is_err());
    }
}
