//! Router-trace recording and replay.
//!
//! DynaExq's policy consumes *router traces* — sequences of per-iteration
//! (layer, expert) selections. This module gives them a durable form: a
//! compact binary format for capturing traces from either engine, and a
//! replayer that feeds a recorded trace back through any
//! [`ResidencyBackend`] (offline policy experiments: replay production
//! traffic against candidate configurations without re-running the model).
//!
//! Format (little-endian):
//! ```text
//! magic "DXTR" | u32 version | u32 n_layers | u32 n_experts
//! per iteration: u32 layer | u32 count | count × u32 expert
//! (layer == u32::MAX marks an iteration boundary / tick)
//! ```

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

const MAGIC: &[u8; 4] = b"DXTR";
const VERSION: u32 = 1;
const TICK_MARK: u32 = u32::MAX;

/// One recorded routing event.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceEvent {
    /// Router selections for one layer within an iteration.
    Routing { layer: u32, experts: Vec<u32> },
    /// Iteration boundary (the engine's tick).
    Tick,
}

/// An in-memory trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub n_layers: u32,
    pub n_experts: u32,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new(n_layers: usize, n_experts: usize) -> Self {
        Self {
            n_layers: n_layers as u32,
            n_experts: n_experts as u32,
            events: Vec::new(),
        }
    }

    pub fn record(&mut self, layer: usize, experts: &[usize]) {
        self.events.push(TraceEvent::Routing {
            layer: layer as u32,
            experts: experts.iter().map(|&e| e as u32).collect(),
        });
    }

    pub fn tick(&mut self) {
        self.events.push(TraceEvent::Tick);
    }

    /// Total routing selections recorded.
    pub fn selections(&self) -> usize {
        self.events
            .iter()
            .map(|e| match e {
                TraceEvent::Routing { experts, .. } => experts.len(),
                TraceEvent::Tick => 0,
            })
            .sum()
    }

    /// Serialize to the binary format.
    pub fn write_to<W: Write>(&self, w: &mut W) -> Result<()> {
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.n_layers.to_le_bytes())?;
        w.write_all(&self.n_experts.to_le_bytes())?;
        for ev in &self.events {
            match ev {
                TraceEvent::Routing { layer, experts } => {
                    w.write_all(&layer.to_le_bytes())?;
                    w.write_all(&(experts.len() as u32).to_le_bytes())?;
                    for e in experts {
                        w.write_all(&e.to_le_bytes())?;
                    }
                }
                TraceEvent::Tick => {
                    w.write_all(&TICK_MARK.to_le_bytes())?;
                    w.write_all(&0u32.to_le_bytes())?;
                }
            }
        }
        Ok(())
    }

    /// Parse from the binary format (validates layer/expert ranges).
    pub fn read_from<R: Read>(r: &mut R) -> Result<Self> {
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic).context("trace: missing magic")?;
        if &magic != MAGIC {
            bail!("trace: bad magic {magic:?}");
        }
        let mut u32buf = [0u8; 4];
        let mut read_u32 = |r: &mut R| -> Result<u32> {
            r.read_exact(&mut u32buf)?;
            Ok(u32::from_le_bytes(u32buf))
        };
        let version = read_u32(r)?;
        if version != VERSION {
            bail!("trace: unsupported version {version}");
        }
        let n_layers = read_u32(r)?;
        let n_experts = read_u32(r)?;
        let mut events = Vec::new();
        loop {
            let layer = match read_u32(r) {
                Ok(v) => v,
                Err(_) => break, // EOF
            };
            let count = read_u32(r)?;
            if layer == TICK_MARK {
                events.push(TraceEvent::Tick);
                continue;
            }
            if layer >= n_layers {
                bail!("trace: layer {layer} out of range ({n_layers})");
            }
            let mut experts = Vec::with_capacity(count as usize);
            for _ in 0..count {
                let e = read_u32(r)?;
                if e >= n_experts {
                    bail!("trace: expert {e} out of range ({n_experts})");
                }
                experts.push(e);
            }
            events.push(TraceEvent::Routing { layer, experts });
        }
        Ok(Self { n_layers, n_experts, events })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        self.write_to(&mut f)
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        Self::read_from(&mut f)
    }

    /// Reject replay against a mismatched model shape: the header must
    /// agree with the target backend's `(n_layers, n_experts)` exactly — a
    /// larger trace would index out of range inside the backend's tables,
    /// a smaller one would silently leave experts untracked.
    pub fn check_matches(
        &self,
        n_layers: usize,
        n_experts: usize,
    ) -> Result<()> {
        if self.n_layers as usize != n_layers
            || self.n_experts as usize != n_experts
        {
            bail!(
                "trace header ({} layers × {} experts) does not match the \
                 target backend's preset ({n_layers} layers × {n_experts} \
                 experts); replaying a mismatched trace would index out of \
                 range",
                self.n_layers,
                self.n_experts,
            );
        }
        Ok(())
    }

    /// Replay through a residency backend at `seconds_per_tick` cadence;
    /// returns the modeled end time. Staging is quiesced before every tick
    /// (see [`ResidencyBackend::sync_staging`]), so two replays of the
    /// same trace through freshly built backends are byte-stable — the
    /// conformance suite's determinism golden test relies on this.
    ///
    /// [`ResidencyBackend::sync_staging`]:
    /// crate::serving::backend::ResidencyBackend::sync_staging
    pub fn replay(
        &self,
        backend: &mut dyn crate::serving::backend::ResidencyBackend,
        seconds_per_tick: f64,
    ) -> f64 {
        let mut now = 0.0;
        let mut scratch: Vec<usize> = Vec::new();
        for ev in &self.events {
            match ev {
                TraceEvent::Routing { layer, experts } => {
                    scratch.clear();
                    scratch.extend(experts.iter().map(|&e| e as usize));
                    backend.record_routing(*layer as usize, &scratch);
                    for &e in &scratch {
                        backend.resolve(*layer as usize, e, now);
                    }
                }
                TraceEvent::Tick => {
                    now += seconds_per_tick;
                    backend.sync_staging();
                    now += backend.tick(now);
                }
            }
        }
        now
    }
}

/// Capture a trace from the modeled routing sampler (synthetic trace
/// generation for offline experiments): one stationary phase of the
/// scenario recorder — [`super::Scenario::synthesize_trace`] is the
/// single implementation of the DXTR sampling loop, so workload- and
/// scenario-recorded traces can never drift apart.
pub fn synthesize(
    profile: &super::WorkloadProfile,
    n_layers: usize,
    n_experts: usize,
    top_k: usize,
    batch: usize,
    iterations: usize,
    seed: u64,
) -> Trace {
    super::Scenario::named(profile.name)
        .phase(profile.name, profile.clone(), 1)
        .synthesize_trace(n_layers, n_experts, top_k, batch, iterations, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Precision;
    use crate::serving::backend::{CountingBackend, ResidencyBackend};
    use crate::workload::WorkloadProfile;

    #[test]
    fn roundtrip_binary() {
        let mut t = Trace::new(4, 16);
        t.record(0, &[1, 5, 5]);
        t.tick();
        t.record(3, &[15]);
        t.tick();
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let t2 = Trace::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(t, t2);
        assert_eq!(t2.selections(), 4);
    }

    #[test]
    fn rejects_corrupt() {
        assert!(Trace::read_from(&mut &b"XXXX"[..]).is_err());
        // out-of-range expert
        let mut t = Trace::new(1, 4);
        t.record(0, &[9]); // invalid but recordable
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        assert!(Trace::read_from(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn replay_feeds_backend() {
        let mut t = Trace::new(2, 8);
        t.record(0, &[1, 1, 2]);
        t.tick();
        t.record(1, &[7]);
        t.tick();
        let mut b = CountingBackend::new(2, 8, Precision::Fp16);
        let end = t.replay(&mut b, 0.5);
        assert_eq!(end, 1.0);
        assert_eq!(b.counts_view().unwrap()[0][1], 2);
        assert_eq!(b.counts_view().unwrap()[1][7], 1);
    }

    #[test]
    fn mismatched_header_rejected_before_replay() {
        let t = Trace::new(2, 8);
        assert!(t.check_matches(2, 8).is_ok());
        let err = t.check_matches(4, 8).unwrap_err().to_string();
        assert!(err.contains("does not match"), "{err}");
        assert!(err.contains("out of range"), "{err}");
        assert!(t.check_matches(2, 16).is_err());
        assert!(t.check_matches(2, 4).is_err(), "smaller preset rejected too");
    }

    #[test]
    fn synthesized_trace_statistics() {
        let t = synthesize(&WorkloadProfile::text(), 4, 128, 8, 8, 10, 1);
        assert_eq!(t.selections(), 10 * 4 * 8 * 8);
        assert_eq!(
            t.events.iter().filter(|e| **e == TraceEvent::Tick).count(),
            10
        );
    }

    #[test]
    fn file_roundtrip() {
        let t = synthesize(&WorkloadProfile::math(), 2, 16, 2, 4, 5, 3);
        let path = std::env::temp_dir().join("dynaexq_trace_test.dxtr");
        t.save(&path).unwrap();
        let t2 = Trace::load(&path).unwrap();
        assert_eq!(t, t2);
        let _ = std::fs::remove_file(&path);
    }
}
