//! Serving requests and generators.

use crate::util::XorShiftRng;

use super::profile::WorkloadProfile;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// Token ids of the prompt (numeric engine) — empty in modeled runs
    /// where only `prompt_len` matters.
    pub prompt: Vec<i32>,
    pub prompt_len: usize,
    /// Number of tokens to generate.
    pub output_len: usize,
    /// Modeled arrival time in seconds.
    pub arrival_s: f64,
    /// Workload the request belongs to (routing statistics tag).
    pub workload: &'static str,
}

/// Generates request batches for experiments.
pub struct RequestGenerator {
    profile: WorkloadProfile,
    rng: XorShiftRng,
    next_id: u64,
    /// If true, synthesize concrete prompt tokens (numeric engine).
    pub materialize_tokens: bool,
}

impl RequestGenerator {
    pub fn new(profile: WorkloadProfile, seed: u64) -> Self {
        Self {
            profile,
            rng: XorShiftRng::new(seed),
            next_id: 0,
            materialize_tokens: false,
        }
    }

    pub fn profile(&self) -> &WorkloadProfile {
        &self.profile
    }

    /// Switch profiles mid-stream (workload shift experiments).
    pub fn set_profile(&mut self, profile: WorkloadProfile) {
        self.profile = profile;
    }

    /// One request with fixed lengths, arriving at `arrival_s`.
    pub fn request(
        &mut self,
        prompt_len: usize,
        output_len: usize,
        arrival_s: f64,
    ) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        let prompt = if self.materialize_tokens {
            self.profile.sample_prompt(&mut self.rng, prompt_len)
        } else {
            Vec::new()
        };
        Request {
            id,
            prompt,
            prompt_len,
            output_len,
            arrival_s,
            workload: self.profile.name,
        }
    }

    /// A batch of `n` identical-shape requests arriving together.
    pub fn batch(
        &mut self,
        n: usize,
        prompt_len: usize,
        output_len: usize,
        arrival_s: f64,
    ) -> Vec<Request> {
        (0..n)
            .map(|_| self.request(prompt_len, output_len, arrival_s))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_monotone() {
        let mut g = RequestGenerator::new(WorkloadProfile::text(), 7);
        let a = g.request(16, 4, 0.0);
        let b = g.request(16, 4, 0.0);
        assert_eq!(b.id, a.id + 1);
        assert!(a.prompt.is_empty(), "tokens off by default");
    }

    #[test]
    fn materialized_prompts() {
        let mut g = RequestGenerator::new(WorkloadProfile::math(), 7);
        g.materialize_tokens = true;
        let r = g.request(64, 8, 0.5);
        assert_eq!(r.prompt.len(), 64);
        assert_eq!(r.prompt_len, 64);
        assert_eq!(r.arrival_s, 0.5);
        assert_eq!(r.workload, "math");
    }

    #[test]
    fn batch_shapes() {
        let mut g = RequestGenerator::new(WorkloadProfile::code(), 7);
        let b = g.batch(8, 32, 16, 1.0);
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|r| r.prompt_len == 32 && r.output_len == 16));
    }
}
