//! Modeled routing: topic-clustered Zipf expert sampling.
//!
//! Used by the modeled engine (performance experiments) where running the
//! real router at batch 32 × 4K tokens would be wasteful: routing outcomes
//! are *sampled* from a workload-derived distribution instead, preserving
//! the statistics those experiments measure. Quality experiments never use
//! this path — they run the real router.
//!
//! Generative model (calibrated against the paper's Tables 1–2 / Fig. 2):
//!
//! * each workload owns a per-layer expert-popularity permutation;
//! * each **request** gets a deterministic *topic rotation* of that
//!   ranking, drawn Zipf-skewed toward the head — requests cluster on
//!   popular topics, so long-horizon traffic is heavy-tailed and the
//!   workload's top experts are stable (Fig. 2);
//! * a token's draw is, with probability `local_mix`, a sharp Zipf pick
//!   from a **truncated window** of the request's rotated ranking (tokens
//!   of one request reuse a small expert set → prefill of one prompt stays
//!   ≈ window-sized), otherwise a pick from the workload-global Zipf;
//! * unions across *distinct* requests grow fast (different rotations) —
//!   activation densifies with batch size exactly as in Table 1.

use crate::util::XorShiftRng;

use super::profile::WorkloadProfile;

/// Per-(workload, layer) expert sampler.
pub struct RoutingSampler {
    n_experts: usize,
    top_k: usize,
    local_mix: f64,
    /// Request-local window size (experts a single request draws from).
    window: usize,
    /// Topic-rotation skew (how strongly requests cluster on hot topics).
    topic_zipf: f64,
    seed: u64,
    /// Global popularity: perm[rank] = expert id (rank 0 = hottest).
    perms: Vec<Vec<usize>>,
    cdf_global: Vec<f64>,
    /// Local CDF truncated to the window.
    cdf_local: Vec<f64>,
    cdf_topic: Vec<f64>,
}

fn zipf_cdf(n: usize, s: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (1..=n).map(|r| 1.0 / (r as f64).powf(s)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in &mut w {
        acc += *x / total;
        *x = acc;
    }
    w
}

fn draw_rank(rng: &mut XorShiftRng, cdf: &[f64]) -> usize {
    let u = rng.next_f64();
    cdf.partition_point(|&c| c < u).min(cdf.len() - 1)
}

impl RoutingSampler {
    pub fn new(
        profile: &WorkloadProfile,
        n_layers: usize,
        n_experts: usize,
        top_k: usize,
    ) -> Self {
        // A *shared* base permutation per layer (same for every workload),
        // rotated by `workload_idx · E/3`: each workload's popularity head
        // lands on a disjoint expert block — the paper's Fig. 2 shows the
        // top-10 hot sets of text/math/code are entirely disjoint. The
        // profile's `rot_frac` adds a scripted extra rotation on top
        // (scenario DSL: gradual hot-set drift).
        let extra = (profile.rot_frac * n_experts as f64).round() as usize;
        let offset =
            (profile.workload_idx * n_experts / 3 + extra) % n_experts.max(1);
        let perms = (0..n_layers)
            .map(|l| {
                let mut base: Vec<usize> = (0..n_experts).collect();
                let mut r =
                    XorShiftRng::new(0x5EED ^ ((l as u64 + 1) * 0x9E37_79B9));
                r.shuffle(&mut base);
                base.rotate_left(offset);
                base
            })
            .collect();
        // Window ≈ a quarter of the expert pool, at least 2·top_k.
        let window = (n_experts / 4).max(2 * top_k).min(n_experts);
        Self {
            n_experts,
            top_k,
            local_mix: profile.local_mix,
            window,
            topic_zipf: 1.0,
            seed: profile.seed,
            perms,
            cdf_global: zipf_cdf(n_experts, profile.zipf_global),
            cdf_local: zipf_cdf(window, profile.zipf_local),
            cdf_topic: zipf_cdf(n_experts, 1.0),
        }
    }

    /// Deterministic topic rotation of a request (stable across steps and
    /// layers, Zipf-skewed toward the ranking head).
    fn rotation(&self, request_tag: u64) -> usize {
        let mut r = XorShiftRng::new(
            self.seed ^ request_tag.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        draw_rank(&mut r, &self.cdf_topic)
    }

    /// Top-k expert ids for one token of request `request_tag` at `layer`.
    ///
    /// Allocates a fresh `Vec` per call — convenience for tests and
    /// offline calibration. The serving engine's inner loop uses
    /// [`RoutingSampler::sample_topk_into`] with a reused scratch buffer
    /// instead (one allocation per engine, not one per routed token);
    /// both paths draw the identical RNG sequence and expert order.
    pub fn sample_topk(
        &self,
        rng: &mut XorShiftRng,
        request_tag: u64,
        layer: usize,
    ) -> Vec<usize> {
        let mut picked = Vec::with_capacity(self.top_k);
        self.sample_topk_into(rng, request_tag, layer, &mut picked);
        picked
    }

    /// [`RoutingSampler::sample_topk`] into a caller-provided scratch
    /// buffer: `out` is cleared and filled with exactly `top_k` distinct
    /// expert ids. The hot-path variant — no per-token allocation.
    pub fn sample_topk_into(
        &self,
        rng: &mut XorShiftRng,
        request_tag: u64,
        layer: usize,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        let perm = &self.perms[layer % self.perms.len()];
        let rot = self.rotation(request_tag);
        let mut attempts = 0;
        while out.len() < self.top_k && attempts < self.top_k * 20 {
            attempts += 1;
            let e = if rng.next_f64() < self.local_mix {
                let rank = draw_rank(rng, &self.cdf_local);
                perm[(rot + rank) % self.n_experts]
            } else {
                perm[draw_rank(rng, &self.cdf_global)]
            };
            if !out.contains(&e) {
                out.push(e);
            }
        }
        // Degenerate fallback: fill with the first unpicked experts.
        let mut next = 0;
        while out.len() < self.top_k {
            if !out.contains(&next) {
                out.push(next);
            }
            next += 1;
        }
    }

    /// The globally hottest `n` experts of a layer (ground truth for tests).
    pub fn global_top(&self, layer: usize, n: usize) -> Vec<usize> {
        self.perms[layer % self.perms.len()][..n].to_vec()
    }

    pub fn top_k(&self) -> usize {
        self.top_k
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Override calibration knobs (ablations/tests).
    pub fn set_topic_zipf(&mut self, s: f64) {
        self.topic_zipf = s;
        self.cdf_topic = zipf_cdf(self.n_experts, s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;
    use std::collections::HashSet;

    fn sampler(profile: WorkloadProfile) -> RoutingSampler {
        RoutingSampler::new(&profile, 4, 128, 8)
    }

    #[test]
    fn topk_distinct_and_in_range() {
        let s = sampler(WorkloadProfile::text());
        let mut rng = XorShiftRng::new(5);
        for tag in 0..100 {
            let picks = s.sample_topk(&mut rng, tag, 0);
            assert_eq!(picks.len(), 8);
            let set: HashSet<_> = picks.iter().collect();
            assert_eq!(set.len(), 8, "picks must be distinct");
            assert!(picks.iter().all(|&e| e < 128));
        }
    }

    #[test]
    fn cumulative_counts_heavy_tailed() {
        // Fig. 2 property: a small hot set dominates cumulative counts.
        let s = sampler(WorkloadProfile::text());
        let mut rng = XorShiftRng::new(9);
        let mut counts = vec![0u64; 128];
        for tag in 0..500 {
            for _ in 0..32 {
                for e in s.sample_topk(&mut rng, tag, 0) {
                    counts[e] += 1;
                }
            }
        }
        let total: u64 = counts.iter().sum();
        let mut sorted = counts.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let top16: u64 = sorted[..16].iter().sum();
        assert!(
            top16 as f64 > 0.35 * total as f64,
            "top-12.5% of experts should carry >35% of traffic ({} / {})",
            top16,
            total
        );
    }

    #[test]
    fn workloads_have_disjoint_hot_heads() {
        // Fig. 2 property: top-10 hot sets disjoint across workloads.
        let mut tops = Vec::new();
        for p in WorkloadProfile::all() {
            let s = sampler(p);
            let mut rng = XorShiftRng::new(1);
            let mut counts = vec![0u64; 128];
            for tag in 0..400 {
                for e in s.sample_topk(&mut rng, tag, 0) {
                    counts[e] += 1;
                }
            }
            let mut idx: Vec<usize> = (0..128).collect();
            idx.sort_by_key(|&e| std::cmp::Reverse(counts[e]));
            tops.push(idx[..10].iter().copied().collect::<HashSet<_>>());
        }
        let i01 = tops[0].intersection(&tops[1]).count();
        let i02 = tops[0].intersection(&tops[2]).count();
        let i12 = tops[1].intersection(&tops[2]).count();
        assert!(
            i01 + i02 + i12 <= 3,
            "hot heads should be (near-)disjoint: {i01} {i02} {i12}"
        );
    }

    #[test]
    fn within_request_narrower_than_across() {
        // Densification property: one request's tokens reuse few experts;
        // many requests union into a much larger set.
        let s = sampler(WorkloadProfile::code());
        let mut rng = XorShiftRng::new(3);
        let mut one_request = HashSet::new();
        for _ in 0..256 {
            one_request.extend(s.sample_topk(&mut rng, 42, 0));
        }
        let mut many_requests = HashSet::new();
        for tag in 0..256 {
            many_requests.extend(s.sample_topk(&mut rng, tag, 0));
        }
        assert!(
            one_request.len() + 10 < many_requests.len(),
            "one req {} vs many {}",
            one_request.len(),
            many_requests.len()
        );
        // and the one-request set is window-bounded (+ global spillover)
        assert!(one_request.len() < s.window() + 40);
    }

    #[test]
    fn rotation_stable_per_request() {
        let s = sampler(WorkloadProfile::text());
        assert_eq!(s.rotation(7), s.rotation(7));
        // different requests usually rotate differently
        let distinct: HashSet<usize> =
            (0..50).map(|t| s.rotation(t)).collect();
        assert!(distinct.len() > 10);
    }

    #[test]
    fn rotated_profile_shifts_the_hot_head() {
        // A quarter-pool rotation relabels the ranking head: the rotated
        // sampler's top experts are the base ranking shifted by E/4, and a
        // zero rotation is the identity.
        let base = sampler(WorkloadProfile::text());
        let same = sampler(WorkloadProfile::text().rotated(0.0));
        assert_eq!(base.global_top(0, 10), same.global_top(0, 10));
        let quarter = sampler(WorkloadProfile::text().rotated(0.25));
        assert_ne!(base.global_top(0, 10), quarter.global_top(0, 10));
        // rank r of the rotated sampler is rank r + E/4 of the base one
        assert_eq!(quarter.global_top(0, 1)[0], {
            let mut top33 = base.global_top(0, 33);
            top33.pop().unwrap()
        });
    }

    #[test]
    fn flash_crowd_concentrates_traffic() {
        let base = sampler(WorkloadProfile::text());
        let crowd = sampler(WorkloadProfile::text().flash_crowd());
        let share = |s: &RoutingSampler| {
            let mut rng = XorShiftRng::new(17);
            let mut counts = vec![0u64; 128];
            for tag in 0..300 {
                for e in s.sample_topk(&mut rng, tag, 0) {
                    counts[e] += 1;
                }
            }
            let total: u64 = counts.iter().sum();
            let mut sorted = counts;
            sorted.sort_unstable_by(|a, b| b.cmp(a));
            sorted[..4].iter().sum::<u64>() as f64 / total as f64
        };
        assert!(
            share(&crowd) > 1.5 * share(&base),
            "flash crowd must pile onto the head: {} vs {}",
            share(&crowd),
            share(&base)
        );
    }

    #[test]
    fn scratch_variant_matches_allocating_path() {
        // The hot-path scratch buffer must draw the identical RNG stream
        // and expert order as the allocating convenience wrapper, even
        // when the buffer is reused (dirty) across calls.
        for p in WorkloadProfile::all() {
            let s = sampler(p);
            let mut rng_a = XorShiftRng::new(0xB0B);
            let mut rng_b = XorShiftRng::new(0xB0B);
            let mut scratch = vec![999usize; 32]; // deliberately dirty
            for tag in 0..200u64 {
                let layer = (tag % 4) as usize;
                let fresh = s.sample_topk(&mut rng_a, tag, layer);
                s.sample_topk_into(&mut rng_b, tag, layer, &mut scratch);
                assert_eq!(fresh, scratch, "tag {tag}");
            }
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG streams");
        }
    }

    #[test]
    fn prop_zipf_cdf_valid() {
        let mut prop = Prop::new("zipf_cdf");
        prop.run(20, |rng| {
            let n = 2 + rng.below(500);
            let s = rng.range_f64(0.1, 3.0);
            let cdf = zipf_cdf(n, s);
            assert_eq!(cdf.len(), n);
            assert!((cdf[n - 1] - 1.0).abs() < 1e-9);
            for i in 1..n {
                assert!(cdf[i] >= cdf[i - 1]);
            }
        });
    }
}
