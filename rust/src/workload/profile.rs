//! Workload profiles (text / math / code analogues).

use crate::util::XorShiftRng;

/// One synthetic workload.
#[derive(Clone, Debug)]
pub struct WorkloadProfile {
    pub name: &'static str,
    /// Identity seed: derives per-layer expert-popularity permutations and
    /// the byte distribution.
    pub seed: u64,
    /// Zipf exponent of the *global* (long-horizon) expert popularity.
    pub zipf_global: f64,
    /// Zipf exponent of the *request-local* preference (sharper).
    pub zipf_local: f64,
    /// Probability a token's routing draw uses the request-local ranking.
    pub local_mix: f64,
    /// Workload index (0, 1, 2): offsets this workload's popularity
    /// ranking by `idx · E/3` within the shared per-layer permutation, so
    /// the top-10 hot sets of distinct workloads are disjoint **by
    /// construction** (the paper's Fig. 2 observation).
    pub workload_idx: usize,
    /// Extra rotation of the popularity ranking as a fraction of the
    /// expert pool (0 = the workload's native ranking). The scenario DSL
    /// uses this to script *gradual* hot-set rotation — each step shifts
    /// the ranking head a few positions instead of swapping it wholesale.
    pub rot_frac: f64,
    /// Unnormalized byte weights for prompt synthesis (numeric engine).
    pub byte_weights: Vec<f64>,
}

fn byte_dist(ranges: &[(u8, u8, f64)]) -> Vec<f64> {
    let mut w = vec![0.01; 256]; // small floor: every byte possible
    for &(lo, hi, weight) in ranges {
        for b in lo..=hi {
            w[b as usize] = weight;
        }
    }
    w
}

impl WorkloadProfile {
    /// WikiText analogue: prose bytes.
    pub fn text() -> Self {
        Self {
            name: "text",
            workload_idx: 0,
            rot_frac: 0.0,
            seed: 0x7e47,
            zipf_global: 1.8,
            zipf_local: 1.2,
            local_mix: 0.85,
            byte_weights: byte_dist(&[
                (b'a', b'z', 8.0),
                (b'A', b'Z', 1.0),
                (b' ', b' ', 12.0),
                (b'.', b'.', 1.0),
                (b',', b',', 1.0),
            ]),
        }
    }

    /// GSM8K analogue: digits and arithmetic.
    pub fn math() -> Self {
        Self {
            name: "math",
            workload_idx: 1,
            rot_frac: 0.0,
            seed: 0x3a7b,
            zipf_global: 1.8,
            zipf_local: 1.2,
            local_mix: 0.85,
            byte_weights: byte_dist(&[
                (b'0', b'9', 10.0),
                (b'+', b'+', 3.0),
                (b'-', b'-', 3.0),
                (b'*', b'*', 3.0),
                (b'/', b'/', 3.0),
                (b'=', b'=', 4.0),
                (b'(', b')', 2.0),
                (b' ', b' ', 8.0),
                (b'a', b'z', 1.5),
            ]),
        }
    }

    /// HumanEval analogue: code-ish bytes.
    pub fn code() -> Self {
        Self {
            name: "code",
            workload_idx: 2,
            rot_frac: 0.0,
            seed: 0xc0de,
            zipf_global: 1.8,
            zipf_local: 1.2,
            local_mix: 0.85,
            byte_weights: byte_dist(&[
                (b'a', b'z', 5.0),
                (b'_', b'_', 4.0),
                (b'{', b'}', 3.0),
                (b'(', b')', 4.0),
                (b';', b';', 3.0),
                (b'=', b'=', 3.0),
                (b'<', b'>', 2.0),
                (b'0', b'9', 2.0),
                (b' ', b' ', 6.0),
                (b'\n', b'\n', 3.0),
            ]),
        }
    }

    pub fn all() -> Vec<Self> {
        vec![Self::text(), Self::math(), Self::code()]
    }

    pub fn by_name(name: &str) -> Option<Self> {
        Self::all().into_iter().find(|p| p.name == name)
    }

    /// Sample a prompt of `len` bytes (numeric engine input).
    pub fn sample_prompt(&self, rng: &mut XorShiftRng, len: usize) -> Vec<i32> {
        (0..len)
            .map(|_| rng.weighted(&self.byte_weights) as i32)
            .collect()
    }

    /// A copy whose popularity ranking is rotated `frac` of the expert
    /// pool further along the shared per-layer permutation (wraps at 1.0).
    /// `rotated(0.0)` is the identity; the scenario DSL chains small steps
    /// to script a gradually drifting hot set.
    pub fn rotated(&self, frac: f64) -> Self {
        let mut p = self.clone();
        p.rot_frac = (self.rot_frac + frac).rem_euclid(1.0);
        p
    }

    /// A flash-crowd copy: the global Zipf sharpens hard and the
    /// request-local window loses its weight, so routing mass collapses
    /// onto the head few experts of the ranking — the scenario DSL's
    /// burst-on-a-few-experts phase.
    pub fn flash_crowd(&self) -> Self {
        let mut p = self.clone();
        p.zipf_global = 4.0;
        p.local_mix = 0.1;
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_distinct() {
        let (t, m, c) = (
            WorkloadProfile::text(),
            WorkloadProfile::math(),
            WorkloadProfile::code(),
        );
        assert_ne!(t.seed, m.seed);
        assert_ne!(m.seed, c.seed);
        assert_ne!(t.byte_weights, m.byte_weights);
    }

    #[test]
    fn prompt_sampling_follows_distribution() {
        let p = WorkloadProfile::math();
        let mut rng = XorShiftRng::new(1);
        let prompt = p.sample_prompt(&mut rng, 4000);
        assert_eq!(prompt.len(), 4000);
        let digits = prompt
            .iter()
            .filter(|&&b| (b as u8).is_ascii_digit())
            .count();
        let letters = prompt
            .iter()
            .filter(|&&b| (b as u8).is_ascii_lowercase())
            .count();
        assert!(digits > letters, "math workload should be digit-heavy");
        assert!(prompt.iter().all(|&b| (0..256).contains(&b)));
    }

    #[test]
    fn by_name_roundtrip() {
        for p in WorkloadProfile::all() {
            assert_eq!(WorkloadProfile::by_name(p.name).unwrap().seed, p.seed);
        }
        assert!(WorkloadProfile::by_name("nope").is_none());
    }

    #[test]
    fn rotation_accumulates_and_wraps() {
        let p = WorkloadProfile::text();
        assert_eq!(p.rot_frac, 0.0);
        let r = p.rotated(0.25).rotated(0.25);
        assert!((r.rot_frac - 0.5).abs() < 1e-12);
        let wrapped = r.rotated(0.75);
        assert!((wrapped.rot_frac - 0.25).abs() < 1e-12);
        // identity rotation leaves everything else alone
        let same = p.rotated(0.0);
        assert_eq!(same.seed, p.seed);
        assert_eq!(same.rot_frac, 0.0);
    }

    #[test]
    fn flash_crowd_sharpens_global_skew() {
        let p = WorkloadProfile::math();
        let f = p.flash_crowd();
        assert!(f.zipf_global > p.zipf_global);
        assert!(f.local_mix < p.local_mix);
        // identity (seed, ranking) is preserved — the crowd rushes the
        // same workload's head experts
        assert_eq!(f.seed, p.seed);
        assert_eq!(f.workload_idx, p.workload_idx);
    }
}
