//! Workload synthesis: token streams for the numeric engine and routing
//! traces for the modeled engine.
//!
//! The paper's three evaluation workloads (WikiText = text, GSM8K = math,
//! HumanEval = code) are substituted by three profiles with:
//!
//! * **distinct byte distributions** — so the numeric engine's *real*
//!   router develops workload-specific hot sets organically;
//! * **distinct expert-popularity permutations** — so the modeled engine's
//!   sampled routing reproduces the paper's long-horizon skew (Fig. 2:
//!   heavy-tailed cumulative counts, disjoint top-10 across workloads);
//! * **request-local routing correlation** — tokens within one request
//!   prefer a request-specific rotation of the popularity ranking, which
//!   reproduces densification: one prompt touches few experts repeatedly,
//!   while a batch of independent requests unions into a much larger
//!   working set (Tables 1–2).
//!
//! Non-stationary traffic is scripted through [`scenario`]: composable
//! phase sequences (steady, hard swap, gradual rotation, flash crowd,
//! multi-tenant interleave, diurnal ramp) consumable by both engines, the
//! trace recorder, and the CLI (DESIGN.md §10).

pub mod profile;
pub mod request;
pub mod sampler;
pub mod scenario;
pub mod traces;

pub use profile::WorkloadProfile;
pub use request::{Request, RequestGenerator};
pub use sampler::RoutingSampler;
pub use scenario::{FaultEvent, FaultKind, FaultPlan, Scenario, ScenarioPhase};
pub use traces::{Trace, TraceEvent};
