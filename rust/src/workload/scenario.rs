//! Scripted workload scenarios (DESIGN.md §10).
//!
//! The paper's evaluation swaps the workload once, mid-run; real traffic
//! drifts in many more shapes. A [`Scenario`] is a named, composable
//! script of [`ScenarioPhase`]s — each phase pins a [`WorkloadProfile`]
//! (possibly a [`WorkloadProfile::rotated`] or
//! [`WorkloadProfile::flash_crowd`] derivation), a number of serving
//! rounds, and a load multiplier — consumable by the modeled engine
//! (`Engine::run_scenario`), the session front door
//! (`ServeSession::run_scenario`), the DXTR trace recorder
//! ([`Scenario::synthesize_trace`]), and the CLI
//! (`dynaexq serve --scenario <name>`).
//!
//! The canned library ([`Scenario::by_name`]) covers the non-stationary
//! regimes the drift-aware hotness layer is tested against:
//!
//! | name          | script                                               |
//! |---------------|------------------------------------------------------|
//! | `steady`      | one stationary Zipf phase (text)                     |
//! | `swap`        | hard hot-set swap: text → code (disjoint heads)      |
//! | `rotation`    | gradual rotation of the popularity permutation       |
//! | `burst`       | flash crowd on a few head experts, then recovery     |
//! | `multi-tenant`| interleaved text/math/code tenants                   |
//! | `diurnal`     | load ramp up and back down on one workload           |

use super::profile::WorkloadProfile;
use super::traces::Trace;
use crate::config::frontdoor::Lane;
use crate::config::qos::QosClass;
use crate::util::XorShiftRng;

/// One scripted phase: a routing distribution held for `rounds` serving
/// rounds at `load` × the caller's base batch size.
#[derive(Clone, Debug)]
pub struct ScenarioPhase {
    /// Display name of the phase (report rows, trace markers).
    pub name: String,
    /// The routing/prompt distribution served during the phase.
    pub profile: WorkloadProfile,
    /// Closed serving rounds the phase lasts.
    pub rounds: usize,
    /// Batch-size multiplier (diurnal ramps, flash-crowd surges).
    pub load: f64,
    /// Tenant the phase's requests bill to when driven through the front
    /// door (`ServeSession::run_scenario_frontdoor` — DESIGN.md §12);
    /// `None` defaults to the profile name. The classic closed-batch
    /// path ignores it.
    pub tenant: Option<String>,
    /// Priority lane for front-door submissions; ignored by the classic
    /// closed-batch path.
    pub lane: Lane,
    /// QoS class the phase's traffic bills to (DESIGN.md §15): pins the
    /// tenant's class at the front door and sets the coordinator's
    /// active attribution class for the phase. `None` leaves both alone,
    /// so scenarios without class tags stay byte-identical whether or
    /// not a [`crate::config::QosConfig`] is armed.
    pub qos_class: Option<QosClass>,
}

/// What a scripted fault does to a replica's heartbeat.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Heartbeats start failing at the event round (and keep failing
    /// until a matching [`FaultKind::Recover`]).
    Fail,
    /// Heartbeats succeed again from the event round on.
    Recover,
}

/// One scripted fault: `replica`'s heartbeat flips at `round`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultEvent {
    /// Fleet replica index the event targets.
    pub replica: usize,
    /// Serve round (0-based, fleet-wide) the event takes effect at.
    pub round: usize,
    pub kind: FaultKind,
}

/// A deterministic fault-injection script for fleet scenarios
/// (DESIGN.md §14): a list of heartbeat flips per replica per round.
/// The fleet's modeled health checker polls
/// [`FaultPlan::heartbeat_ok`] once per replica per serve round — no
/// wall clock, no randomness at poll time — so a fixed plan yields a
/// byte-stable failover trajectory.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// No faults — every heartbeat succeeds (the default).
    pub fn none() -> Self {
        Self::default()
    }

    /// One replica fails at one round and stays down.
    pub fn fail(replica: usize, round: usize) -> Self {
        Self {
            events: vec![FaultEvent { replica, round, kind: FaultKind::Fail }],
        }
    }

    /// Append a recovery for `replica` at `round`.
    pub fn and_recover(mut self, replica: usize, round: usize) -> Self {
        self.events.push(FaultEvent {
            replica,
            round,
            kind: FaultKind::Recover,
        });
        self
    }

    /// Append an arbitrary event.
    pub fn push(&mut self, event: FaultEvent) {
        self.events.push(event);
    }

    /// Seeded random plan: `n_faults` fail/recover pairs over `replicas`
    /// replicas and `rounds` rounds, reproducible from `seed` (stress
    /// harnesses sweep seeds; each seed is one fixed plan).
    pub fn seeded(
        seed: u64,
        replicas: usize,
        rounds: usize,
        n_faults: usize,
    ) -> Self {
        let mut rng = XorShiftRng::new(seed ^ 0xFA17);
        let mut plan = Self::none();
        if replicas == 0 || rounds == 0 {
            return plan;
        }
        for _ in 0..n_faults {
            let replica = rng.below(replicas);
            let round = rng.below(rounds);
            plan.push(FaultEvent { replica, round, kind: FaultKind::Fail });
            let back = round + 1 + rng.below(rounds.max(1));
            plan.push(FaultEvent {
                replica,
                round: back,
                kind: FaultKind::Recover,
            });
        }
        plan
    }

    /// Whether `replica`'s heartbeat succeeds at `round`: the latest
    /// event at or before `round` decides (later list position wins ties
    /// at the same round); with no applicable event the heartbeat is
    /// healthy.
    pub fn heartbeat_ok(&self, replica: usize, round: usize) -> bool {
        let mut ok = true;
        let mut best: Option<usize> = None;
        for ev in &self.events {
            if ev.replica != replica || ev.round > round {
                continue;
            }
            if best.map(|b| ev.round >= b).unwrap_or(true) {
                best = Some(ev.round);
                ok = ev.kind == FaultKind::Recover;
            }
        }
        ok
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// A named script of phases.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub phases: Vec<ScenarioPhase>,
    /// Scripted replica faults applied when the scenario drives a fleet
    /// (`Fleet::run_scenario` — DESIGN.md §14); single-session paths
    /// ignore it. Empty by default.
    pub faults: FaultPlan,
}

impl Scenario {
    /// An empty scenario to compose phases onto.
    pub fn named(name: &str) -> Self {
        Self {
            name: name.to_string(),
            phases: Vec::new(),
            faults: FaultPlan::none(),
        }
    }

    /// Attach a fault-injection plan (fleet consumers only).
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    /// Append a unit-load phase.
    pub fn phase(
        self,
        name: &str,
        profile: WorkloadProfile,
        rounds: usize,
    ) -> Self {
        self.phase_loaded(name, profile, rounds, 1.0)
    }

    /// Append a phase with an explicit load multiplier.
    pub fn phase_loaded(
        mut self,
        name: &str,
        profile: WorkloadProfile,
        rounds: usize,
        load: f64,
    ) -> Self {
        assert!(rounds > 0, "a phase must last at least one round");
        assert!(load > 0.0, "load multiplier must be positive");
        self.phases.push(ScenarioPhase {
            name: name.to_string(),
            profile,
            rounds,
            load,
            tenant: None,
            lane: Lane::Standard,
            qos_class: None,
        });
        self
    }

    /// Append a phase with an explicit front-door tenant and priority
    /// lane (the closed-batch path ignores both).
    pub fn phase_tagged(
        mut self,
        name: &str,
        profile: WorkloadProfile,
        rounds: usize,
        load: f64,
        tenant: &str,
        lane: Lane,
    ) -> Self {
        self = self.phase_loaded(name, profile, rounds, load);
        let last = self.phases.last_mut().unwrap();
        last.tenant = Some(tenant.to_string());
        last.lane = lane;
        self
    }

    /// Tag the most recently appended phase with a QoS class (front-door
    /// consumers only; the closed-batch path ignores it).
    pub fn classed(mut self, class: QosClass) -> Self {
        let last = self
            .phases
            .last_mut()
            .expect("classed() needs at least one phase");
        last.qos_class = Some(class);
        self
    }

    /// Concatenate another scenario's phases after this one's.
    pub fn then(mut self, mut other: Scenario) -> Self {
        self.phases.append(&mut other.phases);
        self
    }

    /// Total serving rounds across all phases.
    pub fn total_rounds(&self) -> usize {
        self.phases.iter().map(|p| p.rounds).sum()
    }

    /// The batch size a phase serves at: `base` scaled by the phase load,
    /// never below one request.
    pub fn scaled_batch(base: usize, load: f64) -> usize {
        ((base as f64 * load).round() as usize).max(1)
    }

    // -- canned library ----------------------------------------------------

    /// Stationary Zipf traffic — the baseline every drift claim is
    /// measured against (and the byte-identical regression anchor).
    pub fn steady() -> Self {
        Self::named("steady").phase("steady", WorkloadProfile::text(), 6)
    }

    /// Hard hot-set swap: text and code have disjoint popularity heads by
    /// construction, so the resident top-n must be rebuilt wholesale.
    pub fn swap() -> Self {
        Self::named("swap")
            .phase("text", WorkloadProfile::text(), 4)
            .phase("code", WorkloadProfile::code(), 4)
    }

    /// Gradual rotation of the popularity permutation: four steps of
    /// 1/32 of the expert pool each, so the ranking head shifts a few
    /// positions per phase and consecutive hot sets largely overlap —
    /// drift the EMA mostly tracks on its own, in contrast to the
    /// wholesale relocation of `swap` (whether the change-point fires
    /// depends on traffic volume vs the detector's noise floor).
    pub fn rotation() -> Self {
        let base = WorkloadProfile::text();
        let mut sc = Self::named("rotation").phase("rot-0", base.clone(), 2);
        for step in 1..=4 {
            sc = sc.phase(
                &format!("rot-{step}"),
                base.rotated(step as f64 / 32.0),
                2,
            );
        }
        sc
    }

    /// Flash crowd: steady traffic, then a 2× surge concentrated on the
    /// head few experts, then recovery at the original distribution.
    /// The surge is tagged as an interactive-lane `crowd` tenant so the
    /// front-door path gets real overflow pressure on the priority lane.
    pub fn burst() -> Self {
        let base = WorkloadProfile::text();
        Self::named("burst")
            .phase("pre", base.clone(), 3)
            .phase_tagged(
                "crowd",
                base.flash_crowd(),
                3,
                2.0,
                "crowd",
                Lane::Interactive,
            )
            .phase("post", base, 3)
    }

    /// Multi-tenant interleave: text/math/code tenants alternate in short
    /// slices, so the union working set cycles through disjoint heads.
    /// Each tenant is pinned to a distinct priority lane (text →
    /// interactive, math → standard, code → batch) and a distinct QoS
    /// class (premium / standard / best-effort in the same order), which
    /// is what the front-door fairness and class-weighted-allocation
    /// invariants exercise.
    pub fn multi_tenant() -> Self {
        let mut sc = Self::named("multi-tenant");
        for rep in 0..2 {
            for (i, w) in WorkloadProfile::all().into_iter().enumerate() {
                let tenant = w.name;
                let lane = Lane::ALL[i % Lane::ALL.len()];
                let class = QosClass::ALL[i % QosClass::ALL.len()];
                sc = sc
                    .phase_tagged(
                        &format!("{}-{rep}", w.name),
                        w,
                        2,
                        1.0,
                        tenant,
                        lane,
                    )
                    .classed(class);
            }
        }
        sc
    }

    /// Diurnal load ramp: one workload, batch load 0.5 → 1 → 2 → 1 → 0.5.
    /// The class tags follow the ramp (off-peak best-effort, peak
    /// premium), so an armed QoS config shifts attribution with load
    /// while the load/batch schedule itself stays untouched.
    pub fn diurnal() -> Self {
        let w = WorkloadProfile::text();
        let classes = [
            QosClass::BestEffort,
            QosClass::Standard,
            QosClass::Premium,
            QosClass::Standard,
            QosClass::BestEffort,
        ];
        let mut sc = Self::named("diurnal");
        for (i, load) in [0.5, 1.0, 2.0, 1.0, 0.5].into_iter().enumerate() {
            sc = sc
                .phase_loaded(&format!("t{i}"), w.clone(), 2, load)
                .classed(classes[i]);
        }
        sc
    }

    /// Canned scenario names, in presentation order.
    pub fn names() -> Vec<&'static str> {
        vec!["steady", "swap", "rotation", "burst", "multi-tenant", "diurnal"]
    }

    /// Look up a canned scenario by name.
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "steady" => Some(Self::steady()),
            "swap" => Some(Self::swap()),
            "rotation" => Some(Self::rotation()),
            "burst" => Some(Self::burst()),
            "multi-tenant" => Some(Self::multi_tenant()),
            "diurnal" => Some(Self::diurnal()),
            _ => None,
        }
    }

    // -- trace recording ---------------------------------------------------

    /// Record this scenario as a `DXTR` trace: each phase samples its own
    /// routing distribution for `rounds × iters_per_round` iterations at
    /// the load-scaled batch size, sharing one RNG stream so the trace is
    /// reproducible from `seed` (the scenario analogue of
    /// [`super::traces::synthesize`]).
    pub fn synthesize_trace(
        &self,
        n_layers: usize,
        n_experts: usize,
        top_k: usize,
        batch: usize,
        iters_per_round: usize,
        seed: u64,
    ) -> Trace {
        let mut rng = XorShiftRng::new(seed);
        let mut trace = Trace::new(n_layers, n_experts);
        let mut it = 0u64;
        for phase in &self.phases {
            let sampler = super::RoutingSampler::new(
                &phase.profile,
                n_layers,
                n_experts,
                top_k,
            );
            let b = Self::scaled_batch(batch, phase.load);
            for _ in 0..phase.rounds * iters_per_round {
                for layer in 0..n_layers {
                    let mut all = Vec::with_capacity(b * top_k);
                    for req in 0..b as u64 {
                        all.extend(sampler.sample_topk(
                            &mut rng,
                            it * 131 + req,
                            layer,
                        ));
                    }
                    trace.record(layer, &all);
                }
                trace.tick();
                it += 1;
            }
        }
        trace
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceEvent;

    #[test]
    fn canned_library_resolves_every_name() {
        for name in Scenario::names() {
            let sc = Scenario::by_name(name).unwrap();
            assert_eq!(sc.name, name);
            assert!(!sc.phases.is_empty(), "{name}");
            assert!(sc.total_rounds() > 0, "{name}");
        }
        assert!(Scenario::by_name("nope").is_none());
    }

    #[test]
    fn composition_concatenates_phases() {
        let sc = Scenario::steady().then(Scenario::swap());
        assert_eq!(
            sc.total_rounds(),
            Scenario::steady().total_rounds() + Scenario::swap().total_rounds()
        );
        let custom = Scenario::named("mine")
            .phase("a", WorkloadProfile::text(), 1)
            .phase_loaded("b", WorkloadProfile::math(), 2, 3.0);
        assert_eq!(custom.phases.len(), 2);
        assert_eq!(custom.phases[1].load, 3.0);
    }

    #[test]
    fn phase_tags_default_and_pin() {
        // untagged phases carry the front-door defaults
        let sc = Scenario::steady();
        assert_eq!(sc.phases[0].tenant, None);
        assert_eq!(sc.phases[0].lane, Lane::Standard);
        assert_eq!(sc.phases[0].qos_class, None);
        // multi-tenant pins one tenant, a distinct lane, and a distinct
        // QoS class per workload
        let mt = Scenario::multi_tenant();
        for p in &mt.phases {
            assert_eq!(p.tenant.as_deref(), Some(p.profile.name));
        }
        let lanes: Vec<Lane> =
            mt.phases.iter().take(3).map(|p| p.lane).collect();
        assert_eq!(lanes, Lane::ALL.to_vec());
        let classes: Vec<Option<QosClass>> =
            mt.phases.iter().take(3).map(|p| p.qos_class).collect();
        assert_eq!(
            classes,
            QosClass::ALL.iter().copied().map(Some).collect::<Vec<_>>()
        );
        // the burst surge rides the interactive lane as its own tenant,
        // with no class tag (QoS stays inert on burst)
        let burst = Scenario::burst();
        assert_eq!(burst.phases[1].tenant.as_deref(), Some("crowd"));
        assert_eq!(burst.phases[1].lane, Lane::Interactive);
        assert_eq!(burst.phases[0].tenant, None);
        assert!(burst.phases.iter().all(|p| p.qos_class.is_none()));
        // diurnal follows the ramp: off-peak best-effort, peak premium
        let di = Scenario::diurnal();
        let tags: Vec<Option<QosClass>> =
            di.phases.iter().map(|p| p.qos_class).collect();
        assert_eq!(
            tags,
            vec![
                Some(QosClass::BestEffort),
                Some(QosClass::Standard),
                Some(QosClass::Premium),
                Some(QosClass::Standard),
                Some(QosClass::BestEffort),
            ]
        );
        // tagging is a builder on the last phase
        let one = Scenario::named("one")
            .phase("a", WorkloadProfile::text(), 1)
            .classed(QosClass::Premium);
        assert_eq!(one.phases[0].qos_class, Some(QosClass::Premium));
    }

    #[test]
    fn scaled_batch_never_drops_to_zero() {
        assert_eq!(Scenario::scaled_batch(8, 1.0), 8);
        assert_eq!(Scenario::scaled_batch(8, 2.0), 16);
        assert_eq!(Scenario::scaled_batch(8, 0.5), 4);
        assert_eq!(Scenario::scaled_batch(1, 0.01), 1);
    }

    #[test]
    fn swap_scenario_has_disjoint_phase_heads() {
        let sc = Scenario::swap();
        assert_ne!(
            sc.phases[0].profile.workload_idx,
            sc.phases[1].profile.workload_idx
        );
    }

    #[test]
    fn rotation_scenario_steps_monotonically() {
        let sc = Scenario::rotation();
        let fracs: Vec<f64> =
            sc.phases.iter().map(|p| p.profile.rot_frac).collect();
        for w in fracs.windows(2) {
            assert!(w[1] > w[0], "rotation must advance: {fracs:?}");
        }
    }

    #[test]
    fn scenario_trace_counts_match_script() {
        let sc = Scenario::swap();
        let t = sc.synthesize_trace(2, 16, 2, 4, 3, 7);
        let iters = sc.total_rounds() * 3;
        assert_eq!(
            t.events.iter().filter(|e| **e == TraceEvent::Tick).count(),
            iters
        );
        assert_eq!(t.selections(), iters * 2 * 4 * 2);
        // deterministic from the seed
        let t2 = sc.synthesize_trace(2, 16, 2, 4, 3, 7);
        assert_eq!(t, t2);
    }

    #[test]
    fn diurnal_trace_scales_batch_with_load() {
        let sc = Scenario::diurnal();
        let t = sc.synthesize_trace(1, 16, 2, 4, 1, 3);
        // per-iteration selection counts follow the load schedule
        let mut per_tick = Vec::new();
        let mut acc = 0usize;
        for ev in &t.events {
            match ev {
                TraceEvent::Routing { experts, .. } => acc += experts.len(),
                TraceEvent::Tick => {
                    per_tick.push(acc);
                    acc = 0;
                }
            }
        }
        // loads 0.5/1/2/1/0.5 at base batch 4, top_k 2 → 4/8/16/8/4
        assert_eq!(per_tick, vec![4, 4, 8, 8, 16, 16, 8, 8, 4, 4]);
    }

    #[test]
    fn fault_plan_heartbeat_semantics() {
        let none = FaultPlan::none();
        assert!(none.is_empty());
        assert!(none.heartbeat_ok(0, 0));
        assert!(none.heartbeat_ok(3, 100));

        let plan = FaultPlan::fail(1, 4);
        assert!(plan.heartbeat_ok(1, 3)); // before the event
        assert!(!plan.heartbeat_ok(1, 4)); // at the event
        assert!(!plan.heartbeat_ok(1, 50)); // stays down
        assert!(plan.heartbeat_ok(0, 4)); // other replicas unaffected

        let plan = plan.and_recover(1, 8);
        assert!(!plan.heartbeat_ok(1, 7));
        assert!(plan.heartbeat_ok(1, 8));
        assert!(plan.heartbeat_ok(1, 9));
    }

    #[test]
    fn fault_plan_same_round_later_event_wins() {
        let mut plan = FaultPlan::fail(0, 2);
        plan.push(FaultEvent { replica: 0, round: 2, kind: FaultKind::Recover });
        assert!(plan.heartbeat_ok(0, 2));
        assert!(plan.heartbeat_ok(0, 3));
    }

    #[test]
    fn seeded_fault_plan_is_deterministic() {
        let a = FaultPlan::seeded(42, 3, 16, 4);
        let b = FaultPlan::seeded(42, 3, 16, 4);
        assert_eq!(a, b);
        assert_eq!(a.events.len(), 8); // fail + recover per fault
        for ev in &a.events {
            assert!(ev.replica < 3);
        }
        // every failed replica eventually recovers
        for ev in a.events.iter().filter(|e| e.kind == FaultKind::Fail) {
            assert!(a
                .events
                .iter()
                .any(|r| r.kind == FaultKind::Recover
                    && r.replica == ev.replica
                    && r.round > ev.round));
        }
        // degenerate dimensions yield an empty plan, not a panic
        assert!(FaultPlan::seeded(42, 0, 16, 4).is_empty());
        assert!(FaultPlan::seeded(42, 3, 0, 4).is_empty());
    }

    #[test]
    fn scenario_carries_faults() {
        let sc = Scenario::steady();
        assert!(sc.faults.is_empty());
        let sc = sc.with_faults(FaultPlan::fail(0, 3));
        assert!(!sc.faults.heartbeat_ok(0, 3));
        assert!(sc.faults.heartbeat_ok(0, 2));
    }
}
