//! Mini benchmark harness (criterion is not in the offline crate set).
//!
//! Statistically honest for its purpose: explicit warmup, N timed
//! iterations, mean/median/p99 reporting with no hidden adaptivity. Paper
//! experiment harnesses (`benches/*.rs`) use [`Bench`] for wall-clock
//! micro-measurements and print their tables directly.
//!
//! On top of the table helpers sit two runtime submodules (DESIGN.md
//! §11): [`runtime`] — the `dynaexq bench` end-to-end serving matrix
//! that emits `BENCH_serving.json` — and [`json`], the minimal JSON
//! writer/parser it serializes through.

pub mod json;
pub mod runtime;

use std::time::Instant;

use crate::util::{mean, percentile};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            human(self.mean_s),
            human(self.p50_s),
            human(self.p99_s),
        )
    }
}

/// Human-readable seconds.
pub fn human(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// The harness.
pub struct Bench {
    warmup: usize,
    iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self { warmup: 3, iters: 20 }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Self {
        assert!(iters > 0);
        Self { warmup, iters }
    }

    /// Time `f` (whole-call granularity).
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_s: mean(&samples),
            p50_s: percentile(&samples, 50.0),
            p99_s: percentile(&samples, 99.0),
            min_s: min,
        }
    }
}

/// Fixed-width table printer for experiment harnesses.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(out.len().saturating_sub(1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let b = Bench::new(1, 5);
        let mut n = 0u64;
        let r = b.run("spin", || {
            n += 1;
            std::hint::black_box(n);
        });
        assert_eq!(n, 6); // 1 warmup + 5 timed
        assert_eq!(r.iters, 5);
        assert!(r.mean_s >= 0.0);
        assert!(r.p99_s >= r.p50_s || r.p50_s - r.p99_s < 1e-9);
    }

    #[test]
    fn human_units() {
        assert_eq!(human(2.0), "2.000 s");
        assert_eq!(human(2e-3), "2.000 ms");
        assert_eq!(human(2e-6), "2.000 µs");
        assert_eq!(human(2e-9), "2.0 ns");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let s = t.render();
        assert!(s.contains("333"));
        assert_eq!(s.lines().count(), 4);
    }
}
