//! Wall-clock serving benchmark harness (DESIGN.md §11).
//!
//! The repo's first *performance trajectory*: `dynaexq bench` runs a
//! fixed matrix of end-to-end modeled serving workloads — registry
//! method × scripted scenario × {1,2}-device groups × batch {1,8,32} —
//! under host wall-clock timing and emits a machine-readable
//! `BENCH_serving.json` that future PRs are judged against. Per cell it
//! records p50/p95 wall-clock per serving round, modeled tokens/s, and
//! the allocation-visible proxy counters from the transition pipeline
//! ([`crate::coordinator::TransitionTotals`]).
//!
//! Wall-clock here measures the *simulator's own hot path* (routing
//! sampling, residency resolution, hotness ingestion, policy updates) —
//! the quantity the hot-path de-allocation work of this module's sibling
//! changes is meant to move — while the modeled metrics prove behaviour
//! stayed fixed. The schema is validated by `tests/bench_smoke.rs` and a
//! self-check before every file write.

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::fleet::FleetConfig;
use crate::config::frontdoor::{FrontDoorConfig, Lane};
use crate::config::{kv, DeviceConfig, QosConfig, ServingConfig};
use crate::coordinator::TransitionTotals;
use crate::experiments::helpers;
use crate::serving::engine::{Engine, EngineConfig};
use crate::serving::fleet::Fleet;
use crate::serving::frontdoor::FrontDoor;
use crate::util::percentile;
use crate::workload::{RequestGenerator, Scenario};

use super::json::{self, Json};
use super::Table;

/// Schema tag stamped into every report; bump on breaking changes.
/// v2: the `frontdoor` axis and per-lane front-door cell columns.
/// v3: the `producers` axis on front-door cells (threaded load
/// generator) with per-cell admission-latency p50/p95.
/// v4: the `replicas` axis on front-door cells (fleet-scale replicated
/// serving — DESIGN.md §14); non-finite f64 cell values are a
/// validation error.
/// v5: the `qos` axis on front-door cells (class-weighted allocation —
/// DESIGN.md §15) with per-class `qos_charged`/`qos_refunded` ledger
/// columns; `qos=off` cells are byte-identical to the v4 bench.
pub const BENCH_SCHEMA: &str = "dynaexq-bench-serving/v5";

/// Serving methods benchmarked by the full matrix: every registry method
/// that serves traffic as a *method under comparison*. The quality
/// reference tiers (`fp16`, `static-hi`) and the calibration pass
/// (`counting`) are excluded — they are measurement apparatus, not
/// serving systems.
pub const BENCH_METHODS: &[&str] = &[
    "static",
    "static-map",
    "expertflow",
    "hobbit",
    "dynaexq",
    "dynaexq-adaptive",
    "dynaexq-3tier",
    "dynaexq-sharded",
    "dynaexq-3tier-sharded",
];

/// Device-group widths of the matrix (single-device methods ignore the
/// knob and serve the 1-device system at both widths — the matrix stays
/// rectangular, mirroring the scenario-matrix invariant suite).
pub const BENCH_DEVICES: &[usize] = &[1, 2];

/// Decode batch caps swept by the matrix (the paper's 1 → 32 range).
pub const BENCH_BATCHES: &[usize] = &[1, 8, 32];

/// Producer-thread counts swept on front-door cells by the full matrix:
/// 1 is the serial reference (inline submission, byte-identical modeled
/// behaviour to the v2 bench), 4 measures admission-path contention on
/// the door's queue lock. Direct (non-front-door) cells pin the knob to
/// 0 — there is no admission path to contend on.
pub const BENCH_PRODUCERS: &[usize] = &[1, 4];

/// Fleet replica counts swept on front-door cells by the full matrix:
/// 1 is the single-group reference (the pre-§14 serving path,
/// byte-identical modeled behaviour to the v3 bench), 2 serves the
/// scenario through a replicated [`Fleet`] behind the shared door.
/// Direct cells pin the knob to 0 — there is no front door to put a
/// fleet behind.
pub const BENCH_REPLICAS: &[usize] = &[1, 2];

/// QoS-config axis swept on front-door cells by the matrix: `false`
/// serves with no [`QosConfig`] (the v4 path, byte-identical modeled
/// behaviour), `true` arms the tiered premium/standard/best-effort
/// config so class-weighted allocation and the per-tenant budget ledger
/// are exercised under load. Direct cells pin the knob off — there is
/// no front door to bill through.
pub const BENCH_QOS: &[bool] = &[false, true];

/// Keys every cell object in `BENCH_serving.json` must carry — the
/// schema contract `bench_smoke` (and the pre-write self-check) enforce.
pub const CELL_KEYS: &[&str] = &[
    "method",
    "scenario",
    "devices",
    "batch",
    "rounds",
    "wall_total_s",
    "wall_p50_round_s",
    "wall_p95_round_s",
    "modeled_duration_s",
    "modeled_tok_s",
    "decode_tokens",
    "prefill_tokens",
    "hi_fraction",
    "migrated_bytes",
    "promotions",
    "demotions",
    "deferred",
    "rejected",
    "published",
    "evictions",
    "drift_events",
    "drift_recovery_ticks",
    "frontdoor",
    "producers",
    "replicas",
    "fd_lane_admitted",
    "fd_lane_rejected",
    "fd_lane_deadline_miss",
    "fd_lane_ttft_p50_s",
    "fd_lane_ttft_p95_s",
    "fd_submit_p50_s",
    "fd_submit_p95_s",
    "qos",
    "qos_charged",
    "qos_refunded",
];

/// The benchmark matrix: which cells run and at what workload shape.
#[derive(Clone, Debug)]
pub struct BenchMatrix {
    pub model: String,
    pub methods: Vec<String>,
    pub scenarios: Vec<String>,
    pub devices: Vec<usize>,
    pub batches: Vec<usize>,
    pub prompt_len: usize,
    pub output_len: usize,
    /// Untimed serving rounds before measurement (adaptive methods
    /// converge; allocator/branch caches warm).
    pub warmup_rounds: usize,
    pub seed: u64,
    /// Front-door axis: `false` serves rounds directly (the pre-§12
    /// path), `true` routes every request through a bounded
    /// [`FrontDoor`] + SLO scheduler, recording per-lane p50/p95 TTFT
    /// and typed-rejection totals.
    pub frontdoor: Vec<bool>,
    /// Producer-thread axis, applied to front-door cells only: each
    /// value spawns that many submission threads against the door and
    /// times every `submit` call (admission-path contention). Direct
    /// cells run once with the knob pinned to 0.
    pub producers: Vec<usize>,
    /// Fleet-replica axis, applied to front-door cells only: 1 serves
    /// through the classic single engine behind the door, >1 through a
    /// replicated [`Fleet`] with load/affinity routing (DESIGN.md §14).
    /// Direct cells run once with the knob pinned to 0.
    pub replicas: Vec<usize>,
    /// QoS axis, applied to front-door cells only: `false` runs with no
    /// [`QosConfig`], `true` arms [`QosConfig::tiered`] (class-weighted
    /// hotness + budget ledger — DESIGN.md §15). Direct cells run once
    /// with the knob pinned off.
    pub qos: Vec<bool>,
}

impl BenchMatrix {
    /// The full matrix on one model: every bench method × every canned
    /// scenario × {1,2} devices × batch {1,8,32}.
    pub fn full(model: &str) -> Self {
        Self {
            model: model.to_string(),
            methods: BENCH_METHODS.iter().map(|s| s.to_string()).collect(),
            scenarios: Scenario::names()
                .iter()
                .map(|s| s.to_string())
                .collect(),
            devices: BENCH_DEVICES.to_vec(),
            batches: BENCH_BATCHES.to_vec(),
            prompt_len: 32,
            output_len: 8,
            warmup_rounds: 1,
            seed: 0xBE4C,
            frontdoor: vec![false, true],
            producers: BENCH_PRODUCERS.to_vec(),
            replicas: BENCH_REPLICAS.to_vec(),
            qos: BENCH_QOS.to_vec(),
        }
    }

    /// The smallest matrix — what CI's `bench-smoke` job runs on every
    /// push: one method, one scenario, one device, batch 1, both sides
    /// of the front-door axis, a serial and a threaded producer count,
    /// a 1- and 2-replica fleet width, and both sides of the QoS axis
    /// (so the queue path, the admission seam, the fleet router, *and*
    /// the class-weighted budget ledger are exercised on every push).
    pub fn smoke(model: &str) -> Self {
        Self {
            model: model.to_string(),
            methods: vec!["dynaexq".into()],
            scenarios: vec!["steady".into()],
            devices: vec![1],
            batches: vec![1],
            prompt_len: 16,
            output_len: 4,
            warmup_rounds: 1,
            seed: 0xBE4C,
            frontdoor: vec![false, true],
            producers: vec![1, 2],
            replicas: vec![1, 2],
            qos: vec![false, true],
        }
    }

    /// Number of cells the matrix spans. Front-door cells fan out over
    /// the producer × replica × qos axes; direct cells do not (all three
    /// knobs are pinned off).
    pub fn n_cells(&self) -> usize {
        let fd_cells: usize = self
            .frontdoor
            .iter()
            .map(|&f| {
                if f {
                    self.producers.len().max(1)
                        * self.replicas.len().max(1)
                        * self.qos.len().max(1)
                } else {
                    1
                }
            })
            .sum();
        self.methods.len()
            * self.scenarios.len()
            * self.devices.len()
            * self.batches.len()
            * fd_cells
    }
}

/// Narrow a matrix to the axis values selected by a `--filter` spec:
/// comma-separated `key=value` pairs over `method`, `scenario`,
/// `devices`, `batch`, `frontdoor` (`0/false/off` or `1/true/on`),
/// `producers`, `replicas`, and `qos` (the latter three front-door
/// cells only). Unknown keys and filters that empty an axis are
/// errors — a bench that silently ran zero cells would read as a clean
/// pass.
pub fn apply_filter(matrix: &mut BenchMatrix, spec: &str) -> Result<()> {
    let m = kv::parse_kv(spec);
    let mut keys: Vec<&String> = m.keys().collect();
    keys.sort();
    for key in keys {
        let val = &m[key];
        match key.as_str() {
            "method" => matrix.methods.retain(|x| x == val),
            "scenario" => matrix.scenarios.retain(|x| x == val),
            "devices" => {
                let n: usize = val
                    .parse()
                    .with_context(|| format!("bad devices filter {val:?}"))?;
                matrix.devices.retain(|&x| x == n);
            }
            "batch" => {
                let n: usize = val
                    .parse()
                    .with_context(|| format!("bad batch filter {val:?}"))?;
                matrix.batches.retain(|&x| x == n);
            }
            "frontdoor" => {
                let want = match val.as_str() {
                    "1" | "true" | "on" => true,
                    "0" | "false" | "off" => false,
                    _ => bail!(
                        "bad frontdoor filter {val:?} (use 0/false/off or \
                         1/true/on)"
                    ),
                };
                matrix.frontdoor.retain(|&x| x == want);
            }
            "producers" => {
                let n: usize = val
                    .parse()
                    .with_context(|| format!("bad producers filter {val:?}"))?;
                matrix.producers.retain(|&x| x == n);
            }
            "replicas" => {
                let n: usize = val
                    .parse()
                    .with_context(|| format!("bad replicas filter {val:?}"))?;
                matrix.replicas.retain(|&x| x == n);
            }
            "qos" => {
                let want = match val.as_str() {
                    "1" | "true" | "on" => true,
                    "0" | "false" | "off" => false,
                    _ => bail!(
                        "bad qos filter {val:?} (use 0/false/off or \
                         1/true/on)"
                    ),
                };
                matrix.qos.retain(|&x| x == want);
            }
            other => bail!(
                "unknown filter key {other:?}; filterable axes: batch, \
                 devices, frontdoor, method, producers, qos, replicas, \
                 scenario"
            ),
        }
    }
    if matrix.n_cells() == 0 {
        bail!("filter {spec:?} matches no cells of the declared matrix");
    }
    Ok(())
}

/// One measured matrix cell.
#[derive(Clone, Debug)]
pub struct BenchCell {
    pub method: String,
    pub scenario: String,
    pub devices: usize,
    pub batch: usize,
    /// Serving rounds timed (the scenario's total, load-scaled batches).
    pub rounds: usize,
    pub wall_total_s: f64,
    pub wall_p50_round_s: f64,
    pub wall_p95_round_s: f64,
    /// Modeled seconds the timed rounds spanned (warmup excluded).
    pub modeled_duration_s: f64,
    /// Modeled throughput over the timed rounds (prefill + decode).
    pub modeled_tok_s: f64,
    pub decode_tokens: u64,
    pub prefill_tokens: u64,
    /// Cumulative (incl. warmup) top-rung resolution ratio — a
    /// convergence diagnostic, not a windowed counter.
    pub hi_fraction: f64,
    /// Bytes migrated during the timed rounds (warmup delta-subtracted).
    pub migrated_bytes: u64,
    /// Transition-pipeline counters over the timed rounds (warmup
    /// delta-subtracted).
    pub transitions: TransitionTotals,
    pub drift_events: u64,
    pub drift_recovery_ticks: u64,
    /// Whether the cell served through the bounded front door.
    pub frontdoor: bool,
    /// Producer threads that submitted this cell's requests (0 for
    /// direct cells, ≥1 for front-door cells; 1 is the serial inline
    /// reference path).
    pub producers: usize,
    /// Fleet replicas that served this cell (0 for direct cells, 1 for
    /// the classic single engine behind the door, ≥2 for a replicated
    /// [`Fleet`]).
    pub replicas: usize,
    /// Per-lane admissions (interactive|standard|batch order); empty for
    /// non-front-door cells.
    pub fd_lane_admitted: Vec<u64>,
    /// Per-lane typed rejections (same order).
    pub fd_lane_rejected: Vec<u64>,
    /// Per-lane SLO deadline misses among served requests (same order).
    pub fd_lane_deadline_miss: Vec<u64>,
    /// Per-lane TTFT p50, modeled seconds (0.0 for lanes with no
    /// traffic).
    pub fd_lane_ttft_p50_s: Vec<f64>,
    /// Per-lane TTFT p95, modeled seconds.
    pub fd_lane_ttft_p95_s: Vec<f64>,
    /// Wall-clock p50 of individual `FrontDoor::submit` calls across
    /// all producers — the admission-path contention signal (0.0 for
    /// direct cells).
    pub fd_submit_p50_s: f64,
    /// Wall-clock p95 of individual `FrontDoor::submit` calls.
    pub fd_submit_p95_s: f64,
    /// Whether the cell served under an armed [`QosConfig::tiered`]
    /// (always false for direct cells).
    pub qos: bool,
    /// Per-class bytes charged by the front door's budget ledger
    /// (premium|standard|best-effort order); empty when `qos` is off.
    pub qos_charged: Vec<u64>,
    /// Per-class bytes refunded at stream completion (same order).
    pub qos_refunded: Vec<u64>,
}

/// A full matrix run.
pub struct BenchReport {
    pub matrix: BenchMatrix,
    pub cells: Vec<BenchCell>,
}

/// Front-door configuration the bench's queue-path cells run under: the
/// default SLO classes with the queue bound tied to the batch size, so
/// load-scaled surges (burst's 2× crowd) overflow into real typed
/// rejections while steady cells admit everything.
fn frontdoor_bench_cfg(batch: usize, qos: bool) -> FrontDoorConfig {
    let mut cfg = FrontDoorConfig::default();
    cfg.queue_capacity = (batch * 3 / 2).max(2);
    if qos {
        cfg.qos = Some(QosConfig::tiered());
    }
    cfg
}

/// Run one cell: build the method's backend at the requested group
/// width, warm it, then serve the scenario end to end with per-round
/// wall-clock sampling. With `frontdoor` set, every request is submitted
/// through a bounded [`FrontDoor`] under the phase's tenant/lane tags
/// and drained through the SLO scheduler each round; `producers > 1`
/// fans the round's submissions out over that many threads (requests
/// are pre-generated on the bench thread, so ids and content are
/// identical at every producer count) and times each `submit` call.
/// `producers` is ignored for direct cells (recorded as 0), and so are
/// `replicas` and `qos`; a front-door cell with `replicas > 1` serves
/// through a replicated [`Fleet`] instead of a single engine, and one
/// with `qos` set arms [`QosConfig::tiered`] across the door's budget
/// ledger and the residency stack's class-weighted hotness.
#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    matrix: &BenchMatrix,
    method: &str,
    scenario_name: &str,
    devices: usize,
    batch: usize,
    frontdoor: bool,
    producers: usize,
    replicas: usize,
    qos: bool,
) -> Result<BenchCell> {
    let qos = qos && frontdoor;
    if frontdoor && replicas > 1 {
        return run_fleet_cell(
            matrix,
            method,
            scenario_name,
            devices,
            batch,
            producers.max(1),
            replicas,
            qos,
        );
    }
    let preset = helpers::preset(&matrix.model)?;
    let sc = helpers::scenario(scenario_name)?;
    let mut cfg = ServingConfig::default();
    if qos {
        cfg.qos = Some(QosConfig::tiered());
    }
    let dev = DeviceConfig::default();
    let first_profile = &sc.phases[0].profile;
    let backend = helpers::backend_with_devices(
        method,
        &preset,
        &cfg,
        &dev,
        Some(first_profile),
        devices,
    )?;
    let mut engine = Engine::new(
        &preset,
        first_profile,
        backend,
        &dev,
        EngineConfig {
            max_batch: batch.max(1),
            seed: matrix.seed,
            track_activation: false,
        },
    );
    engine.warm(first_profile, matrix.warmup_rounds);
    // Post-warmup baselines: every cell counter describes the *timed*
    // rounds only — cumulative backend counters (migration, transitions,
    // drift) are reported as deltas so a change to the warmup protocol
    // cannot shift the trajectory. (`hi_fraction` stays cumulative: it is
    // a resolution-count ratio, i.e. a convergence diagnostic.)
    let modeled_start = engine.now();
    let migrated0 = engine.backend.migrated_bytes();
    let transitions0 = engine.backend.transition_totals();
    let drift0 = engine.backend.drift_stats();

    let producers = if frontdoor { producers.max(1) } else { 0 };
    let replicas = if frontdoor { replicas.max(1) } else { 0 };
    let fd = if frontdoor {
        Some(
            FrontDoor::new(frontdoor_bench_cfg(batch, qos))
                .map_err(anyhow::Error::msg)?,
        )
    } else {
        None
    };
    // One generator across phases: the scheduler tags requests by id, so
    // ids must stay unique across every drain of the cell.
    let mut gen = RequestGenerator::new(
        sc.phases[0].profile.clone(),
        matrix.seed ^ 0xFD00,
    );

    let mut samples = Vec::with_capacity(sc.total_rounds());
    let mut submit_samples = Vec::new();
    let t_all = Instant::now();
    for phase in &sc.phases {
        engine.set_profile(&phase.profile);
        let b = Scenario::scaled_batch(batch, phase.load);
        match &fd {
            None => {
                for _ in 0..phase.rounds {
                    let t0 = Instant::now();
                    engine.serve_uniform(
                        &phase.profile,
                        b,
                        matrix.prompt_len,
                        matrix.output_len,
                    );
                    samples.push(t0.elapsed().as_secs_f64());
                }
            }
            Some(fd) => {
                gen.set_profile(phase.profile.clone());
                let tenant = phase
                    .tenant
                    .clone()
                    .unwrap_or_else(|| phase.profile.name.to_string());
                if let Some(class) = phase.qos_class {
                    // no-ops on an unarmed stack, so qos=off cells stay
                    // byte-identical to the v4 bench
                    fd.set_tenant_class(&tenant, class);
                    engine.backend.set_active_class(class.index());
                }
                for _ in 0..phase.rounds {
                    let t0 = Instant::now();
                    let now = engine.now();
                    // Pre-generate on the bench thread: one sequential
                    // generator decides ids/content before any producer
                    // runs, so the request set is identical at every
                    // producer count.
                    let round_reqs: Vec<_> = (0..b)
                        .map(|_| {
                            gen.request(
                                matrix.prompt_len,
                                matrix.output_len,
                                now,
                            )
                        })
                        .collect();
                    if producers <= 1 {
                        // serial reference: in-order inline submission,
                        // byte-identical to the v2 bench
                        for req in round_reqs {
                            let s0 = Instant::now();
                            // typed rejections are the measured outcome
                            let _ = fd.submit(req, &tenant, phase.lane, now);
                            submit_samples.push(s0.elapsed().as_secs_f64());
                        }
                    } else {
                        let mut chunks: Vec<Vec<_>> =
                            (0..producers).map(|_| Vec::new()).collect();
                        for (i, req) in round_reqs.into_iter().enumerate() {
                            chunks[i % producers].push(req);
                        }
                        let lane = phase.lane;
                        let tenant = tenant.as_str();
                        let per_thread: Vec<Vec<f64>> =
                            std::thread::scope(|s| {
                                let handles: Vec<_> = chunks
                                    .into_iter()
                                    .map(|chunk| {
                                        s.spawn(move || {
                                            let mut lat =
                                                Vec::with_capacity(
                                                    chunk.len(),
                                                );
                                            for req in chunk {
                                                let s0 = Instant::now();
                                                let _ = fd.submit(
                                                    req, tenant, lane, now,
                                                );
                                                lat.push(
                                                    s0.elapsed()
                                                        .as_secs_f64(),
                                                );
                                            }
                                            lat
                                        })
                                    })
                                    .collect();
                                handles
                                    .into_iter()
                                    .map(|h| {
                                        h.join().expect("bench producer")
                                    })
                                    .collect()
                            });
                        for lat in per_thread {
                            submit_samples.extend(lat);
                        }
                    }
                    let (mut sched, reqs) = fd.take_scheduled();
                    let ids: Vec<u64> = reqs.iter().map(|r| r.id).collect();
                    engine.serve_with(&mut sched, reqs);
                    fd.absorb(&sched);
                    fd.settle(&ids);
                    samples.push(t0.elapsed().as_secs_f64());
                }
            }
        }
    }
    let wall_total_s = t_all.elapsed().as_secs_f64();

    let (qos_charged, qos_refunded) = match &fd {
        Some(fd) if fd.qos_armed() => (fd.qos_charged(), fd.qos_refunded()),
        _ => (Vec::new(), Vec::new()),
    };
    let (fd_adm, fd_rej, fd_miss, fd_p50, fd_p95) = match &fd {
        Some(fd) => (
            fd.stats().lane_admitted(),
            fd.stats().lane_rejected(),
            fd.stats().lane_deadline_miss(),
            Lane::ALL
                .iter()
                .map(|&l| percentile(&fd.lane_ttft(l), 50.0))
                .collect(),
            Lane::ALL
                .iter()
                .map(|&l| percentile(&fd.lane_ttft(l), 95.0))
                .collect(),
        ),
        None => {
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new())
        }
    };

    let m = &engine.metrics;
    let modeled_duration_s = engine.now() - modeled_start;
    let modeled_tok_s = if modeled_duration_s > 0.0 {
        (m.prefill_tokens + m.decode_tokens) as f64 / modeled_duration_s
    } else {
        0.0
    };
    let (drift_events, drift_recovery_ticks) = engine.backend.drift_stats();
    Ok(BenchCell {
        method: method.to_string(),
        scenario: scenario_name.to_string(),
        devices,
        batch,
        rounds: samples.len(),
        wall_total_s,
        wall_p50_round_s: percentile(&samples, 50.0),
        wall_p95_round_s: percentile(&samples, 95.0),
        modeled_duration_s,
        modeled_tok_s,
        decode_tokens: m.decode_tokens,
        prefill_tokens: m.prefill_tokens,
        hi_fraction: engine.backend.hi_fraction(),
        migrated_bytes: engine
            .backend
            .migrated_bytes()
            .saturating_sub(migrated0),
        transitions: engine
            .backend
            .transition_totals()
            .delta_since(&transitions0),
        drift_events: drift_events.saturating_sub(drift0.0),
        drift_recovery_ticks: drift_recovery_ticks.saturating_sub(drift0.1),
        frontdoor,
        producers,
        replicas,
        fd_lane_admitted: fd_adm,
        fd_lane_rejected: fd_rej,
        fd_lane_deadline_miss: fd_miss,
        fd_lane_ttft_p50_s: fd_p50,
        fd_lane_ttft_p95_s: fd_p95,
        fd_submit_p50_s: percentile(&submit_samples, 50.0),
        fd_submit_p95_s: percentile(&submit_samples, 95.0),
        qos,
        qos_charged,
        qos_refunded,
    })
}

/// Fleet variant of a front-door cell: `replicas` engine replicas behind
/// the shared door (DESIGN.md §14), each a `devices`-wide group, drained
/// through the fleet's load/affinity router. Requests are pre-generated
/// on the bench thread exactly like the single-engine path, so the
/// submission stream is identical at every producer count.
#[allow(clippy::too_many_arguments)]
fn run_fleet_cell(
    matrix: &BenchMatrix,
    method: &str,
    scenario_name: &str,
    devices: usize,
    batch: usize,
    producers: usize,
    replicas: usize,
    qos: bool,
) -> Result<BenchCell> {
    let sc = helpers::scenario(scenario_name)?;
    let mut fleet_cfg = FleetConfig::default();
    fleet_cfg.replicas = replicas;
    fleet_cfg.devices_per_replica = devices;
    let mut builder = Fleet::builder()
        .model(&matrix.model)
        .method(method)
        .workload(sc.phases[0].profile.name)
        .max_batch(batch.max(1))
        .seed(matrix.seed)
        .warmup(matrix.warmup_rounds)
        .track_activation(false)
        .frontdoor(frontdoor_bench_cfg(batch, false))
        .fleet_cfg(fleet_cfg);
    if qos {
        builder = builder.qos(QosConfig::tiered());
    }
    let mut fleet = builder.build()?;
    let modeled_start = fleet.now();
    let start = fleet.snapshot();
    let transitions0 = fleet.transition_totals();

    let mut gen = RequestGenerator::new(
        sc.phases[0].profile.clone(),
        matrix.seed ^ 0xFD00,
    );
    let mut samples = Vec::with_capacity(sc.total_rounds());
    let mut submit_samples = Vec::new();
    let t_all = Instant::now();
    for phase in &sc.phases {
        fleet.set_profile(&phase.profile);
        gen.set_profile(phase.profile.clone());
        let tenant = phase
            .tenant
            .clone()
            .unwrap_or_else(|| phase.profile.name.to_string());
        if let Some(class) = phase.qos_class {
            fleet.set_qos_class(&tenant, class);
        }
        let b = Scenario::scaled_batch(batch, phase.load);
        for _ in 0..phase.rounds {
            let t0 = Instant::now();
            let now = fleet.now();
            let round_reqs: Vec<_> = (0..b)
                .map(|_| {
                    gen.request(matrix.prompt_len, matrix.output_len, now)
                })
                .collect();
            {
                let fd = fleet.frontdoor();
                if producers <= 1 {
                    for req in round_reqs {
                        let s0 = Instant::now();
                        let _ = fd.submit(req, &tenant, phase.lane, now);
                        submit_samples.push(s0.elapsed().as_secs_f64());
                    }
                } else {
                    let mut chunks: Vec<Vec<_>> =
                        (0..producers).map(|_| Vec::new()).collect();
                    for (i, req) in round_reqs.into_iter().enumerate() {
                        chunks[i % producers].push(req);
                    }
                    let lane = phase.lane;
                    let tenant = tenant.as_str();
                    let per_thread: Vec<Vec<f64>> =
                        std::thread::scope(|s| {
                            let handles: Vec<_> = chunks
                                .into_iter()
                                .map(|chunk| {
                                    s.spawn(move || {
                                        let mut lat =
                                            Vec::with_capacity(chunk.len());
                                        for req in chunk {
                                            let s0 = Instant::now();
                                            let _ = fd.submit(
                                                req, tenant, lane, now,
                                            );
                                            lat.push(
                                                s0.elapsed().as_secs_f64(),
                                            );
                                        }
                                        lat
                                    })
                                })
                                .collect();
                            handles
                                .into_iter()
                                .map(|h| h.join().expect("bench producer"))
                                .collect()
                        });
                    for lat in per_thread {
                        submit_samples.extend(lat);
                    }
                }
            }
            fleet.drain()?;
            samples.push(t0.elapsed().as_secs_f64());
        }
    }
    let wall_total_s = t_all.elapsed().as_secs_f64();

    let fd = fleet.frontdoor();
    let (qos_charged, qos_refunded) = if fd.qos_armed() {
        (fd.qos_charged(), fd.qos_refunded())
    } else {
        (Vec::new(), Vec::new())
    };
    let fd_adm = fd.stats().lane_admitted();
    let fd_rej = fd.stats().lane_rejected();
    let fd_miss = fd.stats().lane_deadline_miss();
    let fd_p50 = Lane::ALL
        .iter()
        .map(|&l| percentile(&fd.lane_ttft(l), 50.0))
        .collect();
    let fd_p95 = Lane::ALL
        .iter()
        .map(|&l| percentile(&fd.lane_ttft(l), 95.0))
        .collect();

    let s = fleet.snapshot();
    let modeled_duration_s = fleet.now() - modeled_start;
    let modeled_tok_s = if modeled_duration_s > 0.0 {
        (s.prefill_tokens + s.decode_tokens) as f64 / modeled_duration_s
    } else {
        0.0
    };
    Ok(BenchCell {
        method: method.to_string(),
        scenario: scenario_name.to_string(),
        devices,
        batch,
        rounds: samples.len(),
        wall_total_s,
        wall_p50_round_s: percentile(&samples, 50.0),
        wall_p95_round_s: percentile(&samples, 95.0),
        modeled_duration_s,
        modeled_tok_s,
        decode_tokens: s.decode_tokens,
        prefill_tokens: s.prefill_tokens,
        hi_fraction: s.hi_fraction,
        migrated_bytes: s.migrated_bytes.saturating_sub(start.migrated_bytes),
        transitions: fleet.transition_totals().delta_since(&transitions0),
        drift_events: s.drift_events.saturating_sub(start.drift_events),
        drift_recovery_ticks: s
            .drift_recovery_ticks
            .saturating_sub(start.drift_recovery_ticks),
        frontdoor: true,
        producers,
        replicas,
        fd_lane_admitted: fd_adm,
        fd_lane_rejected: fd_rej,
        fd_lane_deadline_miss: fd_miss,
        fd_lane_ttft_p50_s: fd_p50,
        fd_lane_ttft_p95_s: fd_p95,
        fd_submit_p50_s: percentile(&submit_samples, 50.0),
        fd_submit_p95_s: percentile(&submit_samples, 95.0),
        qos,
        qos_charged,
        qos_refunded,
    })
}

/// Run the whole matrix. `progress` receives one line per finished cell
/// (the CLI passes an eprintln; tests pass a sink).
pub fn run_matrix(
    matrix: &BenchMatrix,
    mut progress: impl FnMut(&str),
) -> Result<BenchReport> {
    let mut cells = Vec::with_capacity(matrix.n_cells());
    let total = matrix.n_cells();
    for method in &matrix.methods {
        for scenario in &matrix.scenarios {
            for &devices in &matrix.devices {
                for &batch in &matrix.batches {
                    for &frontdoor in &matrix.frontdoor {
                        // direct cells have no admission path: one run,
                        // producers/replicas pinned 0 and qos pinned off
                        let fd_axis: Vec<(usize, usize, bool)> = if frontdoor
                        {
                            matrix
                                .producers
                                .iter()
                                .flat_map(|&p| {
                                    matrix.replicas.iter().flat_map(
                                        move |&r| {
                                            matrix
                                                .qos
                                                .iter()
                                                .map(move |&q| (p, r, q))
                                        },
                                    )
                                })
                                .collect()
                        } else {
                            vec![(0, 0, false)]
                        };
                        for &(producers, replicas, qos) in &fd_axis {
                            let cell = run_cell(
                                matrix, method, scenario, devices, batch,
                                frontdoor, producers, replicas, qos,
                            )
                            .with_context(|| {
                                format!(
                                    "cell {method}×{scenario}×{devices}dev\
                                     ×b{batch}×fd{}×p{producers}×r{replicas}\
                                     ×q{}",
                                    frontdoor as u8, qos as u8
                                )
                            })?;
                            let fd_tag = if frontdoor {
                                format!(
                                    " fd p{producers} r{replicas} q{}",
                                    qos as u8
                                )
                            } else {
                                "            ".to_string()
                            };
                            progress(&format!(
                                "[{}/{total}] {method:<22} {scenario:<12} \
                                 {devices}dev b{batch:<3}{fd_tag} {} / \
                                 round (p50)",
                                cells.len() + 1,
                                super::human(cell.wall_p50_round_s),
                            ));
                            cells.push(cell);
                        }
                    }
                }
            }
        }
    }
    Ok(BenchReport { matrix: matrix.clone(), cells })
}

fn str_arr(xs: &[String]) -> Json {
    Json::Arr(xs.iter().map(|s| Json::Str(s.clone())).collect())
}

fn u64_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&n| Json::U64(n as u64)).collect())
}

fn u64s(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&n| Json::U64(n)).collect())
}

fn f64s(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::F64(x)).collect())
}

/// Serialize a report to the `BENCH_serving.json` schema.
pub fn report_to_json(report: &BenchReport) -> String {
    let m = &report.matrix;
    let mut root = Json::obj();
    root.push("schema", Json::Str(BENCH_SCHEMA.into()));
    root.push("model", Json::Str(m.model.clone()));
    root.push("prompt_len", Json::U64(m.prompt_len as u64));
    root.push("output_len", Json::U64(m.output_len as u64));
    root.push("warmup_rounds", Json::U64(m.warmup_rounds as u64));
    root.push("seed", Json::U64(m.seed));
    root.push("methods", str_arr(&m.methods));
    root.push("scenarios", str_arr(&m.scenarios));
    root.push("devices", u64_arr(&m.devices));
    root.push("batches", u64_arr(&m.batches));
    // JSON's closest stable encoding for the bool axis: 0/1 integers
    root.push(
        "frontdoors",
        Json::Arr(
            m.frontdoor.iter().map(|&b| Json::U64(b as u64)).collect(),
        ),
    );
    root.push("producers", u64_arr(&m.producers));
    root.push("replicas", u64_arr(&m.replicas));
    root.push(
        "qos_axis",
        Json::Arr(m.qos.iter().map(|&b| Json::U64(b as u64)).collect()),
    );
    let mut cells = Vec::with_capacity(report.cells.len());
    for c in &report.cells {
        let mut o = Json::obj();
        o.push("method", Json::Str(c.method.clone()));
        o.push("scenario", Json::Str(c.scenario.clone()));
        o.push("devices", Json::U64(c.devices as u64));
        o.push("batch", Json::U64(c.batch as u64));
        o.push("rounds", Json::U64(c.rounds as u64));
        o.push("wall_total_s", Json::F64(c.wall_total_s));
        o.push("wall_p50_round_s", Json::F64(c.wall_p50_round_s));
        o.push("wall_p95_round_s", Json::F64(c.wall_p95_round_s));
        o.push("modeled_duration_s", Json::F64(c.modeled_duration_s));
        o.push("modeled_tok_s", Json::F64(c.modeled_tok_s));
        o.push("decode_tokens", Json::U64(c.decode_tokens));
        o.push("prefill_tokens", Json::U64(c.prefill_tokens));
        o.push("hi_fraction", Json::F64(c.hi_fraction));
        o.push("migrated_bytes", Json::U64(c.migrated_bytes));
        o.push("promotions", Json::U64(c.transitions.promotions));
        o.push("demotions", Json::U64(c.transitions.demotions));
        o.push("deferred", Json::U64(c.transitions.deferred));
        o.push("rejected", Json::U64(c.transitions.rejected));
        o.push("published", Json::U64(c.transitions.published));
        o.push("evictions", Json::U64(c.transitions.evictions));
        o.push("drift_events", Json::U64(c.drift_events));
        o.push(
            "drift_recovery_ticks",
            Json::U64(c.drift_recovery_ticks),
        );
        o.push("frontdoor", Json::U64(c.frontdoor as u64));
        o.push("producers", Json::U64(c.producers as u64));
        o.push("replicas", Json::U64(c.replicas as u64));
        o.push("fd_lane_admitted", u64s(&c.fd_lane_admitted));
        o.push("fd_lane_rejected", u64s(&c.fd_lane_rejected));
        o.push("fd_lane_deadline_miss", u64s(&c.fd_lane_deadline_miss));
        o.push("fd_lane_ttft_p50_s", f64s(&c.fd_lane_ttft_p50_s));
        o.push("fd_lane_ttft_p95_s", f64s(&c.fd_lane_ttft_p95_s));
        o.push("fd_submit_p50_s", Json::F64(c.fd_submit_p50_s));
        o.push("fd_submit_p95_s", Json::F64(c.fd_submit_p95_s));
        o.push("qos", Json::U64(c.qos as u64));
        o.push("qos_charged", u64s(&c.qos_charged));
        o.push("qos_refunded", u64s(&c.qos_refunded));
        cells.push(o);
    }
    root.push("cells", Json::Arr(cells));
    root.render()
}

/// Validate a `BENCH_serving.json` document against the schema contract:
/// the schema tag, the axis arrays, every required key in every cell,
/// and full matrix coverage (one cell per method × scenario × device ×
/// batch × frontdoor combination, with front-door cells fanned out over
/// the producer × replica axes and direct cells pinned to
/// producers = replicas = 0). Every f64 cell value must be finite —
/// a NaN or infinity in a trajectory report would poison downstream
/// comparisons silently.
pub fn validate_report_json(text: &str) -> Result<()> {
    let doc = json::parse(text).context("BENCH_serving.json parse")?;
    let schema = doc
        .get("schema")
        .and_then(|v| v.as_str())
        .context("missing schema tag")?;
    if schema != BENCH_SCHEMA {
        bail!("schema {schema:?}, expected {BENCH_SCHEMA:?}");
    }
    for key in ["model", "prompt_len", "output_len", "seed"] {
        if doc.get(key).is_none() {
            bail!("missing header key {key:?}");
        }
    }
    let strings = |key: &str| -> Result<Vec<String>> {
        doc.get(key)
            .and_then(|v| v.as_arr())
            .with_context(|| format!("missing axis {key:?}"))?
            .iter()
            .map(|v| {
                v.as_str()
                    .map(String::from)
                    .with_context(|| format!("non-string in {key:?}"))
            })
            .collect()
    };
    let nums = |key: &str| -> Result<Vec<u64>> {
        doc.get(key)
            .and_then(|v| v.as_arr())
            .with_context(|| format!("missing axis {key:?}"))?
            .iter()
            .map(|v| {
                v.as_u64()
                    .with_context(|| format!("non-integer in {key:?}"))
            })
            .collect()
    };
    let methods = strings("methods")?;
    let scenarios = strings("scenarios")?;
    let devices = nums("devices")?;
    let batches = nums("batches")?;
    let frontdoors = nums("frontdoors")?;
    let producers = nums("producers")?;
    let replicas = nums("replicas")?;
    let qos_axis = nums("qos_axis")?;
    let cells =
        doc.get("cells").and_then(|v| v.as_arr()).context("missing cells")?;
    let fd_cells: usize = frontdoors
        .iter()
        .map(|&f| {
            if f != 0 {
                producers.len().max(1)
                    * replicas.len().max(1)
                    * qos_axis.len().max(1)
            } else {
                1
            }
        })
        .sum();
    let expected = methods.len()
        * scenarios.len()
        * devices.len()
        * batches.len()
        * fd_cells;
    if cells.len() != expected {
        bail!("{} cells, expected {expected} (full matrix)", cells.len());
    }
    let mut seen = std::collections::HashSet::new();
    for (i, cell) in cells.iter().enumerate() {
        for &key in CELL_KEYS {
            let v = cell
                .get(key)
                .with_context(|| format!("cell {i}: missing key {key:?}"))?;
            // `is_finite` and not just `is_some`: a JSON number like
            // 1e999 parses to an f64 infinity, and an in-memory NaN
            // would otherwise sail through a pre-write self-check
            let ok = match key {
                "method" | "scenario" => v.as_str().is_some(),
                "wall_total_s" | "wall_p50_round_s" | "wall_p95_round_s"
                | "modeled_duration_s" | "modeled_tok_s" | "hi_fraction"
                | "fd_submit_p50_s" | "fd_submit_p95_s" => {
                    v.as_f64().map_or(false, f64::is_finite)
                }
                "fd_lane_admitted" | "fd_lane_rejected"
                | "fd_lane_deadline_miss" | "qos_charged"
                | "qos_refunded" => v
                    .as_arr()
                    .map(|xs| xs.iter().all(|x| x.as_u64().is_some()))
                    .unwrap_or(false),
                "fd_lane_ttft_p50_s" | "fd_lane_ttft_p95_s" => v
                    .as_arr()
                    .map(|xs| {
                        xs.iter().all(|x| {
                            x.as_f64().map_or(false, f64::is_finite)
                        })
                    })
                    .unwrap_or(false),
                _ => v.as_u64().is_some(),
            };
            if !ok {
                bail!("cell {i}: key {key:?} has wrong type ({v:?})");
            }
        }
        // front-door cells carry one entry per lane; direct cells none
        let fd = cell.get("frontdoor").unwrap().as_u64().unwrap();
        let prod = cell.get("producers").unwrap().as_u64().unwrap();
        let repl = cell.get("replicas").unwrap().as_u64().unwrap();
        let qos = cell.get("qos").unwrap().as_u64().unwrap();
        if fd == 0 {
            if prod != 0 {
                bail!(
                    "cell {i}: direct cell with producers={prod} (must be 0)"
                );
            }
            if repl != 0 {
                bail!(
                    "cell {i}: direct cell with replicas={repl} (must be 0)"
                );
            }
            if qos != 0 {
                bail!("cell {i}: direct cell with qos={qos} (must be 0)");
            }
        } else {
            if !producers.contains(&prod) {
                bail!(
                    "cell {i}: producers={prod} outside the declared axis \
                     {producers:?}"
                );
            }
            if !replicas.contains(&repl) {
                bail!(
                    "cell {i}: replicas={repl} outside the declared axis \
                     {replicas:?}"
                );
            }
            if !qos_axis.contains(&qos) {
                bail!(
                    "cell {i}: qos={qos} outside the declared axis \
                     {qos_axis:?}"
                );
            }
        }
        let want_len = if fd != 0 { 3 } else { 0 };
        for key in [
            "fd_lane_admitted",
            "fd_lane_rejected",
            "fd_lane_deadline_miss",
            "fd_lane_ttft_p50_s",
            "fd_lane_ttft_p95_s",
        ] {
            let n = cell.get(key).unwrap().as_arr().unwrap().len();
            if n != want_len {
                bail!(
                    "cell {i}: {key} has {n} lanes, expected {want_len} \
                     (frontdoor={fd})"
                );
            }
        }
        // armed cells carry one ledger entry per class; others none
        let want_classes = if qos != 0 { 3 } else { 0 };
        for key in ["qos_charged", "qos_refunded"] {
            let n = cell.get(key).unwrap().as_arr().unwrap().len();
            if n != want_classes {
                bail!(
                    "cell {i}: {key} has {n} classes, expected \
                     {want_classes} (qos={qos})"
                );
            }
        }
        let coord = (
            cell.get("method").unwrap().as_str().unwrap().to_string(),
            cell.get("scenario").unwrap().as_str().unwrap().to_string(),
            cell.get("devices").unwrap().as_u64().unwrap(),
            cell.get("batch").unwrap().as_u64().unwrap(),
            fd,
            prod,
            repl,
            qos,
        );
        if !methods.contains(&coord.0)
            || !scenarios.contains(&coord.1)
            || !devices.contains(&coord.2)
            || !batches.contains(&coord.3)
            || !frontdoors.contains(&coord.4)
        {
            bail!("cell {i}: {coord:?} outside the declared axes");
        }
        if !seen.insert(coord.clone()) {
            bail!("cell {i}: duplicate coordinates {coord:?}");
        }
    }
    Ok(())
}

/// Human-readable summary table of a report.
pub fn render_table(report: &BenchReport) -> String {
    let mut t = Table::new(&[
        "method",
        "scenario",
        "dev",
        "batch",
        "fd",
        "prod",
        "repl",
        "qos",
        "rounds",
        "wall p50/round",
        "wall p95/round",
        "submit p50",
        "modeled tok/s",
        "fd-rej",
        "deferred",
        "migrated GB",
    ]);
    for c in &report.cells {
        t.row(&[
            c.method.clone(),
            c.scenario.clone(),
            c.devices.to_string(),
            c.batch.to_string(),
            if c.frontdoor { "y".into() } else { "-".into() },
            if c.frontdoor { c.producers.to_string() } else { "-".into() },
            if c.frontdoor { c.replicas.to_string() } else { "-".into() },
            if c.qos { "y".into() } else { "-".into() },
            c.rounds.to_string(),
            super::human(c.wall_p50_round_s),
            super::human(c.wall_p95_round_s),
            if c.frontdoor {
                super::human(c.fd_submit_p50_s)
            } else {
                "-".into()
            },
            format!("{:.0}", c.modeled_tok_s),
            c.fd_lane_rejected.iter().sum::<u64>().to_string(),
            c.transitions.deferred.to_string(),
            format!("{:.2}", c.migrated_bytes as f64 / 1e9),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_shapes() {
        let full = BenchMatrix::full("qwen30b-sim");
        // direct cells run once; fronted cells fan out over
        // producers × replicas × qos
        assert_eq!(
            full.n_cells(),
            BENCH_METHODS.len()
                * Scenario::names().len()
                * 2
                * 3
                * (1 + BENCH_PRODUCERS.len()
                    * BENCH_REPLICAS.len()
                    * BENCH_QOS.len())
        );
        // smoke spans both sides of the front-door axis plus
        // {serial, threaded} producers × {1, 2} fleet replicas ×
        // {off, on} qos on the fronted side: 1 + 2×2×2 = 9
        let smoke = BenchMatrix::smoke("phi-sim");
        assert_eq!(smoke.n_cells(), 9);
    }

    #[test]
    fn filter_narrows_axes_and_rejects_nonsense() {
        let mut m = BenchMatrix::full("qwen30b-sim");
        apply_filter(&mut m, "method=dynaexq,scenario=steady,batch=8")
            .unwrap();
        assert_eq!(m.methods, vec!["dynaexq".to_string()]);
        assert_eq!(m.scenarios, vec!["steady".to_string()]);
        assert_eq!(m.batches, vec![8]);
        // 1 method × 1 scenario × 2 devices × 1 batch ×
        // (1 direct + 2 producers × 2 replicas × 2 qos fronted) = 18
        assert_eq!(m.n_cells(), 18);
        // the producers/replicas/qos axes narrow fronted cells only
        apply_filter(&mut m, "producers=4").unwrap();
        assert_eq!(m.producers, vec![4]);
        assert_eq!(m.n_cells(), 10);
        apply_filter(&mut m, "replicas=1").unwrap();
        assert_eq!(m.replicas, vec![1]);
        assert_eq!(m.n_cells(), 6);
        apply_filter(&mut m, "qos=off").unwrap();
        assert_eq!(m.qos, vec![false]);
        assert_eq!(m.n_cells(), 4);
        // a single cell
        apply_filter(&mut m, "devices=1,frontdoor=off").unwrap();
        assert_eq!(m.n_cells(), 1);
        assert_eq!(m.frontdoor, vec![false]);
        // unknown keys and emptied axes are errors, not silent no-ops
        let mut m = BenchMatrix::full("qwen30b-sim");
        let err =
            apply_filter(&mut m, "model=phi-sim").unwrap_err().to_string();
        assert!(err.contains("unknown filter key"), "{err}");
        let mut m = BenchMatrix::full("qwen30b-sim");
        let err =
            apply_filter(&mut m, "method=nope").unwrap_err().to_string();
        assert!(err.contains("no cells"), "{err}");
        let mut m = BenchMatrix::full("qwen30b-sim");
        assert!(apply_filter(&mut m, "frontdoor=maybe").is_err());
        let mut m = BenchMatrix::full("qwen30b-sim");
        assert!(apply_filter(&mut m, "qos=maybe").is_err());
    }

    #[test]
    fn validator_rejects_missing_cells_and_keys() {
        // A report claiming axes it does not cover must fail validation.
        let matrix = BenchMatrix::smoke("phi-sim");
        let report = BenchReport { matrix, cells: Vec::new() };
        let text = report_to_json(&report);
        let err = validate_report_json(&text).unwrap_err().to_string();
        assert!(err.contains("0 cells"), "{err}");
        // a tampered cell key must fail too
        let mut matrix = BenchMatrix::smoke("phi-sim");
        matrix.frontdoor = vec![false, true];
        matrix.producers = vec![1, 2];
        matrix.replicas = vec![1];
        matrix.qos = vec![false];
        let direct =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, false, 0, 0, false)
                .unwrap();
        let fronted =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, true, 1, 1, false)
                .unwrap();
        let threaded =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, true, 2, 1, false)
                .unwrap();
        assert!(direct.fd_lane_admitted.is_empty());
        assert_eq!(direct.producers, 0);
        assert_eq!(direct.replicas, 0);
        assert_eq!(fronted.fd_lane_admitted.len(), 3);
        assert_eq!(threaded.producers, 2);
        // threaded admission must agree with the serial reference on
        // every modeled outcome (wall-clock aside)
        assert_eq!(fronted.fd_lane_admitted, threaded.fd_lane_admitted);
        assert_eq!(fronted.fd_lane_rejected, threaded.fd_lane_rejected);
        assert_eq!(fronted.decode_tokens, threaded.decode_tokens);
        let report = BenchReport {
            matrix,
            cells: vec![direct, fronted, threaded],
        };
        let good = report_to_json(&report);
        validate_report_json(&good).unwrap();
        let bad = good.replace("\"hi_fraction\"", "\"hi_frac\"");
        assert!(validate_report_json(&bad).is_err());
        let bad = good.replace("\"fd_lane_rejected\"", "\"fd_rej\"");
        assert!(validate_report_json(&bad).is_err());
        let bad = good.replace("\"fd_submit_p50_s\"", "\"fd_sub\"");
        assert!(validate_report_json(&bad).is_err());
        let bad = good.replace("\"replicas\"", "\"repls\"");
        assert!(validate_report_json(&bad).is_err());
        let bad = good.replace("\"qos_charged\"", "\"qos_ch\"");
        assert!(validate_report_json(&bad).is_err());
    }

    #[test]
    fn validator_rejects_non_finite_f64_values() {
        // A JSON number like 1e999 parses to f64::INFINITY — the
        // validator must reject it, not wave it through as "a number"
        // (the percentile/NaN regression class of PR 8).
        let mut matrix = BenchMatrix::smoke("phi-sim");
        matrix.frontdoor = vec![false];
        matrix.producers = vec![1];
        matrix.replicas = vec![1];
        matrix.qos = vec![false];
        let cell =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, false, 0, 0, false)
                .unwrap();
        let good = report_to_json(&BenchReport { matrix, cells: vec![cell] });
        validate_report_json(&good).unwrap();
        // splice an infinite value over hi_fraction's finite one
        let key = "\"hi_fraction\":";
        let start = good.find(key).unwrap() + key.len();
        let end = start
            + good[start..]
                .find(|c| c == ',' || c == '}')
                .expect("value terminator");
        let bad = format!("{}1e999{}", &good[..start], &good[end..]);
        let err = validate_report_json(&bad).unwrap_err().to_string();
        assert!(err.contains("hi_fraction"), "{err}");
    }

    #[test]
    fn fleet_cells_run_deterministically_and_validate() {
        // A 2-replica fleet cell must produce byte-stable modeled
        // outcomes across identical runs, and a full smoke matrix
        // (which includes the fleet fan-out) must validate.
        let matrix = BenchMatrix::smoke("phi-sim");
        let a =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, true, 1, 2, false)
                .unwrap();
        let b =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, true, 1, 2, false)
                .unwrap();
        assert_eq!(a.replicas, 2);
        assert!(a.decode_tokens > 0);
        assert_eq!(a.fd_lane_admitted.len(), 3);
        assert_eq!(a.decode_tokens, b.decode_tokens);
        assert_eq!(a.prefill_tokens, b.prefill_tokens);
        assert_eq!(a.fd_lane_admitted, b.fd_lane_admitted);
        assert_eq!(a.fd_lane_rejected, b.fd_lane_rejected);
        assert_eq!(a.migrated_bytes, b.migrated_bytes);
        assert_eq!(a.transitions, b.transitions);
        assert_eq!(a.fd_lane_ttft_p50_s, b.fd_lane_ttft_p50_s);
        // threaded producers against the fleet door agree with serial
        let c =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, true, 2, 2, false)
                .unwrap();
        assert_eq!(a.fd_lane_admitted, c.fd_lane_admitted);
        assert_eq!(a.decode_tokens, c.decode_tokens);
        let report = run_matrix(&matrix, |_| {}).unwrap();
        assert_eq!(report.cells.len(), 9);
        validate_report_json(&report_to_json(&report)).unwrap();
    }

    #[test]
    fn qos_cells_balance_the_ledger_and_match_unarmed_baseline() {
        let matrix = BenchMatrix::smoke("phi-sim");
        // single-engine fronted cell with the tiered config armed
        let on =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, true, 1, 1, true)
                .unwrap();
        assert!(on.qos);
        assert_eq!(on.qos_charged.len(), 3);
        assert_eq!(on.qos_refunded.len(), 3);
        // steady admits and completes every request un-chunked, so the
        // per-class ledger balances exactly
        assert_eq!(on.qos_charged, on.qos_refunded);
        assert!(on.qos_charged.iter().sum::<u64>() > 0);
        // arming QoS with a single scenario class must not change the
        // modeled serving outcome (degenerate collapse at equal weights
        // is covered by qos_props; here the armed cell still serves the
        // same request stream)
        let off =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, true, 1, 1, false)
                .unwrap();
        assert!(!off.qos);
        assert!(off.qos_charged.is_empty());
        assert_eq!(on.fd_lane_admitted, off.fd_lane_admitted);
        assert_eq!(on.decode_tokens, off.decode_tokens);
        // fleet variant balances too
        let fleet_on =
            run_cell(&matrix, "dynaexq", "steady", 1, 1, true, 1, 2, true)
                .unwrap();
        assert!(fleet_on.qos);
        assert_eq!(fleet_on.qos_charged, fleet_on.qos_refunded);
        assert!(fleet_on.qos_charged.iter().sum::<u64>() > 0);
    }
}
