//! Minimal JSON writer + parser (serde is not in the offline crate set).
//!
//! Exactly what the bench harness needs: deterministic serialization of
//! `BENCH_serving.json` (object key order preserved) and a strict
//! recursive-descent parser the `bench_smoke` suite uses to assert the
//! emitted file is schema-valid. Not a general-purpose JSON library: no
//! `\uXXXX` surrogate pairs, numbers parse through `f64`.

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects preserve insertion order (diff-friendly files).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Unsigned integer — counters (token counts, bytes) stay exact.
    U64(u64),
    /// Any other number. Non-finite values serialize as `null`.
    F64(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a field to an object (panics on non-objects — builder use).
    pub fn push(&mut self, key: &str, value: Json) -> &mut Json {
        match self {
            Json::Obj(fields) => fields.push((key.to_string(), value)),
            other => panic!("push on non-object {other:?}"),
        }
        self
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::U64(n) => Some(*n),
            Json::F64(x) if x.fract() == 0.0 && *x >= 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::U64(n) => Some(*n as f64),
            Json::F64(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation (stable, diffable output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = |out: &mut String, n: usize| {
            for _ in 0..n {
                out.push_str("  ");
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::U64(n) => out.push_str(&n.to_string()),
            Json::F64(x) => {
                if x.is_finite() {
                    // Rust's shortest-roundtrip Display is always a valid
                    // JSON number (no exponent-only or hex forms).
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                if xs.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    pad(out, indent + 1);
                    x.write(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (strict: exactly one value plus whitespace).
pub fn parse(text: &str) -> Result<Json> {
    let bytes = text.as_bytes();
    let mut pos = 0;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        bail!("trailing bytes at offset {pos}");
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<()> {
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        bail!("expected {:?} at offset {}", c as char, *pos)
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    let Some(&c) = b.get(*pos) else { bail!("unexpected end of input") };
    match c {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_number(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        bail!("bad literal at offset {}", *pos)
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).unwrap();
    if !s.contains(['.', 'e', 'E', '-']) {
        if let Ok(n) = s.parse::<u64>() {
            return Ok(Json::U64(n));
        }
    }
    s.parse::<f64>()
        .map(Json::F64)
        .map_err(|_| anyhow!("bad number {s:?} at offset {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        let Some(&c) = b.get(*pos) else { bail!("unterminated string") };
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let Some(&e) = b.get(*pos) else {
                    bail!("unterminated escape")
                };
                *pos += 1;
                match e {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hex = b
                            .get(*pos..*pos + 4)
                            .ok_or_else(|| anyhow!("short \\u escape"))?;
                        *pos += 4;
                        let n = u32::from_str_radix(
                            std::str::from_utf8(hex)?,
                            16,
                        )?;
                        out.push(
                            char::from_u32(n)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?,
                        );
                    }
                    other => bail!("bad escape \\{}", other as char),
                }
            }
            c => {
                // Re-assemble multi-byte UTF-8 sequences.
                if c < 0x80 {
                    out.push(c as char);
                } else {
                    let len = utf8_len(c)?;
                    let chunk = b
                        .get(*pos - 1..*pos - 1 + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                    out.push_str(std::str::from_utf8(chunk)?);
                    *pos += len - 1;
                }
            }
        }
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'{')?;
    let mut fields = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(fields));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        expect(b, pos, b':')?;
        let value = parse_value(b, pos)?;
        fields.push((key, value));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b'}') => {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            _ => bail!("expected ',' or '}}' at offset {}", *pos),
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    expect(b, pos, b'[')?;
    let mut xs = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(xs));
    }
    loop {
        xs.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(&b',') => *pos += 1,
            Some(&b']') => {
                *pos += 1;
                return Ok(Json::Arr(xs));
            }
            _ => bail!("expected ',' or ']' at offset {}", *pos),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let mut obj = Json::obj();
        obj.push("schema", Json::Str("x/v1".into()));
        obj.push("n", Json::U64(18_446_744_073_709_551_615));
        obj.push("x", Json::F64(0.12345678912345));
        obj.push("neg", Json::F64(-3.5));
        obj.push("flag", Json::Bool(true));
        obj.push("none", Json::Null);
        obj.push(
            "arr",
            Json::Arr(vec![Json::U64(1), Json::Str("two\n\"q\"".into())]),
        );
        obj.push("empty_arr", Json::Arr(vec![]));
        obj.push("empty_obj", Json::obj());
        let text = obj.render();
        let back = parse(&text).unwrap();
        assert_eq!(back, obj);
        // lookups
        assert_eq!(back.get("schema").unwrap().as_str(), Some("x/v1"));
        assert_eq!(back.get("n").unwrap().as_u64(), Some(u64::MAX));
        assert_eq!(
            back.get("x").unwrap().as_f64(),
            Some(0.12345678912345)
        );
        assert_eq!(back.get("arr").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn nonfinite_floats_serialize_as_null() {
        let mut obj = Json::obj();
        obj.push("bad", Json::F64(f64::NAN));
        let back = parse(&obj.render()).unwrap();
        assert_eq!(back.get("bad"), Some(&Json::Null));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_unicode_and_escapes() {
        let v = parse("{\"k\": \"caf\\u00e9 µs\"}").unwrap();
        assert_eq!(v.get("k").unwrap().as_str(), Some("café µs"));
    }
}
