//! Per-op cost model at paper-scale logical dimensions.
//!
//! Quality experiments execute the small simulated model for real; *timing*
//! experiments (TTFT/TPOP/throughput sweeps) need latencies with the paper's
//! shape, which depend on the **real** models' tensor sizes. `LogicalDims`
//! reconstructs those from the paper's Table 3, and `CostModel` converts
//! (op, shape, precision) → seconds on the configured device using a
//! roofline: `time = max(flops / peak_flops, bytes / hbm_bw) + launch`.

use crate::config::{DeviceConfig, ModelPreset};
use crate::model::Precision;

/// Paper-scale dimensions of one evaluation model (Table 3).
#[derive(Clone, Debug)]
pub struct LogicalDims {
    /// Hidden size.
    pub d: usize,
    /// Per-expert FFN dim.
    pub ff: usize,
    /// Transformer layers (the paper's layer count, not the executed one).
    pub layers: usize,
    pub n_experts: usize,
    pub top_k: usize,
    pub n_shared: usize,
    pub vocab: usize,
}

impl LogicalDims {
    /// Dims reconstructed from paper Table 3 (expert-weight totals match to
    /// within a few percent — see DESIGN.md §2).
    pub fn for_preset(preset: &ModelPreset) -> Self {
        match preset.name {
            // 54 GB expert weights = 48L × 128E × 3·2048·768 × 2B ≈ 55 GB
            // (the 3-tier scenario serves the same model through a deeper
            // ladder — identical tensor geometry)
            "qwen30b-sim" | "qwen30b-3tier" => Self {
                d: 2048,
                ff: 768,
                layers: 48,
                n_experts: 128,
                top_k: 8,
                n_shared: 0,
                vocab: 151_936,
            },
            // 37 GB at int4 = 48L × 512E × 3·2048·512 × 0.5B ≈ 39 GB
            "qwen80b-sim" => Self {
                d: 2048,
                ff: 512,
                layers: 48,
                n_experts: 512,
                top_k: 10,
                n_shared: 1,
                vocab: 151_936,
            },
            // 75 GB expert weights = 32L × 16E × 3·4096·6400 × 2B ≈ 80 GB
            "phi-sim" => Self {
                d: 4096,
                ff: 6400,
                layers: 32,
                n_experts: 16,
                top_k: 2,
                n_shared: 0,
                vocab: 32_064,
            },
            other => panic!("no logical dims for preset {other}"),
        }
    }

    /// Parameters of one expert (three FFN matrices).
    pub fn expert_params(&self) -> usize {
        3 * self.d * self.ff
    }

    /// Bytes of one expert at precision `p` (packed weights + scales).
    pub fn expert_bytes(&self, p: Precision) -> usize {
        match p {
            Precision::Fp16 => self.expert_params() * 2,
            _ => {
                self.expert_params() / p.pack() + (2 * self.ff + self.d) * 4
            }
        }
    }

    /// Total expert bytes when every expert is at `p`.
    pub fn total_expert_bytes(&self, p: Precision) -> usize {
        self.layers * (self.n_experts + self.n_shared) * self.expert_bytes(p)
    }

    /// KV-cache bytes per token (fp16 K+V across layers).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.layers * self.d * 2
    }
}

/// Per-device migration-stream bandwidth in an `n_devices` serving group.
///
/// Each device keeps a dedicated PCIe link, but all links share the host
/// root complex / host-memory path: a single device gets the full link
/// bandwidth, while an n-device group splits `host_agg_bytes_per_s` evenly
/// and each device's migration stream is capped at
/// `min(pcie_bytes_per_s, host_agg_bytes_per_s / n)`. A 1-device group
/// returns `pcie_bytes_per_s` exactly (no contention term at all), so a
/// `DeviceGroup` of one reproduces the single-GPU transfer times bit for
/// bit (DESIGN.md §9).
pub fn migration_link_bytes_per_s(dev: &DeviceConfig, n_devices: usize) -> f64 {
    assert!(n_devices >= 1, "a group has at least one device");
    if n_devices == 1 {
        return dev.pcie_bytes_per_s;
    }
    dev.pcie_bytes_per_s
        .min(dev.host_agg_bytes_per_s / n_devices as f64)
}

/// Converts op shapes into modeled seconds on the configured device.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub dims: LogicalDims,
    pub dev: DeviceConfig,
}

impl CostModel {
    pub fn new(preset: &ModelPreset, dev: DeviceConfig) -> Self {
        Self { dims: LogicalDims::for_preset(preset), dev }
    }

    fn roofline(&self, flops: f64, bytes: f64) -> f64 {
        let compute = flops / self.dev.flops_per_s;
        let memory = bytes / self.dev.hbm_bytes_per_s;
        compute.max(memory) + self.dev.launch_overhead_s
    }

    /// Host→device transfer of `bytes` over PCIe.
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        bytes as f64 / self.dev.pcie_bytes_per_s
    }

    /// Expert FFN over `tokens` routed tokens at precision `p`.
    ///
    /// Weight bytes shrink with precision, so low-bit experts are *faster*
    /// in the bandwidth-bound decode regime — the effect HOBBIT exploits
    /// and the reason static-quant TTFT is lowest in the paper's Fig. 6.
    pub fn expert_time(&self, tokens: usize, p: Precision) -> f64 {
        let flops = 2.0 * tokens as f64 * self.dims.expert_params() as f64;
        let bytes = self.dims.expert_bytes(p) as f64
            + (tokens * 2 * (self.dims.d + self.dims.ff) * 2) as f64;
        self.roofline(flops, bytes)
    }

    /// Causal attention over a `tokens`-long prompt (one layer, prefill).
    pub fn attn_prefill_time(&self, tokens: usize) -> f64 {
        let t = tokens as f64;
        let d = self.dims.d as f64;
        let flops = 4.0 * t * d * d + 2.0 * t * t * d;
        let bytes = 4.0 * d * d * 2.0 + 2.0 * t * d * 2.0;
        self.roofline(flops, bytes)
    }

    /// One decode step of attention for `batch` sequences at context `ctx`.
    pub fn attn_decode_time(&self, batch: usize, ctx: usize) -> f64 {
        let b = batch as f64;
        let d = self.dims.d as f64;
        let s = ctx as f64;
        let flops = b * (4.0 * d * d + 2.0 * s * d);
        // KV cache reads dominate decode attention
        let bytes = 4.0 * d * d * 2.0 + b * s * d * 2.0 * 2.0;
        self.roofline(flops, bytes)
    }

    /// Router matmul + top-k over `tokens`.
    pub fn router_time(&self, tokens: usize) -> f64 {
        let flops =
            2.0 * tokens as f64 * self.dims.d as f64 * self.dims.n_experts as f64;
        let bytes = (self.dims.d * self.dims.n_experts) as f64 * 2.0;
        self.roofline(flops, bytes)
    }

    /// Final logits projection over `tokens`.
    pub fn lm_head_time(&self, tokens: usize) -> f64 {
        let flops =
            2.0 * tokens as f64 * self.dims.d as f64 * self.dims.vocab as f64;
        let bytes = (self.dims.d * self.dims.vocab) as f64 * 2.0;
        self.roofline(flops, bytes)
    }

    /// Embedding lookup (bandwidth only).
    pub fn embed_time(&self, tokens: usize) -> f64 {
        self.roofline(0.0, (tokens * self.dims.d * 2) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cm(name: &str) -> CostModel {
        let p = ModelPreset::by_name(name).unwrap();
        CostModel::new(&p, DeviceConfig::default())
    }

    #[test]
    fn table3_expert_totals_roughly_match() {
        // Paper Table 3: 30B → 54 GB fp16 experts; 80B → 37 GB int4;
        // Phi → 75 GB fp16.
        let gb = |b: usize| b as f64 / 1e9;
        let t30 = LogicalDims::for_preset(&ModelPreset::qwen30b_sim());
        assert!((gb(t30.total_expert_bytes(Precision::Fp16)) - 58.0).abs() < 8.0);
        let t80 = LogicalDims::for_preset(&ModelPreset::qwen80b_sim());
        assert!((gb(t80.total_expert_bytes(Precision::Int4)) - 39.0).abs() < 6.0);
        let phi = LogicalDims::for_preset(&ModelPreset::phi_sim());
        assert!((gb(phi.total_expert_bytes(Precision::Fp16)) - 80.0).abs() < 10.0);
    }

    #[test]
    fn lower_precision_experts_faster_when_bw_bound() {
        let c = cm("qwen30b-sim");
        // decode regime: 1 token → bandwidth bound
        let fp = c.expert_time(1, Precision::Fp16);
        let i4 = c.expert_time(1, Precision::Int4);
        let i2 = c.expert_time(1, Precision::Int2);
        assert!(i4 < fp);
        assert!(i2 < i4);
    }

    #[test]
    fn transfer_slower_than_compute() {
        // Moving an expert over PCIe must cost much more than running it —
        // the structural premise of the paper (offloading stalls).
        let c = cm("qwen30b-sim");
        let bytes = c.dims.expert_bytes(Precision::Fp16);
        assert!(c.transfer_time(bytes) > 5.0 * c.expert_time(8, Precision::Fp16));
    }

    #[test]
    fn prefill_scales_superlinearly() {
        let c = cm("qwen30b-sim");
        let t512 = c.attn_prefill_time(512);
        let t2048 = c.attn_prefill_time(2048);
        assert!(t2048 > 4.0 * t512);
    }

    #[test]
    fn one_device_link_is_exactly_the_pcie_link() {
        // even a dev config whose aggregate is below the per-link speed
        // must not perturb the single-GPU system
        let mut dev = DeviceConfig::default();
        dev.host_agg_bytes_per_s = 10e9;
        assert_eq!(migration_link_bytes_per_s(&dev, 1), dev.pcie_bytes_per_s);
    }

    #[test]
    fn link_bandwidth_contends_past_the_host_aggregate() {
        let dev = DeviceConfig::default(); // 25 GB/s link, 50 GB/s host
        assert_eq!(migration_link_bytes_per_s(&dev, 2), 25e9);
        assert_eq!(migration_link_bytes_per_s(&dev, 4), 12.5e9);
        let mut prev = f64::INFINITY;
        for n in 1..=8 {
            let bw = migration_link_bytes_per_s(&dev, n);
            assert!(bw <= prev, "bandwidth must not grow with group size");
            assert!(bw > 0.0);
            prev = bw;
        }
    }

    #[test]
    fn decode_scales_with_batch_and_ctx() {
        let c = cm("phi-sim");
        assert!(c.attn_decode_time(8, 512) > c.attn_decode_time(1, 512));
        assert!(c.attn_decode_time(4, 2048) > c.attn_decode_time(4, 256));
    }
}
