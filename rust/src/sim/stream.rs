//! Stream timelines and the modeled clock.
//!
//! A [`Stream`] is an ordered work queue with a tail time: scheduling work
//! at `now` starts at `max(now, tail)` and completes `duration` later —
//! the same semantics as a CUDA stream. DynaExq uses two streams (compute,
//! migration) so transition traffic never implicitly synchronizes with the
//! forward pass; the ExpertFlow baseline issues on-demand fetches whose
//! completion the compute stream must *wait* for, which is where its GPU
//! waiting time (paper Fig. 1) comes from.

/// Modeled wall-clock in seconds.
#[derive(Debug, Clone, Default)]
pub struct Clock {
    now: f64,
}

impl Clock {
    pub fn new() -> Self {
        Self { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance to `t` (no-op if `t` is in the past).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }

    pub fn advance_by(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0);
        self.now += dt;
    }
}

/// An ordered stream of modeled work.
#[derive(Debug, Clone, Default)]
pub struct Stream {
    tail: f64,
    busy: f64,
}

impl Stream {
    pub fn new() -> Self {
        Self { tail: 0.0, busy: 0.0 }
    }

    /// Schedule `duration` seconds of work issued at `now`; returns the
    /// completion time.
    pub fn schedule(&mut self, now: f64, duration: f64) -> f64 {
        debug_assert!(duration >= 0.0);
        let start = now.max(self.tail);
        self.tail = start + duration;
        self.busy += duration;
        self.tail
    }

    /// Completion time of all currently queued work.
    pub fn tail(&self) -> f64 {
        self.tail
    }

    /// Total busy seconds scheduled so far (utilization accounting).
    pub fn busy(&self) -> f64 {
        self.busy
    }

    /// Seconds the caller must wait if it needs the stream drained at `now`
    /// (the paper's "GPU waiting latency" when applied to fetch events).
    pub fn wait_time(&self, now: f64) -> f64 {
        (self.tail - now).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_serializes_work() {
        let mut s = Stream::new();
        let d1 = s.schedule(0.0, 1.0);
        assert_eq!(d1, 1.0);
        // issued before the first completes → queues behind it
        let d2 = s.schedule(0.5, 1.0);
        assert_eq!(d2, 2.0);
        // issued after drain → starts immediately
        let d3 = s.schedule(5.0, 1.0);
        assert_eq!(d3, 6.0);
        assert_eq!(s.busy(), 3.0);
    }

    #[test]
    fn wait_time_accounting() {
        let mut s = Stream::new();
        s.schedule(0.0, 2.0);
        assert_eq!(s.wait_time(1.0), 1.0);
        assert_eq!(s.wait_time(3.0), 0.0);
    }

    #[test]
    fn clock_monotone() {
        let mut c = Clock::new();
        c.advance_to(2.0);
        c.advance_to(1.0);
        assert_eq!(c.now(), 2.0);
        c.advance_by(0.5);
        assert_eq!(c.now(), 2.5);
    }
}
