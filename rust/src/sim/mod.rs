//! Device simulation: the A6000-class GPU substituted by a cost model.
//!
//! The paper's performance phenomena — transfer stalls, overlap windows,
//! PCIe saturation, migration/compute contention — are functions of bytes,
//! bandwidths and stream overlap. This module models exactly those:
//!
//! * [`CostModel`] — per-op compute times and host↔device transfer times at
//!   the **paper-scale logical dims** (Qwen3-30B/80B, Phi-3.5-MoE, Table 3),
//!   so modeled latencies have the paper's shape;
//! * [`Stream`] — an ordered timeline (compute stream vs. migration stream)
//!   with event-based completion, the CUDA-stream analogue;
//! * numerics still execute for real via the PJRT runtime (quality is
//!   *measured*, never modeled) — see DESIGN.md §2 for the substitution
//!   argument.

pub mod cost;
pub mod stream;

pub use cost::{migration_link_bytes_per_s, CostModel, LogicalDims};
pub use stream::{Clock, Stream};
