//! Test support: a seeded property-test driver (proptest is not in the
//! offline crate set) and shared fixtures.

pub mod prop;
