//! Minimal property-test driver.
//!
//! `proptest` is unavailable offline; this driver covers the part that
//! matters for invariant testing — many randomized cases from a
//! deterministic per-property seed, with the failing case's seed printed so
//! a failure reproduces exactly (`Prop::with_seed`). No shrinking.

use crate::util::XorShiftRng;

/// A named property; the name hashes into the base seed so adding a
/// property never perturbs the cases another property sees.
pub struct Prop {
    name: String,
    base_seed: u64,
}

impl Prop {
    pub fn new(name: &str) -> Self {
        // FNV-1a over the name → stable per-property seed
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in name.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        // Env override lets CI diversify runs: DYNAEXQ_PROP_SEED=n
        let extra = std::env::var("DYNAEXQ_PROP_SEED")
            .ok()
            .and_then(|s| s.parse::<u64>().ok())
            .unwrap_or(0);
        Self { name: name.to_string(), base_seed: h ^ extra }
    }

    /// Run `cases` randomized cases. On panic, the case seed is printed.
    pub fn run<F: FnMut(&mut XorShiftRng)>(&mut self, cases: u32, mut f: F) {
        for i in 0..cases {
            let seed = self.base_seed.wrapping_add(i as u64);
            let mut rng = XorShiftRng::new(seed);
            let result = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| f(&mut rng)),
            );
            if let Err(e) = result {
                eprintln!(
                    "property '{}' failed at case {i} (seed {seed}); \
                     reproduce with Prop::with_seed({seed})",
                    self.name
                );
                std::panic::resume_unwind(e);
            }
        }
    }

    /// Re-run a single failing case by seed.
    pub fn with_seed<F: FnOnce(&mut XorShiftRng)>(seed: u64, f: F) {
        let mut rng = XorShiftRng::new(seed);
        f(&mut rng);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_cases() {
        let mut n = 0;
        Prop::new("counter").run(17, |_| n += 1);
        assert_eq!(n, 17);
    }

    #[test]
    fn deterministic_per_name() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        Prop::new("same").run(5, |r| a.push(r.next_u64()));
        Prop::new("same").run(5, |r| b.push(r.next_u64()));
        assert_eq!(a, b);
        let mut c = Vec::new();
        Prop::new("different").run(5, |r| c.push(r.next_u64()));
        assert_ne!(a, c);
    }
}
