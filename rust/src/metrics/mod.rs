//! Serving metrics: streaming latency collectors (TTFT / TPOP / E2E),
//! throughput, and migration counters — average and P99, matching what the
//! paper reports in §5.3.

use crate::util::{mean, percentile};

/// A named latency series (seconds).
#[derive(Debug, Clone, Default)]
pub struct LatencySeries {
    samples: Vec<f64>,
}

impl LatencySeries {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, seconds: f64) {
        self.samples.push(seconds);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn avg(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        percentile(&self.samples, 50.0)
    }

    pub fn p99(&self) -> f64 {
        percentile(&self.samples, 99.0)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(0.0, f64::max)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }

    /// Append every sample of `other` (fleet aggregation: replica series
    /// fold into one fleet-level series in replica-index order, so the
    /// merged percentiles are deterministic).
    pub fn extend(&mut self, other: &LatencySeries) {
        self.samples.extend_from_slice(&other.samples);
    }
}

/// Full serving-run metrics, one per experiment run.
#[derive(Debug, Clone, Default)]
pub struct ServingMetrics {
    /// Time-to-first-token per request.
    pub ttft: LatencySeries,
    /// Time-per-output-token per decode step.
    pub tpop: LatencySeries,
    /// End-to-end request latency.
    pub e2e: LatencySeries,
    /// Modeled GPU waiting time attributable to expert transfers.
    pub wait: LatencySeries,
    /// Tokens generated (decode) across the run.
    pub decode_tokens: u64,
    /// Tokens ingested (prefill) across the run.
    pub prefill_tokens: u64,
    /// Modeled run duration in seconds.
    pub duration_s: f64,
}

impl ServingMetrics {
    /// End-to-end throughput in tokens/s (prefill + decode).
    pub fn throughput(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        (self.prefill_tokens + self.decode_tokens) as f64 / self.duration_s
    }

    /// Decode-only throughput in tokens/s.
    pub fn decode_throughput(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.decode_tokens as f64 / self.duration_s
    }

    /// Fold another run's metrics into this one (fleet aggregation:
    /// per-replica engines each keep their own metrics; the fleet-level
    /// snapshot merges them in replica-index order). Latency series
    /// concatenate, token counters add, and the merged duration is the
    /// *max* — replicas serve concurrently on independent modeled
    /// clocks, so the fleet's span is its slowest replica's span.
    pub fn merge(&mut self, other: &ServingMetrics) {
        self.ttft.extend(&other.ttft);
        self.tpop.extend(&other.tpop);
        self.e2e.extend(&other.e2e);
        self.wait.extend(&other.wait);
        self.decode_tokens += other.decode_tokens;
        self.prefill_tokens += other.prefill_tokens;
        self.duration_s = self.duration_s.max(other.duration_s);
    }

    /// One-line summary for reports.
    pub fn summary(&self) -> String {
        format!(
            "ttft avg {:.3}s p99 {:.3}s | tpop avg {:.4}s p99 {:.4}s | \
             e2e avg {:.3}s p99 {:.3}s | {:.1} tok/s",
            self.ttft.avg(),
            self.ttft.p99(),
            self.tpop.avg(),
            self.tpop.p99(),
            self.e2e.avg(),
            self.e2e.p99(),
            self.throughput()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = LatencySeries::new();
        for i in 1..=100 {
            s.record(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.avg() - 50.5).abs() < 1e-9);
        assert_eq!(s.p99(), 99.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn throughput_math() {
        let mut m = ServingMetrics::default();
        m.decode_tokens = 300;
        m.prefill_tokens = 700;
        m.duration_s = 10.0;
        assert!((m.throughput() - 100.0).abs() < 1e-9);
        assert!((m.decode_throughput() - 30.0).abs() < 1e-9);
    }

    #[test]
    fn zero_duration_safe() {
        let m = ServingMetrics::default();
        assert_eq!(m.throughput(), 0.0);
    }

    #[test]
    fn merge_concatenates_series_and_takes_max_duration() {
        let mut a = ServingMetrics::default();
        a.ttft.record(1.0);
        a.decode_tokens = 10;
        a.prefill_tokens = 100;
        a.duration_s = 5.0;
        let mut b = ServingMetrics::default();
        b.ttft.record(2.0);
        b.ttft.record(3.0);
        b.decode_tokens = 4;
        b.prefill_tokens = 40;
        b.duration_s = 7.5;
        a.merge(&b);
        assert_eq!(a.ttft.samples(), &[1.0, 2.0, 3.0]);
        assert_eq!(a.decode_tokens, 14);
        assert_eq!(a.prefill_tokens, 140);
        assert_eq!(a.duration_s, 7.5);
    }
}
