//! Partitioned, fixed-granularity device-memory pools (§3.3).
//!
//! Expert weights live in dedicated pools — one per ladder rung
//! (`pool_t0` … `pool_tN`) — disjoint from the KV-cache region. Each pool
//! hands out fixed-size blocks from a
//! constant-time free list — allocation and reclamation are pointer
//! operations that never touch a general-purpose allocator, so background
//! transitions cannot inject allocator jitter into the token critical path,
//! and the address space cannot fragment.

use crate::util::lockorder::{LockRank, OrderedMutex};

/// A block allocation; freeing requires returning it to the same pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolAlloc {
    /// First block index.
    pub first_block: usize,
    /// Number of contiguous-or-not blocks composed into this allocation.
    pub n_blocks: usize,
    /// Logical payload bytes.
    pub bytes: usize,
}

/// Counters for fragmentation / latency analysis (ablation A4).
#[derive(Debug, Default, Clone)]
pub struct PoolStats {
    pub allocs: u64,
    pub frees: u64,
    pub failures: u64,
    pub peak_blocks_used: usize,
}

struct PoolInner {
    free: Vec<usize>, // LIFO free list of block ids
    blocks_used: usize,
    /// block id → next block id for multi-block allocations
    next: Vec<usize>,
    stats: PoolStats,
}

/// A fixed-granularity block pool.
pub struct BlockPool {
    name: &'static str,
    block_bytes: usize,
    n_blocks: usize,
    inner: OrderedMutex<PoolInner>,
}

const NO_BLOCK: usize = usize::MAX;

impl BlockPool {
    /// Create a pool of `capacity_bytes / block_bytes` blocks.
    ///
    /// `block_bytes` is chosen by the caller to balance internal
    /// fragmentation vs. bookkeeping — DynaExq aligns it to the expert size
    /// so one expert == one block in the common case.
    pub fn new(name: &'static str, capacity_bytes: usize, block_bytes: usize) -> Self {
        assert!(block_bytes > 0);
        let n_blocks = capacity_bytes / block_bytes;
        Self {
            name,
            block_bytes,
            n_blocks,
            inner: OrderedMutex::new(
                LockRank::Pool,
                PoolInner {
                    free: (0..n_blocks).rev().collect(),
                    blocks_used: 0,
                    next: vec![NO_BLOCK; n_blocks],
                    stats: PoolStats::default(),
                },
            ),
        }
    }

    /// Allocate `bytes` (composed from ⌈bytes/block⌉ blocks). O(#blocks of
    /// this allocation); returns None when the pool is exhausted (the caller
    /// must have failed admission earlier — see BudgetTracker).
    pub fn alloc(&self, bytes: usize) -> Option<PoolAlloc> {
        let need = crate::util::ceil_div(bytes.max(1), self.block_bytes);
        let mut g = self.inner.lock();
        if g.free.len() < need {
            g.stats.failures += 1;
            return None;
        }
        let first = g.free.pop().unwrap();
        let mut prev = first;
        for _ in 1..need {
            let b = g.free.pop().unwrap();
            g.next[prev] = b;
            prev = b;
        }
        g.next[prev] = NO_BLOCK;
        g.blocks_used += need;
        g.stats.allocs += 1;
        let used = g.blocks_used;
        g.stats.peak_blocks_used = g.stats.peak_blocks_used.max(used);
        Some(PoolAlloc { first_block: first, n_blocks: need, bytes })
    }

    /// Return an allocation's blocks to the free list. O(n_blocks).
    pub fn free(&self, alloc: PoolAlloc) {
        let mut g = self.inner.lock();
        let mut b = alloc.first_block;
        let mut returned = 0;
        while b != NO_BLOCK && returned < alloc.n_blocks {
            let nxt = g.next[b];
            g.next[b] = NO_BLOCK;
            g.free.push(b);
            returned += 1;
            b = nxt;
        }
        debug_assert_eq!(returned, alloc.n_blocks, "{}: chain broken", self.name);
        g.blocks_used -= returned;
        g.stats.frees += 1;
    }

    pub fn blocks_free(&self) -> usize {
        self.inner.lock().free.len()
    }

    pub fn blocks_used(&self) -> usize {
        self.inner.lock().blocks_used
    }

    pub fn capacity_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    pub fn stats(&self) -> PoolStats {
        self.inner.lock().stats.clone()
    }

    /// Invariant: used + free == capacity (no leaked blocks).
    pub fn consistent(&self) -> bool {
        let g = self.inner.lock();
        g.blocks_used + g.free.len() == self.n_blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn alloc_free_roundtrip() {
        let p = BlockPool::new("t", 1024, 256);
        assert_eq!(p.capacity_blocks(), 4);
        let a = p.alloc(256).unwrap();
        let b = p.alloc(512).unwrap(); // 2 blocks
        assert_eq!(p.blocks_used(), 3);
        p.free(a);
        p.free(b);
        assert_eq!(p.blocks_used(), 0);
        assert!(p.consistent());
    }

    #[test]
    fn exhaustion_fails_cleanly() {
        let p = BlockPool::new("t", 1024, 256);
        let _a = p.alloc(1024).unwrap();
        assert!(p.alloc(1).is_none());
        assert_eq!(p.stats().failures, 1);
    }

    #[test]
    fn zero_byte_alloc_takes_one_block() {
        let p = BlockPool::new("t", 1024, 256);
        let a = p.alloc(0).unwrap();
        assert_eq!(a.n_blocks, 1);
        p.free(a);
    }

    #[test]
    fn peak_tracking() {
        let p = BlockPool::new("t", 2048, 256);
        let a = p.alloc(1024).unwrap();
        p.free(a);
        let _b = p.alloc(256).unwrap();
        assert_eq!(p.stats().peak_blocks_used, 4);
    }

    #[test]
    fn prop_never_leaks_blocks() {
        // Property: any interleaving of allocs/frees conserves blocks and
        // double-free cannot occur via the chain encoding.
        let mut prop = Prop::new("pool_conservation");
        prop.run(40, |rng| {
            let blocks = 8 + rng.below(32);
            let bb = 64 + rng.below(512);
            let p = BlockPool::new("prop", blocks * bb, bb);
            let mut live: Vec<PoolAlloc> = Vec::new();
            for _ in 0..300 {
                if rng.below(2) == 0 {
                    let sz = 1 + rng.below(bb * 4);
                    if let Some(a) = p.alloc(sz) {
                        live.push(a);
                    }
                } else if !live.is_empty() {
                    let i = rng.below(live.len());
                    p.free(live.swap_remove(i));
                }
                assert!(p.consistent());
                let used: usize = live.iter().map(|a| a.n_blocks).sum();
                assert_eq!(p.blocks_used(), used);
            }
        });
    }

    #[test]
    fn prop_concurrent_alloc_free() {
        let mut prop = Prop::new("pool_concurrent");
        prop.run(5, |rng| {
            let p = std::sync::Arc::new(BlockPool::new("c", 64 * 256, 256));
            let mut hs = Vec::new();
            for t in 0..4 {
                let p = p.clone();
                let seed = rng.next_u64() ^ t;
                hs.push(std::thread::spawn(move || {
                    let mut r = crate::util::XorShiftRng::new(seed);
                    let mut live = Vec::new();
                    for _ in 0..500 {
                        if r.below(2) == 0 {
                            if let Some(a) = p.alloc(1 + r.below(512)) {
                                live.push(a);
                            }
                        } else if !live.is_empty() {
                            let i = r.below(live.len());
                            p.free(live.swap_remove(i));
                        }
                    }
                    for a in live {
                        p.free(a);
                    }
                }));
            }
            for h in hs {
                h.join().unwrap();
            }
            assert_eq!(p.blocks_used(), 0);
            assert!(p.consistent());
        });
    }
}
