//! Versioned Expert Residency (VER, §3.2), generalized to the N-rung
//! precision ladder.
//!
//! Each expert owns an *entry* with metadata for all supported versions and
//! exports a **stable handle**: immutable in identity, holding an atomic
//! pointer to the currently active (fully materialized) version. The compute
//! path resolves the handle with one atomic load; transitions publish by
//! swapping the pointer — publish-then-switch — so no kernel ever observes a
//! partially populated version. The atomic value is the *rung index* of the
//! active version; the ladder decodes it to a precision.
//!
//! The single invariant enforced here: **a handle always resolves to a
//! complete, resident weight version at some rung of the ladder.**

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use crate::model::{Precision, PrecisionLadder};
use crate::util::lockorder::{LockRank, OrderedMutex, OrderedMutexGuard};

use super::pools::PoolAlloc;

/// Flat expert identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExpertKey {
    pub layer: u16,
    pub expert: u16,
}

impl ExpertKey {
    pub fn new(layer: usize, expert: usize) -> Self {
        Self { layer: layer as u16, expert: expert as u16 }
    }

    pub fn flat(&self, n_experts: usize) -> usize {
        self.layer as usize * n_experts + self.expert as usize
    }
}

/// Residency states of an expert entry (§3.2), per ladder rung.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// The version at rung `tier` is resident; the handle points to it.
    Resident(usize),
    /// A version at rung `to` is in flight; the handle still points to the
    /// complete version at rung `from` (promotion when `to < from`,
    /// demotion when `to > from`).
    Transitioning { from: usize, to: usize },
}

impl Residency {
    /// The rung the handle currently resolves to.
    pub fn active_tier(self) -> usize {
        match self {
            Residency::Resident(t) => t,
            Residency::Transitioning { from, .. } => from,
        }
    }

    pub fn is_transitioning(self) -> bool {
        matches!(self, Residency::Transitioning { .. })
    }
}

/// Per-entry transition bookkeeping (guarded; off the compute path).
#[derive(Debug)]
pub struct EntryState {
    pub residency: Residency,
    /// Allocation backing the *active* version.
    pub active_alloc: Option<PoolAlloc>,
    /// Allocation backing an in-flight (not yet published) version.
    pub pending_alloc: Option<PoolAlloc>,
    /// Id of the in-flight transition job, if any.
    pub pending_job: Option<u64>,
}

/// The handle table: one stable slot per expert.
///
/// `active[i]` is the published rung of expert `i`'s current version —
/// the `active_ptr` of the paper (our device "pointers" are (expert,
/// rung) pairs resolved against the prepared weight store; the
/// indirection and publish atomicity are identical). `state[i]` carries
/// the transition state machine, touched only by the scheduler/pipeline.
pub struct HandleTable {
    n_experts: usize,
    n_layers: usize,
    ladder: PrecisionLadder,
    active: Vec<AtomicU8>,
    resolves: AtomicU64,
    state: Vec<OrderedMutex<EntryState>>,
}

impl HandleTable {
    /// All experts start resident at the ladder's base rung (cold boot).
    pub fn new(
        n_layers: usize,
        n_experts: usize,
        ladder: PrecisionLadder,
    ) -> Self {
        let n = n_layers * n_experts;
        let base = ladder.base_tier();
        assert!(ladder.n_tiers() <= u8::MAX as usize);
        Self {
            n_experts,
            n_layers,
            ladder,
            active: (0..n).map(|_| AtomicU8::new(base as u8)).collect(),
            resolves: AtomicU64::new(0),
            state: (0..n)
                .map(|_| {
                    OrderedMutex::new(
                        LockRank::HandleEntry,
                        EntryState {
                            residency: Residency::Resident(base),
                            active_alloc: None,
                            pending_alloc: None,
                            pending_job: None,
                        },
                    )
                })
                .collect(),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    /// The ladder this table's rung indices decode through.
    pub fn ladder(&self) -> &PrecisionLadder {
        &self.ladder
    }

    /// HOT PATH: resolve a stable handle to the active version's precision.
    /// One atomic load; never blocks, never observes a partial version.
    #[inline]
    pub fn resolve(&self, key: ExpertKey) -> Precision {
        self.ladder.tier(self.resolve_tier(key))
    }

    /// HOT PATH: resolve a stable handle to the active version's rung.
    #[inline]
    pub fn resolve_tier(&self, key: ExpertKey) -> usize {
        self.resolves.fetch_add(1, Ordering::Relaxed); // relaxed-ok: hot-path stat counter
        self.active[key.flat(self.n_experts)].load(Ordering::Acquire) as usize
    }

    /// Number of hot-path resolves so far (overhead accounting).
    pub fn resolve_count(&self) -> u64 {
        self.resolves.load(Ordering::Relaxed) // relaxed-ok: stat counter
    }

    /// PUBLISH: atomically switch the active version to rung `tier`.
    /// Called by the transition pipeline only after the new version is
    /// fully materialized.
    pub fn publish(&self, key: ExpertKey, tier: usize) {
        debug_assert!(tier < self.ladder.n_tiers());
        self.active[key.flat(self.n_experts)]
            .store(tier as u8, Ordering::Release);
    }

    /// Lock an entry's transition state (never taken on the compute
    /// path). Rank [`LockRank::HandleEntry`]: taken under the pipeline
    /// lock, and never two entries at once.
    pub fn entry(&self, key: ExpertKey) -> OrderedMutexGuard<'_, EntryState> {
        self.state[key.flat(self.n_experts)].lock()
    }

    /// Published rung of every expert of one layer (policy input).
    pub fn tier_snapshot(&self, layer: usize) -> Vec<usize> {
        (0..self.n_experts)
            .map(|e| {
                self.active[layer * self.n_experts + e].load(Ordering::Acquire)
                    as usize
            })
            .collect()
    }

    /// Snapshot of the experts of one layer published at rung `tier`.
    pub fn tier_set(&self, layer: usize, tier: usize) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| {
                self.active[layer * self.n_experts + e].load(Ordering::Acquire)
                    as usize
                    == tier
            })
            .collect()
    }

    /// Snapshot of the experts of one layer published at precision `p`
    /// (diagnostics/tests; `p` off the ladder yields an empty set).
    pub fn hi_set(&self, layer: usize, p: Precision) -> Vec<usize> {
        match self.ladder.tier_of(p) {
            Some(t) => self.tier_set(layer, t),
            None => Vec::new(),
        }
    }

    /// Published residency counts per rung, whole table (metrics).
    pub fn tier_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.ladder.n_tiers()];
        for a in &self.active {
            counts[a.load(Ordering::Acquire) as usize] += 1;
        }
        counts
    }

    /// Count of experts currently in a given residency state.
    pub fn count_residency(&self, r: Residency) -> usize {
        self.state
            .iter()
            .filter(|s| s.lock().residency == r)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    fn two_tier() -> PrecisionLadder {
        PrecisionLadder::two_tier(Precision::Fp16, Precision::Int4)
    }

    #[test]
    fn cold_boot_all_base() {
        let t = HandleTable::new(2, 8, two_tier());
        for l in 0..2 {
            for e in 0..8 {
                assert_eq!(t.resolve(ExpertKey::new(l, e)), Precision::Int4);
            }
        }
        assert_eq!(t.count_residency(Residency::Resident(1)), 16);
        assert_eq!(t.resolve_count(), 16);
        assert_eq!(t.tier_counts(), vec![0, 16]);
    }

    #[test]
    fn publish_switches_resolution() {
        let t = HandleTable::new(1, 4, two_tier());
        let k = ExpertKey::new(0, 2);
        t.publish(k, 0);
        assert_eq!(t.resolve(k), Precision::Fp16);
        assert_eq!(t.resolve(ExpertKey::new(0, 1)), Precision::Int4);
        assert_eq!(t.hi_set(0, Precision::Fp16), vec![2]);
        assert_eq!(t.tier_set(0, 0), vec![2]);
        assert_eq!(t.tier_snapshot(0), vec![1, 1, 0, 1]);
    }

    #[test]
    fn three_rung_table_counts_middle_tier() {
        let t = HandleTable::new(1, 4, PrecisionLadder::full());
        t.publish(ExpertKey::new(0, 0), 0);
        t.publish(ExpertKey::new(0, 1), 1);
        assert_eq!(t.resolve(ExpertKey::new(0, 1)), Precision::Int4);
        assert_eq!(t.resolve(ExpertKey::new(0, 3)), Precision::Int2);
        assert_eq!(t.tier_counts(), vec![1, 1, 2]);
        assert_eq!(t.hi_set(0, Precision::Int4), vec![1]);
    }

    #[test]
    fn flat_indexing() {
        let k = ExpertKey::new(3, 7);
        assert_eq!(k.flat(16), 3 * 16 + 7);
    }

    #[test]
    fn residency_active_tier() {
        assert_eq!(Residency::Resident(2).active_tier(), 2);
        let t = Residency::Transitioning { from: 1, to: 0 };
        assert_eq!(t.active_tier(), 1);
        assert!(t.is_transitioning());
        assert!(!Residency::Resident(0).is_transitioning());
    }

    #[test]
    fn prop_resolve_always_sees_complete_version() {
        // Property: under concurrent publishing, resolve() only ever
        // returns one of the two published rungs — never a torn value.
        let mut prop = Prop::new("ver_publish_atomicity");
        prop.run(5, |_rng| {
            let t = std::sync::Arc::new(HandleTable::new(
                1,
                4,
                PrecisionLadder::full(),
            ));
            let k = ExpertKey::new(0, 1);
            let stop = std::sync::Arc::new(std::sync::atomic::AtomicU8::new(0));
            let writer = {
                let t = t.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    for i in 0..20_000u32 {
                        t.publish(k, if i % 2 == 0 { 0 } else { 2 });
                    }
                    stop.store(1, Ordering::Release);
                })
            };
            let t2 = t.clone();
            let reader = std::thread::spawn(move || {
                while stop.load(Ordering::Acquire) == 0 {
                    let p = t2.resolve(k);
                    assert!(p == Precision::Fp16 || p == Precision::Int2);
                }
            });
            writer.join().unwrap();
            reader.join().unwrap();
        });
    }
}
