//! Versioned Expert Residency (VER, §3.2).
//!
//! Each expert owns an *entry* with metadata for all supported versions and
//! exports a **stable handle**: immutable in identity, holding an atomic
//! pointer to the currently active (fully materialized) version. The compute
//! path resolves the handle with one atomic load; transitions publish by
//! swapping the pointer — publish-then-switch — so no kernel ever observes a
//! partially populated version.
//!
//! The single invariant enforced here: **a handle always resolves to a
//! complete, resident weight version.**

use std::sync::atomic::{AtomicU8, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::model::Precision;

use super::pools::PoolAlloc;

/// Flat expert identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExpertKey {
    pub layer: u16,
    pub expert: u16,
}

impl ExpertKey {
    pub fn new(layer: usize, expert: usize) -> Self {
        Self { layer: layer as u16, expert: expert as u16 }
    }

    pub fn flat(&self, n_experts: usize) -> usize {
        self.layer as usize * n_experts + self.expert as usize
    }
}

/// Residency states of an expert entry (§3.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    /// High-precision version resident; handle points to it.
    ResidentHi,
    /// Only the low-precision version resident; handle points to it.
    ResidentLo,
    /// High-precision version in flight; handle still points to lo.
    Promoting,
    /// Low-precision version in flight (replacing hi); handle points to hi.
    Demoting,
}

/// Per-entry transition bookkeeping (guarded; off the compute path).
#[derive(Debug)]
pub struct EntryState {
    pub residency: Residency,
    /// Allocation backing the *active* version.
    pub active_alloc: Option<PoolAlloc>,
    /// Allocation backing an in-flight (not yet published) version.
    pub pending_alloc: Option<PoolAlloc>,
    /// Id of the in-flight transition job, if any.
    pub pending_job: Option<u64>,
}

fn enc(p: Precision) -> u8 {
    match p {
        Precision::Int2 => 0,
        Precision::Int4 => 1,
        Precision::Fp16 => 2,
    }
}

fn dec(v: u8) -> Precision {
    match v {
        0 => Precision::Int2,
        1 => Precision::Int4,
        _ => Precision::Fp16,
    }
}

/// The handle table: one stable slot per expert.
///
/// `active[i]` is the published precision of expert `i`'s current version —
/// the `active_ptr` of the paper (our device "pointers" are (expert,
/// precision) pairs resolved against the prepared weight store; the
/// indirection and publish atomicity are identical). `state[i]` carries
/// the transition state machine, touched only by the scheduler/pipeline.
pub struct HandleTable {
    n_experts: usize,
    n_layers: usize,
    active: Vec<AtomicU8>,
    resolves: AtomicU64,
    state: Vec<Mutex<EntryState>>,
}

impl HandleTable {
    /// All experts start Resident-Lo at `lo` precision (cold boot).
    pub fn new(n_layers: usize, n_experts: usize, lo: Precision) -> Self {
        let n = n_layers * n_experts;
        Self {
            n_experts,
            n_layers,
            active: (0..n).map(|_| AtomicU8::new(enc(lo))).collect(),
            resolves: AtomicU64::new(0),
            state: (0..n)
                .map(|_| {
                    Mutex::new(EntryState {
                        residency: Residency::ResidentLo,
                        active_alloc: None,
                        pending_alloc: None,
                        pending_job: None,
                    })
                })
                .collect(),
        }
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub fn n_experts(&self) -> usize {
        self.n_experts
    }

    /// HOT PATH: resolve a stable handle to the active version's precision.
    /// One atomic load; never blocks, never observes a partial version.
    #[inline]
    pub fn resolve(&self, key: ExpertKey) -> Precision {
        self.resolves.fetch_add(1, Ordering::Relaxed);
        dec(self.active[key.flat(self.n_experts)].load(Ordering::Acquire))
    }

    /// Number of hot-path resolves so far (overhead accounting).
    pub fn resolve_count(&self) -> u64 {
        self.resolves.load(Ordering::Relaxed)
    }

    /// PUBLISH: atomically switch the active version. Called by the
    /// transition pipeline only after the new version is fully materialized.
    pub fn publish(&self, key: ExpertKey, p: Precision) {
        self.active[key.flat(self.n_experts)].store(enc(p), Ordering::Release);
    }

    /// Lock an entry's transition state (never taken on the compute path).
    pub fn entry(&self, key: ExpertKey) -> std::sync::MutexGuard<'_, EntryState> {
        self.state[key.flat(self.n_experts)].lock().unwrap()
    }

    /// Snapshot of the hi-resident set of one layer (diagnostics/tests).
    pub fn hi_set(&self, layer: usize, hi: Precision) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| {
                dec(self.active[layer * self.n_experts + e].load(Ordering::Acquire))
                    == hi
            })
            .collect()
    }

    /// Count of experts currently in a given residency state.
    pub fn count_residency(&self, r: Residency) -> usize {
        self.state
            .iter()
            .filter(|s| s.lock().unwrap().residency == r)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn cold_boot_all_lo() {
        let t = HandleTable::new(2, 8, Precision::Int4);
        for l in 0..2 {
            for e in 0..8 {
                assert_eq!(t.resolve(ExpertKey::new(l, e)), Precision::Int4);
            }
        }
        assert_eq!(t.count_residency(Residency::ResidentLo), 16);
        assert_eq!(t.resolve_count(), 16);
    }

    #[test]
    fn publish_switches_resolution() {
        let t = HandleTable::new(1, 4, Precision::Int4);
        let k = ExpertKey::new(0, 2);
        t.publish(k, Precision::Fp16);
        assert_eq!(t.resolve(k), Precision::Fp16);
        assert_eq!(t.resolve(ExpertKey::new(0, 1)), Precision::Int4);
        assert_eq!(t.hi_set(0, Precision::Fp16), vec![2]);
    }

    #[test]
    fn flat_indexing() {
        let k = ExpertKey::new(3, 7);
        assert_eq!(k.flat(16), 3 * 16 + 7);
    }

    #[test]
    fn prop_resolve_always_sees_complete_version() {
        // Property: under concurrent publishing, resolve() only ever
        // returns one of the two published precisions — never a torn value.
        let mut prop = Prop::new("ver_publish_atomicity");
        prop.run(5, |_rng| {
            let t = std::sync::Arc::new(HandleTable::new(1, 4, Precision::Int2));
            let k = ExpertKey::new(0, 1);
            let stop = std::sync::Arc::new(std::sync::atomic::AtomicU8::new(0));
            let writer = {
                let t = t.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    for i in 0..20_000u32 {
                        t.publish(
                            k,
                            if i % 2 == 0 { Precision::Fp16 } else { Precision::Int2 },
                        );
                    }
                    stop.store(1, Ordering::Release);
                })
            };
            let t2 = t.clone();
            let reader = std::thread::spawn(move || {
                while stop.load(Ordering::Acquire) == 0 {
                    let p = t2.resolve(k);
                    assert!(p == Precision::Fp16 || p == Precision::Int2);
                }
            });
            writer.join().unwrap();
            reader.join().unwrap();
        });
    }
}
