//! The DynaExq coordinator (§3): online, budget-constrained precision
//! allocation, wired from four mechanisms —
//!
//! * [`ver`] — stable expert handles + residency state machine,
//! * [`pools`] + [`budget`] — deterministic memory with admission control,
//! * [`pipeline`] — non-blocking promotions/demotions on a migration stream,
//! * [`hotness`] + [`policy`] — EMA traffic estimation and the
//!   budget-feasible top-n rule with hysteresis.
//!
//! The engine calls [`Coordinator::record_routing`] with router outputs,
//! [`Coordinator::resolve`] on the hot path, and [`Coordinator::tick`] at
//! iteration boundaries; everything else happens off the critical path.

pub mod budget;
pub mod hotness;
pub mod pipeline;
pub mod policy;
pub mod pools;
pub mod ver;

use std::collections::HashSet;
use std::sync::Arc;

pub use budget::{BudgetPlan, BudgetTracker};
pub use hotness::HotnessEstimator;
pub use pipeline::{Admission, StageFn, TransitionKind, TransitionPipeline};
pub use policy::{plan_layer, LayerPlan};
pub use pools::{BlockPool, PoolAlloc};
pub use ver::{ExpertKey, HandleTable, Residency};

use crate::config::{DeviceConfig, ModelPreset, ServingConfig};
use crate::model::Precision;
use crate::sim::LogicalDims;

/// Summary of one policy update (returned by [`Coordinator::tick`]).
#[derive(Debug, Default, Clone)]
pub struct UpdateReport {
    pub ran: bool,
    pub promotions_submitted: usize,
    pub demotions_submitted: usize,
    pub deferred: usize,
    pub published: usize,
}

/// The runtime-side of DynaExq for one model.
pub struct Coordinator {
    pub preset: ModelPreset,
    pub cfg: ServingConfig,
    pub plan: BudgetPlan,
    pub handles: Arc<HandleTable>,
    pub budget: Arc<BudgetTracker>,
    pub pool_hi: Arc<BlockPool>,
    pub pool_lo: Arc<BlockPool>,
    pub pipeline: TransitionPipeline,
    hotness: std::sync::Mutex<HotnessEstimator>,
    next_update_s: std::sync::Mutex<f64>,
}

impl Coordinator {
    /// Build a coordinator with paper-scale (logical) byte accounting and a
    /// no-op stager (used by modeled-timing experiments; the numeric engine
    /// passes a real stager via [`Coordinator::with_stager`]).
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
    ) -> Result<Self, String> {
        Self::with_stager(preset, cfg, dev, Arc::new(|_, _| Vec::new()))
    }

    /// Build with a custom staging function (assembles prepared host bytes
    /// for a given expert/precision on the background worker).
    pub fn with_stager(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
        stager: Arc<StageFn>,
    ) -> Result<Self, String> {
        let dims = LogicalDims::for_preset(preset);
        let plan = Self::derive_logical_plan(preset, &dims, cfg)?;
        let handles = Arc::new(HandleTable::new(
            preset.n_layers_logical(),
            preset.n_experts,
            preset.lo,
        ));
        let budget = Arc::new(BudgetTracker::new(
            plan.hi_pool_bytes,
            plan.lo_pool_bytes,
        ));
        let block_hi = if cfg.pool_block_bytes > 0 {
            cfg.pool_block_bytes
        } else {
            plan.hi_expert_bytes
        };
        let block_lo = if cfg.pool_block_bytes > 0 {
            cfg.pool_block_bytes
        } else {
            plan.lo_expert_bytes
        };
        let pool_hi = Arc::new(BlockPool::new(
            "pool_hi",
            plan.hi_pool_bytes + block_hi - 1,
            block_hi,
        ));
        let pool_lo = Arc::new(BlockPool::new(
            "pool_lo",
            plan.lo_pool_bytes + block_lo - 1,
            block_lo,
        ));

        // Cold boot: every routed expert resident-lo; shared experts pinned
        // hot (their buffers come from pool_hi but are never transitioned).
        let layers = preset.n_layers_logical();
        for l in 0..layers {
            for e in 0..preset.n_experts {
                let a = pool_lo
                    .alloc(plan.lo_expert_bytes)
                    .ok_or("lo pool underprovisioned")?;
                if !budget.try_reserve_lo(plan.lo_expert_bytes) {
                    return Err("lo budget underprovisioned".into());
                }
                handles.entry(ExpertKey::new(l, e)).active_alloc = Some(a);
            }
            for _ in 0..preset.n_shared {
                pool_hi
                    .alloc(plan.hi_expert_bytes)
                    .ok_or("hi pool lacks shared-expert room")?;
                if !budget.try_reserve_hi(plan.hi_expert_bytes) {
                    return Err("hi budget lacks shared-expert room".into());
                }
            }
        }

        let dims_for_bytes = dims.clone();
        let pipeline = TransitionPipeline::new(
            handles.clone(),
            budget.clone(),
            pool_hi.clone(),
            pool_lo.clone(),
            preset.hi,
            preset.lo,
            1.0 / dev.pcie_bytes_per_s,
            Box::new(move |p| dims_for_bytes.expert_bytes(p)),
            cfg.max_inflight_promotions,
            stager,
        );
        Ok(Self {
            preset: preset.clone(),
            cfg: cfg.clone(),
            plan,
            handles,
            budget,
            pool_hi,
            pool_lo,
            pipeline,
            hotness: std::sync::Mutex::new(HotnessEstimator::new(
                layers,
                preset.n_experts,
                cfg.ema_alpha,
            )),
            next_update_s: std::sync::Mutex::new(
                cfg.update_interval_ms / 1e3,
            ),
        })
    }

    /// Public access to budget initialization (used by experiments to
    /// translate the paper-scale plan onto the executed model).
    pub fn plan_for(
        preset: &ModelPreset,
        cfg: &ServingConfig,
    ) -> Result<BudgetPlan, String> {
        let dims = LogicalDims::for_preset(preset);
        Self::derive_logical_plan(preset, &dims, cfg)
    }

    /// Budget initialization at logical (paper) scale.
    fn derive_logical_plan(
        preset: &ModelPreset,
        dims: &LogicalDims,
        cfg: &ServingConfig,
    ) -> Result<BudgetPlan, String> {
        let b_hi = dims.expert_bytes(preset.hi);
        let b_lo = dims.expert_bytes(preset.lo);
        let layers = preset.n_layers_logical();
        let shared = layers * preset.n_shared * b_hi;
        let baseline =
            cfg.fixed_bytes + shared + layers * preset.n_experts * b_lo;
        if baseline > cfg.hbm_budget_bytes {
            return Err(format!(
                "infeasible envelope: all-cold needs {baseline}B > budget \
                 {}B",
                cfg.hbm_budget_bytes
            ));
        }
        let slack = cfg.hbm_budget_bytes - baseline;
        let n_hi = cfg
            .n_hi_override
            .unwrap_or(slack / (layers * (b_hi - b_lo)))
            .min(preset.n_experts);
        Ok(BudgetPlan {
            n_hi_per_layer: n_hi,
            hi_pool_bytes: layers * (n_hi + preset.n_shared) * b_hi,
            lo_pool_bytes: layers * preset.n_experts * b_lo,
            hi_expert_bytes: b_hi,
            lo_expert_bytes: b_lo,
        })
    }

    /// HOT PATH: the precision the forward pass must execute expert
    /// `(layer, expert)` with. One atomic load via the stable handle.
    #[inline]
    pub fn resolve(&self, layer: usize, expert: usize) -> Precision {
        self.handles.resolve(ExpertKey::new(layer, expert))
    }

    /// Feed router trace: `experts` are the top-k ids selected for each
    /// token at `layer` this iteration.
    pub fn record_routing(&self, layer: usize, experts: &[usize]) {
        self.hotness.lock().unwrap().record_layer(layer, experts);
    }

    /// Iteration boundary: publish finished transitions; if the update
    /// interval elapsed, fold counters and reschedule residency.
    pub fn tick(&self, now_s: f64) -> UpdateReport {
        let mut report = UpdateReport::default();
        report.published = self.pipeline.poll(now_s).len();

        {
            let mut next = self.next_update_s.lock().unwrap();
            if now_s < *next {
                return report;
            }
            *next = now_s + self.cfg.update_interval_ms / 1e3;
        }
        report.ran = true;

        let mut hot = self.hotness.lock().unwrap();
        hot.end_interval();
        let layers = self.preset.n_layers_logical();
        // Promoting/demoting sets come from the (small) in-flight list —
        // the published residency from the lock-free handle table — so the
        // update path never sweeps per-entry state mutexes.
        let mut promoting: Vec<Vec<usize>> = vec![Vec::new(); layers];
        for k in self.pipeline.promoting_keys() {
            promoting[k.layer as usize].push(k.expert as usize);
        }
        let mut demoting: Vec<Vec<usize>> = vec![Vec::new(); layers];
        for k in self.pipeline.demoting_keys() {
            demoting[k.layer as usize].push(k.expert as usize);
        }
        for l in 0..layers {
            let mut current: HashSet<usize> = self
                .handles
                .hi_set(l, self.preset.hi)
                .into_iter()
                .collect();
            for &e in &promoting[l] {
                current.insert(e);
            }
            for &e in &demoting[l] {
                current.remove(&e);
            }
            let plan = plan_layer(
                hot.layer_scores(l),
                &current,
                self.plan.n_hi_per_layer,
                self.cfg.hysteresis_margin,
            );
            // Demotions first: their eviction grows the feasible set.
            for &e in &plan.demote {
                match self.pipeline.submit(
                    ExpertKey::new(l, e),
                    TransitionKind::Demote,
                    now_s,
                ) {
                    Admission::Admitted { .. } => {
                        report.demotions_submitted += 1
                    }
                    Admission::Deferred => report.deferred += 1,
                    Admission::Redundant => {}
                }
            }
            for &e in &plan.promote {
                match self.pipeline.submit(
                    ExpertKey::new(l, e),
                    TransitionKind::Promote,
                    now_s,
                ) {
                    Admission::Admitted { .. } => {
                        report.promotions_submitted += 1
                    }
                    Admission::Deferred => report.deferred += 1,
                    Admission::Redundant => {}
                }
            }
        }
        report
    }

    /// Smoothed hotness score (diagnostics/benches).
    pub fn hotness_score(&self, layer: usize, expert: usize) -> f64 {
        self.hotness.lock().unwrap().score(layer, expert)
    }

    /// Top-n hottest experts of a layer (diagnostics/benches).
    pub fn hottest(&self, layer: usize, n: usize) -> Vec<usize> {
        self.hotness.lock().unwrap().top_n(layer, n)
    }
}

impl ModelPreset {
    /// Layers used for residency/accounting: the paper model's layer count
    /// (the executed small model maps its layers onto the first few).
    pub fn n_layers_logical(&self) -> usize {
        self.paper_layers.max(self.n_layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord(preset: ModelPreset) -> Coordinator {
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        Coordinator::new(&preset, &cfg, &dev).unwrap()
    }

    #[test]
    fn boots_all_cold_within_envelope() {
        let c = coord(ModelPreset::qwen30b_sim());
        assert!(c.plan.n_hi_per_layer > 0);
        assert!(c.plan.n_hi_per_layer < 128);
        assert!(c.budget.within_envelope());
        assert_eq!(c.resolve(0, 0), Precision::Int4);
    }

    #[test]
    fn hot_traffic_promotes_within_budget() {
        let c = coord(ModelPreset::phi_sim());
        let n_hi = c.plan.n_hi_per_layer;
        // drive traffic to experts 0..3 of layer 0
        for _ in 0..100 {
            c.record_routing(0, &[0, 1, 2, 3]);
        }
        let r = c.tick(1.0); // past the 50 ms update interval
        assert!(r.ran);
        assert!(r.promotions_submitted > 0);
        // only the four trafficked experts are promotion candidates (idle
        // experts are never promoted), and capacity bounds the rest
        assert!(r.promotions_submitted <= n_hi.max(4).min(4));
        // let transfers complete
        c.pipeline.wait_staged();
        c.tick(1e3);
        for e in 0..4.min(n_hi) {
            assert_eq!(c.resolve(0, e), Precision::Fp16, "expert {e}");
        }
        assert!(c.budget.within_envelope());
    }

    #[test]
    fn update_interval_gates_policy() {
        let c = coord(ModelPreset::phi_sim());
        c.record_routing(0, &[0]);
        let r = c.tick(0.01); // before T_u
        assert!(!r.ran);
        let r = c.tick(0.06);
        assert!(r.ran);
    }

    #[test]
    fn workload_shift_swaps_hot_set() {
        let mut cfg = ServingConfig::default();
        cfg.hysteresis_margin = 0.0;
        cfg.ema_alpha = 0.0; // fully reactive for the test
        cfg.max_inflight_promotions = 1024;
        cfg.n_hi_override = Some(2); // force displacement on shift
        let dev = DeviceConfig::default();
        let preset = ModelPreset::phi_sim();
        let c = Coordinator::new(&preset, &cfg, &dev).unwrap();
        assert_eq!(c.plan.n_hi_per_layer, 2);

        // phase 1: experts {0,1} hot
        for _ in 0..50 {
            c.record_routing(0, &[0, 1]);
        }
        c.tick(0.1);
        c.pipeline.wait_staged();
        c.tick(10.0);
        assert_eq!(c.resolve(0, 0), Precision::Fp16);
        assert_eq!(c.resolve(0, 1), Precision::Fp16);

        // phase 2: shift to {8, 9} — must displace {0, 1}
        for step in 0..20 {
            for _ in 0..50 {
                c.record_routing(0, &[8, 9]);
            }
            c.tick(10.0 + step as f64);
            c.pipeline.wait_staged();
        }
        c.tick(1e4);
        assert_eq!(c.resolve(0, 8), Precision::Fp16);
        assert_eq!(c.resolve(0, 9), Precision::Fp16);
        assert_eq!(c.resolve(0, 0), Precision::Int4);
        assert_eq!(c.resolve(0, 1), Precision::Int4);
        assert!(c.budget.within_envelope());
    }

    #[test]
    fn infeasible_budget_refused() {
        let mut cfg = ServingConfig::default();
        cfg.hbm_budget_bytes = 1 << 20;
        let dev = DeviceConfig::default();
        assert!(
            Coordinator::new(&ModelPreset::qwen30b_sim(), &cfg, &dev).is_err()
        );
    }
}
