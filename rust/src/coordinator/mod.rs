//! The DynaExq coordinator (§3): online, budget-constrained precision
//! allocation over an N-rung precision ladder, wired from four mechanisms —
//!
//! * [`ver`] — stable expert handles + residency state machine (rung
//!   indices behind one atomic),
//! * [`pools`] + [`budget`] — deterministic per-rung memory with admission
//!   control,
//! * [`pipeline`] — non-blocking tier moves on a migration stream,
//! * [`hotness`] + [`policy`] — EMA traffic estimation and the
//!   budget-feasible waterfill tier assignment with per-boundary
//!   hysteresis.
//!
//! The engine calls [`Coordinator::record_routing`] with router outputs,
//! [`Coordinator::resolve`] on the hot path, and [`Coordinator::tick`] at
//! iteration boundaries; everything else happens off the critical path.
//! The classic hi/lo presets are 2-rung ladders and behave identically to
//! the original binary formulation (DESIGN.md §8).

pub mod budget;
pub mod group;
pub mod hotness;
pub mod pipeline;
pub mod policy;
pub mod pools;
pub mod ver;

use std::sync::Arc;

pub use budget::{BudgetPlan, BudgetTracker};
pub use group::DeviceGroup;
pub use hotness::{DriftDetector, HotnessEstimator, HotnessShards};
pub use pipeline::{
    Admission, StageFn, TransitionKind, TransitionPipeline, TransitionTotals,
};
pub use policy::{
    plan_layer, plan_layer_ladder, plan_layer_ladder_into, LadderPlan,
    LadderScratch, LayerPlan, LayerScratch,
};
pub use pools::{BlockPool, PoolAlloc};
pub use ver::{ExpertKey, HandleTable, Residency};

use crate::config::{
    DeviceConfig, ModelPreset, QosClass, ServingConfig,
};
use crate::model::Precision;
use crate::sim::LogicalDims;
use crate::util::lockorder::{LockRank, OrderedMutex};

/// Summary of one policy update (returned by [`Coordinator::tick`]).
#[derive(Debug, Default, Clone)]
pub struct UpdateReport {
    pub ran: bool,
    pub promotions_submitted: usize,
    pub demotions_submitted: usize,
    pub deferred: usize,
    pub published: usize,
    /// The drift-aware hotness layer fired a change-point this update
    /// (always false without `ServingConfig::adaptive_alpha`).
    pub drift_detected: bool,
}

/// Armed QoS weighting (DESIGN.md §15): a class-weighted twin of the
/// hotness score plane. Raw counts keep feeding the estimator and drift
/// detector unchanged; the per-class planes merged at each boundary fold
/// into `scores` with the *same* α the estimator used that interval, and
/// the waterfill ranks experts by this plane instead of the raw one.
/// Present only for a non-degenerate [`crate::config::QosConfig`] — the
/// degenerate/absent case runs the classic plan byte-identically.
struct QosWeighting {
    /// Class hotness weights, [`QosClass::index`] order.
    weights: [f64; 3],
    /// Class attributed to selections recorded *now* (set by the serving
    /// layer before each tagged phase; reads are relaxed — attribution
    /// follows the same boundary-visibility contract as the counts).
    active: std::sync::atomic::AtomicUsize,
    state: OrderedMutex<QosScores>,
}

/// The serial fold state behind [`QosWeighting`].
struct QosScores {
    /// Per-class merged counts of the current interval
    /// (`counts[class][layer * n_experts + expert]`).
    counts: Vec<Vec<u64>>,
    /// Class-weighted EMA score plane, same flat layout as the estimator.
    scores: Vec<f64>,
}

/// The runtime-side of DynaExq for one model.
pub struct Coordinator {
    pub preset: ModelPreset,
    pub cfg: ServingConfig,
    pub plan: BudgetPlan,
    pub handles: Arc<HandleTable>,
    pub budget: Arc<BudgetTracker>,
    /// One block pool per ladder rung, tier 0 first.
    pub pools: Vec<Arc<BlockPool>>,
    pub pipeline: TransitionPipeline,
    /// Lock-free recording front: router selections land in sharded
    /// atomic counters and are merged into `hotness` once per tick
    /// (DESIGN.md §13). The mutex below only guards the serial
    /// fold/plan state, never the record path.
    shards: HotnessShards,
    hotness: OrderedMutex<HotnessEstimator>,
    /// Change-point detector of the adaptive-α mode (`None` when
    /// `cfg.adaptive_alpha` is off — the classic fixed-α stack).
    drift: OrderedMutex<Option<DriftDetector>>,
    next_update_s: OrderedMutex<f64>,
    /// Class-weighted scoring (`None` without an armed QoS config — the
    /// classic tenant-blind waterfill, byte-identically).
    qos: Option<QosWeighting>,
}

impl Coordinator {
    /// Build a coordinator with paper-scale (logical) byte accounting and a
    /// no-op stager (used by modeled-timing experiments; the numeric engine
    /// passes a real stager via [`Coordinator::with_stager`]).
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
    ) -> Result<Self, String> {
        Self::with_stager(preset, cfg, dev, Arc::new(|_, _| Vec::new()))
    }

    /// Build with a custom staging function (assembles prepared host bytes
    /// for a given expert/precision on the background worker).
    pub fn with_stager(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
        stager: Arc<StageFn>,
    ) -> Result<Self, String> {
        let dims = LogicalDims::for_preset(preset);
        let plan = Self::derive_logical_plan(preset, &dims, cfg)?;
        if cfg.adaptive_alpha {
            cfg.drift
                .validate()
                .map_err(|e| format!("adaptive hotness: {e}"))?;
        }
        if let Some(q) = &cfg.qos {
            q.validate()?;
        }
        let ladder = preset.ladder.clone();
        let base = ladder.base_tier();
        let handles = Arc::new(HandleTable::new(
            preset.n_layers_logical(),
            preset.n_experts,
            ladder.clone(),
        ));
        let budget = Arc::new(BudgetTracker::with_caps(plan.pool_bytes.clone()));
        let pools: Vec<Arc<BlockPool>> = POOL_NAMES
            .iter()
            .copied()
            .take(plan.n_tiers())
            .enumerate()
            .map(|(t, name)| {
                let block = if cfg.pool_block_bytes > 0 {
                    cfg.pool_block_bytes
                } else {
                    plan.tier_expert_bytes[t]
                };
                Arc::new(BlockPool::new(
                    name,
                    plan.pool_bytes[t] + block - 1,
                    block,
                ))
            })
            .collect();

        // Cold boot: every routed expert resident at the base rung; shared
        // experts pinned hot (their buffers come from the tier-0 pool but
        // are never transitioned).
        let layers = preset.n_layers_logical();
        let b_base = plan.tier_expert_bytes[base];
        let b_top = plan.tier_expert_bytes[0];
        for l in 0..layers {
            for e in 0..preset.n_experts {
                let a = pools[base]
                    .alloc(b_base)
                    .ok_or("base pool underprovisioned")?;
                if !budget.try_reserve(base, b_base) {
                    return Err("base budget underprovisioned".into());
                }
                handles.entry(ExpertKey::new(l, e)).active_alloc = Some(a);
            }
            for _ in 0..preset.n_shared {
                pools[0]
                    .alloc(b_top)
                    .ok_or("top-rung pool lacks shared-expert room")?;
                if !budget.try_reserve(0, b_top) {
                    return Err("top-rung budget lacks shared-expert room".into());
                }
            }
        }

        // QoS weighting arms only for a non-degenerate config: the score
        // plane, class count planes, and classed recording are otherwise
        // structurally absent, so the collapse is byte-identical.
        let n_classes = QosClass::ALL.len();
        let slots = layers * preset.n_experts;
        let qos = cfg
            .qos
            .as_ref()
            .filter(|q| !q.is_degenerate())
            .map(|q| QosWeighting {
                weights: q.weights(),
                active: std::sync::atomic::AtomicUsize::new(
                    QosClass::Standard.index(),
                ),
                state: OrderedMutex::new(
                    LockRank::QosScores,
                    QosScores {
                        counts: vec![vec![0; slots]; n_classes],
                        scores: vec![0.0; slots],
                    },
                ),
            });
        let shards = if qos.is_some() {
            HotnessShards::with_classes(layers, preset.n_experts, n_classes)
        } else {
            HotnessShards::new(layers, preset.n_experts)
        };

        let dims_for_bytes = dims.clone();
        let pipeline = TransitionPipeline::new(
            handles.clone(),
            budget.clone(),
            pools.clone(),
            1.0 / dev.pcie_bytes_per_s,
            Box::new(move |p| dims_for_bytes.expert_bytes(p)),
            cfg.max_inflight_promotions,
            stager,
        );
        Ok(Self {
            preset: preset.clone(),
            cfg: cfg.clone(),
            plan,
            handles,
            budget,
            pools,
            pipeline,
            shards,
            hotness: OrderedMutex::new(
                LockRank::Hotness,
                HotnessEstimator::new(layers, preset.n_experts, cfg.ema_alpha),
            ),
            drift: OrderedMutex::new(
                LockRank::Drift,
                if cfg.adaptive_alpha {
                    Some(DriftDetector::new(
                        layers,
                        preset.n_experts,
                        &cfg.drift,
                    ))
                } else {
                    None
                },
            ),
            next_update_s: OrderedMutex::new(
                LockRank::UpdateClock,
                cfg.update_interval_ms / 1e3,
            ),
            qos,
        })
    }

    /// Public access to budget initialization (used by experiments to
    /// translate the paper-scale plan onto the executed model).
    pub fn plan_for(
        preset: &ModelPreset,
        cfg: &ServingConfig,
    ) -> Result<BudgetPlan, String> {
        let dims = LogicalDims::for_preset(preset);
        Self::derive_logical_plan(preset, &dims, cfg)
    }

    /// Budget initialization at logical (paper) scale: derive per-rung
    /// capacities from the envelope slack by waterfill. An explicit
    /// `n_hi_override` is validated against the envelope (it used to be
    /// able to silently overcommit the HBM budget).
    fn derive_logical_plan(
        preset: &ModelPreset,
        dims: &LogicalDims,
        cfg: &ServingConfig,
    ) -> Result<BudgetPlan, String> {
        BudgetPlan::derive_with(
            &preset.ladder,
            |p| dims.expert_bytes(p),
            preset.n_layers_logical(),
            preset.n_experts,
            preset.n_shared,
            cfg.hbm_budget_bytes,
            cfg.fixed_bytes,
            cfg.n_hi_override,
        )
    }

    /// HOT PATH: the precision the forward pass must execute expert
    /// `(layer, expert)` with. One atomic load via the stable handle.
    #[inline]
    pub fn resolve(&self, layer: usize, expert: usize) -> Precision {
        self.handles.resolve(ExpertKey::new(layer, expert))
    }

    /// HOT PATH: the ladder rung the expert currently executes at.
    #[inline]
    pub fn resolve_tier(&self, layer: usize, expert: usize) -> usize {
        self.handles.resolve_tier(ExpertKey::new(layer, expert))
    }

    /// Feed router trace: `experts` are the top-k ids selected for each
    /// token at `layer` this iteration. Lock-free: lands in the calling
    /// thread's count shard and becomes visible to policy at the next
    /// interval-boundary merge (DESIGN.md §13).
    pub fn record_routing(&self, layer: usize, experts: &[usize]) {
        let shard = self.shards.shard_for_current_thread();
        match &self.qos {
            Some(q) => self.shards.record_layer_classed(
                shard,
                layer,
                experts,
                q.active.load(std::sync::atomic::Ordering::Relaxed), // relaxed-ok: boundary-visibility attribution tag
            ),
            None => self.shards.record_layer(shard, layer, experts),
        }
    }

    /// Feed several layers' router traces — the iteration-boundary flush
    /// of a backend's per-layer routing buffer (DESIGN.md §11).
    /// Count-equivalent to calling [`Coordinator::record_routing`] once
    /// per batch; the flush semantics are unchanged from the locked era:
    /// everything recorded before a tick is observed by that tick.
    pub fn record_layers<'a, I>(&self, batches: I)
    where
        I: IntoIterator<Item = (usize, &'a [usize])>,
    {
        let shard = self.shards.shard_for_current_thread();
        match &self.qos {
            Some(q) => {
                let class =
                    q.active.load(std::sync::atomic::Ordering::Relaxed); // relaxed-ok: boundary-visibility attribution tag
                for (layer, experts) in batches {
                    self.shards
                        .record_layer_classed(shard, layer, experts, class);
                }
            }
            None => {
                for (layer, experts) in batches {
                    self.shards.record_layer(shard, layer, experts);
                }
            }
        }
    }

    /// Whether class-weighted scoring is armed (a non-degenerate
    /// `ServingConfig::qos`).
    pub fn qos_armed(&self) -> bool {
        self.qos.is_some()
    }

    /// Attribute subsequently recorded routing to `class` (DESIGN.md §15).
    /// A no-op without an armed QoS config; out-of-range indices clamp to
    /// best-effort. Relaxed store — attribution becomes visible with the
    /// counts it tags, at the next interval boundary.
    pub fn set_active_class(&self, class: usize) {
        if let Some(q) = &self.qos {
            q.active.store(
                class.min(QosClass::ALL.len() - 1),
                std::sync::atomic::Ordering::Relaxed, // relaxed-ok: boundary-visibility attribution tag
            );
        }
    }

    /// The class-weighted score of one expert (diagnostics/tests); falls
    /// back to the raw smoothed score when QoS is unarmed.
    pub fn weighted_score(&self, layer: usize, expert: usize) -> f64 {
        match &self.qos {
            Some(q) => {
                let qs = q.state.lock();
                qs.scores[layer * self.preset.n_experts + expert]
            }
            None => self.hotness_score(layer, expert),
        }
    }

    /// Selections recorded but not yet merged into the estimator
    /// (diagnostics/tests of the sharded front).
    pub fn pending_routing(&self) -> u64 {
        self.shards.pending()
    }

    /// Whether a call to [`Coordinator::tick`] at `now_s` would run the
    /// policy update (the interval gate has elapsed). `DeviceGroup` uses
    /// this to skip thread spawns on the per-round ticks that would gate
    /// out anyway.
    pub fn update_due(&self, now_s: f64) -> bool {
        now_s >= *self.next_update_s.lock()
    }

    /// Iteration boundary: publish finished transitions; if the update
    /// interval elapsed, fold counters and reschedule residency.
    pub fn tick(&self, now_s: f64) -> UpdateReport {
        let mut report = UpdateReport::default();
        report.published = self.pipeline.poll(now_s).len();

        {
            let mut next = self.next_update_s.lock();
            if now_s < *next {
                return report;
            }
            *next = now_s + self.cfg.update_interval_ms / 1e3;
        }
        report.ran = true;

        let mut hot = self.hotness.lock();
        // Iteration-boundary merge (DESIGN.md §13): drain the sharded
        // atomic counters into the serial estimator *before* the drift
        // detector reads raw counts and before the EMA fold. u64 sums are
        // commutative, so the merged counters — and every score computed
        // from them — are byte-identical to the old single-lock recording
        // path regardless of producer interleaving.
        self.shards.merge_into(&mut hot);
        // QoS class planes merge at the same boundary, under the same
        // hotness lock (DESIGN.md §15): the class split of this interval's
        // counts is exactly the raw counts the estimator just absorbed.
        let mut qos_state = self.qos.as_ref().map(|q| q.state.lock());
        if let Some(qs) = qos_state.as_deref_mut() {
            self.shards.merge_classes_into(&mut qs.counts);
        }
        // Drift-aware α (DESIGN.md §10): the detector reads this
        // interval's raw counts before the fold; on a change-point the
        // stale scores shrink and the EMA runs at the reactive α for the
        // configured recovery span. Off (the default) this block is
        // skipped entirely and behaviour is byte-identical to the classic
        // fixed-α stack.
        if let Some(det) = self.drift.lock().as_mut() {
            let idle = hot.interval_idle();
            // (observe() is itself a no-op on an idle interval)
            if det.observe(&hot) {
                report.drift_detected = true;
                hot.scale_scores(det.stale_decay());
                // the weighted plane decays in lockstep — stale premium
                // hotness must not outvote post-drift traffic either
                if let Some(qs) = qos_state.as_deref_mut() {
                    let decay = det.stale_decay();
                    for s in &mut qs.scores {
                        *s *= decay;
                    }
                }
            }
            // The recovery budget spans intervals *of traffic*: an idle
            // interval neither consumes reactive intervals nor folds at
            // the dropped α (which would collapse the score table far
            // faster than the classic stack's decay during a lull).
            let alpha = if !idle && det.recovery_step() {
                det.recovery_alpha()
            } else {
                self.cfg.ema_alpha
            };
            hot.set_alpha(alpha);
        }
        hot.end_interval();
        // Weighted fold: the same EMA recurrence as the estimator's, at
        // the exact α it just folded with (adaptive drops included), over
        // class-weighted counts — so the weighted plane tracks the raw
        // one's dynamics and differs only by the class multipliers.
        if let (Some(q), Some(qs)) = (&self.qos, qos_state.as_deref_mut()) {
            let alpha = hot.alpha();
            let QosScores { counts, scores } = qs;
            for (i, s) in scores.iter_mut().enumerate() {
                let mut c = 0.0;
                for (class, plane) in counts.iter_mut().enumerate() {
                    c += q.weights[class] * plane[i] as f64;
                    plane[i] = 0;
                }
                *s = alpha * *s + (1.0 - alpha) * c;
            }
        }
        let layers = self.preset.n_layers_logical();
        // Effective assignment: the published rung from the lock-free
        // handle table, overridden by in-flight transition targets (from
        // the small in-flight list — the update path never sweeps
        // per-entry state mutexes).
        let mut eff: Vec<Vec<usize>> =
            (0..layers).map(|l| self.handles.tier_snapshot(l)).collect();
        for (k, _from, to) in self.pipeline.inflight_transitions() {
            eff[k.layer as usize][k.expert as usize] = to;
        }
        let cum_caps = self.plan.cumulative_capacity();
        // One policy scratch + plan buffer reused across the whole layer
        // loop: a 48-layer update allocates nothing per layer.
        let mut scratch = LadderScratch::default();
        let mut plan = LadderPlan::default();
        let n_experts = self.preset.n_experts;
        for l in 0..layers {
            // Armed QoS substitutes the class-weighted plane for the raw
            // scores; the waterfill itself is unchanged (premium traffic
            // wins rungs purely by outscoring, per DESIGN.md §15).
            let scores = match qos_state.as_deref() {
                Some(qs) => &qs.scores[l * n_experts..(l + 1) * n_experts],
                None => hot.layer_scores(l),
            };
            plan_layer_ladder_into(
                &mut scratch,
                scores,
                &eff[l],
                &cum_caps,
                self.cfg.hysteresis_margin,
                &mut plan,
            );
            // Downward moves come first in the plan: their evictions grow
            // the feasible set for the upward moves.
            for &(e, to) in &plan.moves {
                let up = to < eff[l][e];
                match self.pipeline.submit(
                    ExpertKey::new(l, e),
                    TransitionKind::ToTier(to),
                    now_s,
                ) {
                    Admission::Admitted { .. } => {
                        if up {
                            report.promotions_submitted += 1;
                        } else {
                            report.demotions_submitted += 1;
                        }
                    }
                    Admission::Deferred => report.deferred += 1,
                    Admission::Redundant => {}
                    // The planner only emits on-ladder targets; a rejected
                    // submission is a caller bug surfaced by the pipeline
                    // stats, never a process abort.
                    Admission::Rejected => {}
                }
            }
        }
        report
    }

    /// Smoothed hotness score (diagnostics/benches).
    pub fn hotness_score(&self, layer: usize, expert: usize) -> f64 {
        self.hotness.lock().score(layer, expert)
    }

    /// Top-n hottest experts of a layer (diagnostics/benches).
    pub fn hottest(&self, layer: usize, n: usize) -> Vec<usize> {
        self.hotness.lock().top_n(layer, n)
    }

    /// `(change-point triggers, recovery intervals)` observed by the
    /// adaptive hotness layer; `(0, 0)` with `adaptive_alpha` off.
    pub fn drift_stats(&self) -> (u64, u64) {
        self.drift
            .lock()
            .as_ref()
            .map(|d| (d.drift_events(), d.recovery_ticks()))
            .unwrap_or((0, 0))
    }
}

/// Static names for the per-rung pools (BlockPool holds a `&'static str`).
const POOL_NAMES: [&str; 3] = ["pool_t0", "pool_t1", "pool_t2"];

impl ModelPreset {
    /// Layers used for residency/accounting: the paper model's layer count
    /// (the executed small model maps its layers onto the first few).
    pub fn n_layers_logical(&self) -> usize {
        self.paper_layers.max(self.n_layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn coord(preset: ModelPreset) -> Coordinator {
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        Coordinator::new(&preset, &cfg, &dev).unwrap()
    }

    #[test]
    fn boots_all_cold_within_envelope() {
        let c = coord(ModelPreset::qwen30b_sim());
        assert!(c.plan.n_hi_per_layer() > 0);
        assert!(c.plan.n_hi_per_layer() < 128);
        assert!(c.budget.within_envelope());
        assert_eq!(c.resolve(0, 0), Precision::Int4);
    }

    #[test]
    fn hot_traffic_promotes_within_budget() {
        let c = coord(ModelPreset::phi_sim());
        let n_hi = c.plan.n_hi_per_layer();
        // drive traffic to experts 0..3 of layer 0
        for _ in 0..100 {
            c.record_routing(0, &[0, 1, 2, 3]);
        }
        let r = c.tick(1.0); // past the 50 ms update interval
        assert!(r.ran);
        assert!(r.promotions_submitted > 0);
        // only the four trafficked experts are promotion candidates (idle
        // experts are never promoted), and capacity bounds the rest
        assert!(r.promotions_submitted <= n_hi.max(4).min(4));
        // let transfers complete
        c.pipeline.wait_staged();
        c.tick(1e3);
        for e in 0..4.min(n_hi) {
            assert_eq!(c.resolve(0, e), Precision::Fp16, "expert {e}");
        }
        assert!(c.budget.within_envelope());
    }

    #[test]
    fn sharded_recording_is_invisible_until_tick() {
        let c = coord(ModelPreset::phi_sim());
        c.record_routing(0, &[0, 0, 1]);
        assert_eq!(c.pending_routing(), 3);
        assert_eq!(c.hotness_score(0, 0), 0.0, "pre-boundary");
        assert!(!c.update_due(0.01));
        assert!(c.update_due(1.0));
        let r = c.tick(1.0);
        assert!(r.ran);
        assert_eq!(c.pending_routing(), 0, "tick merges the shards");
        assert!(c.hotness_score(0, 0) > 0.0, "post-boundary");
    }

    #[test]
    fn update_interval_gates_policy() {
        let c = coord(ModelPreset::phi_sim());
        c.record_routing(0, &[0]);
        let r = c.tick(0.01); // before T_u
        assert!(!r.ran);
        let r = c.tick(0.06);
        assert!(r.ran);
    }

    #[test]
    fn workload_shift_swaps_hot_set() {
        let mut cfg = ServingConfig::default();
        cfg.hysteresis_margin = 0.0;
        cfg.ema_alpha = 0.0; // fully reactive for the test
        cfg.max_inflight_promotions = 1024;
        cfg.n_hi_override = Some(2); // force displacement on shift
        let dev = DeviceConfig::default();
        let preset = ModelPreset::phi_sim();
        let c = Coordinator::new(&preset, &cfg, &dev).unwrap();
        assert_eq!(c.plan.n_hi_per_layer(), 2);

        // phase 1: experts {0,1} hot
        for _ in 0..50 {
            c.record_routing(0, &[0, 1]);
        }
        c.tick(0.1);
        c.pipeline.wait_staged();
        c.tick(10.0);
        assert_eq!(c.resolve(0, 0), Precision::Fp16);
        assert_eq!(c.resolve(0, 1), Precision::Fp16);

        // phase 2: shift to {8, 9} — must displace {0, 1}
        for step in 0..20 {
            for _ in 0..50 {
                c.record_routing(0, &[8, 9]);
            }
            c.tick(10.0 + step as f64);
            c.pipeline.wait_staged();
        }
        c.tick(1e4);
        assert_eq!(c.resolve(0, 8), Precision::Fp16);
        assert_eq!(c.resolve(0, 9), Precision::Fp16);
        assert_eq!(c.resolve(0, 0), Precision::Int4);
        assert_eq!(c.resolve(0, 1), Precision::Int4);
        assert!(c.budget.within_envelope());
    }

    #[test]
    fn fixed_alpha_stack_reports_no_drift() {
        let c = coord(ModelPreset::phi_sim());
        for _ in 0..100 {
            c.record_routing(0, &[0, 1]);
        }
        c.tick(1.0);
        c.tick(2.0);
        assert_eq!(c.drift_stats(), (0, 0));
    }

    #[test]
    fn adaptive_coordinator_detects_swap_and_recovers_alpha() {
        let mut cfg = ServingConfig::default();
        cfg.adaptive_alpha = true;
        cfg.ema_alpha = 0.95; // sluggish baseline the detector rescues
        cfg.update_interval_ms = 1.0;
        cfg.drift.window = 2;
        let preset = ModelPreset::phi_sim().executed_scale();
        let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default())
            .unwrap();
        let mut now = 0.0;
        // steady phase on {0,1}: windows fill, nothing triggers
        for _ in 0..8 {
            for _ in 0..60 {
                c.record_routing(0, &[0, 1]);
            }
            now += 0.0011;
            let r = c.tick(now);
            assert!(!r.drift_detected);
        }
        assert_eq!(c.drift_stats().0, 0);
        // hard swap to {8,9}: a change-point fires within 2 windows + 1
        let mut fired = false;
        for _ in 0..(2 * cfg.drift.window + 1) {
            for _ in 0..60 {
                c.record_routing(0, &[8, 9]);
            }
            now += 0.0011;
            fired |= c.tick(now).drift_detected;
            if fired {
                break;
            }
        }
        assert!(fired, "swap must trigger the change-point");
        let (events, _) = c.drift_stats();
        assert_eq!(events, 1);
        // recovery ticks accrue while the dropped α is in effect
        for _ in 0..cfg.drift.recovery_intervals {
            for _ in 0..60 {
                c.record_routing(0, &[8, 9]);
            }
            now += 0.0011;
            c.tick(now);
        }
        let (_, recovery) = c.drift_stats();
        assert!(
            recovery >= cfg.drift.recovery_intervals,
            "recovery ticks {recovery} < span {}",
            cfg.drift.recovery_intervals
        );
        // steady traffic on the new hot set: no further triggers, and the
        // recovery budget stops growing once it is spent
        for _ in 0..6 {
            for _ in 0..60 {
                c.record_routing(0, &[8, 9]);
            }
            now += 0.0011;
            c.tick(now);
        }
        let (events2, recovery2) = c.drift_stats();
        assert_eq!(events2, 1, "steady post-swap traffic must not re-fire");
        assert_eq!(recovery2, events2 * cfg.drift.recovery_intervals);
        assert!(c.budget.within_envelope());
    }

    #[test]
    fn recovery_budget_survives_idle_intervals() {
        // The reactive budget spans intervals of traffic: a lull right
        // after a trigger must neither drain it nor decay scores at the
        // dropped α (lull-invisibility contract, DESIGN.md §10).
        let mut cfg = ServingConfig::default();
        cfg.adaptive_alpha = true;
        cfg.update_interval_ms = 1.0;
        cfg.drift.window = 1;
        let preset = ModelPreset::phi_sim().executed_scale();
        let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default())
            .unwrap();
        let mut now = 0.0;
        let drive = |c: &Coordinator, now: &mut f64, hot: Option<&[usize]>| {
            if let Some(h) = hot {
                for _ in 0..200 {
                    c.record_routing(0, h);
                }
            }
            *now += 0.0011;
            c.tick(*now)
        };
        // steady on {0,1}, then swap to {8,9} → change-point
        for _ in 0..3 {
            drive(&c, &mut now, Some(&[0, 1]));
        }
        let mut fired = false;
        for _ in 0..3 {
            fired |= drive(&c, &mut now, Some(&[8, 9])).drift_detected;
        }
        assert!(fired, "swap must trigger");
        let (_, ticks_before) = c.drift_stats();
        assert!(ticks_before < cfg.drift.recovery_intervals, "budget left");
        // a long lull: no recovery ticks consumed, and scores decay at
        // the classic α, not the dropped one
        let s_before = c.hotness_score(0, 8);
        for _ in 0..6 {
            drive(&c, &mut now, None);
        }
        let (_, ticks_after) = c.drift_stats();
        assert_eq!(ticks_before, ticks_after, "lull drained the budget");
        let expected = s_before * cfg.ema_alpha.powi(6);
        let s_after = c.hotness_score(0, 8);
        assert!(
            (s_after - expected).abs() < 1e-9 * expected.max(1.0),
            "lull decayed at the wrong α: {s_after} vs {expected}"
        );
        // traffic resumes: the remaining reactive budget applies now
        drive(&c, &mut now, Some(&[8, 9]));
        assert!(c.drift_stats().1 > ticks_after);
    }

    #[test]
    fn invalid_drift_config_refused() {
        let mut cfg = ServingConfig::default();
        cfg.adaptive_alpha = true;
        cfg.drift.window = 0;
        let dev = DeviceConfig::default();
        let err = Coordinator::new(&ModelPreset::phi_sim(), &cfg, &dev)
            .unwrap_err();
        assert!(err.contains("drift.window"), "{err}");
        // the same degenerate values are inert with the layer off
        cfg.adaptive_alpha = false;
        assert!(Coordinator::new(&ModelPreset::phi_sim(), &cfg, &dev).is_ok());
    }

    #[test]
    fn infeasible_budget_refused() {
        let mut cfg = ServingConfig::default();
        cfg.hbm_budget_bytes = 1 << 20;
        let dev = DeviceConfig::default();
        assert!(
            Coordinator::new(&ModelPreset::qwen30b_sim(), &cfg, &dev).is_err()
        );
    }

    #[test]
    fn overcommitting_override_refused() {
        let mut cfg = ServingConfig::default();
        cfg.n_hi_override = Some(128); // all-hot qwen30b ≫ 48 GB
        let dev = DeviceConfig::default();
        let err = Coordinator::new(&ModelPreset::qwen30b_sim(), &cfg, &dev)
            .unwrap_err();
        assert!(err.contains("overcommits"), "{err}");
    }

    #[test]
    fn executed_scale_all_hot_override_feasible() {
        // The quality harness (Figure 3) sweeps n_hi_override up to
        // n_experts on executed-scale presets (4 logical layers); the
        // envelope validation must keep accepting those — only paper-scale
        // overcommit (see overcommitting_override_refused) is an error.
        for preset in
            [ModelPreset::phi_sim(), ModelPreset::qwen30b_sim()]
        {
            let exec = preset.executed_scale();
            let mut cfg = ServingConfig::default();
            cfg.n_hi_override = Some(exec.n_experts);
            let c =
                Coordinator::new(&exec, &cfg, &DeviceConfig::default());
            assert!(c.is_ok(), "{}: {:?}", exec.name, c.err());
            assert_eq!(
                c.unwrap().plan.n_hi_per_layer(),
                exec.n_experts
            );
        }
    }

    #[test]
    fn three_tier_coordinator_fills_middle_rung() {
        let mut cfg = ServingConfig::default();
        cfg.hysteresis_margin = 0.0;
        cfg.ema_alpha = 0.0;
        cfg.max_inflight_promotions = 1024;
        cfg.n_hi_override = Some(2);
        let preset = ModelPreset::qwen30b_3tier();
        let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default())
            .unwrap();
        assert_eq!(c.plan.n_tiers(), 3);
        assert_eq!(c.plan.tier_capacity[0], 2);
        assert!(
            c.plan.tier_capacity[1] > 2,
            "int4 rung funded from the remaining slack: {:?}",
            c.plan.tier_capacity
        );
        // traffic gradient: expert 0 ≫ 1 ≫ 2 … over the mid-rung capacity
        let hot = 2 + c.plan.tier_capacity[1].min(6);
        for round in 0..40 {
            for e in 0..hot {
                for _ in 0..(2 * (hot - e)) {
                    c.record_routing(0, &[e]);
                }
            }
            c.tick(0.1 * (round + 1) as f64);
            c.pipeline.wait_staged();
        }
        c.tick(1e3);
        // hottest two at the top rung, the next ones at the middle rung
        assert_eq!(c.resolve(0, 0), Precision::Fp16);
        assert_eq!(c.resolve(0, 1), Precision::Fp16);
        assert_eq!(c.resolve(0, 2), Precision::Int4);
        assert_eq!(c.resolve_tier(0, 2), 1);
        // untouched experts stay at the base rung
        assert_eq!(c.resolve(0, 100), Precision::Int2);
        assert!(c.budget.within_envelope());
        for p in &c.pools {
            assert!(p.consistent());
        }
    }

    #[test]
    fn degenerate_qos_config_is_structurally_inert() {
        let mut cfg = ServingConfig::default();
        cfg.qos = Some(crate::config::QosConfig::degenerate());
        let preset = ModelPreset::phi_sim();
        let dev = DeviceConfig::default();
        let c = Coordinator::new(&preset, &cfg, &dev).unwrap();
        assert!(!c.qos_armed(), "degenerate config must not arm QoS");
        c.set_active_class(0); // must be a no-op when unarmed
        for _ in 0..50 {
            c.record_routing(0, &[0, 1]);
        }
        c.tick(1.0);
        assert!(c.hotness_score(0, 0) > 0.0);
        // the weighted view collapses to the raw estimator exactly
        assert_eq!(c.weighted_score(0, 0), c.hotness_score(0, 0));
        // an invalid config is refused at construction, not at tick
        cfg.qos = Some(
            crate::config::QosConfig::tiered()
                .with_weight(QosClass::Premium, -1.0),
        );
        let err = Coordinator::new(&preset, &cfg, &dev).unwrap_err();
        assert!(err.contains("premium"), "{err}");
    }

    #[test]
    fn premium_weight_wins_top_rung_at_equal_raw_hotness() {
        let mut cfg = ServingConfig::default();
        cfg.hysteresis_margin = 0.0;
        cfg.ema_alpha = 0.0; // fully reactive for the test
        cfg.max_inflight_promotions = 1024;
        cfg.n_hi_override = Some(1); // a single contested top slot
        cfg.qos = Some(crate::config::QosConfig::tiered());
        let preset = ModelPreset::phi_sim();
        let c = Coordinator::new(&preset, &cfg, &DeviceConfig::default())
            .unwrap();
        assert!(c.qos_armed());
        // identical raw traffic from two classes: best-effort on expert 2,
        // premium on expert 5 — the higher index loses index tie-breaks,
        // so only the class weighting can hand it the top rung
        c.set_active_class(QosClass::BestEffort.index());
        for _ in 0..50 {
            c.record_routing(0, &[2]);
        }
        c.set_active_class(QosClass::Premium.index());
        for _ in 0..50 {
            c.record_routing(0, &[5]);
        }
        c.tick(1.0);
        c.pipeline.wait_staged();
        c.tick(1e3);
        assert!(c.weighted_score(0, 5) > c.weighted_score(0, 2));
        assert_eq!(c.resolve(0, 5), Precision::Fp16);
        assert_eq!(c.resolve(0, 2), Precision::Int4);
        assert!(c.budget.within_envelope());
    }
}
