//! Non-blocking transition pipeline (§3.4), generalized to the ladder.
//!
//! Tier moves run off the token critical path:
//!
//! * **Admission** — a transition is accepted only if the [`BudgetTracker`]
//!   reservation at the destination rung and the destination pool
//!   allocation both succeed (backpressure: otherwise it is deferred, and
//!   the forward pass keeps using the currently published version).
//! * **Staging** — a real background worker thread assembles the prepared
//!   weight bytes into a staging buffer (the pinned-host-memory copy of the
//!   paper; `avoid on-the-fly repacking` — bytes were packed offline).
//! * **Modeled transfer** — the copy is scheduled on the dedicated
//!   migration [`Stream`], disjoint from the compute stream; its completion
//!   event is the modeled time at which the version is materialized.
//! * **Publication** — at the first `poll(now)` past the completion event
//!   (and with staging done), the stable handle is atomically switched and
//!   the old version's storage is queued for eviction. Evictions are
//!   drained *before* admissions when the budget is tight.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::model::{Precision, PrecisionLadder};
use crate::sim::Stream;
use crate::util::lockorder::{LockRank, OrderedMutex};

use super::budget::BudgetTracker;
use super::pools::{BlockPool, PoolAlloc};
use super::ver::{ExpertKey, HandleTable, Residency};

/// A precision transition: move the expert's active version to rung `0`
/// of the variant. Toward tier 0 is a promotion, away from it a demotion —
/// the pair the 2-rung ladder calls `Promote`/`Demote`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransitionKind {
    /// Materialize (copy in) the version at the given rung and switch the
    /// handle to it.
    ToTier(usize),
}

impl TransitionKind {
    pub fn target(self) -> usize {
        let TransitionKind::ToTier(t) = self;
        t
    }
}

/// Outcome of a submission attempt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Admission {
    Admitted { job: u64, done_at: f64 },
    /// Budget or pool capacity unavailable — retry after evictions.
    Deferred,
    /// Expert already transitioning or already at the target rung.
    Redundant,
    /// Target rung is off the ladder — the submission is invalid and has
    /// no side effects (previously an `assert!` that aborted the process
    /// mid-serve on a mis-sized rung index).
    Rejected,
}

/// Builds the staged bytes for (expert, precision). The numeric engine
/// assembles real packed weights; the modeled engine supplies byte counts
/// only. Runs on the background worker thread.
pub type StageFn = dyn Fn(ExpertKey, Precision) -> Vec<u8> + Send + Sync;

struct StageJob {
    #[allow(dead_code)] // job identity kept for tracing/debugging
    id: u64,
    key: ExpertKey,
    precision: Precision,
}

struct Inflight {
    #[allow(dead_code)] // job identity kept for tracing/debugging
    id: u64,
    key: ExpertKey,
    /// Rung the expert held when the transition was admitted.
    from: usize,
    /// Destination rung.
    to: usize,
    /// Modeled migration-stream completion time.
    done_at: f64,
    staged: Arc<AtomicBool>,
    new_alloc: PoolAlloc,
}

/// A deferred reclamation of a superseded version's storage.
struct Eviction {
    alloc: PoolAlloc,
    /// Rung whose pool the storage came from.
    tier: usize,
    /// Budget bytes to release at that rung (0 for the statically
    /// provisioned base rung).
    release_bytes: usize,
}

/// Counters exposed for the benches/metrics.
#[derive(Debug, Default)]
pub struct PipelineStats {
    pub promotions: AtomicU64,
    pub demotions: AtomicU64,
    pub deferred: AtomicU64,
    pub rejected: AtomicU64,
    pub published: AtomicU64,
    pub evictions: AtomicU64,
    pub migrated_bytes: AtomicU64,
}

impl PipelineStats {
    /// Plain-value snapshot of the counters (bench/metrics export).
    pub fn totals(&self) -> TransitionTotals {
        let ld = |a: &AtomicU64| a.load(Ordering::Relaxed); // relaxed-ok: stat counter snapshot
        TransitionTotals {
            promotions: ld(&self.promotions),
            demotions: ld(&self.demotions),
            deferred: ld(&self.deferred),
            rejected: ld(&self.rejected),
            published: ld(&self.published),
            evictions: ld(&self.evictions),
            migrated_bytes: ld(&self.migrated_bytes),
        }
    }
}

/// A [`PipelineStats`] snapshot as plain values — what the wall-clock
/// bench harness reports per cell (and sums across a device group). These
/// are the allocation-visible proxy counters of DESIGN.md §11: `deferred`
/// means backpressure (capacity), never a redundant no-op.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransitionTotals {
    pub promotions: u64,
    pub demotions: u64,
    pub deferred: u64,
    pub rejected: u64,
    pub published: u64,
    pub evictions: u64,
    pub migrated_bytes: u64,
}

impl TransitionTotals {
    /// Accumulate another device's counters (device-group aggregation).
    pub fn add(&mut self, o: &TransitionTotals) {
        self.promotions += o.promotions;
        self.demotions += o.demotions;
        self.deferred += o.deferred;
        self.rejected += o.rejected;
        self.published += o.published;
        self.evictions += o.evictions;
        self.migrated_bytes += o.migrated_bytes;
    }

    /// Counter growth since `baseline` (windowed measurement: the bench
    /// harness subtracts a post-warmup snapshot so cells report the timed
    /// rounds only). Saturating, so a mismatched baseline cannot wrap.
    pub fn delta_since(&self, baseline: &TransitionTotals) -> TransitionTotals {
        TransitionTotals {
            promotions: self.promotions.saturating_sub(baseline.promotions),
            demotions: self.demotions.saturating_sub(baseline.demotions),
            deferred: self.deferred.saturating_sub(baseline.deferred),
            rejected: self.rejected.saturating_sub(baseline.rejected),
            published: self.published.saturating_sub(baseline.published),
            evictions: self.evictions.saturating_sub(baseline.evictions),
            migrated_bytes: self
                .migrated_bytes
                .saturating_sub(baseline.migrated_bytes),
        }
    }
}

/// The mutable pipeline state — migration stream, in-flight list, and
/// eviction queue — behind **one** mutex (DESIGN.md §13). They were three
/// separate locks once; every operation that touched two of them (submit
/// drains evictions then schedules a transfer, poll publishes then queues
/// evictions) acquired them in sequence, which was both doubled lock
/// traffic per tick and a latent ordering hazard once device ticks run
/// concurrently. One lock, one order, no interleaving between the
/// admission decision and its bookkeeping.
struct PipelineInner {
    migration: Stream,
    inflight: Vec<Inflight>,
    evictions: VecDeque<Eviction>,
}

/// The transition pipeline. One per engine.
pub struct TransitionPipeline {
    handles: Arc<HandleTable>,
    budget: Arc<BudgetTracker>,
    /// One pool per rung, tier 0 first.
    pools: Vec<Arc<BlockPool>>,
    ladder: PrecisionLadder,
    /// Modeled PCIe seconds per byte (from the cost model).
    secs_per_byte: f64,
    /// Device bytes of one expert at each precision at *logical* scale.
    bytes_of: Box<dyn Fn(Precision) -> usize + Send + Sync>,
    max_inflight: usize,

    inner: OrderedMutex<PipelineInner>,
    next_id: AtomicU64,
    pub stats: PipelineStats,

    stage_tx: Option<Sender<(StageJob, Arc<AtomicBool>)>>,
    worker: Option<JoinHandle<()>>,
}

impl TransitionPipeline {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        handles: Arc<HandleTable>,
        budget: Arc<BudgetTracker>,
        pools: Vec<Arc<BlockPool>>,
        secs_per_byte: f64,
        bytes_of: Box<dyn Fn(Precision) -> usize + Send + Sync>,
        max_inflight: usize,
        stager: Arc<StageFn>,
    ) -> Self {
        let ladder = handles.ladder().clone();
        assert_eq!(pools.len(), ladder.n_tiers(), "one pool per rung");
        let (tx, rx): (
            Sender<(StageJob, Arc<AtomicBool>)>,
            Receiver<(StageJob, Arc<AtomicBool>)>,
        ) = channel();
        let worker = std::thread::Builder::new()
            .name("dynaexq-migration".into())
            .spawn(move || {
                // Background staging worker: the host side of stream_mig.
                while let Ok((job, flag)) = rx.recv() {
                    let bytes = stager(job.key, job.precision);
                    std::hint::black_box(&bytes);
                    flag.store(true, Ordering::Release);
                }
            })
            .expect("spawn migration worker");
        Self {
            handles,
            budget,
            pools,
            ladder,
            secs_per_byte,
            bytes_of,
            max_inflight,
            inner: OrderedMutex::new(
                LockRank::PipelineInner,
                PipelineInner {
                    migration: Stream::new(),
                    inflight: Vec::new(),
                    evictions: VecDeque::new(),
                },
            ),
            next_id: AtomicU64::new(1),
            stats: PipelineStats::default(),
            stage_tx: Some(tx),
            worker: Some(worker),
        }
    }

    /// Submit a transition at modeled time `now`.
    ///
    /// Admission is decided in a fixed order: validity (on-ladder target)
    /// → redundancy → capacity. Redundancy before capacity matters for
    /// the stats contract: a redundant submission against a *full*
    /// pipeline is [`Admission::Redundant`], not [`Admission::Deferred`]
    /// — `deferred` counts backpressure only, which is what the bench
    /// harness reports as a hot-path proxy counter.
    pub fn submit(
        &self,
        key: ExpertKey,
        kind: TransitionKind,
        now: f64,
    ) -> Admission {
        let to = kind.target();
        let base = self.ladder.base_tier();
        if to > base {
            // Off-ladder target: reject with no side effects instead of
            // aborting the process mid-serve on a caller's mis-sized
            // rung index.
            self.stats.rejected.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            return Admission::Rejected;
        }

        // One lock for the whole admission: the eviction drain, the
        // capacity checks, the transfer scheduling and the in-flight
        // bookkeeping all happen under a single acquisition, so a
        // concurrent submitter can never interleave between the decision
        // and its side effects.
        let mut inner = self.inner.lock();

        // Reclaim superseded buffers first — eviction priority under
        // pressure increases the feasible set for this admission.
        self.drain_locked(&mut inner);

        let from = {
            let entry = self.handles.entry(key);
            let cur = entry.residency.active_tier();
            if entry.residency.is_transitioning() || cur == to {
                return Admission::Redundant;
            }
            cur
        };

        if inner.inflight.len() >= self.max_inflight {
            self.stats.deferred.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            return Admission::Deferred;
        }

        // Admission control: budget reservation at the destination rung
        // before anything else (the base rung is statically provisioned).
        let target_precision = self.ladder.tier(to);
        let dev_bytes = (self.bytes_of)(target_precision);
        let reserve_bytes = if to == base { 0 } else { dev_bytes };
        if reserve_bytes > 0 && !self.budget.try_reserve(to, reserve_bytes) {
            self.stats.deferred.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            return Admission::Deferred;
        }

        // Destination pool allocation (guaranteed to fit post-reservation
        // as pools are sized to the caps, but handle failure defensively).
        let Some(new_alloc) = self.pools[to].alloc(dev_bytes) else {
            if reserve_bytes > 0 {
                self.budget.release(to, reserve_bytes);
            }
            self.stats.deferred.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            return Admission::Deferred;
        };

        // Mark the entry and enqueue staging + modeled transfer.
        {
            let mut entry = self.handles.entry(key);
            entry.residency = Residency::Transitioning { from, to };
            entry.pending_alloc = Some(new_alloc);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed); // relaxed-ok: unique id draw, no ordering needed
        let staged = Arc::new(AtomicBool::new(false));
        if let Some(tx) = &self.stage_tx {
            tx.send((
                StageJob { id, key, precision: target_precision },
                staged.clone(),
            ))
            .expect("migration worker alive");
        }
        let done_at = inner
            .migration
            .schedule(now, dev_bytes as f64 * self.secs_per_byte);
        self.stats
            .migrated_bytes
            .fetch_add(dev_bytes as u64, Ordering::Relaxed); // relaxed-ok: stat counter
        if to < from {
            self.stats.promotions.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        } else {
            self.stats.demotions.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        }
        inner.inflight.push(Inflight {
            id,
            key,
            from,
            to,
            done_at,
            staged,
            new_alloc,
        });
        Admission::Admitted { job: id, done_at }
    }

    /// Publish every transition whose modeled completion event has fired
    /// (and whose staging is done). Returns the published (key, precision)
    /// pairs. Called at iteration boundaries by the engine — the forward
    /// pass itself never waits on this.
    pub fn poll(&self, now: f64) -> Vec<(ExpertKey, Precision)> {
        let base = self.ladder.base_tier();
        let mut published = Vec::new();
        let mut inner = self.inner.lock();
        let mut i = 0;
        while i < inner.inflight.len() {
            let ready = inner.inflight[i].done_at <= now
                && inner.inflight[i].staged.load(Ordering::Acquire);
            if !ready {
                i += 1;
                continue;
            }
            let job = inner.inflight.swap_remove(i);
            let mut entry = self.handles.entry(job.key);
            // Publish-then-switch: new version becomes visible atomically...
            let old_alloc = entry.active_alloc.take();
            entry.active_alloc = Some(job.new_alloc);
            entry.pending_alloc = None;
            entry.residency = Residency::Resident(job.to);
            drop(entry);
            self.handles.publish(job.key, job.to);
            self.stats.published.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
            // ...then the superseded version is reclaimed in the background.
            if let Some(alloc) = old_alloc {
                let release_bytes = if job.from == base {
                    0
                } else {
                    (self.bytes_of)(self.ladder.tier(job.from))
                };
                inner.evictions.push_back(Eviction {
                    alloc,
                    tier: job.from,
                    release_bytes,
                });
            }
            published.push((job.key, self.ladder.tier(job.to)));
        }
        self.drain_locked(&mut inner);
        published
    }

    /// Reclaim superseded buffers (the eviction queue of §3.4).
    pub fn drain_evictions(&self) {
        self.drain_locked(&mut self.inner.lock());
    }

    /// The drain body, for callers already holding the pipeline lock.
    fn drain_locked(&self, inner: &mut PipelineInner) {
        while let Some(ev) = inner.evictions.pop_front() {
            self.pools[ev.tier].free(ev.alloc);
            if ev.release_bytes > 0 {
                self.budget.release(ev.tier, ev.release_bytes);
            }
            self.stats.evictions.fetch_add(1, Ordering::Relaxed); // relaxed-ok: stat counter
        }
    }

    /// Modeled time at which all queued migration work completes.
    pub fn migration_tail(&self) -> f64 {
        self.inner.lock().migration.tail()
    }

    /// Total modeled migration busy time (bandwidth accounting).
    pub fn migration_busy(&self) -> f64 {
        self.inner.lock().migration.busy()
    }

    /// Number of in-flight transitions.
    pub fn inflight_count(&self) -> usize {
        self.inner.lock().inflight.len()
    }

    /// The in-flight (key, from, to) moves (policy planning input — avoids
    /// scanning every entry's state mutex on the update path).
    pub fn inflight_transitions(&self) -> Vec<(ExpertKey, usize, usize)> {
        self.inner
            .lock()
            .inflight
            .iter()
            .map(|j| (j.key, j.from, j.to))
            .collect()
    }

    /// Experts currently moving toward tier 0 (diagnostics).
    pub fn promoting_keys(&self) -> Vec<ExpertKey> {
        self.inner
            .lock()
            .inflight
            .iter()
            .filter(|j| j.to < j.from)
            .map(|j| j.key)
            .collect()
    }

    /// Experts currently moving away from tier 0 (diagnostics).
    pub fn demoting_keys(&self) -> Vec<ExpertKey> {
        self.inner
            .lock()
            .inflight
            .iter()
            .filter(|j| j.to > j.from)
            .map(|j| j.key)
            .collect()
    }

    /// Test helper: block until all submitted staging jobs finish.
    pub fn wait_staged(&self) {
        loop {
            let all = self
                .inner
                .lock()
                .inflight
                .iter()
                .all(|j| j.staged.load(Ordering::Acquire));
            if all {
                return;
            }
            std::thread::yield_now();
        }
    }
}

impl Drop for TransitionPipeline {
    fn drop(&mut self) {
        drop(self.stage_tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::expert_bytes;

    fn mk_pipeline(
        n_experts: usize,
        n_hi_slots: usize,
    ) -> (Arc<HandleTable>, Arc<BudgetTracker>, TransitionPipeline) {
        let ladder =
            PrecisionLadder::two_tier(Precision::Fp16, Precision::Int4);
        let handles = Arc::new(HandleTable::new(1, n_experts, ladder));
        let b_hi = expert_bytes(Precision::Fp16);
        let b_lo = expert_bytes(Precision::Int4);
        let budget =
            Arc::new(BudgetTracker::new(n_hi_slots * b_hi, n_experts * b_lo));
        let pool_hi = Arc::new(BlockPool::new("hi", n_hi_slots * b_hi, b_hi));
        let pool_lo = Arc::new(BlockPool::new("lo", n_experts * b_lo, b_lo));
        // mark lo allocations for the boot state
        for e in 0..n_experts {
            let a = pool_lo.alloc(b_lo).unwrap();
            budget.try_reserve_lo(b_lo);
            handles.entry(ExpertKey::new(0, e)).active_alloc = Some(a);
        }
        let p = TransitionPipeline::new(
            handles.clone(),
            budget.clone(),
            vec![pool_hi, pool_lo],
            1e-9, // 1 GB/s → easy math
            Box::new(expert_bytes),
            8,
            Arc::new(|_, _| Vec::new()),
        );
        (handles, budget, p)
    }

    const PROMOTE: TransitionKind = TransitionKind::ToTier(0);
    const DEMOTE: TransitionKind = TransitionKind::ToTier(1);

    #[test]
    fn promotion_publishes_after_completion_event() {
        let (handles, _b, p) = mk_pipeline(4, 2);
        let k = ExpertKey::new(0, 1);
        let adm = p.submit(k, PROMOTE, 0.0);
        let done_at = match adm {
            Admission::Admitted { done_at, .. } => done_at,
            other => panic!("expected admission, got {other:?}"),
        };
        // before the event: still lo, forward path unaffected
        assert_eq!(handles.resolve(k), Precision::Int4);
        p.wait_staged();
        assert!(p.poll(done_at / 2.0).is_empty());
        assert_eq!(handles.resolve(k), Precision::Int4);
        // after the event: published
        let pubs = p.poll(done_at);
        assert_eq!(pubs, vec![(k, Precision::Fp16)]);
        assert_eq!(handles.resolve(k), Precision::Fp16);
    }

    #[test]
    fn admission_respects_budget_cap() {
        let (_h, b, p) = mk_pipeline(8, 2);
        let a1 = p.submit(ExpertKey::new(0, 0), PROMOTE, 0.0);
        let a2 = p.submit(ExpertKey::new(0, 1), PROMOTE, 0.0);
        assert!(matches!(a1, Admission::Admitted { .. }));
        assert!(matches!(a2, Admission::Admitted { .. }));
        // third promotion exceeds the 2-slot cap → deferred, no reservation
        let a3 = p.submit(ExpertKey::new(0, 2), PROMOTE, 0.0);
        assert_eq!(a3, Admission::Deferred);
        assert!(b.within_envelope());
    }

    #[test]
    fn demotion_frees_hi_capacity() {
        let (h, b, p) = mk_pipeline(8, 1);
        let k0 = ExpertKey::new(0, 0);
        let adm = p.submit(k0, PROMOTE, 0.0);
        let t1 = match adm {
            Admission::Admitted { done_at, .. } => done_at,
            _ => panic!(),
        };
        p.wait_staged();
        p.poll(t1);
        assert_eq!(h.resolve(k0), Precision::Fp16);
        // cap full → next promote deferred
        assert_eq!(
            p.submit(ExpertKey::new(0, 1), PROMOTE, t1),
            Admission::Deferred
        );
        // demote k0, publish, evict → capacity returns
        let t2 = match p.submit(k0, DEMOTE, t1) {
            Admission::Admitted { done_at, .. } => done_at,
            other => panic!("{other:?}"),
        };
        p.wait_staged();
        p.poll(t2);
        assert_eq!(h.resolve(k0), Precision::Int4);
        assert_eq!(b.hi_used(), 0);
        assert!(matches!(
            p.submit(ExpertKey::new(0, 1), PROMOTE, t2),
            Admission::Admitted { .. }
        ));
    }

    #[test]
    fn redundant_transitions_rejected() {
        let (_h, _b, p) = mk_pipeline(4, 2);
        let k = ExpertKey::new(0, 0);
        // already lo → demote is redundant
        assert_eq!(p.submit(k, DEMOTE, 0.0), Admission::Redundant);
        let _ = p.submit(k, PROMOTE, 0.0);
        // already promoting → redundant
        assert_eq!(p.submit(k, PROMOTE, 0.0), Admission::Redundant);
        assert_eq!(p.promoting_keys(), vec![k]);
        assert!(p.demoting_keys().is_empty());
    }

    #[test]
    fn migration_stream_serializes_transfers() {
        let (_h, _b, p) = mk_pipeline(4, 2);
        let t1 = match p.submit(ExpertKey::new(0, 0), PROMOTE, 0.0) {
            Admission::Admitted { done_at, .. } => done_at,
            _ => panic!(),
        };
        let t2 = match p.submit(ExpertKey::new(0, 1), PROMOTE, 0.0) {
            Admission::Admitted { done_at, .. } => done_at,
            _ => panic!(),
        };
        // second transfer queues behind the first on stream_mig
        let per = expert_bytes(Precision::Fp16) as f64 * 1e-9;
        assert!((t1 - per).abs() < 1e-12);
        assert!((t2 - 2.0 * per).abs() < 1e-12);
    }

    #[test]
    fn inflight_cap_backpressure() {
        let ladder =
            PrecisionLadder::two_tier(Precision::Fp16, Precision::Int4);
        let handles = Arc::new(HandleTable::new(1, 8, ladder));
        let b_hi = expert_bytes(Precision::Fp16);
        let budget = Arc::new(BudgetTracker::new(8 * b_hi, 0));
        let pool_hi = Arc::new(BlockPool::new("hi", 8 * b_hi, b_hi));
        let pool_lo = Arc::new(BlockPool::new("lo", 8, 1));
        let p = TransitionPipeline::new(
            handles,
            budget,
            vec![pool_hi, pool_lo],
            1e-9,
            Box::new(expert_bytes),
            2, // cap
            Arc::new(|_, _| Vec::new()),
        );
        assert!(matches!(
            p.submit(ExpertKey::new(0, 0), PROMOTE, 0.0),
            Admission::Admitted { .. }
        ));
        assert!(matches!(
            p.submit(ExpertKey::new(0, 1), PROMOTE, 0.0),
            Admission::Admitted { .. }
        ));
        assert_eq!(
            p.submit(ExpertKey::new(0, 2), PROMOTE, 0.0),
            Admission::Deferred
        );
    }

    #[test]
    fn redundant_submission_against_full_pipeline_is_redundant() {
        // Regression: the capacity check used to run before the
        // redundancy check, so resubmitting an already-in-flight expert
        // against a full pipeline was miscounted `deferred`. Redundancy
        // is decided first now.
        let ladder =
            PrecisionLadder::two_tier(Precision::Fp16, Precision::Int4);
        let handles = Arc::new(HandleTable::new(1, 8, ladder));
        let b_hi = expert_bytes(Precision::Fp16);
        let budget = Arc::new(BudgetTracker::new(8 * b_hi, 0));
        let pool_hi = Arc::new(BlockPool::new("hi", 8 * b_hi, b_hi));
        let pool_lo = Arc::new(BlockPool::new("lo", 8, 1));
        let p = TransitionPipeline::new(
            handles,
            budget,
            vec![pool_hi, pool_lo],
            1e-9,
            Box::new(expert_bytes),
            1, // cap: the pipeline is full after one admission
            Arc::new(|_, _| Vec::new()),
        );
        let k = ExpertKey::new(0, 0);
        assert!(matches!(p.submit(k, PROMOTE, 0.0), Admission::Admitted { .. }));
        // same expert, pipeline full → Redundant, deferred stat untouched
        assert_eq!(p.submit(k, PROMOTE, 0.0), Admission::Redundant);
        assert_eq!(p.stats.deferred.load(Ordering::Relaxed), 0); // relaxed-ok: test assertion
        // a *different* expert against the full pipeline is real
        // backpressure and is the only thing `deferred` counts
        assert_eq!(
            p.submit(ExpertKey::new(0, 1), PROMOTE, 0.0),
            Admission::Deferred
        );
        assert_eq!(p.stats.deferred.load(Ordering::Relaxed), 1); // relaxed-ok: test assertion
    }

    #[test]
    fn off_ladder_target_rejected_without_side_effects() {
        // Hardened satellite: a mis-sized rung index from a future caller
        // must not abort the process — it is rejected with zero state
        // change and the pipeline keeps serving.
        let (h, b, p) = mk_pipeline(4, 2);
        let k = ExpertKey::new(0, 2);
        let adm = p.submit(k, TransitionKind::ToTier(99), 0.0);
        assert_eq!(adm, Admission::Rejected);
        assert_eq!(p.stats.rejected.load(Ordering::Relaxed), 1); // relaxed-ok: test assertion
        assert_eq!(p.inflight_count(), 0);
        assert_eq!(b.hi_used(), 0, "no reservation leaked");
        assert_eq!(h.resolve(k), Precision::Int4, "residency untouched");
        // the pipeline still admits valid work afterwards
        assert!(matches!(p.submit(k, PROMOTE, 0.0), Admission::Admitted { .. }));
        assert_eq!(p.stats.totals().rejected, 1);
        assert_eq!(p.stats.totals().promotions, 1);
    }

    #[test]
    fn three_rung_moves_reserve_and_release_per_rung() {
        // qwen30b-3tier style pipeline: 1 fp16 slot, 2 int4 slots.
        let ladder = PrecisionLadder::full();
        let handles = Arc::new(HandleTable::new(1, 4, ladder));
        let b: Vec<usize> = [Precision::Fp16, Precision::Int4, Precision::Int2]
            .iter()
            .map(|&p| expert_bytes(p))
            .collect();
        let budget =
            Arc::new(BudgetTracker::with_caps(vec![b[0], 2 * b[1], 4 * b[2]]));
        let pools = vec![
            Arc::new(BlockPool::new("t0", b[0], b[0])),
            Arc::new(BlockPool::new("t1", 2 * b[1], b[1])),
            Arc::new(BlockPool::new("t2", 4 * b[2], b[2])),
        ];
        for e in 0..4 {
            let a = pools[2].alloc(b[2]).unwrap();
            budget.try_reserve(2, b[2]);
            handles.entry(ExpertKey::new(0, e)).active_alloc = Some(a);
        }
        let p = TransitionPipeline::new(
            handles.clone(),
            budget.clone(),
            pools,
            1e-9,
            Box::new(expert_bytes),
            8,
            Arc::new(|_, _| Vec::new()),
        );
        // base → mid
        let k = ExpertKey::new(0, 0);
        let t1 = match p.submit(k, TransitionKind::ToTier(1), 0.0) {
            Admission::Admitted { done_at, .. } => done_at,
            other => panic!("{other:?}"),
        };
        p.wait_staged();
        p.poll(t1);
        assert_eq!(handles.resolve(k), Precision::Int4);
        assert_eq!(budget.used(1), b[1]);
        // mid → top releases the mid reservation on eviction
        let t2 = match p.submit(k, TransitionKind::ToTier(0), t1) {
            Admission::Admitted { done_at, .. } => done_at,
            other => panic!("{other:?}"),
        };
        p.wait_staged();
        p.poll(t2);
        assert_eq!(handles.resolve(k), Precision::Fp16);
        assert_eq!(budget.used(1), 0);
        assert_eq!(budget.used(0), b[0]);
        // top rung full → second fp16 promotion deferred
        assert_eq!(
            p.submit(ExpertKey::new(0, 1), TransitionKind::ToTier(0), t2),
            Admission::Deferred
        );
        // top → base frees everything non-base
        let t3 = match p.submit(k, TransitionKind::ToTier(2), t2) {
            Admission::Admitted { done_at, .. } => done_at,
            other => panic!("{other:?}"),
        };
        p.wait_staged();
        p.poll(t3);
        assert_eq!(handles.resolve(k), Precision::Int2);
        assert_eq!(budget.used(0), 0);
        assert_eq!(budget.used(1), 0);
        assert!(budget.within_envelope());
    }
}
