//! Multi-device sharded serving: a coordinator per device (DESIGN.md §9).
//!
//! A [`DeviceGroup`] generalizes the single-[`Coordinator`] stack to
//! expert-sharded serving across a device group. A [`ShardPlan`] assigns
//! every `(layer, expert)` to a device; each device owns a full coordinator
//! over its shard — its **own** [`super::BudgetTracker`] under its slice of
//! the HBM envelope, per-rung [`super::BlockPool`]s, and a
//! [`super::TransitionPipeline`] whose migration stream runs at the
//! per-device link bandwidth from
//! [`crate::sim::cost::migration_link_bytes_per_s`] (links contend on the
//! host aggregate). The waterfill policy
//! ([`super::policy::plan_layer_ladder`]) therefore runs per device over
//! that device's expert subset, so every shard's envelope is respected
//! independently — there is no global budget authority to coordinate with,
//! which is exactly what makes the group scale.
//!
//! **1-device equivalence guarantee** (property-tested in this module): a
//! group of one device is the single-GPU system — identical budget plan,
//! identical transfer times, identical residency trajectory for identical
//! traffic.

use std::sync::atomic::Ordering;

use crate::config::{DeviceConfig, ModelPreset, ServingConfig, ShardPlan};
use crate::model::Precision;
use crate::sim::cost::migration_link_bytes_per_s;

use super::{Coordinator, UpdateReport};

/// A group of expert-sharded coordinators, one per device.
pub struct DeviceGroup {
    shard: ShardPlan,
    /// One coordinator per device, device 0 first. Each manages only its
    /// shard's experts, addressed by *local* (dense) expert ids.
    pub devices: Vec<Coordinator>,
}

impl DeviceGroup {
    /// Build an `n_devices`-wide group under striped expert placement.
    /// The group-wide envelope in `cfg` is split evenly across devices
    /// (see [`DeviceGroup::device_cfg`]); each device's migration stream
    /// gets the contended per-device link bandwidth.
    pub fn new(
        preset: &ModelPreset,
        cfg: &ServingConfig,
        dev: &DeviceConfig,
        n_devices: usize,
    ) -> Result<Self, String> {
        let shard = ShardPlan::striped(preset.n_experts, n_devices)?;
        let link = migration_link_bytes_per_s(dev, n_devices);
        let mut devices = Vec::with_capacity(n_devices);
        for d in 0..n_devices {
            // Shared experts are replicated on every device (each device
            // runs them for its tokens), so each shard preset keeps
            // `n_shared` and only the routed experts are partitioned.
            let mut shard_preset = preset.clone();
            shard_preset.n_experts = shard.shard_size(d);
            let shard_cfg = Self::device_cfg(cfg, d, n_devices);
            let mut shard_dev = dev.clone();
            shard_dev.pcie_bytes_per_s = link;
            let coord = Coordinator::new(&shard_preset, &shard_cfg, &shard_dev)
                .map_err(|e| format!("device {d}: {e}"))?;
            devices.push(coord);
        }
        Ok(Self { shard, devices })
    }

    /// The per-device slice of the group envelope: HBM budget and the
    /// fixed reservation split evenly (remainder bytes dropped —
    /// conservative), `n_hi_override` distributed round-robin (low device
    /// ids take the remainder). A 1-device group reproduces the input
    /// config exactly.
    pub fn device_cfg(
        cfg: &ServingConfig,
        device: usize,
        n_devices: usize,
    ) -> ServingConfig {
        let mut c = cfg.clone();
        c.hbm_budget_bytes = cfg.hbm_budget_bytes / n_devices;
        c.fixed_bytes = cfg.fixed_bytes / n_devices;
        c.n_hi_override = cfg
            .n_hi_override
            .map(|n| n / n_devices + usize::from(device < n % n_devices));
        c
    }

    pub fn shard(&self) -> &ShardPlan {
        &self.shard
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Device owning `(layer, expert)` (global expert id).
    #[inline]
    pub fn device_of(&self, layer: usize, expert: usize) -> usize {
        self.shard.device_of(layer, expert)
    }

    /// HOT PATH: the ladder rung a (globally addressed) expert executes at.
    #[inline]
    pub fn resolve_tier(&self, layer: usize, expert: usize) -> usize {
        let d = self.shard.device_of(layer, expert);
        self.devices[d].resolve_tier(layer, self.shard.local_of(expert))
    }

    /// HOT PATH: the precision a (globally addressed) expert executes at.
    #[inline]
    pub fn resolve(&self, layer: usize, expert: usize) -> Precision {
        let d = self.shard.device_of(layer, expert);
        self.devices[d].resolve(layer, self.shard.local_of(expert))
    }

    /// Feed router trace for one layer (global expert ids, duplicates
    /// included): selections are split by owning device and translated to
    /// local ids before reaching each device's hotness estimator.
    pub fn record_routing(&self, layer: usize, experts: &[usize]) {
        if self.devices.len() == 1 {
            self.devices[0].record_routing(layer, experts);
            return;
        }
        let mut scratch: Vec<Vec<usize>> =
            vec![Vec::new(); self.devices.len()];
        self.record_routing_into(layer, experts, &mut scratch);
    }

    /// [`DeviceGroup::record_routing`] with caller-owned scratch buffers
    /// (one per device) — the single implementation of the device-split +
    /// local-id translation; hot callers reuse the buffers across layers.
    pub fn record_routing_into(
        &self,
        layer: usize,
        experts: &[usize],
        scratch: &mut [Vec<usize>],
    ) {
        debug_assert_eq!(scratch.len(), self.devices.len());
        if self.devices.len() == 1 {
            self.devices[0].record_routing(layer, experts);
            return;
        }
        for locals in scratch.iter_mut() {
            locals.clear();
        }
        for &e in experts {
            scratch[self.shard.device_of(layer, e)]
                .push(self.shard.local_of(e));
        }
        for (d, locals) in scratch.iter().enumerate() {
            if !locals.is_empty() {
                self.devices[d].record_routing(layer, locals);
            }
        }
    }

    /// Iteration boundary on every device, reports merged deterministically
    /// by device index (DESIGN.md §13).
    ///
    /// When more than one device has a policy update due, the per-device
    /// coordinators tick **concurrently** on scoped threads — they are
    /// independent state machines (own budget, pools, pipeline, hotness)
    /// sharing nothing but `Arc`-held atomics, so the parallel walk
    /// produces exactly the state [`DeviceGroup::tick_serial`] would.
    /// Per-round ticks that would only gate out (the common case between
    /// update intervals) stay on the calling thread: spawning would cost
    /// more than the early-return poll it parallelizes.
    pub fn tick(&self, now_s: f64) -> UpdateReport {
        if self.devices.len() <= 1
            || !self.devices.iter().any(|c| c.update_due(now_s))
        {
            return self.tick_serial(now_s);
        }
        let reports: Vec<UpdateReport> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .devices
                .iter()
                .map(|c| s.spawn(move || c.tick(now_s)))
                .collect();
            // join in spawn order — the merge below is therefore always
            // device 0, 1, … regardless of completion order
            handles
                .into_iter()
                .map(|h| h.join().expect("device tick panicked"))
                .collect()
        });
        let mut agg = UpdateReport::default();
        for r in &reports {
            Self::merge_report(&mut agg, r);
        }
        agg
    }

    /// The serial reference walk: tick device 0, then 1, … on the calling
    /// thread. Equivalence with the concurrent [`DeviceGroup::tick`] is
    /// pinned by the parallel-stress suite.
    pub fn tick_serial(&self, now_s: f64) -> UpdateReport {
        let mut agg = UpdateReport::default();
        for c in &self.devices {
            let r = c.tick(now_s);
            Self::merge_report(&mut agg, &r);
        }
        agg
    }

    /// Deterministic report merge: counters sum, flags OR — commutative
    /// and associative, but always applied in device-index order anyway.
    fn merge_report(agg: &mut UpdateReport, r: &UpdateReport) {
        agg.ran |= r.ran;
        agg.promotions_submitted += r.promotions_submitted;
        agg.demotions_submitted += r.demotions_submitted;
        agg.deferred += r.deferred;
        agg.published += r.published;
        agg.drift_detected |= r.drift_detected;
    }

    /// `(change-point triggers, recovery intervals)` summed across every
    /// device's adaptive hotness layer; `(0, 0)` with `adaptive_alpha` off.
    pub fn drift_stats(&self) -> (u64, u64) {
        self.devices.iter().fold((0, 0), |(e, r), c| {
            let (de, dr) = c.drift_stats();
            (e + de, r + dr)
        })
    }

    /// Per-device `(change-point triggers, recovery intervals)`, device
    /// index order — the asymmetric-drift diagnostics: the group-level
    /// [`DeviceGroup::drift_stats`] sum (and the report's OR-merged
    /// `drift_detected` flag) cannot reveal *which* shard drifted.
    pub fn device_drift_stats(&self) -> Vec<(u64, u64)> {
        self.devices.iter().map(|c| c.drift_stats()).collect()
    }

    /// Publish finished transitions on every device; returns the total
    /// published count.
    pub fn poll(&self, now_s: f64) -> usize {
        self.devices
            .iter()
            .map(|c| c.pipeline.poll(now_s).len())
            .sum()
    }

    /// Block until every device's host-side staging is quiescent.
    pub fn wait_staged(&self) {
        for c in &self.devices {
            c.pipeline.wait_staged();
        }
    }

    /// Modeled time at which every device's migration queue drains.
    pub fn migration_tail(&self) -> f64 {
        self.devices
            .iter()
            .map(|c| c.pipeline.migration_tail())
            .fold(0.0, f64::max)
    }

    /// Total bytes moved across all device links so far (modeled).
    pub fn migrated_bytes(&self) -> u64 {
        self.devices
            .iter()
            .map(|c| c.pipeline.stats.migrated_bytes.load(Ordering::Relaxed)) // relaxed-ok: stat counter
            .sum()
    }

    /// Transition-pipeline counter totals summed across every device
    /// (the bench harness's per-cell proxy counters).
    pub fn transition_totals(&self) -> super::TransitionTotals {
        let mut t = super::TransitionTotals::default();
        for c in &self.devices {
            t.add(&c.pipeline.stats.totals());
        }
        t
    }

    /// Published residency counts per rung, summed over devices.
    pub fn tier_counts(&self) -> Vec<usize> {
        let mut total = vec![0usize; self.devices[0].preset.ladder.n_tiers()];
        for c in &self.devices {
            for (t, n) in c.handles.tier_counts().into_iter().enumerate() {
                total[t] += n;
            }
        }
        total
    }

    /// Published residency counts per device (tier 0 first within each).
    pub fn device_tier_counts(&self) -> Vec<Vec<usize>> {
        self.devices.iter().map(|c| c.handles.tier_counts()).collect()
    }

    /// In-flight transition count per device — the cross-device
    /// promotion-queue depth the metrics snapshot reports.
    pub fn inflight_depths(&self) -> Vec<usize> {
        self.devices.iter().map(|c| c.pipeline.inflight_count()).collect()
    }

    /// C1 across the group: every device inside its own envelope.
    pub fn within_envelope(&self) -> bool {
        self.devices.iter().all(|c| c.budget.within_envelope())
    }

    /// Pool conservation across every device's per-rung pools.
    pub fn pools_consistent(&self) -> bool {
        self.devices
            .iter()
            .all(|c| c.pools.iter().all(|p| p.consistent()))
    }

    /// The group's policy update interval in seconds.
    pub fn update_interval_s(&self) -> f64 {
        self.devices[0].cfg.update_interval_ms / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;
    use crate::util::XorShiftRng;

    fn shrunk_preset(rng: &mut XorShiftRng) -> ModelPreset {
        let mut p = match rng.below(3) {
            0 => ModelPreset::qwen30b_sim(),
            1 => ModelPreset::qwen80b_sim(),
            _ => ModelPreset::phi_sim(),
        };
        p.paper_layers = 2 + rng.below(3);
        p.n_layers = p.paper_layers;
        p
    }

    #[test]
    fn rejects_degenerate_group_sizes() {
        let preset = ModelPreset::phi_sim();
        let cfg = ServingConfig::default();
        let dev = DeviceConfig::default();
        assert!(DeviceGroup::new(&preset, &cfg, &dev, 0).is_err());
        assert!(DeviceGroup::new(&preset, &cfg, &dev, 17).is_err());
    }

    #[test]
    fn one_device_group_matches_coordinator_plan() {
        for preset in ModelPreset::all() {
            let cfg = ServingConfig::default();
            let dev = DeviceConfig::default();
            let solo = Coordinator::new(&preset, &cfg, &dev).unwrap();
            let group = DeviceGroup::new(&preset, &cfg, &dev, 1).unwrap();
            assert_eq!(
                solo.plan.tier_capacity, group.devices[0].plan.tier_capacity,
                "{}",
                preset.name
            );
            assert_eq!(solo.plan.pool_bytes, group.devices[0].plan.pool_bytes);
            assert_eq!(
                solo.plan.tier_expert_bytes,
                group.devices[0].plan.tier_expert_bytes
            );
        }
    }

    #[test]
    fn prop_one_device_group_reproduces_single_coordinator() {
        // The acceptance guarantee: a 1-device group and the plain
        // coordinator walk identical residency trajectories under random
        // hotness shifts (staging is quiesced before each tick so
        // publication depends only on modeled completion events).
        let mut prop = Prop::new("group_one_device_equiv");
        prop.run(6, |rng| {
            let preset = shrunk_preset(rng);
            let mut cfg = ServingConfig::default();
            cfg.update_interval_ms = 1.0;
            cfg.hysteresis_margin = rng.range_f64(0.0, 0.3);
            cfg.ema_alpha = rng.range_f64(0.0, 0.9);
            cfg.n_hi_override = Some(1 + rng.below(preset.n_experts.min(8)));
            let dev = DeviceConfig::default();
            let solo = Coordinator::new(&preset, &cfg, &dev).unwrap();
            let group = DeviceGroup::new(&preset, &cfg, &dev, 1).unwrap();
            let mut now = 0.0;
            for _ in 0..30 {
                // a hot set that drifts: random experts, random burst size
                let layer = rng.below(preset.n_layers);
                let hot: Vec<usize> = (0..1 + rng.below(6))
                    .map(|_| rng.below(preset.n_experts))
                    .collect();
                for _ in 0..10 {
                    solo.record_routing(layer, &hot);
                    group.record_routing(layer, &hot);
                }
                solo.pipeline.wait_staged();
                group.wait_staged();
                now += rng.range_f64(0.001, 0.01);
                solo.tick(now);
                group.tick(now);
                for l in 0..preset.n_layers {
                    for e in 0..preset.n_experts {
                        assert_eq!(
                            solo.resolve_tier(l, e),
                            group.resolve_tier(l, e),
                            "layer {l} expert {e} diverged"
                        );
                    }
                }
            }
            assert_eq!(solo.handles.tier_counts(), group.tier_counts());
            assert_eq!(
                solo.pipeline.stats.migrated_bytes.load(Ordering::Relaxed), // relaxed-ok: test assertion
                group.migrated_bytes()
            );
            assert!(group.within_envelope());
            assert!(group.pools_consistent());
        });
    }

    #[test]
    fn two_device_group_partitions_residency_and_promotes_per_shard() {
        let preset = ModelPreset::phi_sim().executed_scale();
        let mut cfg = ServingConfig::default();
        cfg.update_interval_ms = 1.0;
        cfg.hysteresis_margin = 0.0;
        cfg.ema_alpha = 0.0;
        cfg.n_hi_override = Some(4); // 2 top-rung slots per device
        let dev = DeviceConfig::default();
        let group =
            DeviceGroup::new(&preset, &cfg, &dev, 2).unwrap();
        assert_eq!(group.devices[0].plan.n_hi_per_layer(), 2);
        assert_eq!(group.devices[1].plan.n_hi_per_layer(), 2);
        // experts 0, 2 live on device 0; experts 1, 3 on device 1
        let mut now = 0.0;
        for _ in 0..12 {
            for _ in 0..30 {
                group.record_routing(0, &[0, 1, 2, 3]);
            }
            group.wait_staged();
            now += 0.002;
            group.tick(now);
        }
        group.wait_staged();
        group.tick(now + 1e3);
        for e in 0..4 {
            assert_eq!(group.resolve(0, e), Precision::Fp16, "expert {e}");
        }
        assert_eq!(group.resolve(0, 8), Precision::Int4);
        // residency partitions: per-device counts sum to the group totals
        let per_dev = group.device_tier_counts();
        assert_eq!(per_dev.len(), 2);
        let layers = preset.n_layers_logical();
        for (d, counts) in per_dev.iter().enumerate() {
            assert_eq!(
                counts.iter().sum::<usize>(),
                layers * group.shard().shard_size(d),
                "device {d}"
            );
        }
        assert_eq!(
            group.tier_counts().iter().sum::<usize>(),
            layers * preset.n_experts
        );
        assert!(group.within_envelope());
        assert!(group.pools_consistent());
        assert_eq!(group.inflight_depths().len(), 2);
    }

    #[test]
    fn prop_parallel_tick_matches_serial_reference() {
        // Twin groups fed identical traffic: one ticked through the
        // concurrent path, one through the serial reference walk. Reports
        // and the full residency table must stay equal step for step —
        // the determinism contract of DESIGN.md §13.
        let mut prop = Prop::new("group_parallel_tick_equiv");
        prop.run(6, |rng| {
            let preset = shrunk_preset(rng);
            let mut cfg = ServingConfig::default();
            cfg.update_interval_ms = 1.0;
            cfg.hysteresis_margin = rng.range_f64(0.0, 0.3);
            cfg.ema_alpha = rng.range_f64(0.0, 0.9);
            let dev = DeviceConfig::default();
            let n_dev = 2 + rng.below(2);
            let par = DeviceGroup::new(&preset, &cfg, &dev, n_dev).unwrap();
            let ser = DeviceGroup::new(&preset, &cfg, &dev, n_dev).unwrap();
            let mut now = 0.0;
            for _ in 0..20 {
                let layer = rng.below(preset.n_layers);
                let hot: Vec<usize> = (0..1 + rng.below(6))
                    .map(|_| rng.below(preset.n_experts))
                    .collect();
                for _ in 0..10 {
                    par.record_routing(layer, &hot);
                    ser.record_routing(layer, &hot);
                }
                par.wait_staged();
                ser.wait_staged();
                now += rng.range_f64(0.001, 0.01);
                let rp = par.tick(now);
                let rs = ser.tick_serial(now);
                assert_eq!(rp.ran, rs.ran);
                assert_eq!(
                    rp.promotions_submitted, rs.promotions_submitted,
                    "promotion counts diverged at t={now}"
                );
                assert_eq!(rp.demotions_submitted, rs.demotions_submitted);
                assert_eq!(rp.deferred, rs.deferred);
                for l in 0..preset.n_layers {
                    for e in 0..preset.n_experts {
                        assert_eq!(
                            par.resolve_tier(l, e),
                            ser.resolve_tier(l, e),
                            "layer {l} expert {e} diverged at t={now}"
                        );
                    }
                }
            }
            assert_eq!(par.tier_counts(), ser.tier_counts());
            assert_eq!(par.migrated_bytes(), ser.migrated_bytes());
            assert!(par.within_envelope() && ser.within_envelope());
            assert!(par.pools_consistent() && ser.pools_consistent());
        });
    }

    #[test]
    fn group_budget_slices_the_envelope() {
        let cfg = ServingConfig::default();
        let half = DeviceGroup::device_cfg(&cfg, 0, 2);
        assert_eq!(half.hbm_budget_bytes, cfg.hbm_budget_bytes / 2);
        assert_eq!(half.fixed_bytes, cfg.fixed_bytes / 2);
        // override split round-robin: 5 over 2 devices → 3 + 2
        let mut with_override = cfg.clone();
        with_override.n_hi_override = Some(5);
        assert_eq!(
            DeviceGroup::device_cfg(&with_override, 0, 2).n_hi_override,
            Some(3)
        );
        assert_eq!(
            DeviceGroup::device_cfg(&with_override, 1, 2).n_hi_override,
            Some(2)
        );
        // identity at one device
        let same = DeviceGroup::device_cfg(&with_override, 0, 1);
        assert_eq!(same.hbm_budget_bytes, cfg.hbm_budget_bytes);
        assert_eq!(same.n_hi_override, Some(5));
    }
}
