//! Hotness estimation (§3.5) and drift detection (DESIGN.md §10).
//!
//! Per-(layer, expert) counters accumulate router selections during the
//! current update interval `T_u`; at each interval boundary a smoothed score
//! is updated with an exponential moving average
//! `S ← α·S + (1−α)·c` and the counters reset. Time-based intervals keep
//! the estimate stable under varying batch composition and prompt lengths.
//! Only router outputs are used — no labels, no quality signals.
//!
//! A fixed α trades steady-state stability against post-shift reactivity.
//! The [`DriftDetector`] resolves that trade-off: it watches the
//! per-layer routing *distribution* over consecutive interval windows and,
//! when the total-variation distance between windows exceeds the
//! sensitivity floor (a change-point), the coordinator temporarily drops
//! α and rescales the stale scores — reactive exactly while the hot set is
//! moving, smooth the rest of the time.

use crate::config::DriftConfig;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// EMA hotness estimator over all experts of all layers.
#[derive(Debug, Clone)]
pub struct HotnessEstimator {
    n_experts: usize,
    alpha: f64,
    counts: Vec<u64>,
    scores: Vec<f64>,
    intervals: u64,
}

impl HotnessEstimator {
    pub fn new(n_layers: usize, n_experts: usize, alpha: f64) -> Self {
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0,1)");
        Self {
            n_experts,
            alpha,
            counts: vec![0; n_layers * n_experts],
            scores: vec![0.0; n_layers * n_experts],
            intervals: 0,
        }
    }

    /// Record one router selection of `(layer, expert)`.
    #[inline]
    pub fn record(&mut self, layer: usize, expert: usize) {
        self.counts[layer * self.n_experts + expert] += 1;
    }

    /// Record a batch of selections for one layer.
    pub fn record_layer(&mut self, layer: usize, experts: &[usize]) {
        let base = layer * self.n_experts;
        for &e in experts {
            self.counts[base + e] += 1;
        }
    }

    /// Interval boundary: fold counters into the EMA and reset them.
    pub fn end_interval(&mut self) {
        for i in 0..self.scores.len() {
            self.scores[i] =
                self.alpha * self.scores[i] + (1.0 - self.alpha) * self.counts[i] as f64;
            self.counts[i] = 0;
        }
        self.intervals += 1;
    }

    /// Smoothed score of one expert.
    pub fn score(&self, layer: usize, expert: usize) -> f64 {
        self.scores[layer * self.n_experts + expert]
    }

    /// All scores of one layer.
    pub fn layer_scores(&self, layer: usize) -> &[f64] {
        &self.scores[layer * self.n_experts..(layer + 1) * self.n_experts]
    }

    /// Raw in-interval counts of one layer (drift detection reads these
    /// *before* [`HotnessEstimator::end_interval`] folds and resets them).
    pub fn layer_counts(&self, layer: usize) -> &[u64] {
        &self.counts[layer * self.n_experts..(layer + 1) * self.n_experts]
    }

    pub fn n_layers(&self) -> usize {
        self.scores.len() / self.n_experts
    }

    /// Whether the current interval recorded no traffic at all (drift
    /// detection and the recovery budget treat idle intervals as
    /// invisible).
    pub fn interval_idle(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    /// Retune the smoothing factor (the adaptive layer drops α while
    /// recovering from a detected drift and restores it afterwards).
    pub fn set_alpha(&mut self, alpha: f64) {
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0,1)");
        self.alpha = alpha;
    }

    /// Uniformly rescale all smoothed scores (stale-score decay at a
    /// drift trigger: shrinks pre-drift hotness below one interval's worth
    /// of fresh traffic without disturbing relative order).
    pub fn scale_scores(&mut self, factor: f64) {
        assert!(factor >= 0.0);
        for s in &mut self.scores {
            *s *= factor;
        }
    }

    /// Raw in-interval count (diagnostics).
    pub fn raw_count(&self, layer: usize, expert: usize) -> u64 {
        self.counts[layer * self.n_experts + expert]
    }

    pub fn intervals(&self) -> u64 {
        self.intervals
    }

    /// Indices of the top-n experts of a layer by score (stable order:
    /// score desc, index asc — determinism matters for reproducibility).
    /// Same hardening as the planner: `total_cmp` with NaN scored as idle
    /// (0), so a degenerate config neither panics the diagnostics nor
    /// ranks a NaN-scored expert hottest while the planner treats it as
    /// cold.
    pub fn top_n(&self, layer: usize, n: usize) -> Vec<usize> {
        let scores = self.layer_scores(layer);
        let key = |i: usize| {
            let s = scores[i];
            if s.is_nan() {
                0.0
            } else {
                s
            }
        };
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| key(b).total_cmp(&key(a)).then(a.cmp(&b)));
        idx.truncate(n);
        idx
    }
}

/// Number of atomic count shards in front of the estimator. Small and
/// fixed: enough to split a handful of recording threads (decode workers
/// plus the session thread), cheap to merge in one linear sweep.
pub const HOTNESS_SHARDS: usize = 4;

/// Lock-free sharded routing-count buffers in front of a
/// [`HotnessEstimator`] (DESIGN.md §13).
///
/// The hot path records router selections with a relaxed `fetch_add` on a
/// per-thread shard slot — no mutex, no contention between recording
/// threads beyond false sharing. At the iteration boundary the
/// coordinator's tick merges every shard into the estimator's serial
/// counters (under the existing hotness lock) and zeroes the shards.
/// Because per-(layer, expert) counts are u64 sums, the merge is exactly
/// commutative: the merged counters are byte-identical to what the old
/// single-lock `record_layer` path would have produced for any
/// interleaving of producers, and the EMA fold that follows therefore
/// yields bit-equal scores. Visibility follows the PR 5 contract: a
/// recorded selection becomes observable to policy exactly at the next
/// interval boundary, never earlier.
#[derive(Debug)]
pub struct HotnessShards {
    n_slots: usize,
    n_experts: usize,
    /// `shards[s][layer * n_experts + expert]`, same flat layout as the
    /// estimator's `counts`.
    shards: Vec<Vec<AtomicU64>>,
    /// Optional per-QoS-class count planes (`class_shards[class][s][slot]`,
    /// DESIGN.md §15): armed only by [`HotnessShards::with_classes`], so
    /// the unclassed hot path carries zero extra work. Classed recording
    /// bumps the raw shard *and* the active class's plane; the raw counts
    /// keep feeding the estimator and drift detector unchanged, while the
    /// class planes feed the coordinator's weighted score fold.
    class_shards: Vec<Vec<Vec<AtomicU64>>>,
}

/// Process-wide round-robin assignment of recording threads to shard
/// slots. A thread keeps its slot for its lifetime, so repeated records
/// from one thread always hit the same cache lines.
fn shard_slot() -> usize {
    use std::cell::Cell;
    static NEXT_SLOT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static SLOT: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    SLOT.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = NEXT_SLOT.fetch_add(1, Ordering::Relaxed); // relaxed-ok: unique slot draw, no ordering needed
            s.set(v);
        }
        v
    })
}

impl HotnessShards {
    pub fn new(n_layers: usize, n_experts: usize) -> Self {
        let n_slots = n_layers * n_experts;
        Self {
            n_slots,
            n_experts,
            shards: (0..HOTNESS_SHARDS)
                .map(|_| (0..n_slots).map(|_| AtomicU64::new(0)).collect())
                .collect(),
            class_shards: Vec::new(),
        }
    }

    /// Like [`HotnessShards::new`] but with `n_classes` per-class count
    /// planes armed (the QoS-weighted coordinator path).
    pub fn with_classes(
        n_layers: usize,
        n_experts: usize,
        n_classes: usize,
    ) -> Self {
        let mut s = Self::new(n_layers, n_experts);
        s.class_shards = (0..n_classes)
            .map(|_| {
                (0..HOTNESS_SHARDS)
                    .map(|_| {
                        (0..s.n_slots).map(|_| AtomicU64::new(0)).collect()
                    })
                    .collect()
            })
            .collect();
        s
    }

    /// Number of armed class planes (0 = classless).
    pub fn n_classes(&self) -> usize {
        self.class_shards.len()
    }

    /// The shard index the calling thread should record into.
    #[inline]
    pub fn shard_for_current_thread(&self) -> usize {
        shard_slot() % self.shards.len()
    }

    /// Record one router selection into `shard` (lock-free).
    #[inline]
    pub fn record(&self, shard: usize, layer: usize, expert: usize) {
        self.shards[shard][layer * self.n_experts + expert]
            .fetch_add(1, Ordering::Relaxed); // relaxed-ok: count visible at boundary merge under hotness lock
    }

    /// Record a batch of selections for one layer into `shard`
    /// (lock-free).
    #[inline]
    pub fn record_layer(&self, shard: usize, layer: usize, experts: &[usize]) {
        let row = &self.shards[shard];
        let base = layer * self.n_experts;
        for &e in experts {
            row[base + e].fetch_add(1, Ordering::Relaxed); // relaxed-ok: count visible at boundary merge under hotness lock
        }
    }

    /// [`HotnessShards::record_layer`] attributed to a QoS class: bumps
    /// the raw shard and `class`'s plane in one pass (lock-free). Requires
    /// armed class planes.
    #[inline]
    pub fn record_layer_classed(
        &self,
        shard: usize,
        layer: usize,
        experts: &[usize],
        class: usize,
    ) {
        let row = &self.shards[shard];
        let classed = &self.class_shards[class][shard];
        let base = layer * self.n_experts;
        for &e in experts {
            row[base + e].fetch_add(1, Ordering::Relaxed); // relaxed-ok: count visible at boundary merge under hotness lock
            classed[base + e].fetch_add(1, Ordering::Relaxed); // relaxed-ok: count visible at boundary merge under hotness lock
        }
    }

    /// Iteration-boundary merge: drain every shard into the estimator's
    /// serial counters and zero the shards. The caller holds the hotness
    /// lock, so the merged counts become visible to the drift detector
    /// and the EMA fold atomically with the boundary.
    pub fn merge_into(&self, est: &mut HotnessEstimator) {
        assert_eq!(
            est.counts.len(),
            self.n_slots,
            "shard/estimator dimension mismatch"
        );
        for shard in &self.shards {
            for (i, cell) in shard.iter().enumerate() {
                let v = cell.swap(0, Ordering::Relaxed); // relaxed-ok: drain serialized by the hotness lock
                if v != 0 {
                    est.counts[i] += v;
                }
            }
        }
    }

    /// Iteration-boundary merge of the class planes: drain every class's
    /// shards into `planes[class][slot]` and zero them. Same visibility
    /// contract as [`HotnessShards::merge_into`] — the caller performs
    /// both merges under the hotness lock at the same boundary, so the
    /// class split always sums to the raw counts the estimator folded.
    pub fn merge_classes_into(&self, planes: &mut [Vec<u64>]) {
        assert_eq!(
            planes.len(),
            self.class_shards.len(),
            "class plane count mismatch"
        );
        for (class, shards) in self.class_shards.iter().enumerate() {
            let plane = &mut planes[class];
            assert_eq!(plane.len(), self.n_slots);
            for shard in shards {
                for (i, cell) in shard.iter().enumerate() {
                    let v = cell.swap(0, Ordering::Relaxed); // relaxed-ok: drain serialized by the hotness lock
                    if v != 0 {
                        plane[i] += v;
                    }
                }
            }
        }
    }

    /// Total unmerged selections across all shards (diagnostics/tests).
    pub fn pending(&self) -> u64 {
        self.shards
            .iter()
            .flat_map(|s| s.iter())
            .map(|c| c.load(Ordering::Relaxed)) // relaxed-ok: diagnostic sum
            .sum()
    }
}

/// Sliding-window change-point detector over the per-layer routing
/// distribution.
///
/// A ring buffer keeps the last `2·window` update intervals' raw counts.
/// Every interval (once the ring is full) the trailing `window` intervals
/// are compared, per layer, against the `window` intervals before them by
/// total-variation distance; a layer whose TV exceeds
/// `threshold + noise_coeff·sqrt(E / min(N))` (the second term floors out
/// sampling noise — TV between two samples of the *same* distribution
/// concentrates below `~0.6·sqrt(E/N)`) marks a drift event. The windows
/// slide one interval at a time, so a hard swap is guaranteed a fully
/// disjoint trailing-vs-prior comparison within `window` intervals (of
/// traffic) — a tumbling window would dilute a mid-window swap across
/// both sides. Idle intervals never enter the ring: they neither trigger
/// nor age the windows, so a swap on the far side of a lull is still
/// compared against the last pre-lull traffic. A trigger restarts
/// accumulation (the detector re-learns the new regime before it may
/// fire again) and hands out `recovery_intervals` reactive intervals
/// through [`DriftDetector::recovery_step`]; the caller runs its EMA at
/// the dropped α for exactly those intervals.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    n_experts: usize,
    cfg: DriftConfig,
    /// Ring of the last `2·window` intervals' counts: `ring[slot][layer]`
    /// is one interval's per-expert count vector.
    ring: Vec<Vec<Vec<u64>>>,
    /// Next ring slot to overwrite.
    head: usize,
    /// Intervals accumulated since (re)start, saturating at `2·window`.
    filled: usize,
    /// Scratch: per-layer window sums (reused across intervals so the
    /// comparison is allocation-free).
    trailing: Vec<u64>,
    prior: Vec<u64>,
    recovery_left: u64,
    drift_events: u64,
    recovery_ticks: u64,
}

impl DriftDetector {
    pub fn new(n_layers: usize, n_experts: usize, cfg: &DriftConfig) -> Self {
        assert!(cfg.window >= 1, "drift window must be at least 1 interval");
        assert!((0.0..1.0).contains(&cfg.alpha));
        assert!((0.0..=1.0).contains(&cfg.stale_decay));
        let slots = 2 * cfg.window as usize;
        Self {
            n_experts,
            cfg: cfg.clone(),
            ring: vec![vec![vec![0; n_experts]; n_layers]; slots],
            head: 0,
            filled: 0,
            trailing: vec![0; n_experts],
            prior: vec![0; n_experts],
            recovery_left: 0,
            drift_events: 0,
            recovery_ticks: 0,
        }
    }

    /// Feed one update interval's raw counts (call before the EMA fold
    /// resets them). Returns `true` when the trailing window's
    /// distribution broke from the window before it — a change-point.
    pub fn observe(&mut self, hot: &HotnessEstimator) -> bool {
        let slots = self.ring.len();
        debug_assert_eq!(hot.n_layers(), self.ring[0].len());
        // Idle intervals are invisible: an empty interval neither enters
        // the ring nor ages the windows, so a hot-set swap straddling a
        // traffic lull still gets compared against pre-lull windows
        // instead of vanishing into zero-count slots.
        if hot.interval_idle() {
            return false;
        }
        for (l, row) in self.ring[self.head].iter_mut().enumerate() {
            row.copy_from_slice(hot.layer_counts(l));
        }
        self.head = (self.head + 1) % slots;
        self.filled = (self.filled + 1).min(slots);
        if self.filled < slots {
            return false;
        }
        let drifted = self.windows_diverged();
        if drifted {
            self.drift_events += 1;
            self.recovery_left = self.cfg.recovery_intervals;
            // restart: re-learn the new regime before firing again
            self.filled = 0;
        }
        drifted
    }

    /// Compare the trailing `window` ring slots against the `window`
    /// slots before them, per layer.
    fn windows_diverged(&mut self) -> bool {
        let slots = self.ring.len();
        let w = self.cfg.window as usize;
        let n_layers = self.ring[0].len();
        let n_experts = self.n_experts;
        let (threshold, noise_coeff) =
            (self.cfg.threshold, self.cfg.noise_coeff);
        // slot ages: head-1 is the newest interval, head the oldest
        let head = self.head;
        let slot_at = move |age: usize| (head + slots - 1 - age) % slots;
        let Self { ring, trailing, prior, .. } = self;
        for layer in 0..n_layers {
            trailing.fill(0);
            prior.fill(0);
            for age in 0..w {
                let (ts, ps) = (slot_at(age), slot_at(w + age));
                for e in 0..n_experts {
                    trailing[e] += ring[ts][layer][e];
                    prior[e] += ring[ps][layer][e];
                }
            }
            let cur_total: u64 = trailing.iter().sum();
            let ref_total: u64 = prior.iter().sum();
            if cur_total == 0 || ref_total == 0 {
                continue;
            }
            let mut tv = 0.0;
            for (&c, &r) in trailing.iter().zip(prior.iter()) {
                tv += (c as f64 / cur_total as f64
                    - r as f64 / ref_total as f64)
                    .abs();
            }
            let tv = tv / 2.0;
            let floor = noise_coeff
                * (n_experts as f64 / ref_total.min(cur_total) as f64)
                    .sqrt();
            if tv > threshold + floor {
                return true;
            }
        }
        false
    }

    /// Whether the EMA should run at the dropped (reactive) α this
    /// interval; consumes one recovery tick when it does.
    pub fn recovery_step(&mut self) -> bool {
        if self.recovery_left > 0 {
            self.recovery_left -= 1;
            self.recovery_ticks += 1;
            true
        } else {
            false
        }
    }

    /// Change-point triggers so far.
    pub fn drift_events(&self) -> u64 {
        self.drift_events
    }

    /// Total update intervals spent at the dropped α.
    pub fn recovery_ticks(&self) -> u64 {
        self.recovery_ticks
    }

    /// The configured reactive α.
    pub fn recovery_alpha(&self) -> f64 {
        self.cfg.alpha
    }

    /// The configured stale-score decay applied at a trigger.
    pub fn stale_decay(&self) -> f64 {
        self.cfg.stale_decay
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn shard_merge_matches_direct_recording() {
        // Serial reference: record straight into an estimator.
        let mut direct = HotnessEstimator::new(2, 4, 0.5);
        direct.record_layer(0, &[0, 1, 1, 3]);
        direct.record_layer(1, &[2, 2]);
        // Sharded path: spread the same selections across every shard.
        let shards = HotnessShards::new(2, 4);
        shards.record_layer(0, 0, &[0, 1]);
        shards.record_layer(1 % HOTNESS_SHARDS, 0, &[1, 3]);
        shards.record(2 % HOTNESS_SHARDS, 1, 2);
        shards.record(3 % HOTNESS_SHARDS, 1, 2);
        assert_eq!(shards.pending(), 6);
        let mut merged = HotnessEstimator::new(2, 4, 0.5);
        shards.merge_into(&mut merged);
        assert_eq!(shards.pending(), 0, "merge drains the shards");
        for l in 0..2 {
            assert_eq!(merged.layer_counts(l), direct.layer_counts(l));
        }
        direct.end_interval();
        merged.end_interval();
        for l in 0..2 {
            assert_eq!(merged.layer_scores(l), direct.layer_scores(l));
        }
    }

    #[test]
    fn classed_recording_splits_and_sums_to_raw() {
        let shards = HotnessShards::with_classes(2, 4, 3);
        assert_eq!(shards.n_classes(), 3);
        shards.record_layer_classed(0, 0, &[0, 1, 1], 0);
        shards.record_layer_classed(1 % HOTNESS_SHARDS, 0, &[1], 2);
        shards.record_layer_classed(0, 1, &[3], 1);
        // raw counts see everything, exactly as the classless path would
        let mut est = HotnessEstimator::new(2, 4, 0.5);
        shards.merge_into(&mut est);
        assert_eq!(est.layer_counts(0), &[1, 3, 0, 0]);
        assert_eq!(est.layer_counts(1), &[0, 0, 0, 1]);
        // class planes partition the same selections
        let mut planes = vec![vec![0u64; 8]; 3];
        shards.merge_classes_into(&mut planes);
        assert_eq!(&planes[0][..4], &[1, 2, 0, 0]);
        assert_eq!(&planes[2][..4], &[0, 1, 0, 0]);
        assert_eq!(&planes[1][4..], &[0, 0, 0, 1]);
        // and the drain zeroed them
        let mut again = vec![vec![0u64; 8]; 3];
        shards.merge_classes_into(&mut again);
        assert!(again.iter().flatten().all(|&v| v == 0));
        // classless construction stays plane-free
        assert_eq!(HotnessShards::new(1, 1).n_classes(), 0);
    }

    #[test]
    fn shard_slot_is_stable_per_thread() {
        let shards = HotnessShards::new(1, 2);
        let a = shards.shard_for_current_thread();
        let b = shards.shard_for_current_thread();
        assert_eq!(a, b, "a thread keeps its shard slot");
        assert!(a < HOTNESS_SHARDS);
        let other = std::thread::spawn(shard_slot).join().unwrap();
        assert_ne!(
            other,
            usize::MAX,
            "spawned thread gets a real slot assignment"
        );
    }

    #[test]
    fn ema_update_formula() {
        let mut h = HotnessEstimator::new(1, 4, 0.5);
        h.record(0, 1);
        h.record(0, 1);
        h.end_interval();
        assert_eq!(h.score(0, 1), 1.0); // 0.5·0 + 0.5·2
        h.end_interval();
        assert_eq!(h.score(0, 1), 0.5); // decays with no traffic
        assert_eq!(h.raw_count(0, 1), 0);
    }

    #[test]
    fn top_n_orders_by_score_then_index() {
        let mut h = HotnessEstimator::new(1, 5, 0.0);
        h.record_layer(0, &[3, 3, 3, 1, 1, 4]);
        h.end_interval();
        assert_eq!(h.top_n(0, 3), vec![3, 1, 4]);
        // tie between 0 and 2 (both zero) → lower index first
        assert_eq!(h.top_n(0, 5), vec![3, 1, 4, 0, 2]);
    }

    #[test]
    fn layers_independent() {
        let mut h = HotnessEstimator::new(2, 3, 0.0);
        h.record(0, 0);
        h.record(1, 2);
        h.end_interval();
        assert_eq!(h.score(0, 0), 1.0);
        assert_eq!(h.score(0, 2), 0.0);
        assert_eq!(h.score(1, 2), 1.0);
    }

    #[test]
    fn prop_scores_converge_to_rate() {
        // Property: constant per-interval traffic c converges to score c.
        let mut prop = Prop::new("hotness_convergence");
        prop.run(20, |rng| {
            let alpha = rng.range_f64(0.0, 0.95);
            let c = 1 + rng.below(50);
            let mut h = HotnessEstimator::new(1, 1, alpha);
            for _ in 0..200 {
                for _ in 0..c {
                    h.record(0, 0);
                }
                h.end_interval();
            }
            let s = h.score(0, 0);
            assert!(
                (s - c as f64).abs() < 1e-6 + c as f64 * alpha.powi(150),
                "alpha={alpha} c={c} s={s}"
            );
        });
    }

    /// Zipf-weighted deterministic traffic over an explicit expert set:
    /// rank r of `set` gets `reps/(r+1) + 1` selections.
    fn record_zipf_set(
        h: &mut HotnessEstimator,
        layer: usize,
        set: &[usize],
        reps: usize,
    ) {
        for (rank, &e) in set.iter().enumerate() {
            for _ in 0..reps / (rank + 1) + 1 {
                h.record(layer, e);
            }
        }
    }

    #[test]
    fn detector_recovery_budget_is_exact() {
        let cfg = crate::config::DriftConfig {
            window: 1,
            recovery_intervals: 3,
            ..Default::default()
        };
        let mut h = HotnessEstimator::new(1, 8, 0.8);
        let mut det = DriftDetector::new(1, 8, &cfg);
        // two steady windows on {0,1}, then a hard swap to {4,5}
        for _ in 0..2 {
            record_zipf_set(&mut h, 0, &[0, 1], 100);
            assert!(!det.observe(&h));
            assert!(!det.recovery_step());
            h.end_interval();
        }
        record_zipf_set(&mut h, 0, &[4, 5], 100);
        assert!(det.observe(&h), "disjoint swap must trigger");
        h.end_interval();
        assert_eq!(det.drift_events(), 1);
        // exactly `recovery_intervals` reactive steps, then back to normal
        for _ in 0..3 {
            assert!(det.recovery_step());
        }
        assert!(!det.recovery_step());
        assert_eq!(det.recovery_ticks(), 3);
    }

    #[test]
    fn detector_sees_through_idle_gaps() {
        let cfg = crate::config::DriftConfig {
            window: 1,
            ..Default::default()
        };
        let mut h = HotnessEstimator::new(1, 8, 0.5);
        let mut det = DriftDetector::new(1, 8, &cfg);
        record_zipf_set(&mut h, 0, &[0, 1], 50);
        assert!(!det.observe(&h), "no reference window yet");
        h.end_interval();
        record_zipf_set(&mut h, 0, &[0, 1], 50);
        assert!(!det.observe(&h), "steady traffic");
        h.end_interval();
        // a traffic lull neither triggers nor ages the windows
        for _ in 0..5 {
            assert!(!det.observe(&h));
            h.end_interval();
        }
        // the hard swap on the far side of the lull is still detected:
        // trailing traffic compares against the last pre-lull window
        record_zipf_set(&mut h, 0, &[4, 5], 50);
        assert!(det.observe(&h), "post-lull swap must trigger");
        assert_eq!(det.drift_events(), 1);
    }

    #[test]
    fn prop_drift_no_false_trigger_on_steady_zipf() {
        // Satellite property: seeded steady Zipf traffic never trips the
        // default sensitivity, across randomized (α, window, E) configs.
        use crate::workload::{RoutingSampler, WorkloadProfile};
        let mut prop = Prop::new("drift_no_false_trigger");
        prop.run(12, |rng| {
            let n_experts = [16usize, 64, 128, 256][rng.below(4)];
            let top_k = 8.min(n_experts / 2);
            let n_layers = 1 + rng.below(2);
            let alpha = rng.range_f64(0.0, 0.95);
            let mut dcfg = crate::config::DriftConfig::default();
            dcfg.window = 1 + rng.below(4) as u64;
            let profile = match rng.below(3) {
                0 => WorkloadProfile::text(),
                1 => WorkloadProfile::math(),
                _ => WorkloadProfile::code(),
            };
            let sampler =
                RoutingSampler::new(&profile, n_layers, n_experts, top_k);
            let mut h = HotnessEstimator::new(n_layers, n_experts, alpha);
            let mut det = DriftDetector::new(n_layers, n_experts, &dcfg);
            for interval in 0..30u64 {
                for l in 0..n_layers {
                    for tok in 0..16u64 {
                        let picks = sampler.sample_topk(
                            rng,
                            interval * 31 + tok / 4,
                            l,
                        );
                        h.record_layer(l, &picks);
                    }
                }
                det.observe(&h);
                h.end_interval();
            }
            assert_eq!(
                det.drift_events(),
                0,
                "false trigger: E={n_experts} window={} α={alpha}",
                dcfg.window
            );
        });
    }

    #[test]
    fn prop_drift_detects_hard_swap_within_bound() {
        // Satellite property: a hard hot-set swap (disjoint supports) is
        // detected within 2·window + 1 update intervals, across randomized
        // (α, window, E) configurations — the bounded-reconvergence
        // contract's detection half.
        let mut prop = Prop::new("drift_detects_swap");
        prop.run(12, |rng| {
            let n_experts = [16usize, 32, 64, 128][rng.below(4)];
            let alpha = rng.range_f64(0.0, 0.95);
            let mut dcfg = crate::config::DriftConfig::default();
            dcfg.window = 1 + rng.below(4) as u64;
            let hot_a: Vec<usize> = (0..4).collect();
            let hot_b: Vec<usize> = (n_experts / 2..n_experts / 2 + 4).collect();
            // enough traffic that the noise floor sits well under a
            // disjoint-support swap's TV of ~1
            let reps = 10 * n_experts;
            let mut h = HotnessEstimator::new(1, n_experts, alpha);
            let mut det = DriftDetector::new(1, n_experts, &dcfg);
            // converge on A long enough to fill several windows
            for _ in 0..3 * dcfg.window {
                record_zipf_set(&mut h, 0, &hot_a, reps);
                assert!(!det.observe(&h), "steady phase must not trigger");
                h.end_interval();
            }
            // swap to B; the change-point must fire within 2·window + 1
            let mut detected_at = None;
            for i in 1..=2 * dcfg.window + 1 {
                record_zipf_set(&mut h, 0, &hot_b, reps);
                if det.observe(&h) {
                    detected_at = Some(i);
                    h.end_interval();
                    break;
                }
                h.end_interval();
            }
            assert!(
                detected_at.is_some(),
                "swap undetected after {} intervals (E={n_experts}, \
                 window={})",
                2 * dcfg.window + 1,
                dcfg.window
            );
        });
    }

    #[test]
    fn prop_higher_alpha_slower_response() {
        let mut prop = Prop::new("hotness_alpha_response");
        prop.run(20, |rng| {
            let a_slow = rng.range_f64(0.7, 0.95);
            let a_fast = rng.range_f64(0.0, 0.5);
            let mut hs = HotnessEstimator::new(1, 1, a_slow);
            let mut hf = HotnessEstimator::new(1, 1, a_fast);
            // Immediate response to a fresh burst: S = (1−α)·c, so lower α
            // reacts harder...
            for h in [&mut hs, &mut hf] {
                for _ in 0..10 {
                    h.record(0, 0);
                }
                h.end_interval();
            }
            assert!(hf.score(0, 0) > hs.score(0, 0));
            // ...while higher α retains proportionally more through silence
            // (S decays by factor α per empty interval).
            let (s0, f0) = (hs.score(0, 0), hf.score(0, 0));
            hs.end_interval();
            hf.end_interval();
            assert!(hs.score(0, 0) / s0 > hf.score(0, 0) / f0 - 1e-12);
        });
    }
}
