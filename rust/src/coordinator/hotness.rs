//! Hotness estimation (§3.5).
//!
//! Per-(layer, expert) counters accumulate router selections during the
//! current update interval `T_u`; at each interval boundary a smoothed score
//! is updated with an exponential moving average
//! `S ← α·S + (1−α)·c` and the counters reset. Time-based intervals keep
//! the estimate stable under varying batch composition and prompt lengths.
//! Only router outputs are used — no labels, no quality signals.

/// EMA hotness estimator over all experts of all layers.
#[derive(Debug, Clone)]
pub struct HotnessEstimator {
    n_experts: usize,
    alpha: f64,
    counts: Vec<u64>,
    scores: Vec<f64>,
    intervals: u64,
}

impl HotnessEstimator {
    pub fn new(n_layers: usize, n_experts: usize, alpha: f64) -> Self {
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0,1)");
        Self {
            n_experts,
            alpha,
            counts: vec![0; n_layers * n_experts],
            scores: vec![0.0; n_layers * n_experts],
            intervals: 0,
        }
    }

    /// Record one router selection of `(layer, expert)`.
    #[inline]
    pub fn record(&mut self, layer: usize, expert: usize) {
        self.counts[layer * self.n_experts + expert] += 1;
    }

    /// Record a batch of selections for one layer.
    pub fn record_layer(&mut self, layer: usize, experts: &[usize]) {
        let base = layer * self.n_experts;
        for &e in experts {
            self.counts[base + e] += 1;
        }
    }

    /// Interval boundary: fold counters into the EMA and reset them.
    pub fn end_interval(&mut self) {
        for i in 0..self.scores.len() {
            self.scores[i] =
                self.alpha * self.scores[i] + (1.0 - self.alpha) * self.counts[i] as f64;
            self.counts[i] = 0;
        }
        self.intervals += 1;
    }

    /// Smoothed score of one expert.
    pub fn score(&self, layer: usize, expert: usize) -> f64 {
        self.scores[layer * self.n_experts + expert]
    }

    /// All scores of one layer.
    pub fn layer_scores(&self, layer: usize) -> &[f64] {
        &self.scores[layer * self.n_experts..(layer + 1) * self.n_experts]
    }

    /// Raw in-interval count (diagnostics).
    pub fn raw_count(&self, layer: usize, expert: usize) -> u64 {
        self.counts[layer * self.n_experts + expert]
    }

    pub fn intervals(&self) -> u64 {
        self.intervals
    }

    /// Indices of the top-n experts of a layer by score (stable order:
    /// score desc, index asc — determinism matters for reproducibility).
    pub fn top_n(&self, layer: usize, n: usize) -> Vec<usize> {
        let scores = self.layer_scores(layer);
        let mut idx: Vec<usize> = (0..scores.len()).collect();
        idx.sort_by(|&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx.truncate(n);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::prop::Prop;

    #[test]
    fn ema_update_formula() {
        let mut h = HotnessEstimator::new(1, 4, 0.5);
        h.record(0, 1);
        h.record(0, 1);
        h.end_interval();
        assert_eq!(h.score(0, 1), 1.0); // 0.5·0 + 0.5·2
        h.end_interval();
        assert_eq!(h.score(0, 1), 0.5); // decays with no traffic
        assert_eq!(h.raw_count(0, 1), 0);
    }

    #[test]
    fn top_n_orders_by_score_then_index() {
        let mut h = HotnessEstimator::new(1, 5, 0.0);
        h.record_layer(0, &[3, 3, 3, 1, 1, 4]);
        h.end_interval();
        assert_eq!(h.top_n(0, 3), vec![3, 1, 4]);
        // tie between 0 and 2 (both zero) → lower index first
        assert_eq!(h.top_n(0, 5), vec![3, 1, 4, 0, 2]);
    }

    #[test]
    fn layers_independent() {
        let mut h = HotnessEstimator::new(2, 3, 0.0);
        h.record(0, 0);
        h.record(1, 2);
        h.end_interval();
        assert_eq!(h.score(0, 0), 1.0);
        assert_eq!(h.score(0, 2), 0.0);
        assert_eq!(h.score(1, 2), 1.0);
    }

    #[test]
    fn prop_scores_converge_to_rate() {
        // Property: constant per-interval traffic c converges to score c.
        let mut prop = Prop::new("hotness_convergence");
        prop.run(20, |rng| {
            let alpha = rng.range_f64(0.0, 0.95);
            let c = 1 + rng.below(50);
            let mut h = HotnessEstimator::new(1, 1, alpha);
            for _ in 0..200 {
                for _ in 0..c {
                    h.record(0, 0);
                }
                h.end_interval();
            }
            let s = h.score(0, 0);
            assert!(
                (s - c as f64).abs() < 1e-6 + c as f64 * alpha.powi(150),
                "alpha={alpha} c={c} s={s}"
            );
        });
    }

    #[test]
    fn prop_higher_alpha_slower_response() {
        let mut prop = Prop::new("hotness_alpha_response");
        prop.run(20, |rng| {
            let a_slow = rng.range_f64(0.7, 0.95);
            let a_fast = rng.range_f64(0.0, 0.5);
            let mut hs = HotnessEstimator::new(1, 1, a_slow);
            let mut hf = HotnessEstimator::new(1, 1, a_fast);
            // Immediate response to a fresh burst: S = (1−α)·c, so lower α
            // reacts harder...
            for h in [&mut hs, &mut hf] {
                for _ in 0..10 {
                    h.record(0, 0);
                }
                h.end_interval();
            }
            assert!(hf.score(0, 0) > hs.score(0, 0));
            // ...while higher α retains proportionally more through silence
            // (S decays by factor α per empty interval).
            let (s0, f0) = (hs.score(0, 0), hf.score(0, 0));
            hs.end_interval();
            hf.end_interval();
            assert!(hs.score(0, 0) / s0 > hf.score(0, 0) / f0 - 1e-12);
        });
    }
}
